//! Hot-path micro-benchmarks (the criterion substitute): per-component
//! timings of everything on the serving request path, used by the §Perf
//! iteration log in EXPERIMENTS.md.

use std::time::Instant;

use miniconv::envs::{CropMode, Env, Pendulum, PixelPipeline};
use miniconv::net::framing::{Msg, Payload, Request};
use miniconv::net::quantize_features;
use miniconv::runtime::{default_artifact_dir, Runtime, Value};
use miniconv::shader::{pipeline_from_manifest, TextureFormat};
use miniconv::util::rng::Rng;
use miniconv::util::tables::Table;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> (String, f64) {
    // warmup
    for _ in 0..(iters / 10).max(1) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    (name.to_string(), per)
}

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("micro_hotpath: no artifacts — run `make artifacts`");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let x = rt.manifest.serve_x;
    let mut rows: Vec<(String, f64)> = Vec::new();

    // -- environment + observation pipeline ------------------------------
    let mut env = Pendulum::new();
    let mut rng = Rng::new(0);
    env.reset(&mut rng);
    let mut pipe = PixelPipeline::new(100, x, CropMode::Center);
    pipe.observe(&env, &mut rng);
    rows.push(bench("env.step + render + crop + stack", 200, || {
        env.step(&[0.1]);
        pipe.observe(&env, &mut rng);
    }));
    rows.push(bench("pipeline.obs (normalize 9x84x84)", 200, || {
        std::hint::black_box(pipe.obs());
    }));

    // -- shader-interpreter encode (the client device path) --------------
    let (serve_meta, _) = &rt.manifest.encoders["miniconv4"];
    let shader = pipeline_from_manifest(
        &rt.manifest, "miniconv4", serve_meta, x, "serve_enc_miniconv4", TextureFormat::Float,
    )
    .expect("shader");
    let obs_chw = pipe.obs_chw();
    rows.push(bench("shader interp encode (miniconv4, 84²)", 50, || {
        std::hint::black_box(shader.run(&obs_chw).unwrap());
    }));

    // -- XLA encoder + heads ----------------------------------------------
    let enc = rt.load(&rt.manifest.serve_encoder("miniconv4")).unwrap();
    let enc_p = rt.manifest.load_params("serve_enc_miniconv4").unwrap();
    let enc_pv = Value::f32(&[enc_p.len()], enc_p);
    let obs_v = Value::f32(&[1, 9, x, x], pipe.obs());
    rows.push(bench("XLA encoder b1 (miniconv4)", 100, || {
        std::hint::black_box(enc.run(&[&enc_pv, &obs_v]).unwrap());
    }));

    let s = x.div_ceil(8);
    let head_p = rt.manifest.load_params("serve_head_miniconv4").unwrap();
    let head_pv = Value::f32(&[head_p.len()], head_p);
    let head_dp = rt.to_device(&head_pv).unwrap();
    for b in [1usize, 8, 32] {
        let head = rt.load(&rt.manifest.serve_head("miniconv4", b)).unwrap();
        let feat = Value::f32(&[b, 4, s, s], vec![0.3; b * 4 * s * s]);
        rows.push(bench(&format!("head b{b} (host params)"), 100, || {
            std::hint::black_box(head.run(&[&head_pv, &feat]).unwrap());
        }));
        let featd = rt.to_device(&feat).unwrap();
        rows.push(bench(&format!("head b{b} (device-resident)"), 100, || {
            std::hint::black_box(head.run_device(&[&head_dp, &featd]).unwrap());
        }));
    }

    let full_p = rt.manifest.load_params("serve_full_fullcnn").unwrap();
    let full_pv = Value::f32(&[full_p.len()], full_p);
    let full_dp = rt.to_device(&full_pv).unwrap();
    for b in [1usize, 8] {
        let full = rt.load(&rt.manifest.serve_full(b)).unwrap();
        let obs_b = Value::f32(&[b, 9, x, x], vec![0.3; b * 9 * x * x]);
        let obs_d = rt.to_device(&obs_b).unwrap();
        rows.push(bench(&format!("full-CNN b{b} (host params)"), 30, || {
            std::hint::black_box(full.run(&[&full_pv, &obs_b]).unwrap());
        }));
        rows.push(bench(&format!("full-CNN b{b} (device-resident)"), 30, || {
            std::hint::black_box(full.run_device(&[&full_dp, &obs_d]).unwrap());
        }));
    }

    // -- wire path ---------------------------------------------------------
    let feat_flat: Vec<f32> = (0..4 * s * s).map(|i| (i % 17) as f32 * 0.1).collect();
    rows.push(bench("quantize features to u8", 1000, || {
        std::hint::black_box(quantize_features(&feat_flat));
    }));
    let (scale, q) = quantize_features(&feat_flat);
    let msg = Msg::Request(Request {
        client: 0,
        id: 0,
        payload: Payload::Features { c: 4, h: s as u16, w: s as u16, scale, data: q },
    });
    rows.push(bench("frame encode (features)", 1000, || {
        std::hint::black_box(msg.encode());
    }));
    let raw = Msg::Request(Request {
        client: 0,
        id: 0,
        payload: Payload::RawRgba { x: x as u16, data: pipe.rgba_bytes() },
    });
    rows.push(bench("frame encode (raw 84² RGBA)", 500, || {
        std::hint::black_box(raw.encode());
    }));

    let mut t = Table::new("hot-path micro-benchmarks", &["component", "per-op"]);
    for (name, per) in &rows {
        t.row(&[name.clone(), miniconv::util::tables::fmt_ns(per * 1e9)]);
    }
    t.print();
}

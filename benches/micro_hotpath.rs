//! Hot-path micro-benchmarks (the criterion substitute): per-component
//! timings of everything on the serving request path, used by the §Perf
//! iteration log in EXPERIMENTS.md.
//!
//! Section 1 (always runs, artifact-free): legacy shader interpreter vs
//! the precompiled pipeline on the default 84x84 MiniConv plans — Float
//! and Rgba8 at 1/2/4 threads — plus a steady-state allocation count from
//! a counting global allocator. Results are written to
//! `BENCH_hotpath.json` (override the path with `BENCH_HOTPATH_OUT`) so
//! the perf trajectory is machine-readable from this PR onward.
//!
//! Section 2 (requires `make artifacts`): XLA encoder/head/full-CNN and
//! wire-path timings, unchanged.

use std::time::Instant;

use miniconv::envs::{CropMode, Env, Pendulum, PixelPipeline};
use miniconv::experiments::execution::{miniconv4_ir, miniconv16_ir};
use miniconv::experiments::hotpath::{run_hotpath, synthetic_frame, synthetic_weights};
use miniconv::net::framing::{quantize_features_into, Msg, Payload, Request};
use miniconv::net::quantize_features;
use miniconv::runtime::{default_artifact_dir, Runtime, Value};
use miniconv::shader::{
    pipeline_from_manifest, plan, unpack_conv_weights, CompiledPipeline, TextureFormat,
};
use miniconv::tensor::Chw;
use miniconv::util::alloc_counter::CountingAlloc;
use miniconv::util::rng::Rng;
use miniconv::util::tables::Table;

// counts heap allocations so the zero-allocation claim is measured, not
// asserted by inspection (shared impl: util::alloc_counter)
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations across `frames` steady-state compiled frames (threads = 1).
fn steady_state_allocs(x: usize, frames: usize) -> u64 {
    let ir = miniconv4_ir();
    let p = plan(&ir, x).expect("plan");
    let flat = synthetic_weights(&ir, 1);
    let ws = unpack_conv_weights(&ir, &flat).expect("weights");
    let mut pipe = CompiledPipeline::new(p, ws, TextureFormat::Float).expect("compile");
    let frame = synthetic_frame(ir.input_channels, x, 2);
    let mut out = Chw::zeros(1, 1, 1);
    // warm the arena and the output buffer, then count
    for _ in 0..3 {
        pipe.run_into(&frame, &mut out).expect("warmup frame");
    }
    let before = CountingAlloc::count();
    for _ in 0..frames {
        pipe.run_into(&frame, &mut out).expect("frame");
    }
    std::hint::black_box(&out);
    CountingAlloc::count() - before
}

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> (String, f64) {
    // warmup
    for _ in 0..(iters / 10).max(1) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    (name.to_string(), per)
}

fn main() {
    // -- section 1: legacy vs compiled interpreter (artifact-free) --------
    let x = 84;
    let threads = [1usize, 2, 4];
    let mut t = Table::new(
        "shader hot path — legacy interpreter vs compiled pipeline (84²)",
        &["arch", "format", "engine", "threads", "frames/s", "ns/pass", "speedup"],
    );
    let mut report4 = run_hotpath(&miniconv4_ir(), x, 40, &threads).expect("hotpath miniconv4");
    let frames = 200;
    let allocs = steady_state_allocs(x, frames);
    // ceiling division: even one allocation per few hundred frames must
    // show up as nonzero rather than rounding the gate green
    report4.allocs_per_frame = Some(allocs.div_ceil(frames as u64));
    let report16 = run_hotpath(&miniconv16_ir(), x, 15, &threads).expect("hotpath miniconv16");

    for rep in [&report4, &report16] {
        for r in &rep.rows {
            let speedup = if r.engine == "compiled" {
                let legacy = rep
                    .rows
                    .iter()
                    .find(|l| l.format == r.format && l.engine == "legacy")
                    .map(|l| l.frames_per_sec)
                    .unwrap_or(0.0);
                format!("{:.2}x", r.frames_per_sec / legacy.max(1e-12))
            } else {
                "1.00x".into()
            };
            t.row(&[
                rep.arch.clone(),
                r.format.clone(),
                r.engine.clone(),
                r.threads.to_string(),
                format!("{:.1}", r.frames_per_sec),
                format!("{:.0}", r.ns_per_pass),
                speedup,
            ]);
        }
    }
    t.print();
    println!(
        "steady-state allocations: {allocs} total over {frames} compiled frames (threads=1)"
    );
    println!(
        "single-thread speedup (miniconv4): float {:.2}x, rgba8 {:.2}x",
        report4.speedup_float_1t, report4.speedup_rgba8_1t
    );

    let out_path =
        std::env::var("BENCH_HOTPATH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    match std::fs::write(&out_path, report4.to_json()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }

    // the acceptance gates are enforced, not just printed: regressions must
    // turn this bench red for whoever runs it
    let mut failed = false;
    if allocs > 0 {
        eprintln!("FAIL: {allocs} steady-state allocations over {frames} frames (gate: 0)");
        failed = true;
    }
    for (fmt, sp) in
        [("float", report4.speedup_float_1t), ("rgba8", report4.speedup_rgba8_1t)]
    {
        if sp < 2.0 {
            eprintln!("FAIL: {fmt} single-thread speedup {sp:.2}x is under the 2.00x gate");
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }

    // -- section 2: PJRT artifacts (skipped when absent) -------------------
    let Some(rt) = Runtime::try_new(&default_artifact_dir()) else {
        println!("micro_hotpath: no artifacts — XLA/wire sections skipped (run `make artifacts`)");
        return;
    };
    let x = rt.manifest.serve_x;
    let mut rows: Vec<(String, f64)> = Vec::new();

    // -- environment + observation pipeline ------------------------------
    let mut env = Pendulum::new();
    let mut rng = Rng::new(0);
    env.reset(&mut rng);
    let mut pipe = PixelPipeline::new(100, x, CropMode::Center);
    pipe.observe(&env, &mut rng);
    rows.push(bench("env.step + render + crop + stack", 200, || {
        env.step(&[0.1]);
        pipe.observe(&env, &mut rng);
    }));
    rows.push(bench("pipeline.obs (normalize 9x84x84)", 200, || {
        std::hint::black_box(pipe.obs());
    }));

    // -- shader-interpreter encode (the client device path) --------------
    let (serve_meta, _) = &rt.manifest.encoders["miniconv4"];
    let shader = pipeline_from_manifest(
        &rt.manifest, "miniconv4", serve_meta, x, "serve_enc_miniconv4", TextureFormat::Float,
    )
    .expect("shader");
    let obs_chw = pipe.obs_chw();
    rows.push(bench("shader interp encode (miniconv4, 84²)", 50, || {
        std::hint::black_box(shader.run(&obs_chw).unwrap());
    }));
    let mut compiled = CompiledPipeline::from_legacy(&shader).expect("compile");
    let mut feat = Chw::zeros(1, 1, 1);
    rows.push(bench("compiled encode (miniconv4, 84²)", 200, || {
        compiled.run_into(&obs_chw, &mut feat).unwrap();
        std::hint::black_box(&feat);
    }));

    // -- XLA encoder + heads ----------------------------------------------
    let enc = rt.load(&rt.manifest.serve_encoder("miniconv4")).unwrap();
    let enc_p = rt.manifest.load_params("serve_enc_miniconv4").unwrap();
    let enc_pv = Value::f32(&[enc_p.len()], enc_p);
    let obs_v = Value::f32(&[1, 9, x, x], pipe.obs());
    rows.push(bench("XLA encoder b1 (miniconv4)", 100, || {
        std::hint::black_box(enc.run(&[&enc_pv, &obs_v]).unwrap());
    }));

    let s = x.div_ceil(8);
    let head_p = rt.manifest.load_params("serve_head_miniconv4").unwrap();
    let head_pv = Value::f32(&[head_p.len()], head_p);
    let head_dp = rt.to_device(&head_pv).unwrap();
    for b in [1usize, 8, 32] {
        let head = rt.load(&rt.manifest.serve_head("miniconv4", b)).unwrap();
        let feat = Value::f32(&[b, 4, s, s], vec![0.3; b * 4 * s * s]);
        rows.push(bench(&format!("head b{b} (host params)"), 100, || {
            std::hint::black_box(head.run(&[&head_pv, &feat]).unwrap());
        }));
        let featd = rt.to_device(&feat).unwrap();
        rows.push(bench(&format!("head b{b} (device-resident)"), 100, || {
            std::hint::black_box(head.run_device(&[&head_dp, &featd]).unwrap());
        }));
    }

    let full_p = rt.manifest.load_params("serve_full_fullcnn").unwrap();
    let full_pv = Value::f32(&[full_p.len()], full_p);
    let full_dp = rt.to_device(&full_pv).unwrap();
    for b in [1usize, 8] {
        let full = rt.load(&rt.manifest.serve_full(b)).unwrap();
        let obs_b = Value::f32(&[b, 9, x, x], vec![0.3; b * 9 * x * x]);
        let obs_d = rt.to_device(&obs_b).unwrap();
        rows.push(bench(&format!("full-CNN b{b} (host params)"), 30, || {
            std::hint::black_box(full.run(&[&full_pv, &obs_b]).unwrap());
        }));
        rows.push(bench(&format!("full-CNN b{b} (device-resident)"), 30, || {
            std::hint::black_box(full.run_device(&[&full_dp, &obs_d]).unwrap());
        }));
    }

    // -- wire path ---------------------------------------------------------
    let feat_flat: Vec<f32> = (0..4 * s * s).map(|i| (i % 17) as f32 * 0.1).collect();
    rows.push(bench("quantize features to u8", 1000, || {
        std::hint::black_box(quantize_features(&feat_flat));
    }));
    let mut q_buf = Vec::new();
    rows.push(bench("quantize features (reused buffer)", 1000, || {
        std::hint::black_box(quantize_features_into(&feat_flat, &mut q_buf));
    }));
    let (scale, q) = quantize_features(&feat_flat);
    let msg = Msg::Request(Request {
        client: 0,
        id: 0,
        payload: Payload::Features { c: 4, h: s as u16, w: s as u16, scale, data: q },
    });
    rows.push(bench("frame encode (features)", 1000, || {
        std::hint::black_box(msg.encode());
    }));
    let raw = Msg::Request(Request {
        client: 0,
        id: 0,
        payload: Payload::RawRgba { x: x as u16, data: pipe.rgba_bytes() },
    });
    rows.push(bench("frame encode (raw 84² RGBA)", 500, || {
        std::hint::black_box(raw.encode());
    }));

    let mut t = Table::new("hot-path micro-benchmarks", &["component", "per-op"]);
    for (name, per) in &rows {
        t.row(&[name.clone(), miniconv::util::tables::fmt_ns(per * 1e9)]);
    }
    t.print();
}

//! Adaptive-codec wire benchmark: the delta + entropy-packed feature
//! format vs the flat u8 format on a real pendulum raster stream, across
//! the quantisation ladder.
//!
//! Per quantisation level it measures mean bytes/frame (flat vs delta),
//! the compression ratio, encode/decode ns/frame, and asserts bit-exact
//! reconstruction of every frame. A steady-state allocation count over
//! pooled encode/decode buffers guards the zero-allocation discipline
//! (shared counting allocator: `util::alloc_counter`).
//!
//! Results land in `BENCH_codec.json` (override with `--out` or the
//! `BENCH_CODEC_OUT` env var). Gates, also embedded in the JSON:
//!   * compression ratio ≥ 2.0 at qmax 255 on the pendulum stream (the
//!     simnet acceptance scenario's wire-level counterpart);
//!   * 0 steady-state heap allocations per encoded+decoded frame;
//!   * every frame reconstructs bit-exactly at every level.
//!
//! `--iters N` caps the stream length — CI runs a cheap smoke pass with a
//! tiny N; gate verdicts are only meaningful at the default.

use std::time::Instant;

use miniconv::codec::{self, Decoder, Encoder};
use miniconv::envs::pendulum_raster_stream;
use miniconv::util::alloc_counter::CountingAlloc;
use miniconv::util::argparse::Parser;
use miniconv::util::tables::Table;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Raster side length of the transmitted frame (3 RGB planes).
const SIDE: usize = 48;
const QMAX_LADDER: [u8; 4] = [255, 127, 63, 31];

struct Cell {
    qmax: u8,
    flat_bytes_per_frame: f64,
    delta_bytes_per_frame: f64,
    ratio: f64,
    encode_ns_per_frame: f64,
    decode_ns_per_frame: f64,
    keyframes: u64,
    exact: bool,
}

fn run_cell(stream: &[Vec<f32>], qmax: u8) -> Cell {
    let n = stream[0].len();
    let mut enc = Encoder::new();
    let mut dec = Decoder::new();
    let mut qbuf = Vec::new();
    let mut wire = Vec::new();
    let mut delta_bytes = 0usize;
    let mut exact = true;
    let mut enc_ns = 0.0f64;
    let mut dec_ns = 0.0f64;
    for f in stream {
        let t0 = Instant::now();
        codec::quantize_into(f, qmax, &mut qbuf);
        let (flags, seq) = enc.encode_into(&qbuf, &mut wire);
        enc_ns += t0.elapsed().as_nanos() as f64;
        delta_bytes += wire.len();
        let t1 = Instant::now();
        dec.apply(flags, qmax, seq, n, &wire).expect("decode");
        dec_ns += t1.elapsed().as_nanos() as f64;
        exact &= dec.frame() == qbuf.as_slice();
    }
    let frames = stream.len() as f64;
    Cell {
        qmax,
        flat_bytes_per_frame: n as f64,
        delta_bytes_per_frame: delta_bytes as f64 / frames,
        ratio: n as f64 * frames / delta_bytes as f64,
        encode_ns_per_frame: enc_ns / frames,
        decode_ns_per_frame: dec_ns / frames,
        keyframes: enc.keyframes,
        exact,
    }
}

/// Steady-state allocations per encode+decode round over pooled buffers:
/// one full pass warms every buffer to its high-water capacity, then the
/// measured pass must not touch the heap.
fn steady_state_allocs_per_frame(stream: &[Vec<f32>]) -> u64 {
    let n = stream[0].len();
    let mut enc = Encoder::new();
    let mut dec = Decoder::new();
    let mut qbuf = Vec::new();
    let mut wire = Vec::new();
    let mut pump = |enc: &mut Encoder, dec: &mut Decoder, qbuf: &mut Vec<u8>, wire: &mut Vec<u8>| {
        for f in stream {
            codec::quantize_into(f, 255, qbuf);
            let (flags, seq) = enc.encode_into(qbuf, wire);
            dec.apply(flags, 255, seq, n, wire).expect("decode");
        }
    };
    // two warm passes: the second includes the wrap-around delta (last
    // frame -> first frame), so every pooled buffer reaches the high-water
    // capacity the measured pass will need
    pump(&mut enc, &mut dec, &mut qbuf, &mut wire);
    pump(&mut enc, &mut dec, &mut qbuf, &mut wire);
    let before = CountingAlloc::count();
    pump(&mut enc, &mut dec, &mut qbuf, &mut wire);
    let allocs = CountingAlloc::count() - before;
    std::hint::black_box(dec.frame().len());
    allocs.div_ceil(stream.len() as u64)
}

fn main() {
    let args = Parser::new("codec wire format — delta + entropy packing vs flat u8")
        .opt("iters", "200", "pendulum stream length (frames)")
        .opt("seed", "7", "pendulum stream seed")
        .opt("out", "", "output path (default BENCH_CODEC_OUT or BENCH_codec.json)")
        .parse();
    let iters: usize = args.usize("iters").max(2);
    let out_path = {
        let o = args.str("out");
        if o.is_empty() {
            std::env::var("BENCH_CODEC_OUT").unwrap_or_else(|_| "BENCH_codec.json".into())
        } else {
            o
        }
    };

    let stream = pendulum_raster_stream(args.u64("seed"), SIDE, iters);
    let cells: Vec<Cell> = QMAX_LADDER.iter().map(|&q| run_cell(&stream, q)).collect();
    let allocs = steady_state_allocs_per_frame(&stream);

    let mut t = Table::new(
        &format!("codec wire — pendulum raster stream, 3x{SIDE}x{SIDE}, {iters} frames"),
        &[
            "qmax",
            "flat B/frame",
            "delta B/frame",
            "ratio",
            "enc ns",
            "dec ns",
            "keyframes",
            "exact",
        ],
    );
    for c in &cells {
        t.row(&[
            c.qmax.to_string(),
            format!("{:.0}", c.flat_bytes_per_frame),
            format!("{:.1}", c.delta_bytes_per_frame),
            format!("{:.2}x", c.ratio),
            format!("{:.0}", c.encode_ns_per_frame),
            format!("{:.0}", c.decode_ns_per_frame),
            c.keyframes.to_string(),
            c.exact.to_string(),
        ]);
    }
    t.print();

    let ratio_255 = cells[0].ratio;
    let all_exact = cells.iter().all(|c| c.exact);
    println!("steady-state allocations per encoded+decoded frame: {allocs}");
    println!(
        "gates: ratio@255 >= 2.0 -> {}, allocs == 0 -> {}, bit-exact -> {}",
        if ratio_255 >= 2.0 { "PASS" } else { "FAIL" },
        if allocs == 0 { "PASS" } else { "FAIL" },
        if all_exact { "PASS" } else { "FAIL" },
    );

    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"codec_wire\",\n");
    s.push_str(&format!("  \"iters\": {iters},\n"));
    s.push_str(&format!("  \"stream\": \"pendulum_raster_3x{SIDE}x{SIDE}\",\n"));
    s.push_str(&format!("  \"seed\": {},\n", args.u64("seed")));
    s.push_str(&format!("  \"compression_ratio_at_qmax_255\": {:.3},\n", ratio_255));
    s.push_str(&format!("  \"steady_state_allocs_per_frame\": {allocs},\n"));
    s.push_str(&format!("  \"bit_exact_all_levels\": {all_exact},\n"));
    s.push_str("  \"gates\": {\n");
    s.push_str("    \"min_compression_ratio_at_qmax_255\": 2.0,\n");
    s.push_str("    \"max_steady_state_allocs_per_frame\": 0,\n");
    s.push_str(&format!("    \"ratio_pass\": {},\n", ratio_255 >= 2.0));
    s.push_str(&format!("    \"alloc_pass\": {},\n", allocs == 0));
    s.push_str(&format!("    \"exact_pass\": {all_exact}\n"));
    s.push_str("  },\n");
    s.push_str("  \"results\": [\n");
    for (i, c) in cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"qmax\": {}, \"flat_bytes_per_frame\": {:.1}, \
             \"delta_bytes_per_frame\": {:.1}, \"compression_ratio\": {:.3}, \
             \"encode_ns_per_frame\": {:.0}, \"decode_ns_per_frame\": {:.0}, \
             \"keyframes\": {}, \"bit_exact\": {}}}{}\n",
            c.qmax,
            c.flat_bytes_per_frame,
            c.delta_bytes_per_frame,
            c.ratio,
            c.encode_ns_per_frame,
            c.decode_ns_per_frame,
            c.keyframes,
            c.exact,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write(&out_path, &s) {
        eprintln!("could not write {out_path}: {e}");
    } else {
        println!("wrote {out_path}");
    }
}

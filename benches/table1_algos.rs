//! Table 1 — algorithms used for each visual control task, plus an
//! artifact-presence audit (every trainstate's update/act artifacts must
//! exist and parse).

use miniconv::experiments::table1_algorithms;
use miniconv::runtime::{default_artifact_dir, Runtime};

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("table1: no artifacts at {} — run `make artifacts`", dir.display());
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    table1_algorithms(&rt).print();

    // audit: every artifact file referenced by the manifest exists on disk
    let mut missing = 0;
    for a in rt.manifest.artifacts.values() {
        if !rt.manifest.dir.join(&a.file).exists() {
            println!("MISSING: {}", a.file);
            missing += 1;
        }
    }
    println!(
        "\nartifact audit: {} artifacts, {} missing",
        rt.manifest.artifacts.len(),
        missing
    );
}

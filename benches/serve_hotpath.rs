//! Serve hot-path before/after harness: the coordinator's
//! ingest→batch→policy→reply pipeline, legacy per-request engine vs the
//! pooled `BatchArena` engine, over a 1/8/64-client matrix on both routes
//! (raw 84² RGBA ingest and quantised 4×11×11 features).
//!
//! Results land in `BENCH_serve.json` (override with `--out` or the
//! `BENCH_SERVE_OUT` env var). Gates, also embedded in the JSON:
//!   * pooled ≥ 2x legacy requests/sec at clients == max_batch (8) on the
//!     server-only route (the data-movement-dominated one);
//!   * 0 steady-state heap allocations per pooled request, measured by
//!     the counting global allocator (shared impl: `util::alloc_counter`).
//!
//! `--iters N` caps the measured rounds per cell — CI runs a cheap smoke
//! pass with a tiny N; gate verdicts are only meaningful at the default.

use miniconv::coordinator::Route;
use miniconv::experiments::serving::{
    bench_payloads, run_serve_hotpath, ServeDriver, ServeEngine,
};
use miniconv::util::alloc_counter::CountingAlloc;
use miniconv::util::argparse::Parser;
use miniconv::util::tables::Table;

// counts heap allocations so the zero-allocation claim is measured, not
// asserted by inspection
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const MAX_BATCH: usize = 8;

/// Allocations per steady-state pooled request: both routes at
/// clients == max_batch, counted after the driver state is warm.
fn steady_state_allocs_per_request(rounds: usize) -> u64 {
    let (split, split_dim) = bench_payloads(Route::Split, MAX_BATCH, 84, (4, 11, 11), 0xA110C);
    let (full, full_dim) = bench_payloads(Route::Full, MAX_BATCH, 84, (4, 11, 11), 0xA110D);
    let mut ds = ServeDriver::new(&split, MAX_BATCH, split_dim, 4);
    let mut df = ServeDriver::new(&full, MAX_BATCH, full_dim, 4);
    for _ in 0..3 {
        ds.round(ServeEngine::Pooled).expect("warmup split round");
        df.round(ServeEngine::Pooled).expect("warmup full round");
    }
    let before = CountingAlloc::count();
    for _ in 0..rounds {
        ds.round(ServeEngine::Pooled).expect("split round");
        df.round(ServeEngine::Pooled).expect("full round");
    }
    let allocs = CountingAlloc::count() - before;
    std::hint::black_box((ds.sink().len(), df.sink().len()));
    let requests = (2 * MAX_BATCH * rounds) as u64;
    // ceiling division: even one allocation per few hundred requests must
    // show up as nonzero rather than rounding the gate green
    allocs.div_ceil(requests)
}

fn main() {
    let args = Parser::new("serve hot path — legacy vs pooled ingest→batch→policy→reply")
        .opt("iters", "400", "measured rounds per cell")
        .opt("out", "", "output path (default BENCH_SERVE_OUT or BENCH_serve.json)")
        .parse();
    let iters: usize = args.usize("iters");
    let out_path = {
        let o = args.str("out");
        if o.is_empty() {
            std::env::var("BENCH_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".into())
        } else {
            o
        }
    };

    let mut report =
        run_serve_hotpath(&[1, MAX_BATCH, 64], MAX_BATCH, iters).expect("serve hotpath matrix");
    let alloc_rounds = 50.min(iters.max(1));
    report.allocs_per_request = Some(steady_state_allocs_per_request(alloc_rounds));

    let mut t = Table::new(
        "serve hot path — legacy vs pooled pipeline (84² raw / 4×11×11 features)",
        &["route", "engine", "clients", "max_batch", "req/s", "ns/req", "speedup"],
    );
    for c in &report.cells {
        let speedup = if c.engine == "pooled" {
            let legacy = report
                .cells
                .iter()
                .find(|l| l.route == c.route && l.clients == c.clients && l.engine == "legacy")
                .map(|l| l.requests_per_sec)
                .unwrap_or(0.0);
            format!("{:.2}x", c.requests_per_sec / legacy.max(1e-12))
        } else {
            "1.00x".into()
        };
        t.row(&[
            c.route.into(),
            c.engine.into(),
            c.clients.to_string(),
            c.max_batch.to_string(),
            format!("{:.0}", c.requests_per_sec),
            format!("{:.0}", c.ns_per_request),
            speedup,
        ]);
    }
    t.print();
    println!(
        "speedup at batch {MAX_BATCH}: server-only {:.2}x, split {:.2}x",
        report.speedup_full_b, report.speedup_split_b
    );
    println!(
        "steady-state allocations per pooled request: {}",
        report.allocs_per_request.unwrap_or(u64::MAX)
    );
    println!(
        "gates: speedup_full >= 2.0 -> {}, allocs == 0 -> {}",
        if report.speedup_full_b >= 2.0 { "PASS" } else { "FAIL" },
        if report.allocs_per_request == Some(0) { "PASS" } else { "FAIL" },
    );

    let json = report.to_json();
    if let Err(e) = std::fs::write(&out_path, &json) {
        eprintln!("could not write {out_path}: {e}");
    } else {
        println!("wrote {out_path}");
    }
}

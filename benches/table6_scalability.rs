//! Table 6 — server scalability at a fixed decision rate: maximum
//! concurrent clients at 10 Hz under a p95 < 100 ms budget.
//!
//! Sim mode reproduces the paper's GPU-server numbers; real mode ramps
//! actual client fleets against the coordinator (set MINICONV_T6_REAL=1 —
//! it is minutes-long and CPU-bound).

use std::time::Duration;

use miniconv::coordinator::{
    merged_latencies, run_fleet, BatchPolicy, ClientConfig, Route, ServerConfig,
};
use miniconv::experiments::table6_scalability_sim;
use miniconv::util::tables::Table;

fn main() {
    let (t, so, sp) = table6_scalability_sim(10.0, 0.1);
    t.print();
    println!("paper: 12 vs 36 clients (ratio 3.0); here {so} vs {sp} (ratio {:.1})\n", sp as f64 / so as f64);

    if std::env::var("MINICONV_T6_REAL").ok().as_deref() != Some("1") {
        println!("(real-mode ramp skipped; set MINICONV_T6_REAL=1 to run it)");
        return;
    }
    let dir = miniconv::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("(no artifacts)");
        return;
    }
    let server = miniconv::coordinator::serve(ServerConfig {
        policy: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(4) },
        ..ServerConfig::default()
    })
    .expect("server");

    let mut t = Table::new(
        "Table 6 (real mode) — p95 decision latency vs fleet size, X=84, 10 Hz clients",
        &["clients", "pipeline", "p95 (ms)", "under 100ms?"],
    );
    for mode in [Route::Full, Route::Split] {
        for n in [2usize, 4, 8, 16] {
            let cfg = ClientConfig {
                mode,
                decisions: 40,
                rate_hz: Some(10.0),
                ..ClientConfig::default()
            };
            let reports = run_fleet(server.addr, n, &cfg).expect("fleet");
            let mut lat = merged_latencies(&reports);
            let p95 = lat.p95() * 1e3;
            t.row(&[
                n.to_string(),
                (if mode == Route::Split { "split" } else { "server-only" }).into(),
                format!("{p95:.1}"),
                (p95 < 100.0).to_string(),
            ]);
        }
    }
    t.print();
    server.shutdown();
}

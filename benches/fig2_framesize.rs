//! Figure 2 — per-frame processing time across devices as the input image
//! size varies (mean of 100 consecutive inferences ± sd), on the calibrated
//! device simulators over the real MiniConv-4 shader plan.

use miniconv::device::all_devices;
use miniconv::experiments::fig2_framesize;

fn main() {
    let sizes = [100usize, 200, 300, 400, 500, 750, 1000, 1500, 2000, 3000];
    let t = fig2_framesize(&all_devices(), &sizes, 100);
    t.print();
    println!("\ncsv:\n{}", t.to_csv());
    // paper anchors, checked on every bench run:
    // pi-zero-2w crosses 5 fps near X=500; jetson is fastest everywhere
    println!("anchor: pi-zero-2w j(400) should be ~100ms; 5fps bound near X=500+");
}

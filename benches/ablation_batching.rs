//! Ablation: dynamic-batching policy (max_wait × max_batch) vs decision
//! latency and throughput on the real coordinator — the design-choice study
//! behind the batcher defaults (DESIGN.md §Perf).
//!
//! Also ablates the wire representation: float vs uint8 features (the
//! paper transmits uint8; this quantifies the action-fidelity cost).

use std::time::Duration;

use miniconv::coordinator::{
    merged_latencies, run_fleet, serve, BatchPolicy, ClientConfig, Route, ServerConfig,
};
use miniconv::net::{dequantize_features, quantize_features};
use miniconv::runtime::{default_artifact_dir, Runtime, Value};
use miniconv::util::tables::Table;

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("ablation_batching: no artifacts — run `make artifacts`");
        return;
    }

    // ---- batching policy sweep -----------------------------------------
    let mut t = Table::new(
        "ablation — batching policy (8 split clients, closed loop, 30 decisions each)",
        &["max_wait (ms)", "max_batch", "median (ms)", "p95 (ms)", "mean batch", "dec/s"],
    );
    for (wait_ms, max_batch) in [(0u64, 1usize), (1, 8), (3, 8), (3, 32), (10, 32)] {
        let server = serve(ServerConfig {
            policy: BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(wait_ms),
            },
            ..ServerConfig::default()
        })
        .expect("server");
        let cfg = ClientConfig { mode: Route::Split, decisions: 30, ..ClientConfig::default() };
        let reports = run_fleet(server.addr, 8, &cfg).expect("fleet");
        let mut lat = merged_latencies(&reports);
        let hz: f64 = reports.iter().map(|r| r.achieved_hz()).sum();
        let m = server.metrics.snapshot();
        t.row(&[
            wait_ms.to_string(),
            max_batch.to_string(),
            format!("{:.1}", lat.median() * 1e3),
            format!("{:.1}", lat.p95() * 1e3),
            format!("{:.2}", m.split.mean_batch()),
            format!("{hz:.0}"),
        ]);
        server.shutdown();
    }
    t.print();

    // ---- wire-quantisation ablation -------------------------------------
    let rt = Runtime::new(&dir).expect("runtime");
    let x = rt.manifest.serve_x;
    let s = x.div_ceil(8);
    let enc = rt.load(&rt.manifest.serve_encoder("miniconv4")).unwrap();
    let head = rt.load(&rt.manifest.serve_head("miniconv4", 1)).unwrap();
    let enc_p = rt.manifest.load_params("serve_enc_miniconv4").unwrap();
    let head_p = rt.manifest.load_params("serve_head_miniconv4").unwrap();
    let enc_pv = Value::f32(&[enc_p.len()], enc_p);
    let head_pv = Value::f32(&[head_p.len()], head_p);

    let mut max_rel = 0.0f64;
    let mut rng = miniconv::util::rng::Rng::new(5);
    for _ in 0..20 {
        let obs: Vec<f32> = (0..9 * x * x).map(|_| rng.uniform() as f32).collect();
        let feat = enc
            .run(&[&enc_pv, &Value::f32(&[1, 9, x, x], obs)])
            .unwrap()[0]
            .as_f32()
            .unwrap()
            .to_vec();
        let a_float = head
            .run(&[&head_pv, &Value::f32(&[1, 4, s, s], feat.clone())])
            .unwrap()[0]
            .as_f32()
            .unwrap()[0];
        let (scale, q) = quantize_features(&feat);
        let a_u8 = head
            .run(&[&head_pv, &Value::f32(&[1, 4, s, s], dequantize_features(scale, &q))])
            .unwrap()[0]
            .as_f32()
            .unwrap()[0];
        let rel = ((a_float - a_u8).abs() / (a_float.abs() + 1e-3)) as f64;
        max_rel = max_rel.max(rel);
    }
    println!(
        "\nwire-quantisation ablation: max relative action deviation over 20 \
         random observations (float vs uint8 features): {:.3}%",
        max_rel * 100.0
    );
}

//! §4.2 break-even analysis: B = 32X²(1 − K/(4·2²ⁿ))/j, swept over input
//! size and representation width, with the measured Pi Zero 2 W encode
//! time j — and a cross-check that the analytic crossover agrees with the
//! simulated Table-5 latencies.

use miniconv::analysis::breakeven_bandwidth_bps;
use miniconv::experiments::serving::device_j;
use miniconv::experiments::{table5_latency_sim, ServerCostModel};
use miniconv::util::tables::Table;

fn main() {
    let mut t = Table::new(
        "break-even bandwidth B = 32X²(1 − K/(4·2²ⁿ))/j (j measured on sim Pi Zero 2 W)",
        &["X", "K", "j (ms)", "break-even (Mb/s)"],
    );
    for x in [200usize, 400, 800] {
        let j = device_j(x, 200);
        for k in [4usize, 16] {
            t.row(&[
                x.to_string(),
                k.to_string(),
                format!("{:.0}", j * 1e3),
                format!("{:.1}", breakeven_bandwidth_bps(x, 3, k, j) / 1e6),
            ]);
        }
    }
    t.print();
    println!("\npaper anchor: X=400, K=4, j≈0.1 s → ≈50.4 Mb/s");

    // consistency: simulate latencies just below/above the X=400 crossover
    let j = device_j(400, 200);
    let be = breakeven_bandwidth_bps(400, 3, 4, j) / 1e6;
    let t5 = table5_latency_sim(&[be * 0.7, be * 1.4], 300, &ServerCostModel::default());
    println!("\ncrossover cross-check (sim at 0.7x and 1.4x of B={be:.1} Mb/s):");
    t5.print();
}

//! Figure 5 — breakdown of the steps contributing to decision latency,
//! server-only vs split-policy, at several link bandwidths (X=400, K=4,
//! n=3, Pi Zero 2 W encode time).

use miniconv::experiments::{fig5_breakdown, ServerCostModel};

fn main() {
    let model = ServerCostModel::default();
    for mbps in [10.0, 50.0, 100.0] {
        fig5_breakdown(400, mbps * 1e6, &model).print();
    }
}

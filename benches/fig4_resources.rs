//! Figure 4 — resource usage during sustained inference: Pi Zero 2 W CPU
//! temperature and RAM (CPU vs GPU execution; 512 MB budget), Jetson Nano
//! power and memory pressure (5 W cap vs no limit, 5,000×3000² frames).

use miniconv::experiments::fig4_resources;

fn main() {
    let (traces, table) = fig4_resources(5000);
    table.print();
    for tr in &traces {
        println!(
            "\n{}: temp {} | watts {} | ram {}",
            tr.label,
            tr.recorder.sparkline("temp_c", 50),
            tr.recorder.sparkline("watts", 50),
            tr.recorder.sparkline("ram_mb", 50),
        );
    }
}

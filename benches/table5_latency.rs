//! Table 5 — end-to-end decision latency under bandwidth shaping.
//!
//! Two modes, both printed:
//!   * paper scale (sim): X=400, analytic link + Pi Zero 2 W device sim +
//!     calibrated GPU-server cost model; 1,000 decisions per setting.
//!   * task scale (real): X=84, the actual coordinator over loopback TCP
//!     with token-bucket-shaped uplinks, real artifacts, real shader
//!     encoding; bandwidths scaled to where the 84² wire sizes separate.

use std::time::Duration;

use miniconv::coordinator::{run_client, BatchPolicy, ClientConfig, Route, ServerConfig};
use miniconv::experiments::{table5_latency_sim, ServerCostModel};
use miniconv::util::tables::Table;

fn main() {
    // --- paper scale (simulated) ---------------------------------------
    table5_latency_sim(&[10.0, 25.0, 50.0, 100.0], 1000, &ServerCostModel::default()).print();
    println!("paper: 540/240/140/90 vs 145/140/138/137 ms — crossover near 50 Mb/s\n");

    // --- task scale (real coordinator) ----------------------------------
    let dir = miniconv::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("(skipping real-mode rows: no artifacts)");
        return;
    }
    let server = miniconv::coordinator::serve(ServerConfig {
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        ..ServerConfig::default()
    })
    .expect("server");

    let mut t = Table::new(
        "Table 5 (real mode) — X=84 over loopback TCP with shaped uplink (median of 40 decisions)",
        &["bandwidth", "server-only (ms)", "split-policy (ms)", "winner"],
    );
    for mbps in [0.5f64, 1.0, 2.0, 5.0, 25.0] {
        let mut med = [0.0f64; 2];
        for (i, mode) in [Route::Full, Route::Split].into_iter().enumerate() {
            let cfg = ClientConfig {
                mode,
                decisions: 40,
                shape_bps: Some(mbps * 1e6),
                device: Some(miniconv::device::pi_zero_2w()),
                ..ClientConfig::default()
            };
            let report = run_client(server.addr, 90 + i as u32, &cfg).expect("client");
            let mut lat = report.latencies;
            med[i] = lat.median() * 1e3;
        }
        t.row(&[
            format!("{mbps} Mb/s"),
            format!("{:.0}", med[0]),
            format!("{:.0}", med[1]),
            (if med[1] < med[0] { "split" } else { "server-only" }).into(),
        ]);
    }
    t.print();
    server.shutdown();
}

//! Fleet scalability — aggregate throughput and tail latency as the same
//! simulated client fleet is served by 1/2/4/8 coordinator shards behind
//! the consistent-hash gateway.
//!
//! Shards run the Sim backend (real TCP, batching, sessions and metrics;
//! modelled accelerator time of `fixed + per_item·n` per batch), so the
//! sweep needs no AOT artifacts and isolates the *serving architecture*:
//! one executor thread per shard is the serialisation bottleneck the
//! gateway shards away. With a saturating client fleet, aggregate
//! throughput must rise monotonically from 1 to 4 shards — asserted at the
//! end, since this is the acceptance gauge for the fleet subsystem.
//!
//! Run: `cargo bench --bench fleet_scalability` (or cargo run --release).

use std::time::{Duration, Instant};

use miniconv::coordinator::{
    merged_latencies, run_fleet, Backend, BatchPolicy, ClientConfig, Route, ServerConfig, SimSpec,
};
use miniconv::fleet::{launch_local, FleetConfig};
use miniconv::util::tables::Table;

const OBS_X: usize = 24;

struct Point {
    shards: usize,
    clients: usize,
    throughput: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    busiest: u64,
    quietest: u64,
}

fn run_point(shards: usize, clients: usize, decisions: usize) -> Point {
    let fleet = launch_local(FleetConfig {
        shards,
        server: ServerConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            backend: Backend::Sim(SimSpec {
                fixed: Duration::from_millis(4),
                per_item: Duration::from_millis(1),
                action_dim: 1,
                // shards run the real compiled encoder inside the modelled
                // budget, so the sweep stresses the genuine hot path
                encode: true,
            }),
            ..ServerConfig::default()
        },
        ..FleetConfig::default()
    })
    .expect("fleet");

    let cfg = ClientConfig {
        mode: Route::Full,
        decisions,
        obs_x: Some(OBS_X),
        ..ClientConfig::default()
    };
    let t0 = Instant::now();
    let reports = run_fleet(fleet.addr(), clients, &cfg).expect("client fleet");
    let elapsed = t0.elapsed().as_secs_f64();

    let ok: usize = reports.iter().map(|r| r.decisions).sum();
    let errors: usize = reports.iter().map(|r| r.errors).sum();
    assert_eq!(errors, 0, "back-pressure rejections during the sweep");
    let mut lat = merged_latencies(&reports);

    let per_shard: Vec<u64> = fleet
        .shard_ids()
        .iter()
        .map(|&id| fleet.shard_metrics(id).unwrap().full.requests)
        .collect();
    let point = Point {
        shards,
        clients,
        throughput: ok as f64 / elapsed,
        p50_ms: lat.median() * 1e3,
        p95_ms: lat.p95() * 1e3,
        p99_ms: lat.p99() * 1e3,
        busiest: per_shard.iter().copied().max().unwrap_or(0),
        quietest: per_shard.iter().copied().min().unwrap_or(0),
    };
    fleet.shutdown();
    point
}

fn main() {
    let decisions = 40;
    let sweep_clients = [8usize, 32];
    let shard_counts = [1usize, 2, 4, 8];

    let mut table = Table::new(
        "Fleet scalability — Sim shards (4 ms + 1 ms/item per batch, max batch 8), \
         closed-loop clients, X=24 raw frames through the gateway",
        &["shards", "clients", "agg dec/s", "p50 (ms)", "p95 (ms)", "p99 (ms)", "shard load max/min"],
    );

    let mut fixed_fleet = Vec::new();
    for &clients in &sweep_clients {
        for &shards in &shard_counts {
            let p = run_point(shards, clients, decisions);
            table.row(&[
                p.shards.to_string(),
                p.clients.to_string(),
                format!("{:.0}", p.throughput),
                format!("{:.1}", p.p50_ms),
                format!("{:.1}", p.p95_ms),
                format!("{:.1}", p.p99_ms),
                format!("{}/{}", p.busiest, p.quietest),
            ]);
            if clients == 32 {
                fixed_fleet.push(p);
            }
        }
    }
    table.print();

    // acceptance gauge: under the fixed 32-client fleet, aggregate
    // throughput rises monotonically over 1 -> 2 -> 4 shards (the 1-shard
    // executor is saturated by construction; 8 shards may plateau once the
    // clients become the bottleneck, so that step only forbids collapse)
    let thr: Vec<f64> = fixed_fleet.iter().map(|p| p.throughput).collect();
    println!(
        "\nscaling @32 clients: 1 shard {:.0}/s -> 2 shards {:.0}/s -> 4 shards {:.0}/s -> 8 shards {:.0}/s",
        thr[0], thr[1], thr[2], thr[3]
    );
    assert!(
        thr[1] > thr[0] * 1.15,
        "2 shards did not outscale 1 ({:.0} vs {:.0} dec/s)",
        thr[1],
        thr[0]
    );
    assert!(
        thr[2] > thr[1] * 1.15,
        "4 shards did not outscale 2 ({:.0} vs {:.0} dec/s)",
        thr[2],
        thr[1]
    );
    assert!(
        thr[3] > thr[2] * 0.85,
        "8 shards collapsed vs 4 ({:.0} vs {:.0} dec/s)",
        thr[3],
        thr[2]
    );
    println!("monotonic scaling 1 -> 4 shards: OK");
}

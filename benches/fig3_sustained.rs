//! Figure 3 — sustained inference performance over 5,000 consecutive
//! frames: (a) Jetson Nano at 3000² with/without the 5 W power cap;
//! (b) Pi Zero 2 W at 400², GPU (OpenGL) vs CPU (PyTorch) execution.

use miniconv::experiments::fig3_sustained;

fn main() {
    let (traces, table) = fig3_sustained(5000);
    table.print();
    for tr in &traces {
        println!("\n{} — frame-time csv (downsampled):", tr.label);
        print!("{}", tr.recorder.downsample(40).to_csv());
    }
}

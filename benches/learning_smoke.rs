//! Tables 2–4 (smoke scale) — the learning-table machinery end to end:
//! train every encoder on Pendulum for a few episodes through the real
//! update artifacts and print the paper-format Best/Final/Mean table.
//!
//! Paper-scale runs: `miniconv exp learning --task <t> --scale paper`.

use miniconv::experiments::{learning_table, LearningScale};
use miniconv::runtime::{default_artifact_dir, Runtime};

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("learning_smoke: no artifacts — run `make artifacts`");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let (t, rows) = learning_table(
        &rt,
        "pendulum",
        &["miniconv4", "miniconv16", "fullcnn"],
        LearningScale::Smoke,
        0,
    )
    .expect("learning table");
    t.print();
    for r in &rows {
        assert!(r.updates > 0, "{}: no updates ran", r.arch);
        assert!(r.best.is_finite());
    }
    println!("\n(smoke scale: {} episodes/encoder; Tables 2-4 shapes need --scale tiny/paper)", rows[0].episodes);
}

//! Online-learning smoke gate (DESIGN.md §8): train the native PPO engine
//! twice at the same seed — once offline (`rl::NativeTrainer`), once
//! through the full serving stack (gateway + shard + experience codec in
//! the deterministic simnet) — and compare final-100 mean returns.
//!
//! Gates, embedded in `BENCH_learn.json` (override the path with `--out`
//! or the `BENCH_LEARN_OUT` env var) and enforced against the committed
//! baseline by `scripts/bench_diff`:
//!   * online final-100 within 10% of the offline baseline (the ideal-link
//!     run is bit-identical, so the gap is 0 unless the loop regresses);
//!   * zero actions applied beyond the staleness bound;
//!   * policy-version adoption strictly monotonic.
//!
//! `--episodes N` caps the run — CI uses a tiny N; gate verdicts are only
//! meaningful at the default. With artifacts present the legacy Tables 2–4
//! smoke table (update/act artifacts for every encoder) also runs.

use miniconv::experiments::{learning_table, LearningScale};
use miniconv::learn::LearnerConfig;
use miniconv::rl::native::NativeConfig;
use miniconv::rl::{NativeTrainer, TrainConfig};
use miniconv::runtime::{default_artifact_dir, Runtime};
use miniconv::sim::{run_scenario, LearnSpec, ScenarioConfig};
use miniconv::util::argparse::Parser;
use miniconv::util::tables::Table;

fn final_n_mean(returns: &[f64], n: usize) -> f64 {
    if returns.is_empty() {
        return 0.0;
    }
    let tail = &returns[returns.len().saturating_sub(n)..];
    tail.iter().sum::<f64>() / tail.len() as f64
}

fn main() {
    let args = Parser::new("learning smoke — online fleet loop vs offline PPO baseline")
        .opt("episodes", "30", "pendulum episodes per run")
        .opt("seed", "0", "environment + engine seed")
        .opt("out", "", "output path (default BENCH_LEARN_OUT or BENCH_learn.json)")
        .parse();
    let episodes: usize = args.usize("episodes").max(1);
    let seed = args.u64("seed");
    let out_path = {
        let o = args.str("out");
        if o.is_empty() {
            std::env::var("BENCH_LEARN_OUT").unwrap_or_else(|_| "BENCH_learn.json".into())
        } else {
            o
        }
    };

    // offline baseline: the native trainer, 256-step segments
    let mut offline = NativeTrainer::new(
        TrainConfig {
            episodes,
            rollout_steps: 256,
            ppo_epochs: 10,
            gae_lambda: 0.95,
            seed,
            log_every: 0,
            ..TrainConfig::default()
        },
        NativeConfig { seed, ..NativeConfig::default() },
    );
    offline.train().expect("offline train");
    let off_final = offline.stats.final_100();

    // online: the same engine and knobs behind the gateway + shard +
    // experience-codec stack, one learning client replaying the trainer's
    // per-episode environment streams
    let cfg = ScenarioConfig {
        seed,
        shards: 1,
        raw_clients: 0,
        learning: Some(LearnSpec {
            clients: 1,
            episodes,
            learner: LearnerConfig {
                core: NativeConfig { seed, ..NativeConfig::default() },
                rollout_steps: 256,
                ppo_epochs: 10,
                gae_lambda: 0.95,
                publish_every: 1,
            },
            max_lag: 4,
            update_cost: 0.002,
        }),
        ..ScenarioConfig::default()
    };
    let r = run_scenario(&cfg).expect("online scenario");
    let c = &r.clients[0];
    let s = &r.shards[0];
    let on_final = final_n_mean(&c.returns, 100);

    let parity_gap_pct = if off_final.abs() > f64::EPSILON {
        (on_final - off_final).abs() / off_final.abs() * 100.0
    } else {
        0.0
    };
    let applied_stale = r.total_applied_stale();
    let monotonic = s.adopted_versions.windows(2).all(|w| w[0] < w[1]);
    let parity_pass = parity_gap_pct <= 10.0 && c.returns.len() == episodes;
    let stale_pass = applied_stale == 0 && r.total_give_ups() == 0;

    let mut t = Table::new(
        &format!("learning smoke — pendulum, {episodes} episodes, seed {seed}"),
        &["run", "final-100", "best", "episodes", "updates", "versions"],
    );
    t.row(&[
        "offline".into(),
        format!("{:.1}", off_final),
        format!("{:.1}", offline.stats.best()),
        offline.stats.episodes().to_string(),
        offline.updates.to_string(),
        "-".into(),
    ]);
    t.row(&[
        "online".into(),
        format!("{:.1}", on_final),
        format!("{:.1}", c.returns.iter().copied().fold(f64::NEG_INFINITY, f64::max)),
        c.returns.len().to_string(),
        s.updates.to_string(),
        s.final_version.to_string(),
    ]);
    t.print();
    println!(
        "parity gap {:.2}%  experience frames {}  stale rejections {}  resyncs {}",
        parity_gap_pct,
        s.exp_frames,
        r.total_stale_rejections(),
        r.gateway.policy_resyncs
    );
    println!(
        "gates: parity <= 10% -> {}, zero applied-stale -> {}, monotonic versions -> {}",
        if parity_pass { "PASS" } else { "FAIL" },
        if stale_pass { "PASS" } else { "FAIL" },
        if monotonic { "PASS" } else { "FAIL" },
    );

    let mut j = String::new();
    j.push_str("{\n");
    j.push_str("  \"bench\": \"learning_smoke\",\n");
    j.push_str(&format!("  \"episodes\": {episodes},\n"));
    j.push_str(&format!("  \"seed\": {seed},\n"));
    j.push_str("  \"offline\": {\n");
    j.push_str(&format!("    \"final_100\": {:.3},\n", off_final));
    j.push_str(&format!("    \"best\": {:.3},\n", offline.stats.best()));
    j.push_str(&format!("    \"mean\": {:.3},\n", offline.stats.mean()));
    j.push_str(&format!("    \"updates\": {}\n", offline.updates));
    j.push_str("  },\n");
    j.push_str("  \"online\": {\n");
    j.push_str(&format!("    \"final_100\": {:.3},\n", on_final));
    j.push_str(&format!("    \"episodes\": {},\n", c.returns.len()));
    j.push_str(&format!("    \"updates\": {},\n", s.updates));
    j.push_str(&format!("    \"versions_published\": {},\n", r.gateway.policy_published));
    j.push_str(&format!("    \"final_version\": {},\n", s.final_version));
    j.push_str(&format!("    \"experience_frames\": {},\n", s.exp_frames));
    j.push_str(&format!("    \"stale_rejections\": {},\n", r.total_stale_rejections()));
    j.push_str(&format!("    \"applied_stale\": {applied_stale},\n"));
    j.push_str(&format!("    \"policy_resyncs\": {}\n", r.gateway.policy_resyncs));
    j.push_str("  },\n");
    j.push_str(&format!("  \"parity_gap_pct\": {:.4},\n", parity_gap_pct));
    j.push_str("  \"gates\": {\n");
    j.push_str("    \"max_parity_gap_pct\": 10.0,\n");
    j.push_str(&format!("    \"parity_pass\": {parity_pass},\n"));
    j.push_str(&format!("    \"zero_applied_stale_pass\": {stale_pass},\n"));
    j.push_str(&format!("    \"version_monotonic_pass\": {monotonic}\n"));
    j.push_str("  }\n}\n");
    if let Err(e) = std::fs::write(&out_path, &j) {
        eprintln!("could not write {out_path}: {e}");
    } else {
        println!("wrote {out_path}");
    }

    // legacy Tables 2–4 smoke (real update/act artifacts) when present
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        println!("learning_smoke: no artifacts — skipping the encoder table");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let (t, rows) = learning_table(
        &rt,
        "pendulum",
        &["miniconv4", "miniconv16", "fullcnn"],
        LearningScale::Smoke,
        0,
    )
    .expect("learning table");
    t.print();
    for row in &rows {
        assert!(row.updates > 0, "{}: no updates ran", row.arch);
        assert!(row.best.is_finite());
    }
}

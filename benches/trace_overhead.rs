//! Tracing overhead harness (DESIGN.md §12): the per-decision span layer
//! must be cheap enough to leave on.
//!
//! Two measurements, two gates (both embedded in the JSON):
//!   * end-to-end: identical loopback fleets (Sim backend, 8 clients)
//!     with tracing off vs on — traced throughput may lose at most 5% of
//!     untraced requests/sec;
//!   * trace layer in isolation: the full per-decision op chain (mint →
//!     client stamps → trailer append → gateway in-place stamp → shard
//!     peel/stamp/re-append → client peel → ring push) over preallocated
//!     buffers must do 0 heap allocations per decision, measured by the
//!     counting global allocator (shared impl: `util::alloc_counter`).
//!
//! Results land in `BENCH_trace.json` (override with `--out` or the
//! `BENCH_TRACE_OUT` env var). `--iters N` sets decisions per client — CI
//! runs a cheap smoke pass with a tiny N, where loopback throughput is
//! noise; below 100 iters the throughput metrics and the overhead gate
//! are emitted as `null` (the alloc count is deterministic and always
//! reported). Gate verdicts are only meaningful at the default.

use std::time::{Duration, Instant};

use miniconv::coordinator::{
    run_fleet, serve, Backend, BatchPolicy, ClientConfig, Route, ServerConfig, SimSpec,
};
use miniconv::net::framing::{Msg, Payload, Request};
use miniconv::trace::{
    append_trailer, split_trailer, stamp_body_tail, Ring, TraceCtx, STAGE_DEQUEUE, STAGE_ENCODE,
    STAGE_ENQUEUE, STAGE_EXECUTE, STAGE_GW_FORWARD, STAGE_PACK, STAGE_RECV, STAGE_REPLY,
    STAGE_SEND, TRACE_WIRE_BYTES,
};
use miniconv::util::alloc_counter::CountingAlloc;
use miniconv::util::argparse::Parser;
use miniconv::util::tables::Table;

// counts heap allocations so the zero-allocation claim is measured, not
// asserted by inspection
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const CLIENTS: usize = 8;
const MAX_BATCH: usize = 8;
const OBS_X: usize = 24;
const RING_CAP: usize = 1024;
/// Below this many decisions per client, loopback req/s is noise: the
/// throughput metrics and the overhead verdict are withheld (null).
const MEANINGFUL_ITERS: usize = 100;

fn server_config(trace: bool) -> ServerConfig {
    ServerConfig {
        policy: BatchPolicy { max_batch: MAX_BATCH, max_wait: Duration::from_millis(1) },
        backend: Backend::Sim(SimSpec {
            fixed: Duration::from_micros(300),
            per_item: Duration::from_micros(100),
            action_dim: 1,
            encode: false,
        }),
        trace,
        ..ServerConfig::default()
    }
}

fn client_config(trace: bool, decisions: usize) -> ClientConfig {
    ClientConfig {
        mode: Route::Full,
        decisions,
        obs_x: Some(OBS_X),
        trace,
        ..ClientConfig::default()
    }
}

/// One loopback cell: a fresh server, `CLIENTS` concurrent clients,
/// `decisions` each. Returns end-to-end requests/sec.
fn loopback_req_s(trace: bool, decisions: usize) -> f64 {
    let server = serve(server_config(trace)).expect("loopback server");
    let t0 = Instant::now();
    let reports =
        run_fleet(server.addr, CLIENTS, &client_config(trace, decisions)).expect("fleet run");
    let secs = t0.elapsed().as_secs_f64();
    for (c, r) in reports.iter().enumerate() {
        assert_eq!(r.decisions, decisions, "client {c} lost decisions");
        assert_eq!(r.errors, 0, "client {c} saw rejections");
        // the traced cell must actually trace, or the comparison is a lie
        let want = if trace { decisions } else { 0 };
        assert_eq!(r.traces.len(), want, "client {c}: unexpected span count");
    }
    server.shutdown();
    (CLIENTS * decisions) as f64 / secs.max(1e-9)
}

/// The complete trace-layer op chain for one decision, client to client,
/// over preallocated buffers. Timestamps come from a counter — the chain
/// under test is the span plumbing, not the clock.
fn one_decision(proto: &[u8], body: &mut Vec<u8>, ring: &mut Ring, t: &mut u64, id: u64) {
    let tick = |t: &mut u64| {
        *t += 1;
        *t
    };
    // client: encode into the reused wire buffer, open + stamp the span
    body.clear();
    body.extend_from_slice(proto);
    let mut ctx = TraceCtx::mint(id, tick(t));
    ctx.stamp(STAGE_ENCODE, tick(t));
    ctx.stamp(STAGE_SEND, tick(t));
    append_trailer(body, &ctx);
    // gateway: forward-pump stamp, in place, no decode
    assert!(stamp_body_tail(body, STAGE_GW_FORWARD, tick(t)), "gateway stamp refused");
    // shard: peel (ctx is Copy — extract it, end the borrow), stamp the
    // batching hops, re-append onto the reply
    let (inner_len, mut shard) = {
        let (inner, c) = split_trailer(body).expect("request trailer peels");
        (inner.len(), c)
    };
    for stage in [STAGE_ENQUEUE, STAGE_DEQUEUE, STAGE_PACK, STAGE_EXECUTE, STAGE_REPLY] {
        shard.stamp(stage, tick(t));
    }
    body.truncate(inner_len);
    append_trailer(body, &shard);
    // client: peel the reply, close the span, land it in the recorder
    let (_, mut closed) = split_trailer(body).expect("reply trailer peels");
    closed.stamp(STAGE_RECV, tick(t));
    ring.push(closed);
}

/// Heap allocations per decision across the isolated trace layer,
/// counted after buffers are warm. Ceiling division: even one allocation
/// per few hundred decisions must show as nonzero, not round green.
fn trace_layer_allocs_per_decision(iters: usize) -> u64 {
    let frame = Msg::Request(Request {
        client: 1,
        id: 0,
        payload: Payload::RawRgba { x: 8, data: vec![7; 8 * 8 * 4] },
    })
    .encode();
    let proto = frame[4..].to_vec();
    let mut body = Vec::with_capacity(proto.len() + TRACE_WIRE_BYTES);
    let mut ring = Ring::with_capacity(RING_CAP);
    let mut t: u64 = 0;
    for d in 0..16u64 {
        one_decision(&proto, &mut body, &mut ring, &mut t, d);
    }
    let before = CountingAlloc::count();
    for d in 0..iters as u64 {
        one_decision(&proto, &mut body, &mut ring, &mut t, d);
    }
    let allocs = CountingAlloc::count() - before;
    std::hint::black_box((ring.len(), body.len(), t));
    allocs.div_ceil(iters.max(1) as u64)
}

fn fmt_opt(v: Option<f64>) -> String {
    v.map_or("null".into(), |x| format!("{x:.4}"))
}

fn main() {
    let args = Parser::new("per-decision tracing overhead — traced vs untraced loopback + alloc count")
        .opt("iters", "400", "decisions per client per cell")
        .opt("out", "", "output path (default BENCH_TRACE_OUT or BENCH_trace.json)")
        .parse();
    let iters: usize = args.usize("iters");
    let out_path = {
        let o = args.str("out");
        if o.is_empty() {
            std::env::var("BENCH_TRACE_OUT").unwrap_or_else(|_| "BENCH_trace.json".into())
        } else {
            o
        }
    };

    let untraced = loopback_req_s(false, iters.max(1));
    let traced = loopback_req_s(true, iters.max(1));
    let overhead_pct = (untraced - traced) / untraced.max(1e-9) * 100.0;
    let allocs = trace_layer_allocs_per_decision(200.min(iters.max(1)) * 4);

    let mut table = Table::new(
        "per-decision tracing — loopback fleet, Sim backend",
        &["cell", "clients", "decisions", "req/s"],
    );
    table.row(&["untraced".into(), CLIENTS.to_string(), iters.to_string(), format!("{untraced:.0}")]);
    table.row(&["traced".into(), CLIENTS.to_string(), iters.to_string(), format!("{traced:.0}")]);
    table.print();
    println!("tracing overhead: {overhead_pct:.2}% of untraced req/s");
    println!("trace-layer allocations per decision: {allocs}");

    let meaningful = iters >= MEANINGFUL_ITERS;
    let overhead_pass = meaningful.then_some(overhead_pct <= 5.0);
    let alloc_pass = allocs == 0;
    println!(
        "gates: overhead <= 5% -> {}, allocs == 0 -> {}",
        overhead_pass.map_or("SKIP (smoke iters)".into(), |p| {
            String::from(if p { "PASS" } else { "FAIL" })
        }),
        if alloc_pass { "PASS" } else { "FAIL" },
    );

    // throughput fields go null on smoke runs so bench_diff (which skips
    // nulls) never judges loopback noise
    let (j_untraced, j_traced, j_overhead) = if meaningful {
        (Some(untraced), Some(traced), Some(overhead_pct))
    } else {
        (None, None, None)
    };
    let json = format!(
        concat!(
            "{{\n",
            "  \"bench\": \"trace_overhead\",\n",
            "  \"iters\": {},\n",
            "  \"clients\": {},\n",
            "  \"max_batch\": {},\n",
            "  \"obs_x\": {},\n",
            "  \"ring_cap\": {},\n",
            "  \"untraced_req_s\": {},\n",
            "  \"traced_req_s\": {},\n",
            "  \"overhead_pct\": {},\n",
            "  \"trace_layer_allocs_per_decision\": {},\n",
            "  \"gates\": {{\n",
            "    \"max_overhead_pct\": 5.0,\n",
            "    \"max_trace_layer_allocs_per_decision\": 0,\n",
            "    \"overhead_pass\": {},\n",
            "    \"alloc_pass\": {}\n",
            "  }}\n",
            "}}\n"
        ),
        iters,
        CLIENTS,
        MAX_BATCH,
        OBS_X,
        RING_CAP,
        fmt_opt(j_untraced),
        fmt_opt(j_traced),
        fmt_opt(j_overhead),
        allocs,
        overhead_pass.map_or("null".into(), |p| p.to_string()),
        alloc_pass,
    );
    if let Err(e) = std::fs::write(&out_path, &json) {
        eprintln!("could not write {out_path}: {e}");
    } else {
        println!("wrote {out_path}");
    }
}

//! Deployment-path parity: the GLSL shader interpreter must agree with the
//! AOT Pallas/XLA encoder artifacts on real rendered observations — the
//! guarantee that what ships to the device computes what was trained.
//! Requires `make artifacts`.

use miniconv::envs::{CropMode, Env, Pendulum, PixelPipeline};
use miniconv::runtime::{default_artifact_dir, Runtime, Value};
use miniconv::shader::{pipeline_from_manifest, plan, EncoderIr, ShaderPipeline, TextureFormat};
use miniconv::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn real_obs(rt: &Runtime, steps: usize) -> (Vec<f32>, miniconv::tensor::Chw) {
    let x = rt.manifest.serve_x;
    let mut env = Pendulum::new();
    let mut rng = Rng::new(123);
    env.reset(&mut rng);
    let mut pipe = PixelPipeline::new(100, x, CropMode::Center);
    pipe.observe(&env, &mut rng);
    for _ in 0..steps {
        env.step(&[1.0]);
        pipe.observe(&env, &mut rng);
    }
    (pipe.obs(), pipe.obs_chw())
}

fn parity_for(rt: &Runtime, arch: &str, k: usize) {
    let x = rt.manifest.serve_x;
    let (obs, obs_chw) = real_obs(rt, 3);

    let enc = rt.load(&rt.manifest.serve_encoder(arch)).unwrap();
    let p = rt.manifest.load_params(&format!("serve_enc_{arch}")).unwrap();
    let out = enc
        .run(&[&Value::f32(&[p.len()], p), &Value::f32(&[1, 9, x, x], obs)])
        .unwrap();
    let feat_xla = out[0].as_f32().unwrap();

    let (serve_meta, _) = &rt.manifest.encoders[arch];
    let shader = pipeline_from_manifest(
        &rt.manifest,
        arch,
        serve_meta,
        x,
        &format!("serve_enc_{arch}"),
        TextureFormat::Float,
    )
    .unwrap();
    let feat_gl = shader.run(&obs_chw).unwrap();

    let s = x.div_ceil(8);
    let mut max_diff = 0.0f32;
    for c in 0..k {
        for yy in 0..s {
            for xx in 0..s {
                let v_xla = feat_xla[(c * s + yy) * s + xx];
                let d = (v_xla - feat_gl.at(c, yy, xx)).abs();
                max_diff = max_diff.max(d);
            }
        }
    }
    assert!(max_diff < 1e-3, "{arch}: shader vs XLA diff {max_diff}");
}

#[test]
fn miniconv4_shader_matches_artifact() {
    let Some(rt) = runtime() else { return };
    parity_for(&rt, "miniconv4", 4);
}

#[test]
fn miniconv16_shader_matches_artifact() {
    let Some(rt) = runtime() else { return };
    parity_for(&rt, "miniconv16", 16);
}

#[test]
fn rgba8_textures_bounded_error_at_serve_scale() {
    // The real Pi Zero 2 W renders to RGBA8 textures; quantisation error
    // through 3 passes must stay small relative to the feature scale.
    let Some(rt) = runtime() else { return };
    let x = rt.manifest.serve_x;
    let (_, obs_chw) = real_obs(&rt, 2);
    let (serve_meta, _) = &rt.manifest.encoders["miniconv4"];
    let flat = rt.manifest.load_params("serve_enc_miniconv4").unwrap();
    let ir = EncoderIr::from_meta("miniconv4", 9, serve_meta);
    let pl = plan(&ir, x).unwrap();
    let ws = miniconv::shader::unpack_conv_weights(&ir, &flat).unwrap();

    let scales = ShaderPipeline::calibrate(&pl, &ws, &obs_chw).unwrap();
    let f_pipe = ShaderPipeline::new(pl.clone(), ws.clone(), TextureFormat::Float).unwrap();
    let q_pipe =
        ShaderPipeline::new(pl, ws, TextureFormat::Rgba8 { scales: scales.clone() }).unwrap();
    let f = f_pipe.run(&obs_chw).unwrap();
    let q = q_pipe.run(&obs_chw).unwrap();
    let diff = f.max_abs_diff(&q);
    let tol = scales.last().unwrap() * 0.05;
    assert!(diff < tol, "rgba8 error {diff} vs tol {tol}");
    assert!(diff > 0.0, "quantisation should not be bit-exact");
}

#[test]
fn glsl_sources_generated_for_every_pass() {
    let Some(rt) = runtime() else { return };
    for arch in ["miniconv4", "miniconv16"] {
        let (serve_meta, _) = &rt.manifest.encoders[arch];
        let ir = EncoderIr::from_meta(arch, 9, serve_meta);
        let p = plan(&ir, rt.manifest.serve_x).unwrap();
        let shaders = miniconv::shader::gen_all(&p);
        assert_eq!(shaders.len(), p.passes.len());
        for (s, pass) in shaders.iter().zip(&p.passes) {
            // emitted sample count equals the planner's per-pixel budget
            assert_eq!(
                s.fragment.matches("fetch(u_tex").count(),
                pass.samples,
                "{arch}/{}",
                s.name
            );
        }
    }
}

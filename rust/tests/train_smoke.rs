//! Training-loop integration: the generic trainer drives real update/act
//! artifacts for all three (algorithm, task) pairs at tiny budgets and
//! produces finite losses and episodic returns. Requires `make artifacts`.
//! The native PPO baseline (`NativeTrainer`) needs no artifacts and always
//! runs — it is the offline reference the online learning loop is gated
//! against.

use miniconv::rl::native::NativeConfig;
use miniconv::rl::{NativeTrainer, TrainConfig, Trainer};
use miniconv::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let dir = miniconv::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

#[test]
fn ddpg_pendulum_trains_and_loss_is_finite() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig {
        episodes: 2,
        warmup_steps: 64,
        train_freq: 16,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(&rt, "pendulum_miniconv4", cfg).expect("trainer");
    t.train().expect("train");
    assert_eq!(t.report.stats.episodes(), 2);
    assert!(t.report.updates > 5, "too few updates: {}", t.report.updates);
    // pendulum returns are in [-17*200, 0]
    for &r in t.report.stats.returns() {
        assert!((-4000.0..=0.0).contains(&r), "return {r}");
    }
    let (name, closses) = &t.report.metrics[0];
    assert_eq!(name, "critic_loss");
    assert!(closses.iter().all(|l| l.is_finite()));
}

#[test]
fn sac_hopper_trains() {
    let Some(rt) = runtime() else { return };
    // hopper episodes terminate early under random actions (~30-80 steps);
    // the replay needs >= 64 transitions (one artifact batch) before the
    // first gradient step, so give the run a few episodes
    let cfg = TrainConfig {
        episodes: 5,
        warmup_steps: 30,
        train_freq: 8,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(&rt, "hopper_miniconv4", cfg).expect("trainer");
    t.train().expect("train");
    assert_eq!(t.report.stats.episodes(), 5);
    assert!(t.report.updates >= 1);
    // alpha metric stays positive
    let alpha_idx = t.report.metrics.iter().position(|(n, _)| n == "alpha").unwrap();
    assert!(t.report.metrics[alpha_idx].1.iter().all(|&a| a > 0.0));
}

#[test]
fn ppo_walker_trains_one_segment() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig {
        episodes: 1,
        rollout_steps: 64,
        ppo_epochs: 1,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(&rt, "walker_fullcnn", cfg).expect("trainer");
    t.train().expect("train");
    assert!(t.report.stats.episodes() >= 1);
    assert!(t.report.updates >= 1);
    // first-epoch KL should be near zero (on-policy batch)
    let kl_idx = t.report.metrics.iter().position(|(n, _)| n == "approx_kl").unwrap();
    let first_kl = t.report.metrics[kl_idx].1[0];
    assert!(first_kl.abs() < 0.05, "first-minibatch KL {first_kl}");
}

#[test]
fn evaluation_runs_deterministically() {
    let Some(rt) = runtime() else { return };
    let cfg = TrainConfig { episodes: 0, ..TrainConfig::default() };
    let mut t = Trainer::new(&rt, "pendulum_miniconv16", cfg).expect("trainer");
    let a = t.evaluate(1).expect("eval");
    let b = t.evaluate(1).expect("eval");
    assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    assert!(a <= 0.0 && a > -4000.0);
}

#[test]
fn unknown_trainstate_is_error() {
    let Some(rt) = runtime() else { return };
    assert!(Trainer::new(&rt, "nope", TrainConfig::default()).is_err());
}

// -- native (artifact-free) baseline ----------------------------------------

fn native_run(episodes: usize, seed: u64) -> NativeTrainer {
    let cfg = TrainConfig {
        episodes,
        rollout_steps: 256,
        ppo_epochs: 10,
        gae_lambda: 0.95,
        seed,
        log_every: 0,
        ..TrainConfig::default()
    };
    let native = NativeConfig { seed, ..NativeConfig::default() };
    let mut t = NativeTrainer::new(cfg, native);
    t.train().expect("native train");
    t
}

#[test]
fn native_ppo_is_deterministic_across_runs() {
    let a = native_run(4, 9);
    let b = native_run(4, 9);
    assert_eq!(a.stats.returns(), b.stats.returns());
    assert_eq!(a.updates, b.updates);
    assert_eq!(a.core.params(), b.core.params());
    let c = native_run(4, 10);
    assert_ne!(a.stats.returns(), c.stats.returns(), "seed must matter");
}

#[test]
fn native_ppo_pendulum_final_stats_stay_in_band() {
    let t = native_run(30, 0);
    assert_eq!(t.stats.episodes(), 30);
    // 30 episodes x 200 steps in 256-step segments
    assert_eq!(t.updates, 30 * 200 / 256);
    for &r in t.stats.returns() {
        assert!((-4000.0..=0.0).contains(&r), "return {r} out of pendulum range");
        assert!(r.is_finite());
    }
    // pinned final-100 band: a random pendulum policy sits near -1200;
    // catastrophic divergence (NaN params, saturated torque spins) lands
    // below -2800. The band is deliberately loose — the tight 10% parity
    // gate lives in the learning_smoke e2e, not here.
    let final_100 = t.stats.final_100();
    assert!(
        (-2800.0..0.0).contains(&final_100),
        "final-100 mean {final_100} outside the pinned band"
    );
}

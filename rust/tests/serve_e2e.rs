//! End-to-end serving integration: real coordinator over loopback TCP,
//! real artifacts, real shader-interpreter encoding on split clients.
//! Requires `make artifacts` (skipped otherwise).
//!
//! Readiness is event-driven by construction: `serve()` returns only
//! after the listener is bound and the executor has compiled its batch-1
//! executables, so no test here waits on wall-clock polling. The
//! bandwidth-shaping claim this file checks on real sockets
//! (`shaped_split_latency_beats_raw_at_low_bandwidth`) is pinned
//! deterministically — across a 1/5/20 Mb/s matrix and against the
//! analytic break-even model — by the virtual-time suite in
//! `sim_scenarios.rs`; the generous 3× margin here only guards the
//! real-socket plumbing, not the timing claim itself.

use std::time::Duration;

use miniconv::coordinator::{
    merged_latencies, run_client, run_fleet, BatchPolicy, ClientConfig, Route, ServerConfig,
};

fn have_artifacts() -> bool {
    miniconv::runtime::default_artifact_dir().join("manifest.json").exists()
}

fn start_server() -> miniconv::coordinator::ServerHandle {
    miniconv::coordinator::serve(ServerConfig {
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        ..ServerConfig::default()
    })
    .expect("server")
}

#[test]
fn split_client_completes_decisions() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let server = start_server();
    let cfg = ClientConfig { mode: Route::Split, decisions: 20, ..ClientConfig::default() };
    let report = run_client(server.addr, 0, &cfg).expect("client run");
    assert_eq!(report.decisions, 20);
    assert_eq!(report.errors, 0);
    assert_eq!(report.latencies.len(), 20);
    // real split decisions on loopback take millis, not seconds
    let mut lats = report.latencies;
    assert!(lats.median() < 0.5, "median {}s", lats.median());
    // encode times were recorded
    assert_eq!(report.encode_times.len(), 20);
    // wire bytes: K(X/8)^2 = 4*11*11 per decision
    assert_eq!(report.bytes_sent, 20 * 4 * 11 * 11);

    let m = server.metrics.snapshot();
    assert_eq!(m.split.requests, 20);
    assert_eq!(m.full.requests, 0);
    server.shutdown();
}

#[test]
fn server_only_client_streams_raw_frames() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let server = start_server();
    let cfg = ClientConfig { mode: Route::Full, decisions: 10, ..ClientConfig::default() };
    let report = run_client(server.addr, 1, &cfg).expect("client run");
    assert_eq!(report.decisions, 10);
    // raw wire bytes: 4 * 84^2 per decision (the paper's 4X^2)
    assert_eq!(report.bytes_sent, 10 * 4 * 84 * 84);
    let m = server.metrics.snapshot();
    assert_eq!(m.full.requests, 10);
    server.shutdown();
}

#[test]
fn mixed_fleet_batches_and_all_complete() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let server = start_server();
    // 4 split clients, closed loop
    let split_cfg = ClientConfig { mode: Route::Split, decisions: 15, ..ClientConfig::default() };
    let reports = run_fleet(server.addr, 4, &split_cfg).expect("fleet");
    assert_eq!(reports.len(), 4);
    for r in &reports {
        assert_eq!(r.decisions, 15);
    }
    let all = merged_latencies(&reports);
    assert_eq!(all.len(), 60);

    let m = server.metrics.snapshot();
    assert_eq!(m.split.requests, 60);
    // with 4 concurrent clients the batcher should form some multi-item
    // batches (mean batch > 1) — the whole point of dynamic batching
    assert!(m.split.batches < 60, "no batching happened");
    server.shutdown();
}

#[test]
fn shaped_split_latency_beats_raw_at_low_bandwidth() {
    // The paper's core claim (Table 5) at a bandwidth where the 84-scale
    // wire sizes separate: raw = 28 kB/frame vs features = 484 B/frame.
    // At 2 Mb/s raw transmission alone is ~113 ms; split is ~2 ms.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let server = start_server();
    let bw = 2e6; // 2 Mb/s
    let split = run_client(
        server.addr,
        10,
        &ClientConfig {
            mode: Route::Split,
            decisions: 12,
            shape_bps: Some(bw),
            ..ClientConfig::default()
        },
    )
    .expect("split client");
    let raw = run_client(
        server.addr,
        11,
        &ClientConfig {
            mode: Route::Full,
            decisions: 12,
            shape_bps: Some(bw),
            ..ClientConfig::default()
        },
    )
    .expect("raw client");
    let mut s = split.latencies;
    let mut r = raw.latencies;
    assert!(
        s.median() * 3.0 < r.median(),
        "split {}s vs raw {}s at 2 Mb/s",
        s.median(),
        r.median()
    );
    server.shutdown();
}

#[test]
fn fixed_rate_client_honours_rate() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let server = start_server();
    let cfg = ClientConfig {
        mode: Route::Split,
        decisions: 20,
        rate_hz: Some(20.0),
        ..ClientConfig::default()
    };
    let report = run_client(server.addr, 2, &cfg).expect("client");
    // 20 decisions at 20 Hz ≈ 1s; allow generous slack for CI noise
    assert!(report.elapsed > 0.8, "ran too fast: {}s", report.elapsed);
    assert!(report.achieved_hz() < 25.0);
    server.shutdown();
}

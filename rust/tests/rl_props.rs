//! Property tests for the RL primitives the online learning loop leans
//! on: `rl::replay` eviction order under ring wraparound, and
//! `rl::rollout` GAE(λ) boundary semantics — truncation bootstraps the
//! last value, termination suppresses it, and an episode cut never leaks
//! advantage mass across the boundary.

use miniconv::rl::{Replay, Rollout};
use miniconv::util::proptest::{check, prop_assert, Gen};
use miniconv::util::rng::Rng;

// -- replay eviction ---------------------------------------------------------

/// Tag each pushed transition with a unique reward so samples reveal
/// exactly which transitions the ring still holds.
fn fill_replay(cap: usize, pushes: usize) -> Replay {
    let mut rp = Replay::new(cap, 1, 1);
    for i in 0..pushes {
        rp.push(&[0.5], &[0.0], i as f32, &[0.5], false);
    }
    rp
}

/// Drain every distinct reward currently sampleable out of the buffer.
fn sampled_rewards(rp: &Replay, seed: u64, draws: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let (mut obs, mut act, mut nobs) = (vec![0.0f32; 1], vec![0.0f32; 1], vec![0.0f32; 1]);
    let mut rew = vec![0.0f32; 1];
    let mut done = vec![0.0f32; 1];
    let mut seen = Vec::new();
    for _ in 0..draws {
        assert!(rp.sample(&mut rng, 1, &mut obs, &mut act, &mut rew, &mut nobs, &mut done));
        if !seen.contains(&rew[0]) {
            seen.push(rew[0]);
        }
    }
    seen.sort_by(f32::total_cmp);
    seen
}

#[test]
fn prop_replay_evicts_oldest_first() {
    check(60, |g| {
        let cap = g.usize(1, 24);
        let pushes = g.usize(0, 3 * cap);
        let rp = fill_replay(cap, pushes);
        prop_assert(rp.len() == pushes.min(cap), format!("len {} cap {cap}", rp.len()))?;
        if pushes == 0 {
            return Ok(());
        }
        // after wraparound the ring must hold exactly the newest `cap`
        // transitions: rewards [pushes - len, pushes)
        let lo = pushes - rp.len();
        let seen = sampled_rewards(&rp, 7, 64 * cap);
        for &r in &seen {
            prop_assert(
                (r as usize) >= lo && (r as usize) < pushes,
                format!("sampled evicted transition {r} (live range {lo}..{pushes})"),
            )?;
        }
        // with 64·cap draws, missing a live slot is ~(1-1/cap)^(64·cap)
        // ≈ e^-64 — a deterministic seed makes this exact, not flaky
        prop_assert(
            seen.len() == rp.len(),
            format!("sampled {} distinct of {} live", seen.len(), rp.len()),
        )
    });
}

#[test]
fn prop_replay_sample_needs_enough_data() {
    check(40, |g| {
        let cap = g.usize(2, 16);
        let pushes = g.usize(0, cap - 1);
        let rp = fill_replay(cap, pushes);
        let batch = pushes + 1;
        let mut rng = Rng::new(1);
        let (mut obs, mut act, mut nobs) =
            (vec![0.0f32; batch], vec![0.0f32; batch], vec![0.0f32; batch]);
        let mut rew = vec![0.0f32; batch];
        let mut done = vec![0.0f32; batch];
        prop_assert(
            !rp.sample(&mut rng, batch, &mut obs, &mut act, &mut rew, &mut nobs, &mut done),
            "sample must refuse batches larger than the stored count",
        )
    });
}

// -- GAE boundary semantics --------------------------------------------------

/// A random rollout whose final step ends an episode; `terminated`
/// selects MDP termination vs time-limit truncation for that step.
fn arb_final_done_rollout(g: &mut Gen, terminated: bool) -> Rollout {
    let n = g.usize(1, 12);
    let mut r = Rollout::new(n, 1, 1);
    for t in 0..n {
        let last = t == n - 1;
        r.push(
            &[g.f64(0.0, 1.0) as f32],
            &[g.f64(-1.0, 1.0) as f32],
            g.f64(-2.0, 0.0) as f32,
            g.f64(-1.0, 1.0) as f32,
            g.f64(-16.0, 0.0) as f32,
            last,
            last && terminated,
        );
    }
    r
}

/// Clone a rollout's stored tensors (Rollout is plain data).
fn clone_rollout(r: &Rollout) -> Rollout {
    let mut c = Rollout::new(r.capacity, r.obs_len, r.act_len);
    for t in 0..r.len() {
        c.push(
            &r.obs[t..t + 1],
            &r.act[t..t + 1],
            r.logp[t],
            r.value[t],
            r.rew[t],
            r.done[t] > 0.5,
            r.terminated[t] > 0.5,
        );
    }
    c
}

#[test]
fn prop_gae_truncation_bootstraps_termination_does_not() {
    check(120, |g| {
        let gamma = g.f64(0.5, 0.999);
        let lam = g.f64(0.0, 1.0);
        let last_value = g.f64(-5.0, 5.0) as f32;
        // identical rollouts, only the final terminated flag differs
        let trunc = arb_final_done_rollout(g, false);
        let mut term = clone_rollout(&trunc);
        let n = term.len();
        term.terminated[n - 1] = 1.0;
        let (adv_tr, _) = trunc.gae(gamma, lam, last_value);
        let (adv_te, _) = term.gae(gamma, lam, last_value);
        // at the boundary the only difference is the bootstrap term
        let want = gamma * last_value as f64;
        let got = adv_tr[n - 1] as f64 - adv_te[n - 1] as f64;
        prop_assert(
            (got - want).abs() < 1e-4,
            format!("boundary bootstrap: got {got}, want γ·last_v = {want}"),
        )?;
        // the final step is `done` in both runs, so the chain cut stops
        // the bootstrap difference from propagating backwards: every
        // pre-boundary advantage must be bit-identical
        for t in 0..n - 1 {
            prop_assert(
                (adv_tr[t] - adv_te[t]).abs() < 1e-6,
                format!("pre-boundary advantage moved at step {t}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_gae_terminated_boundary_blocks_all_leakage() {
    check(80, |g| {
        let gamma = g.f64(0.5, 0.999);
        let lam = g.f64(0.0, 1.0);
        // episode A (terminated at tc), then episode B with arbitrary data
        let a_len = g.usize(1, 6);
        let b_len = g.usize(1, 6);
        let n = a_len + b_len;
        let mut r = Rollout::new(n, 1, 1);
        for t in 0..a_len {
            let done = t == a_len - 1;
            r.push(&[0.0], &[0.0], 0.0, g.f64(-1.0, 1.0) as f32, -1.0, done, done);
        }
        for _ in 0..b_len {
            let act = g.f64(-1.0, 1.0) as f32;
            let rew = g.f64(-16.0, 0.0) as f32;
            r.push(&[0.0], &[0.0], 0.0, act, rew, false, false);
        }
        let (base, _) = r.gae(gamma, lam, g.f64(-5.0, 5.0) as f32);
        // mutate everything after the terminated boundary: episode A's
        // advantages must not move at all
        let mut m = clone_rollout(&r);
        for t in a_len..n {
            m.rew[t] = g.f64(-16.0, 0.0) as f32;
            m.value[t] = g.f64(-1.0, 1.0) as f32;
        }
        let (mutated, _) = m.gae(gamma, lam, g.f64(-5.0, 5.0) as f32);
        for t in 0..a_len {
            prop_assert(
                (base[t] - mutated[t]).abs() < 1e-6,
                format!("advantage leaked across termination at step {t}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_gae_lambda_zero_is_one_step_td() {
    check(80, |g| {
        let gamma = g.f64(0.5, 0.999);
        let last_value = g.f64(-5.0, 5.0) as f32;
        let r = arb_final_done_rollout(g, g.bool());
        let n = r.len();
        let (adv, ret) = r.gae(gamma, 0.0, last_value);
        for t in 0..n {
            let (next_v, nonterm) = if t == n - 1 {
                (last_value as f64, if r.terminated[t] > 0.5 { 0.0 } else { 1.0 })
            } else {
                (r.value[t + 1] as f64, if r.terminated[t] > 0.5 { 0.0 } else { 1.0 })
            };
            let delta = r.rew[t] as f64 + gamma * next_v * nonterm - r.value[t] as f64;
            prop_assert(
                (adv[t] as f64 - delta).abs() < 1e-4,
                format!("λ=0 advantage at {t}: {} vs TD {delta}", adv[t]),
            )?;
            prop_assert(
                (ret[t] - (adv[t] + r.value[t])).abs() < 1e-5,
                "returns must be advantages + values",
            )?;
        }
        Ok(())
    });
}

//! Cross-module property tests (the proptest-substitute harness from
//! util::proptest): invariants that hold for arbitrary inputs across the
//! coordinator, analysis, replay, JSON, and device layers.

use miniconv::analysis::breakeven::{breakeven_bandwidth_bps, feature_bits, raw_bits};
use miniconv::analysis::latency::DecisionBreakdown;
use miniconv::coordinator::{chunk_batches, pick_batch};
use miniconv::net::framing::{Msg, Payload, Request, Response};
use miniconv::net::shaped::LinkModel;
use miniconv::net::{dequantize_features, quantize_features};
use miniconv::rl::{Replay, Rollout};
use miniconv::util::json::Json;
use miniconv::util::proptest::{check, prop_assert};
use miniconv::util::rng::Rng;

#[test]
fn prop_breakeven_is_the_true_crossover() {
    // Split wins strictly below the analytic break-even and loses above it
    // when server compute and latency are zero (the paper's idealisation).
    check(200, |g| {
        let x = g.usize(32, 1024);
        let k = *g.choice(&[4usize, 16]);
        let j = g.f64(0.005, 0.5);
        let be = breakeven_bandwidth_bps(x, 3, k, j);
        if be <= 0.0 {
            return Ok(());
        }
        for (factor, split_should_win) in [(0.8, true), (1.25, false)] {
            let link = LinkModel::new(be * factor, 0.0);
            let so = DecisionBreakdown::server_only(&link, x, 0.0, 0);
            let sp = DecisionBreakdown::split(&link, x, 3, k, j, 0.0, 0);
            // analytic bits model uses ceil'd feature sides; allow epsilon
            let wins = sp.total() < so.total() + 1e-9;
            if wins != split_should_win {
                // tolerance: ceil() in feature size perturbs the crossover
                let rel = (sp.total() - so.total()).abs() / so.total().max(1e-9);
                prop_assert(rel < 0.08, format!("x={x} k={k} j={j} f={factor} rel={rel}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wire_bytes_match_paper_model() {
    check(100, |g| {
        let x = g.usize(8, 512);
        let raw = Payload::RawRgba { x: x as u16, data: vec![0; 4 * x * x] };
        prop_assert(
            raw.wire_bytes() * 8 == raw_bits(x) as usize,
            "raw bits mismatch",
        )?;
        let s = x.div_ceil(8);
        let k = *g.choice(&[4usize, 16]);
        let feat = Payload::Features {
            c: k as u16,
            h: s as u16,
            w: s as u16,
            scale: 1.0,
            data: vec![0; k * s * s],
        };
        prop_assert(
            feat.wire_bytes() * 8 == feature_bits(x, 3, k) as usize,
            "feature bits mismatch",
        )
    });
}

#[test]
fn prop_framing_roundtrips_arbitrary_messages() {
    check(300, |g| {
        let msg = match g.usize(0, 2) {
            0 => {
                let x = g.usize(1, 64);
                let mut data = vec![0u8; 4 * x * x];
                for b in data.iter_mut() {
                    *b = g.usize(0, 255) as u8;
                }
                Msg::Request(Request {
                    client: g.u64(0, u32::MAX as u64) as u32,
                    id: g.u64(0, u64::MAX - 1),
                    payload: Payload::RawRgba { x: x as u16, data },
                })
            }
            1 => {
                let (c, h, w) = (g.usize(1, 8), g.usize(1, 16), g.usize(1, 16));
                Msg::Request(Request {
                    client: 7,
                    id: g.u64(0, 1 << 40),
                    payload: Payload::Features {
                        c: c as u16,
                        h: h as u16,
                        w: w as u16,
                        scale: g.f64(1e-6, 100.0) as f32,
                        data: vec![9; c * h * w],
                    },
                })
            }
            _ => {
                let n = g.usize(0, 16);
                Msg::Response(Response {
                    client: 1,
                    id: 2,
                    action: (0..n).map(|_| g.f64(-10.0, 10.0) as f32).collect(),
                })
            }
        };
        let enc = msg.encode();
        let dec = Msg::decode(&enc[4..]).map_err(|e| e.to_string())?;
        prop_assert(dec == msg, "roundtrip mismatch")
    });
}

#[test]
fn prop_quantization_error_bounded_by_half_step() {
    check(200, |g| {
        let n = g.usize(1, 256);
        let scale_hint = g.f64(0.01, 50.0);
        let feat: Vec<f32> = (0..n).map(|_| g.f64(0.0, scale_hint) as f32).collect();
        let (scale, q) = quantize_features(&feat);
        let back = dequantize_features(scale, &q);
        for (a, b) in feat.iter().zip(&back) {
            prop_assert(
                (a - b).abs() <= scale / 255.0 * 0.5 + 1e-6,
                format!("{a} vs {b} (scale {scale})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_batch_ladder_covers_and_bounds_waste() {
    check(300, |g| {
        // arbitrary ascending ladders that include 1
        let mut ladder = vec![1usize];
        let mut v = 1;
        for _ in 0..g.usize(0, 6) {
            v *= g.usize(2, 3);
            ladder.push(v);
        }
        let n = g.usize(1, 200);
        let b = pick_batch(n, &ladder);
        prop_assert(b >= n.min(*ladder.last().unwrap()), "pick too small")?;
        let chunks = chunk_batches(n, &ladder);
        let total: usize = chunks.iter().sum();
        prop_assert(total >= n, "chunks don't cover")?;
        prop_assert(total <= 3 * n, format!("waste too high: {n} -> {chunks:?}"))
    });
}

#[test]
fn prop_json_roundtrips_arbitrary_trees() {
    fn gen_value(g: &mut miniconv::util::proptest::Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize(0, 3) } else { g.usize(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}-\"q\"\n", g.usize(0, 999))),
            4 => Json::Arr((0..g.usize(0, 4)).map(|_| gen_value(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize(0, 4))
                    .map(|i| (format!("k{i}"), gen_value(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(300, |g| {
        let v = gen_value(g, 3);
        let compact = v.to_string();
        let pretty = v.to_string_pretty();
        let a = Json::parse(&compact).map_err(|e| e.to_string())?;
        let b = Json::parse(&pretty).map_err(|e| e.to_string())?;
        prop_assert(a == v && b == v, "json roundtrip mismatch")
    });
}

#[test]
fn prop_replay_never_yields_unpushed_data() {
    check(100, |g| {
        let obs_len = g.usize(1, 16);
        let cap = g.usize(2, 32);
        let mut r = Replay::new(cap, obs_len, 1);
        let n_push = g.usize(2, 64);
        for i in 0..n_push {
            let v = (i % 200) as f32 / 255.0;
            r.push(&vec![v; obs_len], &[i as f32], i as f32, &vec![v; obs_len], false);
        }
        let mut rng = Rng::new(g.u64(0, 1 << 40));
        let batch = 2;
        let mut obs = vec![0.0; batch * obs_len];
        let (mut act, mut rew, mut nobs, mut done) =
            (vec![0.0; batch], vec![0.0; batch], vec![0.0; batch * obs_len], vec![0.0; batch]);
        if r.sample(&mut rng, batch, &mut obs, &mut act, &mut rew, &mut nobs, &mut done) {
            for &a in &act {
                let idx = a as usize;
                prop_assert(idx < n_push, format!("phantom transition {idx}"))?;
                // ring semantics: only the last `cap` transitions survive
                prop_assert(
                    idx + cap >= n_push,
                    format!("stale transition {idx} (cap {cap}, pushed {n_push})"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gae_zero_lambda_is_td_error() {
    check(100, |g| {
        let n = g.usize(1, 20);
        let gamma = g.f64(0.5, 0.999);
        let mut r = Rollout::new(n, 1, 1);
        let mut rewards = Vec::new();
        let mut values = Vec::new();
        for _ in 0..n {
            let rew = g.f64(-1.0, 1.0) as f32;
            let val = g.f64(-1.0, 1.0) as f32;
            rewards.push(rew);
            values.push(val);
            r.push(&[0.0], &[0.0], 0.0, val, rew, false, false);
        }
        let last_v = g.f64(-1.0, 1.0) as f32;
        let (adv, _) = r.gae(gamma, 0.0, last_v);
        for t in 0..n {
            let next_v = if t == n - 1 { last_v } else { values[t + 1] };
            let delta = rewards[t] as f64 + gamma * next_v as f64 - values[t] as f64;
            prop_assert(
                (adv[t] as f64 - delta).abs() < 1e-4,
                format!("t={t}: {} vs {delta}", adv[t]),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_device_monotonic_in_input_size() {
    use miniconv::device::{Device, ExecPath};
    use miniconv::experiments::execution::frame_cost;
    check(40, |g| {
        let x1 = g.usize(64, 512);
        let x2 = x1 * 2;
        let spec = match g.usize(0, 2) {
            0 => miniconv::device::pi_zero_2w(),
            1 => miniconv::device::pi_4b(),
            _ => miniconv::device::jetson_nano(None),
        };
        let mut d = Device::new(spec, g.u64(0, 1000));
        let mean = |d: &mut Device, x: usize| {
            let c = frame_cost(x);
            (0..20).map(|_| d.encode_frame(&c, ExecPath::Gpu).duration).sum::<f64>() / 20.0
        };
        let t1 = mean(&mut d, x1);
        let t2 = mean(&mut d, x2);
        prop_assert(t2 > t1 * 1.5, format!("x={x1}->{x2}: {t1} -> {t2}"))
    });
}

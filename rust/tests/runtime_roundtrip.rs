//! Integration: the Rust runtime loads real AOT artifacts, executes them on
//! the PJRT CPU client, and the split pipeline (enc -> head) matches the
//! monolithic policy — the core split-policy invariant, now across the
//! python/rust boundary.
//!
//! Requires `make artifacts` to have run (skipped otherwise).

use miniconv::runtime::{Runtime, Value};

fn runtime() -> Option<Runtime> {
    let dir = miniconv::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {}", dir.display());
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn ramp(n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|i| ((i % 97) as f32 / 97.0) * scale).collect()
}

#[test]
fn encoder_executes_and_reports_feature_shape() {
    let Some(rt) = runtime() else { return };
    let name = rt.manifest.serve_encoder("miniconv4");
    let exe = rt.load(&name).expect("compile");
    let p_len = exe.spec.inputs[0].elems();
    let params = rt.manifest.load_params("serve_enc_miniconv4").unwrap();
    assert_eq!(params.len(), p_len);

    let x = rt.manifest.serve_x;
    let obs = Value::f32(&[1, 9, x, x], ramp(9 * x * x, 1.0));
    let out = exe
        .run(&[&Value::f32(&[p_len], params), &obs])
        .expect("execute");
    assert_eq!(out.len(), 1);
    let s = x.div_ceil(8);
    assert_eq!(out[0].shape(), &[1, 4, s, s]);
    // post-ReLU features are non-negative (what makes u8 wire quantisation work)
    assert!(out[0].as_f32().unwrap().iter().all(|&v| v >= 0.0));
}

#[test]
fn split_pipeline_matches_between_batch_sizes() {
    // head_b1(feat) must equal row 0 of head_b4([feat; pad]) — the batcher
    // relies on padded batches being consistent.
    let Some(rt) = runtime() else { return };
    let head1 = rt.load(&rt.manifest.serve_head("miniconv4", 1)).unwrap();
    let head4 = rt.load(&rt.manifest.serve_head("miniconv4", 4)).unwrap();
    let p_len = head1.spec.inputs[0].elems();
    let params = Value::f32(&[p_len], rt.manifest.load_params("serve_head_miniconv4").unwrap());

    let feat_shape = &head1.spec.inputs[1].shape;
    let n_feat: usize = feat_shape[1..].iter().product();
    let feat = ramp(n_feat, 0.5);

    let out1 = head1
        .run(&[&params, &Value::f32(feat_shape, feat.clone())])
        .unwrap();
    let mut batched = feat.clone();
    batched.extend(vec![0.0; n_feat * 3]);
    let mut shape4 = feat_shape.clone();
    shape4[0] = 4;
    let out4 = head4.run(&[&params, &Value::f32(&shape4, batched)]).unwrap();

    let a1 = out1[0].as_f32().unwrap();
    let a4 = out4[0].as_f32().unwrap();
    let adim = a1.len();
    for i in 0..adim {
        assert!(
            (a1[i] - a4[i]).abs() < 1e-5,
            "batch-1 vs batch-4 row0 mismatch: {} vs {}",
            a1[i],
            a4[i]
        );
    }
}

#[test]
fn full_policy_bounded_actions() {
    let Some(rt) = runtime() else { return };
    let full = rt.load(&rt.manifest.serve_full(2)).unwrap();
    let p_len = full.spec.inputs[0].elems();
    let params = Value::f32(&[p_len], rt.manifest.load_params("serve_full_fullcnn").unwrap());
    let x = rt.manifest.serve_x;
    let obs = Value::f32(&[2, 9, x, x], ramp(2 * 9 * x * x, 1.0));
    let out = full.run(&[&params, &obs]).unwrap();
    // pendulum serving actor: |a| <= max_action = 2.0
    for &a in out[0].as_f32().unwrap() {
        assert!(a.abs() <= 2.0 + 1e-5, "action {a} out of bounds");
    }
}

#[test]
fn device_resident_params_match_host_path() {
    let Some(rt) = runtime() else { return };
    let name = rt.manifest.serve_head("miniconv4", 1);
    let exe = rt.load(&name).unwrap();
    let p_len = exe.spec.inputs[0].elems();
    let params = Value::f32(&[p_len], rt.manifest.load_params("serve_head_miniconv4").unwrap());
    let feat_shape = exe.spec.inputs[1].shape.clone();
    let feat = Value::f32(&feat_shape, ramp(feat_shape.iter().product(), 0.3));

    let host = exe.run(&[&params, &feat]).unwrap();
    let dp = rt.to_device(&params).unwrap();
    let df = rt.to_device(&feat).unwrap();
    let dev = exe.run_device(&[&dp, &df]).unwrap();
    let (h, d) = (host[0].as_f32().unwrap(), dev[0].as_f32().unwrap());
    for (a, b) in h.iter().zip(d) {
        assert!((a - b).abs() < 1e-6);
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(rt) = runtime() else { return };
    let name = rt.manifest.serve_head("miniconv4", 1);
    let a = rt.load(&name).unwrap();
    let n = rt.compiled_count();
    let b = rt.load(&name).unwrap();
    assert_eq!(rt.compiled_count(), n);
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}

#[test]
fn input_validation_rejects_wrong_shapes() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load(&rt.manifest.serve_head("miniconv4", 1)).unwrap();
    let bad = Value::f32(&[3], vec![0.0; 3]);
    let err = exe.run(&[&bad, &bad]).unwrap_err().to_string();
    assert!(err.contains("expects"), "{err}");
    let one = Value::f32(&[1], vec![0.0]);
    assert!(exe.run(&[&one]).is_err()); // arity
}

#[test]
fn ddpg_update_step_runs_and_increments_step() {
    // Execute a real training artifact once with zero batches: verifies the
    // full 14-input/11-output signature decoding.
    let Some(rt) = runtime() else { return };
    let ts = rt.manifest.trainstates.get("pendulum_miniconv4").unwrap().clone();
    let exe = rt.load(&ts.artifacts["update"]).unwrap();

    let mut inputs: Vec<Value> = Vec::new();
    for s in &ts.state {
        match s.dtype {
            miniconv::runtime::DType::F32 => {
                let data = match &s.file {
                    Some(_) => rt
                        .manifest
                        .load_params(&format!("{}_{}", ts.name, s.name))
                        .unwrap(),
                    None => vec![0.0; s.shape.iter().product()],
                };
                inputs.push(Value::f32(&s.shape, data));
            }
            miniconv::runtime::DType::I32 => inputs.push(Value::scalar_i32(0)),
        }
    }
    let b = ts.batch;
    let x = ts.x;
    for name in &ts.batch_inputs {
        let v = match name.as_str() {
            "obs" | "nobs" => Value::f32(&[b, 9, x, x], ramp(b * 9 * x * x, 1.0)),
            "act" => Value::f32(&[b, ts.action_dim], vec![0.1; b * ts.action_dim]),
            "rew" | "done" => Value::f32(&[b], vec![0.0; b]),
            other => panic!("unexpected batch input {other}"),
        };
        inputs.push(v);
    }
    let refs: Vec<&Value> = inputs.iter().collect();
    let out = exe.run(&refs).expect("update step");
    assert_eq!(out.len(), ts.state.len() + ts.metrics.len());
    // step incremented to 1
    let step_idx = ts.state.iter().position(|s| s.name == "step").unwrap();
    assert_eq!(out[step_idx].as_i32().unwrap()[0], 1);
    // metrics are finite scalars
    for m in &out[ts.state.len()..] {
        assert!(m.scalar().unwrap().is_finite());
    }
}

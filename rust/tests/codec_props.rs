//! Property tests for the adaptive feature codec (`miniconv::codec`):
//! encode/decode round-trip bit-exactness at every quantisation level,
//! the flat-path oracle at qmax 255, payload-size bounds on constant and
//! slowly-varying streams, and corrupt/truncated payload rejection
//! without panics.

use miniconv::codec::{
    self, Decoder, Decoders, Encoder, CODEC_DELTA, FLAG_KEYFRAME,
};
use miniconv::net::framing::{FeatureFrame, Msg, Payload, Request};
use miniconv::util::proptest::{check, prop_assert, Gen};

const QMAX_LADDER: [u8; 4] = [255, 127, 63, 31];

/// A random "feature stream": frame 0 is arbitrary, later frames perturb
/// a random subset of values — the slowly-varying shape split features
/// actually have.
fn arb_stream(g: &mut Gen, frames: usize, n: usize, churn: f64) -> Vec<Vec<f32>> {
    let mut cur: Vec<f32> = (0..n).map(|_| g.f64(0.0, 4.0) as f32).collect();
    let mut out = vec![cur.clone()];
    for _ in 1..frames {
        let changes = ((n as f64 * churn) as usize).max(1);
        for _ in 0..changes {
            let i = g.usize(0, n - 1);
            cur[i] = g.f64(0.0, 4.0) as f32;
        }
        out.push(cur.clone());
    }
    out
}

#[test]
fn prop_roundtrip_is_bit_exact_at_every_quant_level() {
    check(60, |g| {
        let n = g.usize(1, 400);
        let frames = g.usize(1, 8);
        let stream = arb_stream(g, frames, n, 0.1);
        let qmax = *g.choice(&QMAX_LADDER);
        let mut enc = Encoder::new();
        let mut dec = Decoder::new();
        let mut qbuf = Vec::new();
        let mut wire = Vec::new();
        for f in &stream {
            let scale = codec::quantize_into(f, qmax, &mut qbuf);
            let (flags, seq) = enc.encode_into(&qbuf, &mut wire);
            prop_assert(
                wire.len() <= n,
                format!("payload {} exceeded flat frame {n}", wire.len()),
            )?;
            dec.apply(flags, qmax, seq, n, &wire)
                .map_err(|e| format!("apply failed: {e}"))?;
            prop_assert(dec.frame() == qbuf.as_slice(), "reconstruction not bit-exact")?;
            // dequantisation error bounded by half a quant step
            let mut back = vec![0.0f32; n];
            codec::dequantize_into(scale, qmax, dec.frame(), &mut back);
            let step = scale / qmax as f32;
            for (a, b) in f.iter().zip(&back) {
                prop_assert(
                    (a - b).abs() <= step * 0.5 + scale * 1e-6,
                    format!("qmax {qmax}: |{a} - {b}| > half step"),
                )?;
            }
        }
        Ok(())
    });
}

/// The acceptance oracle: at qmax 255 the codec's quantise → wire →
/// reconstruct → dequantise pipeline is bit-identical to the flat v1 path
/// (`quantize_features` + `dequantize_features_into`) on every frame.
#[test]
fn prop_qmax_255_is_bit_exact_with_the_flat_path() {
    check(60, |g| {
        let n = g.usize(1, 300);
        let stream = arb_stream(g, g.usize(1, 6), n, 0.2);
        let mut enc = Encoder::new();
        let mut dec = Decoder::new();
        let mut qbuf = Vec::new();
        let mut wire = Vec::new();
        for f in &stream {
            let (flat_scale, flat_q) = miniconv::net::quantize_features(f);
            let scale = codec::quantize_into(f, 255, &mut qbuf);
            prop_assert(scale.to_bits() == flat_scale.to_bits(), "scale diverged")?;
            prop_assert(qbuf == flat_q, "quantised bytes diverged from the flat path")?;
            let (flags, seq) = enc.encode_into(&qbuf, &mut wire);
            dec.apply(flags, 255, seq, n, &wire)
                .map_err(|e| format!("apply: {e}"))?;
            prop_assert(dec.frame() == flat_q.as_slice(), "wire round trip diverged")?;
            let mut via_codec = vec![0.0f32; n];
            let mut via_flat = vec![0.0f32; n];
            codec::dequantize_into(scale, 255, dec.frame(), &mut via_codec);
            miniconv::net::dequantize_features_into(flat_scale, &flat_q, &mut via_flat);
            for (a, b) in via_codec.iter().zip(&via_flat) {
                prop_assert(a.to_bits() == b.to_bits(), "dequantised floats diverged")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_codec_frames_survive_the_wire_protocol() {
    check(80, |g| {
        let n = g.usize(1, 200);
        let stream = arb_stream(g, 2, n, 0.1);
        let qmax = *g.choice(&QMAX_LADDER);
        let mut enc = Encoder::new();
        let mut qbuf = Vec::new();
        for (i, f) in stream.iter().enumerate() {
            let mut data = Vec::new();
            let scale = codec::quantize_into(f, qmax, &mut qbuf);
            let (flags, seq) = enc.encode_into(&qbuf, &mut data);
            let msg = Msg::Request(Request {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: i as u64,
                payload: Payload::FeaturesV2(FeatureFrame {
                    c: 1,
                    h: 1,
                    w: n as u16,
                    codec: CODEC_DELTA,
                    flags,
                    qmax,
                    seq,
                    scale,
                    data,
                }),
            });
            let encd = msg.encode();
            let back = Msg::decode(&encd[4..]).map_err(|e| format!("decode: {e}"))?;
            prop_assert(back == msg, "codec frame mutated on the wire")?;
        }
        Ok(())
    });
}

/// Corrupt or truncated payloads must be rejected with an error — never a
/// panic, never a silent half-decode — and the chain must recover with a
/// keyframe.
#[test]
fn prop_corrupt_payloads_are_rejected_without_panic() {
    check(120, |g| {
        let n = g.usize(8, 300);
        let stream = arb_stream(g, 3, n, 0.05);
        let qmax = *g.choice(&QMAX_LADDER);
        let mut enc = Encoder::new();
        let mut dec = Decoder::new();
        let mut qbuf = Vec::new();
        let mut wire = Vec::new();
        // prime the chain with the first two frames
        for f in &stream[..2] {
            codec::quantize_into(f, qmax, &mut qbuf);
            let (flags, seq) = enc.encode_into(&qbuf, &mut wire);
            dec.apply(flags, qmax, seq, n, &wire)
                .map_err(|e| format!("prime: {e}"))?;
        }
        // mangle frame 3
        codec::quantize_into(&stream[2], qmax, &mut qbuf);
        let (flags, seq) = enc.encode_into(&qbuf, &mut wire);
        let mut bent = wire.clone();
        let verdict = match g.usize(0, 2) {
            0 if !bent.is_empty() => {
                // truncate
                let cut = g.usize(0, bent.len() - 1);
                bent.truncate(cut);
                dec.apply(flags, qmax, seq, n, &bent)
            }
            1 => {
                // append garbage
                bent.push(g.usize(0, 255) as u8);
                dec.apply(flags, qmax, seq, n, &bent)
            }
            _ => {
                // wrong sequence number: a lost frame in the chain
                dec.apply(flags, qmax, seq.wrapping_add(1 + g.u64(0, 100) as u32), n, &bent)
            }
        };
        match verdict {
            Err(_) => {
                // after any rejection, the true delta is also refused (the
                // base is poisoned) until a keyframe re-primes the chain
                if flags & FLAG_KEYFRAME == 0 {
                    prop_assert(
                        dec.apply(flags, qmax, seq, n, &wire).is_err(),
                        "poisoned chain accepted a delta",
                    )?;
                }
                enc.force_keyframe();
                codec::quantize_into(&stream[2], qmax, &mut qbuf);
                let (kf, ks) = enc.encode_into(&qbuf, &mut wire);
                dec.apply(kf, qmax, ks, n, &wire)
                    .map_err(|e| format!("keyframe recovery: {e}"))?;
                prop_assert(dec.frame() == qbuf.as_slice(), "recovery frame diverged")
            }
            Ok(()) => {
                // a mangling that happens to decode must still be exact for
                // keyframes (raw keyframes at unchanged length, or the
                // wrong-seq case, which keyframes ignore by design)
                Ok(())
            }
        }
    });
}

/// Random byte soup into the unpacker: errors allowed, panics not.
#[test]
fn prop_unpack_never_panics_on_garbage() {
    check(300, |g| {
        let n = g.usize(0, 128);
        let soup: Vec<u8> = (0..g.usize(0, 64)).map(|_| g.usize(0, 255) as u8).collect();
        let mut base = vec![0u8; n];
        let _ = codec::pack::unpack_residuals_into(&soup, &mut base, *g.choice(&QMAX_LADDER));
        let mut dec = Decoder::new();
        let flags = g.usize(0, 3) as u8;
        let _ = dec.apply(flags, 255, g.u64(0, u32::MAX as u64) as u32, n, &soup);
        Ok(())
    });
}

/// Compression bound: on constant and slowly-varying streams the wire
/// payload stays at or below the flat size on EVERY frame, and the mean
/// over the stream is strictly smaller once deltas flow.
#[test]
fn prop_compressed_size_bounded_on_smooth_streams() {
    check(60, |g| {
        let n = g.usize(64, 512);
        let frames = g.usize(4, 12);
        // churn ≤ 2% of values per frame: "slowly varying"
        let stream = arb_stream(g, frames, n, 0.02);
        let qmax = *g.choice(&QMAX_LADDER);
        let mut enc = Encoder::new();
        let mut qbuf = Vec::new();
        let mut wire = Vec::new();
        let mut total = 0usize;
        for f in &stream {
            codec::quantize_into(f, qmax, &mut qbuf);
            enc.encode_into(&qbuf, &mut wire);
            prop_assert(
                wire.len() <= n,
                format!("frame cost {} > flat {n}", wire.len()),
            )?;
            total += wire.len();
        }
        prop_assert(
            total < frames * n,
            format!("stream cost {total} not below flat {}", frames * n),
        )?;
        // constant stream: mask-only deltas
        let constant = vec![stream[0].clone(); 6];
        let mut enc = Encoder::new();
        let mut total_const = 0usize;
        for f in &constant {
            codec::quantize_into(f, qmax, &mut qbuf);
            enc.encode_into(&qbuf, &mut wire);
            total_const += wire.len();
        }
        let mask_bytes = n.div_ceil(codec::BLOCK).div_ceil(8);
        prop_assert(
            total_const <= n + 5 * mask_bytes,
            format!("constant stream cost {total_const} (n={n})"),
        )
    });
}

/// The serving-side `Decoders` map isolates sessions: two interleaved
/// chains never contaminate each other.
#[test]
fn prop_sessions_are_isolated_in_the_decoder_map() {
    check(40, |g| {
        let n = g.usize(16, 128);
        let a = arb_stream(g, 4, n, 0.1);
        let b = arb_stream(g, 4, n, 0.1);
        let mut enc_a = Encoder::new();
        let mut enc_b = Encoder::new();
        let mut decs = Decoders::new();
        let mut qbuf = Vec::new();
        for (fa, fb) in a.iter().zip(&b) {
            for (client, enc, f) in [(1u32, &mut enc_a, fa), (2u32, &mut enc_b, fb)] {
                let mut data = Vec::new();
                let scale = codec::quantize_into(f, 255, &mut qbuf);
                let (flags, seq) = enc.encode_into(&qbuf, &mut data);
                let frame = FeatureFrame {
                    c: 1,
                    h: 1,
                    w: n as u16,
                    codec: CODEC_DELTA,
                    flags,
                    qmax: 255,
                    seq,
                    scale,
                    data,
                };
                let mut row = vec![0.0f32; n];
                decs.decode_into(client, &frame, &mut row)
                    .map_err(|e| format!("client {client}: {e}"))?;
                prop_assert(
                    decs.frame(client) == Some(qbuf.as_slice()),
                    format!("client {client} frame diverged"),
                )?;
            }
        }
        Ok(())
    });
}

//! Property tests for the wire protocol (`net::framing` / `net::tcp`):
//! encode/decode round-trips over arbitrary messages, the quantisation
//! error bound, frame-length invariants, and oversized-frame rejection —
//! plus the shaped-link (`net::shaped`) conservation/liveness properties,
//! driven under the virtual clock so arbitrary write schedules run in
//! microseconds with zero real sleeps.

use std::io::Write;

use miniconv::net::framing::{
    ErrorMsg, ExperienceFrame, FeatureFrame, Hello, Msg, Payload, PolicySync, Request, Response,
    ResponseLearn, ResponseV2, MAX_FRAME,
};
use miniconv::net::tcp::{read_msg, write_msg};
use miniconv::net::{dequantize_features, quantize_features, ShapedWriter, TokenBucket};
use miniconv::sim::{Clock, SimClock};
use miniconv::util::proptest::{check, prop_assert, Gen};

/// Draw an arbitrary message of any variant.
fn arb_msg(g: &mut Gen) -> Msg {
    match g.usize(0, 9) {
        0 => {
            let shard = if g.bool() { Some(g.usize(0, u16::MAX as usize) as u16) } else { None };
            Msg::Hello(Hello {
                client: g.u64(0, u32::MAX as u64) as u32,
                split: g.bool(),
                codec: g.usize(0, 1) as u8,
                caps: g.usize(0, 1) as u8,
                shard,
            })
        }
        1 => {
            let x = g.usize(1, 12) as u16;
            let data = (0..4 * x as usize * x as usize)
                .map(|_| g.usize(0, 255) as u8)
                .collect();
            Msg::Request(Request {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: g.u64(0, u64::MAX - 1),
                payload: Payload::RawRgba { x, data },
            })
        }
        2 => {
            let (c, h, w) = (g.usize(1, 6), g.usize(1, 8), g.usize(1, 8));
            let data = (0..c * h * w).map(|_| g.usize(0, 255) as u8).collect();
            Msg::Request(Request {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: g.u64(0, 1 << 40),
                payload: Payload::Features {
                    c: c as u16,
                    h: h as u16,
                    w: w as u16,
                    scale: g.f64(1e-6, 100.0) as f32,
                    data,
                },
            })
        }
        3 => {
            // codec frame: the payload is opaque to the framing layer, but
            // its length must respect the ≤ flat-frame bound the decoder
            // enforces
            let (c, h, w) = (g.usize(1, 4), g.usize(1, 8), g.usize(1, 8));
            let dlen = g.usize(0, c * h * w);
            Msg::Request(Request {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: g.u64(0, 1 << 40),
                payload: Payload::FeaturesV2(FeatureFrame {
                    c: c as u16,
                    h: h as u16,
                    w: w as u16,
                    codec: g.usize(0, 1) as u8,
                    flags: g.usize(0, 3) as u8,
                    qmax: g.usize(1, 255) as u8,
                    seq: g.u64(0, u32::MAX as u64) as u32,
                    scale: g.f64(1e-6, 100.0) as f32,
                    data: (0..dlen).map(|_| g.usize(0, 255) as u8).collect(),
                }),
            })
        }
        4 => {
            let n = g.usize(0, 8);
            Msg::ResponseV2(ResponseV2 {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: g.u64(0, 1 << 40),
                seq: g.u64(0, u32::MAX as u64) as u32,
                flags: g.usize(0, 1) as u8,
                queue_wait_us: g.u64(0, u32::MAX as u64) as u32,
                action: (0..n).map(|_| g.f64(-10.0, 10.0) as f32).collect(),
            })
        }
        5 => {
            let n = g.usize(0, 8);
            Msg::Response(Response {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: g.u64(0, 1 << 40),
                action: (0..n).map(|_| g.f64(-10.0, 10.0) as f32).collect(),
            })
        }
        6 => {
            // experience frame: a codec feature frame plus the episode
            // cursor and reward flags of the online-learning extension
            let (c, h, w) = (g.usize(1, 4), g.usize(1, 4), g.usize(1, 4));
            let dlen = g.usize(0, c * h * w);
            Msg::Request(Request {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: g.u64(0, 1 << 40),
                payload: Payload::Experience(ExperienceFrame {
                    feat: FeatureFrame {
                        c: c as u16,
                        h: h as u16,
                        w: w as u16,
                        codec: g.usize(0, 1) as u8,
                        flags: g.usize(0, 3) as u8,
                        qmax: g.usize(1, 255) as u8,
                        seq: g.u64(0, u32::MAX as u64) as u32,
                        scale: g.f64(1e-6, 100.0) as f32,
                        data: (0..dlen).map(|_| g.usize(0, 255) as u8).collect(),
                    },
                    ep: g.u64(0, u32::MAX as u64) as u32,
                    step: g.u64(0, u32::MAX as u64) as u32,
                    flags: g.usize(0, 15) as u8,
                    reward: g.f64(-20.0, 0.0) as f32,
                }),
            })
        }
        7 => {
            let n = g.usize(0, 8);
            Msg::ResponseLearn(ResponseLearn {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: g.u64(0, 1 << 40),
                seq: g.u64(0, u32::MAX as u64) as u32,
                flags: g.usize(0, 3) as u8,
                acting_version: g.u64(0, 1 << 40),
                latest_version: g.u64(0, 1 << 40),
                action: (0..n).map(|_| g.f64(-10.0, 10.0) as f32).collect(),
            })
        }
        8 => {
            let n = g.usize(0, 32);
            Msg::Policy(PolicySync {
                version: g.u64(0, 1 << 40),
                params: (0..n).map(|_| g.f64(-2.0, 2.0) as f32).collect(),
            })
        }
        _ => {
            let n = g.usize(0, 40);
            Msg::Error(ErrorMsg {
                client: g.u64(0, u32::MAX as u64) as u32,
                code: g.usize(0, 255) as u8,
                detail: (0..n).map(|_| char::from(g.usize(97, 122) as u8)).collect(),
            })
        }
    }
}

#[test]
fn prop_every_msg_variant_roundtrips() {
    check(300, |g| {
        let msg = arb_msg(g);
        let enc = msg.encode();
        let dec = Msg::decode(&enc[4..]).map_err(|e| format!("decode failed: {e}"))?;
        prop_assert(dec == msg, format!("roundtrip changed the message: {msg:?}"))
    });
}

#[test]
fn prop_length_prefix_matches_frame_body() {
    check(300, |g| {
        let enc = arb_msg(g).encode();
        let len = u32::from_le_bytes(enc[0..4].try_into().unwrap()) as usize;
        prop_assert(len == enc.len() - 4, format!("prefix {len} != body {}", enc.len() - 4))?;
        prop_assert(len <= MAX_FRAME, "frame exceeds MAX_FRAME")
    });
}

#[test]
fn prop_truncated_frames_are_rejected() {
    check(200, |g| {
        let enc = arb_msg(g).encode();
        let body = &enc[4..];
        if body.len() <= 1 {
            return Ok(());
        }
        let cut = g.usize(1, body.len() - 1);
        prop_assert(
            Msg::decode(&body[..cut]).is_err(),
            format!("decode accepted a {cut}-byte truncation of {} bytes", body.len()),
        )
    });
}

#[test]
fn prop_trailing_garbage_is_rejected() {
    check(200, |g| {
        let enc = arb_msg(g).encode();
        let mut body = enc[4..].to_vec();
        body.push(g.usize(0, 255) as u8);
        prop_assert(Msg::decode(&body).is_err(), "decode accepted trailing bytes")
    });
}

#[test]
fn prop_transport_rejects_frames_above_max_frame() {
    check(100, |g| {
        // forge a header claiming an oversized (or zero) body
        let len = if g.bool() {
            g.u64(MAX_FRAME as u64 + 1, u32::MAX as u64) as u32
        } else {
            0
        };
        let mut wire = Vec::new();
        wire.extend_from_slice(&len.to_le_bytes());
        wire.push(1);
        let mut cursor = std::io::Cursor::new(wire);
        prop_assert(
            read_msg(&mut cursor).is_err(),
            format!("transport accepted a frame of claimed length {len}"),
        )
    });
}

#[test]
fn prop_transport_roundtrips_message_streams() {
    check(60, |g| {
        let n = g.usize(1, 6);
        let msgs: Vec<Msg> = (0..n).map(|_| arb_msg(g)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            write_msg(&mut wire, m).map_err(|e| format!("write: {e}"))?;
        }
        let mut cursor = std::io::Cursor::new(wire);
        for (i, m) in msgs.iter().enumerate() {
            let got = read_msg(&mut cursor)
                .map_err(|e| format!("read {i}: {e}"))?
                .ok_or_else(|| format!("early EOF at {i}"))?;
            prop_assert(&got == m, format!("message {i} mutated in transit"))?;
        }
        prop_assert(
            read_msg(&mut cursor).map_err(|e| e.to_string())?.is_none(),
            "stream did not end cleanly",
        )
    });
}

#[test]
fn prop_quantization_error_within_half_step_of_scale() {
    check(300, |g| {
        let n = g.usize(1, 256);
        // post-ReLU features: non-negative, arbitrary magnitude
        let mag = g.f64(1e-4, 1e4);
        let feat: Vec<f32> = (0..n).map(|_| g.f64(0.0, mag) as f32).collect();
        let (scale, q) = quantize_features(&feat);
        prop_assert(scale > 0.0, "scale must be positive")?;
        let back = dequantize_features(scale, &q);
        prop_assert(back.len() == feat.len(), "length changed")?;
        let step = scale / 255.0;
        for (a, b) in feat.iter().zip(&back) {
            let err = (a - b).abs();
            prop_assert(
                err <= step * 0.5 + scale * 1e-6,
                format!("|{a} - {b}| = {err} > half step {}", step * 0.5),
            )?;
        }
        Ok(())
    });
}

#[test]
fn token_bucket_oversized_demand_terminates() {
    // Regression for the starvation edge: `delay_for(n)` with
    // n > burst_bytes could never be satisfied after refill capping, so a
    // delay/sleep/retry loop (ShapedWriter's write loop) spun forever.
    // The demand now clamps to the bucket depth: the wait is bounded.
    let clock = SimClock::new();
    let mut bucket = TokenBucket::new_at(8_000.0, 100, clock.instant_at(0.0));
    bucket.consume(100); // empty
    let n = 5_000; // 50x the bucket depth
    let mut waits = 0;
    loop {
        let d = bucket.delay_for(n, clock.now());
        if d.is_zero() {
            break;
        }
        assert!(d.as_secs_f64().is_finite() && d.as_secs_f64() > 0.0);
        clock.advance(d);
        waits += 1;
        assert!(waits <= 4, "delay/retry loop failed to converge");
    }
    bucket.consume(n);
    // the overshoot back-pressures: the next byte needs ~ (n - burst)/rate
    let d = bucket.delay_for(1, clock.now());
    assert!((d.as_secs_f64() - 4.901).abs() < 0.01, "{d:?}");
}

#[test]
fn prop_shaped_writer_never_exceeds_rate_times_t_plus_burst() {
    // Conservation: under any seeded write schedule on the virtual clock,
    // bytes released through the shaper never exceed rate·t + burst.
    // Liveness: every write_all returns and the full payload drains.
    check(60, |g| {
        let rate_bps = g.f64(10_000.0, 1e8);
        let rate_bytes = rate_bps / 8.0;
        let burst = (rate_bytes * 0.02).max(1500.0);
        let clock = SimClock::new();
        let mut w = ShapedWriter::with_clock(Vec::new(), rate_bps, clock.handle());
        let n_writes = g.usize(1, 30);
        let mut total = 0usize;
        for _ in 0..n_writes {
            // occasional idle gaps let the bucket refill between writes
            if g.bool() {
                clock.advance_secs(g.f64(0.0, 0.05));
            }
            let size = g.usize(1, 50_000);
            total += size;
            let chunk = vec![0u8; size];
            w.write_all(&chunk).map_err(|e| format!("write: {e}"))?;
            let elapsed = clock.now_secs();
            let cap = rate_bytes * elapsed + burst + 1.0;
            prop_assert(
                total as f64 <= cap,
                format!("released {total} B > rate·t+burst = {cap:.1} B at t={elapsed:.4}"),
            )?;
        }
        let inner = w.into_inner();
        prop_assert(inner.len() == total, format!("drained {} of {total}", inner.len()))
    });
}

#[test]
fn prop_token_bucket_delays_are_finite_and_nonnegative() {
    // Under arbitrary interleavings of delay_for/consume (including
    // demands far above the burst and token balances driven negative),
    // no NaN and no panic-producing negative duration can appear.
    check(120, |g| {
        let rate_bps = g.f64(1.0, 1e9);
        let burst = g.usize(1, 1_000_000);
        let clock = SimClock::new();
        let mut b = TokenBucket::new_at(rate_bps, burst, clock.instant_at(0.0));
        for _ in 0..g.usize(1, 40) {
            clock.advance_secs(g.f64(0.0, 10.0));
            let n = g.usize(0, 10_000_000);
            let d = b.delay_for(n, clock.now());
            prop_assert(d.as_secs_f64().is_finite(), "delay is not finite")?;
            if g.bool() {
                b.consume(n);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantization_is_exact_at_zero_and_scale() {
    check(100, |g| {
        let n = g.usize(2, 64);
        let peak = g.f64(1e-3, 1e3) as f32;
        let mut feat = vec![0.0f32; n];
        feat[0] = peak;
        let (scale, q) = quantize_features(&feat);
        prop_assert((scale - peak).abs() <= peak * 1e-6, "scale should be the max")?;
        prop_assert(q[0] == 255, "peak must quantise to 255")?;
        prop_assert(q[1..].iter().all(|&b| b == 0), "zeros must quantise to 0")
    });
}

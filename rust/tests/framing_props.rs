//! Property tests for the wire protocol (`net::framing` / `net::tcp`):
//! encode/decode round-trips over arbitrary messages, the quantisation
//! error bound, frame-length invariants, and oversized-frame rejection.

use miniconv::net::framing::{Hello, Msg, Payload, Request, Response, MAX_FRAME};
use miniconv::net::tcp::{read_msg, write_msg};
use miniconv::net::{dequantize_features, quantize_features};
use miniconv::util::proptest::{check, prop_assert, Gen};

/// Draw an arbitrary message of any variant.
fn arb_msg(g: &mut Gen) -> Msg {
    match g.usize(0, 3) {
        0 => {
            let shard = if g.bool() { Some(g.usize(0, u16::MAX as usize) as u16) } else { None };
            Msg::Hello(Hello {
                client: g.u64(0, u32::MAX as u64) as u32,
                split: g.bool(),
                shard,
            })
        }
        1 => {
            let x = g.usize(1, 12) as u16;
            let data = (0..4 * x as usize * x as usize)
                .map(|_| g.usize(0, 255) as u8)
                .collect();
            Msg::Request(Request {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: g.u64(0, u64::MAX - 1),
                payload: Payload::RawRgba { x, data },
            })
        }
        2 => {
            let (c, h, w) = (g.usize(1, 6), g.usize(1, 8), g.usize(1, 8));
            let data = (0..c * h * w).map(|_| g.usize(0, 255) as u8).collect();
            Msg::Request(Request {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: g.u64(0, 1 << 40),
                payload: Payload::Features {
                    c: c as u16,
                    h: h as u16,
                    w: w as u16,
                    scale: g.f64(1e-6, 100.0) as f32,
                    data,
                },
            })
        }
        _ => {
            let n = g.usize(0, 8);
            Msg::Response(Response {
                client: g.u64(0, u32::MAX as u64) as u32,
                id: g.u64(0, 1 << 40),
                action: (0..n).map(|_| g.f64(-10.0, 10.0) as f32).collect(),
            })
        }
    }
}

#[test]
fn prop_every_msg_variant_roundtrips() {
    check(300, |g| {
        let msg = arb_msg(g);
        let enc = msg.encode();
        let dec = Msg::decode(&enc[4..]).map_err(|e| format!("decode failed: {e}"))?;
        prop_assert(dec == msg, format!("roundtrip changed the message: {msg:?}"))
    });
}

#[test]
fn prop_length_prefix_matches_frame_body() {
    check(300, |g| {
        let enc = arb_msg(g).encode();
        let len = u32::from_le_bytes(enc[0..4].try_into().unwrap()) as usize;
        prop_assert(len == enc.len() - 4, format!("prefix {len} != body {}", enc.len() - 4))?;
        prop_assert(len <= MAX_FRAME, "frame exceeds MAX_FRAME")
    });
}

#[test]
fn prop_truncated_frames_are_rejected() {
    check(200, |g| {
        let enc = arb_msg(g).encode();
        let body = &enc[4..];
        if body.len() <= 1 {
            return Ok(());
        }
        let cut = g.usize(1, body.len() - 1);
        prop_assert(
            Msg::decode(&body[..cut]).is_err(),
            format!("decode accepted a {cut}-byte truncation of {} bytes", body.len()),
        )
    });
}

#[test]
fn prop_trailing_garbage_is_rejected() {
    check(200, |g| {
        let enc = arb_msg(g).encode();
        let mut body = enc[4..].to_vec();
        body.push(g.usize(0, 255) as u8);
        prop_assert(Msg::decode(&body).is_err(), "decode accepted trailing bytes")
    });
}

#[test]
fn prop_transport_rejects_frames_above_max_frame() {
    check(100, |g| {
        // forge a header claiming an oversized (or zero) body
        let len = if g.bool() {
            g.u64(MAX_FRAME as u64 + 1, u32::MAX as u64) as u32
        } else {
            0
        };
        let mut wire = Vec::new();
        wire.extend_from_slice(&len.to_le_bytes());
        wire.push(1);
        let mut cursor = std::io::Cursor::new(wire);
        prop_assert(
            read_msg(&mut cursor).is_err(),
            format!("transport accepted a frame of claimed length {len}"),
        )
    });
}

#[test]
fn prop_transport_roundtrips_message_streams() {
    check(60, |g| {
        let n = g.usize(1, 6);
        let msgs: Vec<Msg> = (0..n).map(|_| arb_msg(g)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            write_msg(&mut wire, m).map_err(|e| format!("write: {e}"))?;
        }
        let mut cursor = std::io::Cursor::new(wire);
        for (i, m) in msgs.iter().enumerate() {
            let got = read_msg(&mut cursor)
                .map_err(|e| format!("read {i}: {e}"))?
                .ok_or_else(|| format!("early EOF at {i}"))?;
            prop_assert(&got == m, format!("message {i} mutated in transit"))?;
        }
        prop_assert(
            read_msg(&mut cursor).map_err(|e| e.to_string())?.is_none(),
            "stream did not end cleanly",
        )
    });
}

#[test]
fn prop_quantization_error_within_half_step_of_scale() {
    check(300, |g| {
        let n = g.usize(1, 256);
        // post-ReLU features: non-negative, arbitrary magnitude
        let mag = g.f64(1e-4, 1e4);
        let feat: Vec<f32> = (0..n).map(|_| g.f64(0.0, mag) as f32).collect();
        let (scale, q) = quantize_features(&feat);
        prop_assert(scale > 0.0, "scale must be positive")?;
        let back = dequantize_features(scale, &q);
        prop_assert(back.len() == feat.len(), "length changed")?;
        let step = scale / 255.0;
        for (a, b) in feat.iter().zip(&back) {
            let err = (a - b).abs();
            prop_assert(
                err <= step * 0.5 + scale * 1e-6,
                format!("|{a} - {b}| = {err} > half step {}", step * 0.5),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_quantization_is_exact_at_zero_and_scale() {
    check(100, |g| {
        let n = g.usize(2, 64);
        let peak = g.f64(1e-3, 1e3) as f32;
        let mut feat = vec![0.0f32; n];
        feat[0] = peak;
        let (scale, q) = quantize_features(&feat);
        prop_assert((scale - peak).abs() <= peak * 1e-6, "scale should be the max")?;
        prop_assert(q[0] == 255, "peak must quantise to 255")?;
        prop_assert(q[1..].iter().all(|&b| b == 0), "zeros must quantise to 0")
    });
}

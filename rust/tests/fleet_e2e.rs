//! End-to-end fleet integration: real gateway + N real coordinator shards
//! (Sim backend, so no AOT artifacts are needed) driven by the real
//! simulated-device client fleet over loopback TCP.
//!
//! The session-affinity invariant is verified two independent ways: the
//! gateway's own session→shard table must never reassign, and each shard's
//! request counter must equal exactly `decisions × clients assigned to it`
//! — which cannot hold if any session's requests leaked onto two shards.
//!
//! No sleep-polling: state convergence (drain completion, crash
//! detection) is observed through the gateway's change `Signal`
//! (`wait_drained` / `wait_shard_state`), which wakes the instant the
//! monitor or a connection thread commits the transition. The same
//! scenarios also run under virtual time in `sim_scenarios.rs`; these
//! tests keep the real-socket coverage.

use std::time::Duration;

use miniconv::coordinator::{
    run_client, run_fleet, Backend, BatchPolicy, ClientConfig, Route, ServerConfig, SimSpec,
};
use miniconv::fleet::{
    launch_local, AutoscaleConfig, FleetAutoscaleConfig, FleetConfig, HealthConfig, ScaleAction,
    ShardId, ShardState,
};

const OBS_X: usize = 24;

fn sim_fleet(shards: usize) -> FleetConfig {
    FleetConfig {
        shards,
        server: ServerConfig {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            backend: Backend::Sim(SimSpec {
                fixed: Duration::from_micros(300),
                per_item: Duration::from_micros(100),
                action_dim: 1,
                // real compiled-shader encodes behind the modelled cost:
                // the fleet path exercises the serving hot path end-to-end
                encode: true,
            }),
            ..ServerConfig::default()
        },
        ..FleetConfig::default()
    }
}

fn client_cfg(decisions: usize) -> ClientConfig {
    ClientConfig {
        mode: Route::Full,
        decisions,
        obs_x: Some(OBS_X),
        ..ClientConfig::default()
    }
}

#[test]
fn fleet_serves_a_client_fleet_with_strict_shard_affinity() {
    let fleet = launch_local(sim_fleet(3)).expect("fleet");
    let (n_clients, decisions) = (12, 10);

    let reports = run_fleet(fleet.addr(), n_clients, &client_cfg(decisions)).expect("fleet run");
    assert_eq!(reports.len(), n_clients);
    for (i, r) in reports.iter().enumerate() {
        assert_eq!(r.decisions, decisions, "client {i} lost decisions");
        assert_eq!(r.errors, 0, "client {i} saw rejections");
        // raw route wire bytes: 4·X² per decision
        assert_eq!(r.bytes_sent, (decisions * 4 * OBS_X * OBS_X) as u64);
    }

    let stats = fleet.gateway.stats();
    let total = (n_clients * decisions) as u64;
    assert_eq!(stats.assignments.len(), n_clients, "one pin per session");
    assert_eq!(stats.reassigned, 0, "a session moved between shards");
    assert_eq!(stats.forwarded_requests, total);
    assert_eq!(stats.forwarded_responses, total);

    // cross-check affinity against shard-side metrics: every shard served
    // exactly decisions × (sessions pinned to it) requests
    let mut accounted = 0u64;
    for id in fleet.shard_ids() {
        let pinned = stats.assignments.values().filter(|&&s| s == id).count() as u64;
        let m = fleet.shard_metrics(id).expect("shard metrics");
        assert_eq!(m.split.requests, 0);
        assert_eq!(
            m.full.requests,
            pinned * decisions as u64,
            "{id}: requests do not match its pinned sessions — affinity broken"
        );
        accounted += m.full.requests;
    }
    assert_eq!(accounted, total, "requests leaked outside the shard set");

    // merged fleet snapshot sees everything exactly once
    let snap = fleet.snapshot();
    assert_eq!(snap.total_requests(), total);
    assert_eq!(snap.total_dropped(), 0);
    assert_eq!(snap.merged.full.service.count(), total);

    fleet.shutdown();
}

#[test]
fn reconnecting_sessions_land_on_their_original_shard() {
    let fleet = launch_local(sim_fleet(4)).expect("fleet");
    let cfg = client_cfg(3);
    // two separate connections per session id
    for round in 0..2 {
        for id in 0..8u32 {
            let r = run_client(fleet.addr(), id, &cfg)
                .unwrap_or_else(|e| panic!("round {round} client {id}: {e:#}"));
            assert_eq!(r.decisions, 3);
        }
    }
    let stats = fleet.gateway.stats();
    assert_eq!(stats.connections, 16, "8 sessions × 2 connections");
    assert_eq!(stats.assignments.len(), 8);
    assert_eq!(stats.reassigned, 0, "a reconnect hashed to a different shard");
    fleet.shutdown();
}

#[test]
fn draining_shard_keeps_serving_but_gets_no_new_sessions() {
    let fleet = launch_local(sim_fleet(2)).expect("fleet");
    let cfg = client_cfg(5);

    // place a few sessions, find a shard that owns at least one
    for id in 0..4u32 {
        run_client(fleet.addr(), id, &cfg).expect("seed client");
    }
    let before = fleet.gateway.stats();
    let victim = *before.assignments.values().next().expect("no assignments");

    fleet.gateway.drain(victim);

    // fresh sessions must all land elsewhere
    for id in 100..112u32 {
        let r = run_client(fleet.addr(), id, &cfg).expect("post-drain client");
        assert_eq!(r.decisions, 5);
    }
    let after = fleet.gateway.stats();
    for id in 100..112u32 {
        assert_ne!(
            after.assignments.get(&id),
            Some(&victim),
            "session {id} landed on the draining shard"
        );
    }
    // all clients have disconnected, so the drain completes; the signal
    // fires on the closing connection's final topology edit
    assert!(
        fleet.gateway.wait_drained(victim, Duration::from_secs(2)),
        "draining shard still holds connections"
    );
    fleet.shutdown();
}

#[test]
fn crashed_shard_is_routed_around_without_client_errors_for_new_sessions() {
    let mut fleet = launch_local(sim_fleet(2)).expect("fleet");
    let cfg = client_cfg(4);

    // kill shard 1 outright: its listener closes mid-fleet
    assert!(fleet.stop_shard(ShardId(1)));

    // every new session still completes — the gateway marks the dead shard
    // Down on the first refused pin and rehashes onto the survivor
    for id in 0..10u32 {
        let r = run_client(fleet.addr(), id, &cfg).expect("client after crash");
        assert_eq!(r.decisions, 4, "client {id} degraded");
    }
    let stats = fleet.gateway.stats();
    for (session, shard) in &stats.assignments {
        assert_eq!(*shard, ShardId(0), "session {session} pinned to the dead shard");
    }
    let states = fleet.gateway.shard_states();
    let dead = states.iter().find(|(id, ..)| *id == ShardId(1)).expect("dead shard listed");
    assert_eq!(dead.1, ShardState::Down);
    fleet.shutdown();
}

#[test]
fn autoscaler_grows_the_fleet_under_load_and_parks_shards_when_idle() {
    let mut fleet = launch_local(sim_fleet(2)).expect("fleet");
    // Degenerate watermarks make the verdict depend only on "did anything
    // wait in a queue this window": the smallest recordable wait (~100ns)
    // clears queue_high_ns = 2, while an empty window reads p95 = 0 < 1.
    // That turns wall-clock load levels — flaky to predict in CI — into a
    // binary traffic/no-traffic signal.
    fleet
        .start_autoscale(FleetAutoscaleConfig {
            policy: AutoscaleConfig {
                min_shards: 2,
                max_shards: 4,
                queue_high_ns: 2,
                queue_low_ns: 1,
                shed_high: 0.5,
                shed_low: 0.01,
                confirm: 2,
                cooldown: 0.15,
            },
            interval: Duration::from_millis(40),
        })
        .expect("start autoscale");
    assert!(
        fleet.start_autoscale(FleetAutoscaleConfig::default()).is_err(),
        "a second sampler loop must refuse to start"
    );

    // phase 1 — sustained closed-loop traffic: every sampling window sees
    // queued requests, up-pressure confirms, and the fleet grows.
    let reports = run_fleet(fleet.addr(), 8, &client_cfg(3000)).expect("fleet run");
    assert!(reports.iter().all(|r| r.errors == 0), "clients saw rejections");
    assert!(
        fleet.wait_scale(Duration::from_secs(10), |ev| {
            ev.iter().any(|e| e.action == ScaleAction::ScaleUp)
        }),
        "no scale-up under sustained load: {:?}",
        fleet.scale_events()
    );

    // phase 2 — idle: empty windows read p95 = 0 with zero shed, so
    // down-pressure confirms and the fleet shrinks back to min_shards.
    assert!(
        fleet.wait_scale(Duration::from_secs(15), |ev| {
            let ups = ev.iter().filter(|e| e.action == ScaleAction::ScaleUp).count();
            let downs = ev.iter().filter(|e| e.action == ScaleAction::ScaleDown).count();
            ups >= 1 && downs >= ups
        }),
        "fleet never shrank back after going idle: {:?}",
        fleet.scale_events()
    );

    // Replay the event log: the ring never leaves [min, max], every up was
    // driven by real pressure in its window, and Hold is never recorded.
    let events = fleet.scale_events();
    let mut routable = 2i64;
    for e in &events {
        match e.action {
            ScaleAction::ScaleUp => {
                assert!(
                    e.sample.queue_p95_ns > 2 || e.sample.shed_rate > 0.5,
                    "scale-up without pressure in its window: {e:?}"
                );
                routable += 1;
            }
            ScaleAction::ScaleDown => routable -= 1,
            ScaleAction::Hold => panic!("Hold verdicts must not be recorded: {events:?}"),
        }
        assert!((2..=4).contains(&routable), "ring left [min,max]: {events:?}");
    }
    assert_eq!(fleet.gateway.n_routable() as i64, routable, "ring drifted from the event log");

    // Scale-down parks the process rather than killing it: every shard the
    // autoscaler ever touched is still in the process table, ready for
    // revival without a relaunch.
    assert!(fleet.n_shards() >= 3, "scale-up never launched a shard");
    let ids = fleet.shard_ids();
    for e in &events {
        assert!(ids.contains(&e.shard), "scaled shard {} left the process table", e.shard);
    }
    fleet.shutdown();
}

#[test]
fn health_monitor_detects_a_crash_and_flags_it_down() {
    let mut cfg = sim_fleet(2);
    cfg.health = Some(HealthConfig {
        interval: Duration::from_millis(40),
        timeout: Duration::from_millis(200),
        fail_threshold: 2,
        degraded_after: Duration::from_secs(5),
    });
    let mut fleet = launch_local(cfg).expect("fleet");
    assert!(fleet.stop_shard(ShardId(0)));

    // event-driven: woken on the probe verdict that flips the state
    assert!(
        fleet
            .gateway
            .wait_shard_state(ShardId(0), ShardState::Down, Duration::from_secs(5)),
        "health monitor never marked the crashed shard down"
    );
    // the survivor keeps serving
    let r = run_client(fleet.addr(), 42, &client_cfg(3)).expect("survivor client");
    assert_eq!(r.decisions, 3);
    fleet.shutdown();
}

//! Zero-allocation gate for the compiled shader hot path, enforced under
//! plain `cargo test` (no bench run needed): steady-state `run_into`
//! frames at threads = 1 must not touch the heap.
//!
//! This file is its own test binary with exactly one test so the counting
//! global allocator sees no concurrent test threads — keep it that way.

use miniconv::shader::{plan, unpack_conv_weights, CompiledPipeline, EncoderIr, Op, TextureFormat};
use miniconv::tensor::Chw;
use miniconv::util::alloc_counter::CountingAlloc;
use miniconv::util::rng::Rng;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_frames_do_not_allocate() {
    let ir = EncoderIr {
        name: "miniconv4".into(),
        input_channels: 9,
        ops: (0..3)
            .flat_map(|_| vec![Op::Conv { cout: 4, k: 3, stride: 2, same: true }, Op::Relu])
            .collect(),
    };
    let p = plan(&ir, 84).unwrap();
    let mut rng = Rng::new(1);
    let flat: Vec<f32> = (0..ir.param_count()).map(|_| rng.normal_f32() * 0.3).collect();
    let ws = unpack_conv_weights(&ir, &flat).unwrap();
    let mut pipe = CompiledPipeline::new(p, ws, TextureFormat::Float).unwrap();
    let mut frame = Chw::zeros(9, 84, 84);
    for v in frame.data.iter_mut() {
        *v = (rng.uniform() * 255.0).round() as f32 / 255.0;
    }
    let mut out = Chw::zeros(1, 1, 1);
    // warm the arena and size the output buffer
    for _ in 0..3 {
        pipe.run_into(&frame, &mut out).unwrap();
    }
    let before = CountingAlloc::count();
    for _ in 0..50 {
        pipe.run_into(&frame, &mut out).unwrap();
    }
    let during = CountingAlloc::count() - before;
    std::hint::black_box(&out);
    assert_eq!(during, 0, "compiled frame loop allocated {during} times over 50 frames");
}

//! Property tests for the batched server ingest→policy pack path: the
//! fused dequantise-and-pack must be bit-exact with the legacy per-request
//! dequantise, arena rows must not bleed across clients, the batcher's
//! drain-into must preserve the FIFO/max-batch invariants, and the pooled
//! serve engine must reply byte-identically to the legacy engine.

use std::time::{Duration, Instant};

use miniconv::coordinator::batcher::{BatchCollector, BatchPolicy};
use miniconv::coordinator::{BatchArena, Route, SessionManager};
use miniconv::experiments::serving::{bench_payloads, ServeDriver, ServeEngine};
use miniconv::net::framing::{dequantize_features, dequantize_features_into, quantize_features};
use miniconv::util::proptest::{check, prop_assert};

#[test]
fn prop_quantise_pack_row_equals_legacy_dequantise() {
    check(200, |g| {
        let n = g.usize(1, 600);
        let feat: Vec<f32> = (0..n).map(|_| (g.f64(0.0, 5.0)) as f32).collect();
        let (scale, q) = quantize_features(&feat);
        let legacy = dequantize_features(scale, &q);
        let mut row = vec![f32::NAN; n];
        dequantize_features_into(scale, &q, &mut row);
        prop_assert(legacy == row, format!("pack row diverged at scale {scale}"))
    });
}

#[test]
fn prop_arena_rows_do_not_bleed_across_clients() {
    check(100, |g| {
        let rows_used = g.usize(1, 8);
        let rows = rows_used + g.usize(0, 4);
        let d = g.usize(1, 64);
        let mut arena = BatchArena::new();
        // two batches back to back: the second must show no trace of the
        // first beyond its own packed rows
        for round in 0..2 {
            arena.begin(rows_used, rows, d);
            let mut want: Vec<Vec<f32>> = Vec::new();
            for i in 0..rows_used {
                let feat: Vec<f32> =
                    (0..d).map(|k| (round * 1000 + i * 10 + k) as f32 * 0.25).collect();
                let (scale, q) = quantize_features(&feat);
                dequantize_features_into(scale, &q, arena.row_mut(i));
                want.push(dequantize_features(scale, &q));
            }
            for i in 0..rows_used {
                prop_assert(
                    arena.row(i) == want[i].as_slice(),
                    format!("row {i} corrupted in round {round}"),
                )?;
            }
            for i in rows_used..rows {
                prop_assert(
                    arena.row(i).iter().all(|&v| v == 0.0),
                    format!("padding row {i} not zeroed in round {round}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_take_into_preserves_fifo_and_max_batch() {
    check(100, |g| {
        let max_batch = g.usize(1, 16);
        let n = g.usize(1, 60);
        let mut c: BatchCollector<usize> =
            BatchCollector::new(BatchPolicy { max_batch, max_wait: Duration::ZERO }, 1000);
        let now = Instant::now();
        for i in 0..n {
            let route = if g.bool() { Route::Split } else { Route::Full };
            c.push(route, i, now);
        }
        // one pooled buffer reused across every drain
        let mut batch = Vec::new();
        let mut seen = Vec::new();
        let mut prev_per_route = [None::<usize>, None::<usize>];
        let later = now + Duration::from_millis(1);
        while let Some(route) = c.ready(later) {
            c.take_into(route, &mut batch);
            prop_assert(batch.len() <= max_batch, "batch exceeds max_batch")?;
            prop_assert(!batch.is_empty(), "ready route drained empty")?;
            for item in &batch {
                let slot = route.index();
                if let Some(p) = prev_per_route[slot] {
                    prop_assert(item.work > p, "FIFO violated within route")?;
                }
                prev_per_route[slot] = Some(item.work);
                seen.push(item.work);
            }
        }
        seen.sort_unstable();
        prop_assert(
            seen == (0..n).collect::<Vec<_>>(),
            format!("items lost or duplicated: {seen:?}"),
        )
    });
}

#[test]
fn prop_session_ingest_into_matches_legacy_wrapper() {
    check(60, |g| {
        let mut a = SessionManager::new();
        let mut b = SessionManager::new();
        let steps = g.usize(1, 12);
        for _ in 0..steps {
            let client = g.usize(0, 2) as u32;
            let x = *g.choice(&[2usize, 3, 4]);
            let frame: Vec<u8> = (0..4 * x * x).map(|_| g.usize(0, 255) as u8).collect();
            let want = a.ingest_rgba(client, x, &frame).map_err(|e| e.to_string())?;
            let mut got = vec![f32::NAN; 9 * x * x];
            b.ingest_rgba_into(client, x, &frame, &mut got).map_err(|e| e.to_string())?;
            prop_assert(want == got, format!("obs diverged for client {client} x {x}"))?;
        }
        Ok(())
    });
}

/// The acceptance oracle: the pooled engine's reply bytes equal the legacy
/// engine's for identical request streams, on both routes, across rounds
/// (so evolving frame-stack state is covered).
#[test]
fn pooled_engine_is_action_identical_to_legacy() {
    for (route, clients, max_batch) in
        [(Route::Full, 6, 4), (Route::Split, 6, 4), (Route::Full, 1, 8), (Route::Split, 8, 8)]
    {
        let (payloads, feat_dim) = bench_payloads(route, clients, 12, (4, 5, 5), 0xFACE);
        let mut legacy = ServeDriver::new(&payloads, max_batch, feat_dim, 4);
        let mut pooled = ServeDriver::new(&payloads, max_batch, feat_dim, 4);
        for round in 0..4 {
            legacy.round(ServeEngine::Legacy).unwrap();
            pooled.round(ServeEngine::Pooled).unwrap();
            assert!(!legacy.sink().is_empty());
            assert_eq!(
                legacy.sink(),
                pooled.sink(),
                "{} clients={clients} round={round}: replies diverged",
                route.name()
            );
        }
    }
}

//! Online-learning loopback e2e (DESIGN.md §8): a real TCP server with a
//! shard-local learner behind it, driven by `run_learn_client` over the
//! experience wire format. Uses `Backend::Sim`, so no artifacts are
//! required — the learner itself is the native PPO core. Also pins the
//! capability negotiation: a server without a learner clears
//! `CAP_EXPERIENCE` in its hello ack, and an un-negotiated experience
//! frame is answered with an explicit error frame, never silence.

use std::net::TcpStream;

use miniconv::codec::{self, Encoder, CODEC_DELTA};
use miniconv::coordinator::{
    run_learn_client, serve, Backend, LearnClientConfig, ServerConfig, ServerHandle, SimSpec,
};
use miniconv::learn::LearnerConfig;
use miniconv::net::framing::{
    ExperienceFrame, FeatureFrame, Hello, Msg, Payload, Request, CAP_EXPERIENCE,
    ERR_EXPERIENCE_UNSUPPORTED, EXP_EP_START,
};
use miniconv::net::tcp::{read_msg, write_msg};
use miniconv::rl::native::NativeConfig;

/// A tiny learner so tier-1 debug builds stay fast: 8 hidden units,
/// 32-step segments, 2 PPO epochs.
fn small_learner(seed: u64) -> LearnerConfig {
    LearnerConfig {
        core: NativeConfig { hidden: 8, minibatch: 8, seed, ..NativeConfig::default() },
        rollout_steps: 32,
        ppo_epochs: 2,
        gae_lambda: 0.95,
        publish_every: 1,
    }
}

fn start_server(learn: Option<LearnerConfig>) -> ServerHandle {
    serve(ServerConfig {
        backend: Backend::Sim(SimSpec::default()),
        learn,
        ..ServerConfig::default()
    })
    .expect("serve")
}

#[test]
fn learn_client_trains_through_the_serving_stack() {
    let server = start_server(Some(small_learner(0)));
    let cfg = LearnClientConfig { episodes: 2, seed: 0, max_lag: 4 };
    let report = run_learn_client(server.addr, 0, &cfg).expect("learn client");
    server.shutdown();

    assert!(!report.fallback, "capability must be granted by a learn server");
    assert_eq!(report.errors, 0, "no error frames expected: {report:?}");
    assert_eq!(report.returns.len(), 2, "episodes completed: {report:?}");
    for &r in &report.returns {
        assert!((-4000.0..=0.0).contains(&r), "pendulum return {r}");
    }
    // 2 episodes x 200 steps, +1 flush frame, + any keyframe resends
    assert!(report.experience_frames >= 401, "frames {}", report.experience_frames);
    assert!(report.bytes_sent > 0);
    // 400 steps in 32-step segments with publish_every=1: the version
    // stamp must have advanced well past the first update
    assert!(report.latest_version >= 10, "latest version {}", report.latest_version);
    // the shard-local store self-adopts on publish, so lag stays 0
    assert_eq!(report.applied_stale, 0, "stale actions applied: {report:?}");
    assert_eq!(report.stale_rejections, 0);
}

#[test]
fn learn_run_is_deterministic_for_a_seed() {
    let run = |seed: u64| {
        let server = start_server(Some(small_learner(seed)));
        let cfg = LearnClientConfig { episodes: 1, seed, max_lag: 4 };
        let report = run_learn_client(server.addr, 0, &cfg).expect("learn client");
        server.shutdown();
        report
    };
    let a = run(3);
    let b = run(3);
    assert_eq!(a.returns, b.returns, "same seed must replay bit-identically");
    assert_eq!(a.latest_version, b.latest_version);
    let c = run(4);
    assert_ne!(a.returns, c.returns, "seed must matter");
}

#[test]
fn server_without_learner_downgrades_client_to_inference() {
    let server = start_server(None);
    let cfg = LearnClientConfig { episodes: 1, seed: 0, max_lag: 4 };
    let report = run_learn_client(server.addr, 0, &cfg).expect("client");
    server.shutdown();

    assert!(report.fallback, "hello ack must clear CAP_EXPERIENCE");
    assert_eq!(report.experience_frames, 0, "no experience frames after downgrade");
    assert_eq!(report.returns.len(), 1, "inference-only episodes still complete");
    assert_eq!(report.latest_version, 0, "no policy versions without a learner");
}

/// Hand-rolled socket: negotiate nothing, send an experience frame
/// anyway, and require the explicit `ERR_EXPERIENCE_UNSUPPORTED` error
/// frame — then confirm the same session still serves inference frames.
#[test]
fn unnegotiated_experience_frame_gets_explicit_error() {
    let server = start_server(None);
    let stream = TcpStream::connect(server.addr).expect("connect");
    stream.set_nodelay(true).ok();
    let mut recv = stream.try_clone().expect("clone");
    let mut send = stream;

    write_msg(
        &mut send,
        &Msg::Hello(Hello {
            client: 7,
            split: true,
            codec: CODEC_DELTA,
            caps: CAP_EXPERIENCE,
            shard: None,
            epoch: None,
        }),
    )
    .expect("hello");
    let ack = loop {
        match read_msg(&mut recv).expect("read ack") {
            Some(Msg::Hello(h)) => break h,
            Some(_) => continue,
            None => panic!("server closed during negotiation"),
        }
    };
    assert_eq!(ack.caps & CAP_EXPERIENCE, 0, "no-learn server must clear the capability");

    // send the experience frame the ack just refused
    let mut encoder = Encoder::new();
    let obs = [0.5f32, 0.5, 0.25];
    let mut qbuf = Vec::new();
    let scale = codec::quantize_into(&obs, 255, &mut qbuf);
    let mut data = Vec::new();
    let (flags, seq) = encoder.encode_into(&qbuf, &mut data);
    let feat = FeatureFrame {
        c: 3,
        h: 1,
        w: 1,
        codec: CODEC_DELTA,
        flags,
        qmax: 255,
        seq,
        scale,
        data,
    };
    let payload = Payload::Experience(ExperienceFrame {
        feat,
        ep: 0,
        step: 0,
        flags: EXP_EP_START,
        reward: 0.0,
    });
    write_msg(&mut send, &Msg::Request(Request { client: 7, id: 0, payload })).expect("send");

    let err = loop {
        match read_msg(&mut recv).expect("read error") {
            Some(Msg::Error(e)) => break e,
            Some(_) => continue,
            None => panic!("server closed instead of rejecting"),
        }
    };
    assert_eq!(err.client, 7);
    assert_eq!(err.code, ERR_EXPERIENCE_UNSUPPORTED);
    assert!(!err.detail.is_empty(), "rejection must say why");

    // the session survives the rejection: a plain inference frame on the
    // same connection is still answered
    encoder.force_keyframe();
    let mut data = Vec::new();
    let (flags, seq) = encoder.encode_into(&qbuf, &mut data);
    let feat = FeatureFrame {
        c: 3,
        h: 1,
        w: 1,
        codec: CODEC_DELTA,
        flags,
        qmax: 255,
        seq,
        scale,
        data,
    };
    write_msg(
        &mut send,
        &Msg::Request(Request { client: 7, id: 1, payload: Payload::FeaturesV2(feat) }),
    )
    .expect("send v2");
    loop {
        match read_msg(&mut recv).expect("read v2") {
            Some(Msg::ResponseV2(r)) => {
                assert_eq!(r.id, 1);
                break;
            }
            Some(Msg::Error(e)) => panic!("inference frame rejected: {e:?}"),
            Some(_) => continue,
            None => panic!("server closed on inference frame"),
        }
    }
    server.shutdown();
}

//! Zero-allocation gate for the batched server ingest→policy→reply path,
//! enforced under plain `cargo test` (no bench run needed): steady-state
//! pooled rounds over both routes must not touch the heap once the
//! collector, session rings, and arena are warm.
//!
//! This file is its own test binary with exactly one test so the counting
//! global allocator sees no concurrent test threads — keep it that way
//! (same setup as `rust/tests/compiled_alloc.rs`).

use miniconv::coordinator::Route;
use miniconv::experiments::serving::{bench_payloads, ServeDriver, ServeEngine};
use miniconv::util::alloc_counter::CountingAlloc;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_serve_rounds_do_not_allocate() {
    // small raw frames keep the test fast; the allocation profile is
    // geometry-independent (capacities, not sizes, decide reuse)
    let (split, split_dim) = bench_payloads(Route::Split, 8, 16, (4, 11, 11), 1);
    let (full, full_dim) = bench_payloads(Route::Full, 8, 16, (4, 11, 11), 2);
    let mut ds = ServeDriver::new(&split, 8, split_dim, 4);
    let mut df = ServeDriver::new(&full, 8, full_dim, 4);
    // warm the collector queues, session rings, arena, and reply sink
    for _ in 0..3 {
        ds.round(ServeEngine::Pooled).unwrap();
        df.round(ServeEngine::Pooled).unwrap();
    }
    let before = CountingAlloc::count();
    for _ in 0..50 {
        ds.round(ServeEngine::Pooled).unwrap();
        df.round(ServeEngine::Pooled).unwrap();
    }
    let during = CountingAlloc::count() - before;
    std::hint::black_box((ds.sink().len(), df.sink().len()));
    assert_eq!(
        during, 0,
        "pooled serve rounds allocated {during} times over 50 rounds x 16 requests"
    );
}

//! Hostile-wire regression corpus (DESIGN.md §9): named malformed
//! inputs — truncated varints, forged element counts, bad codec
//! headers, mid-negotiation capability flips — pinned as plain tests.
//!
//! This is where fuzz findings come to rest: an input that crashes a
//! target in `rust/fuzz/fuzz_targets/` (via the smoke driver
//! `rust/tests/fuzz_smoke.rs` or a real fuzzer run) gets minimized,
//! named, and added here so the crash can never quietly return.
//! Every case asserts the clean-rejection contract: hostile bytes come
//! back as `Err`, never as a panic, an oversized allocation, or a
//! mutation of another session's decoder state.

use miniconv::codec::pack::get_varint;
use miniconv::codec::{quantize_into, Decoders, Encoder, CODEC_DELTA, FLAG_KEYFRAME, FLAG_RAW};
use miniconv::net::framing::{
    quantize_features, ExperienceFrame, FeatureFrame, Hello, Msg, Payload, Request,
    CAP_EXPERIENCE, EXP_HAS_REWARD, MSG_EXPERIENCE, MSG_POLICY, MSG_REQUEST_FEAT,
    MSG_REQUEST_FEAT_V2, MSG_REQUEST_RAW, MSG_RESPONSE,
};
use miniconv::net::limits::{LimitsConfig, SessionGate};
use miniconv::trace::{
    append_trailer, split_trailer, stamp_body_tail, trace_eligible, TraceCtx, STAGE_GW_FORWARD,
    STAGE_SEND, TRACE_TAG, TRACE_WIRE_BYTES,
};

// -- Msg::decode: framing-level hostility -----------------------------------

/// Valid frame bodies covering the request-side decode arms, built
/// through the real encoders.
fn valid_bodies() -> Vec<Vec<u8>> {
    let feats: Vec<f32> = (0..48).map(|i| (i % 5) as f32 * 0.3).collect();
    let (scale, q) = quantize_features(&feats);
    let mut enc = Encoder::new();
    let mut wire = Vec::new();
    let (flags, seq) = enc.encode_into(&q, &mut wire);
    let v2 = FeatureFrame {
        c: 3,
        h: 4,
        w: 4,
        codec: CODEC_DELTA,
        flags,
        qmax: 255,
        seq,
        scale,
        data: wire,
    };
    let exp = ExperienceFrame {
        feat: v2.clone(),
        ep: 1,
        step: 3,
        flags: EXP_HAS_REWARD,
        reward: -0.25,
    };
    let msgs = [
        Msg::Hello(Hello {
            client: 9,
            split: true,
            codec: CODEC_DELTA,
            caps: CAP_EXPERIENCE,
            shard: Some(1),
            epoch: None,
        }),
        Msg::Request(Request {
            client: 9,
            id: 1,
            payload: Payload::RawRgba { x: 4, data: vec![7; 64] },
        }),
        Msg::Request(Request { client: 9, id: 2, payload: Payload::FeaturesV2(v2) }),
        Msg::Request(Request { client: 9, id: 3, payload: Payload::Experience(exp) }),
    ];
    msgs.iter().map(|m| m.encode()[4..].to_vec()).collect()
}

#[test]
fn every_truncation_of_every_valid_frame_is_rejected() {
    // the wire format is fully length-determined, so no strict prefix of
    // a valid body may decode — a frame torn anywhere must be an Err
    for body in valid_bodies() {
        assert!(Msg::decode(&body).is_ok());
        for cut in 0..body.len() {
            assert!(
                Msg::decode(&body[..cut]).is_err(),
                "prefix {cut}/{} of type {} decoded",
                body.len(),
                body[0]
            );
        }
    }
}

/// Assemble a frame body by hand: type byte + payload bytes.
fn body(ty: u8, payload: &[u8]) -> Vec<u8> {
    let mut b = vec![ty];
    b.extend_from_slice(payload);
    b
}

#[test]
fn forged_element_counts_are_rejected_before_they_buy_an_allocation() {
    // MSG_RESPONSE claiming 65 535 action floats over a 4-byte body
    let mut p = Vec::new();
    p.extend_from_slice(&9u32.to_le_bytes());
    p.extend_from_slice(&1u64.to_le_bytes());
    p.extend_from_slice(&0xFFFFu16.to_le_bytes());
    p.extend_from_slice(&[0; 4]);
    assert!(Msg::decode(&body(MSG_RESPONSE, &p)).is_err());

    // MSG_POLICY claiming u32::MAX parameters — the count·4 product must
    // be rejected overflow-safe, not wrapped into a small allocation
    let mut p = Vec::new();
    p.extend_from_slice(&1u64.to_le_bytes());
    p.extend_from_slice(&u32::MAX.to_le_bytes());
    p.extend_from_slice(&[0; 8]);
    assert!(Msg::decode(&body(MSG_POLICY, &p)).is_err());

    // MSG_POLICY claiming one float more than the frame carries
    let mut p = Vec::new();
    p.extend_from_slice(&1u64.to_le_bytes());
    p.extend_from_slice(&3u32.to_le_bytes());
    p.extend_from_slice(&[0; 8]);
    assert!(Msg::decode(&body(MSG_POLICY, &p)).is_err());

    // MSG_REQUEST_RAW claiming a 65 535-pixel edge (a 16 GiB frame)
    let mut p = Vec::new();
    p.extend_from_slice(&9u32.to_le_bytes());
    p.extend_from_slice(&1u64.to_le_bytes());
    p.extend_from_slice(&0xFFFFu16.to_le_bytes());
    p.extend_from_slice(&[0; 8]);
    assert!(Msg::decode(&body(MSG_REQUEST_RAW, &p)).is_err());

    // MSG_REQUEST_FEAT with dims that multiply to ~2.8e14 elements
    let mut p = Vec::new();
    p.extend_from_slice(&9u32.to_le_bytes());
    p.extend_from_slice(&1u64.to_le_bytes());
    for d in [0xFFFFu16, 0xFFFF, 0xFFFF] {
        p.extend_from_slice(&d.to_le_bytes());
    }
    p.extend_from_slice(&1.0f32.to_le_bytes());
    p.extend_from_slice(&[0; 16]);
    assert!(Msg::decode(&body(MSG_REQUEST_FEAT, &p)).is_err());

    // MSG_REQUEST_FEAT_V2 whose payload length outruns the flat frame
    let mut p = Vec::new();
    p.extend_from_slice(&9u32.to_le_bytes());
    p.extend_from_slice(&1u64.to_le_bytes());
    for d in [2u16, 2, 2] {
        p.extend_from_slice(&d.to_le_bytes());
    }
    p.extend_from_slice(&[CODEC_DELTA, FLAG_KEYFRAME, 255]);
    p.extend_from_slice(&1u32.to_le_bytes());
    p.extend_from_slice(&1.0f32.to_le_bytes());
    p.extend_from_slice(&u32::MAX.to_le_bytes());
    p.extend_from_slice(&[0; 32]);
    assert!(Msg::decode(&body(MSG_REQUEST_FEAT_V2, &p)).is_err());
}

// -- codec layer: varint and header hostility -------------------------------

#[test]
fn truncated_and_overflowing_varints_are_rejected() {
    // every prefix of a pure continuation run is a truncated varint
    let run = [0x80u8; 4];
    for cut in 0..=run.len() {
        let mut pos = 0;
        assert!(get_varint(&run[..cut], &mut pos).is_err(), "prefix {cut} decoded");
    }
    // a 5th byte carrying more than the 4 bits a u32 has left
    let mut pos = 0;
    assert!(get_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x7F], &mut pos).is_err());
    // …while the canonical 5-byte maximum still decodes
    let mut pos = 0;
    assert_eq!(get_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x0F], &mut pos).unwrap(), u32::MAX);
}

const HONEST: u32 = 1;
const ATTACKER: u32 = 2;

/// Prime an honest 64-element delta chain, fire one attacker frame that
/// must be rejected, then prove the honest chain neither changed nor
/// stopped decoding. `counted` says whether the rejection happens deep
/// enough to charge the attacker's consecutive-reject streak (header
/// short-circuits — unknown codec id, zero qmax — bail before the
/// payload machinery and leave the streak untouched).
fn assert_rejected_without_poison(attack: &FeatureFrame, counted: bool) {
    let feats: Vec<f32> = (0..64).map(|i| (i % 9) as f32 * 0.5).collect();
    let mut q = Vec::new();
    let scale = quantize_into(&feats, 200, &mut q);
    let mut enc = Encoder::new();
    let mut wire = Vec::new();
    let mut decs = Decoders::new();
    let mut row = vec![0.0f32; 64];
    let hf = |flags, seq, data: Vec<u8>| FeatureFrame {
        c: 4,
        h: 4,
        w: 4,
        codec: CODEC_DELTA,
        flags,
        qmax: 200,
        seq,
        scale,
        data,
    };
    let (flags, seq) = enc.encode_into(&q, &mut wire);
    decs.decode_into(HONEST, &hf(flags, seq, wire.clone()), &mut row).unwrap();
    let before = decs.frame(HONEST).unwrap().to_vec();

    let mut arow = vec![0.0f32; attack.feat_len()];
    assert!(decs.decode_into(ATTACKER, attack, &mut arow).is_err(), "hostile frame decoded");
    assert_eq!(decs.consecutive_rejects(ATTACKER), u32::from(counted));
    assert_eq!(decs.consecutive_rejects(HONEST), 0, "reject charged to the honest session");
    assert_eq!(decs.frame(HONEST).unwrap(), &before[..], "honest state mutated");

    let (flags, seq) = enc.encode_into(&q, &mut wire);
    decs.decode_into(HONEST, &hf(flags, seq, wire.clone()), &mut row)
        .expect("honest delta chain broken by a rejected neighbor");
}

#[test]
fn bad_codec_headers_are_rejected_without_poisoning_neighbors() {
    let n = 64usize;
    let base = FeatureFrame {
        c: 4,
        h: 4,
        w: 4,
        codec: CODEC_DELTA,
        flags: FLAG_KEYFRAME | FLAG_RAW,
        qmax: 200,
        seq: 1,
        scale: 1.0,
        data: vec![0; n],
    };
    // unknown codec id (header short-circuit)
    assert_rejected_without_poison(&FeatureFrame { codec: 7, ..base.clone() }, false);
    // zero quantisation ceiling (header short-circuit)
    assert_rejected_without_poison(&FeatureFrame { qmax: 0, ..base.clone() }, false);
    // raw keyframe whose values exceed its own qmax
    assert_rejected_without_poison(&FeatureFrame { data: vec![255; n], ..base.clone() }, true);
    // raw keyframe lying about its length
    assert_rejected_without_poison(&FeatureFrame { data: vec![0; n - 1], ..base.clone() }, true);
    // delta against a base that was never decoded
    let junk = FeatureFrame { flags: 0, data: vec![0xFF; n], ..base.clone() };
    assert_rejected_without_poison(&junk, true);
    // packed keyframe with nonzero padding bits in its block mask
    let pad = FeatureFrame { flags: FLAG_KEYFRAME, data: vec![0xF0], ..base.clone() };
    assert_rejected_without_poison(&pad, true);
    // packed keyframe with trailing bytes after its residual stream
    let trail = FeatureFrame { flags: FLAG_KEYFRAME, data: vec![0x00, 0xAA, 0xBB], ..base };
    assert_rejected_without_poison(&trail, true);
}

#[test]
fn delta_seq_jumps_poison_the_chain_until_a_keyframe() {
    let n = 64usize;
    let mut decs = Decoders::new();
    let mut row = vec![0.0f32; n];
    let f = |flags, seq, data: Vec<u8>| FeatureFrame {
        c: 4,
        h: 4,
        w: 4,
        codec: CODEC_DELTA,
        flags,
        qmax: 200,
        seq,
        scale: 1.0,
        data,
    };
    decs.decode_into(5, &f(FLAG_KEYFRAME | FLAG_RAW, 10, vec![5; n]), &mut row).unwrap();
    // a delta that skips a sequence number is a chain break
    assert!(decs.decode_into(5, &f(0, 12, vec![0x00]), &mut row).is_err());
    // the poisoned chain rejects even a well-formed next delta
    assert!(decs.decode_into(5, &f(0, 11, vec![0x00]), &mut row).is_err());
    assert_eq!(decs.consecutive_rejects(5), 2);
    // a keyframe at any sequence number re-primes and clears the streak
    decs.decode_into(5, &f(FLAG_KEYFRAME | FLAG_RAW, 20, vec![5; n]), &mut row).unwrap();
    assert_eq!(decs.consecutive_rejects(5), 0);
    // …and the chain continues from the new base
    assert!(decs.decode_into(5, &f(0, 21, vec![0x00]), &mut row).is_ok());
}

// -- admission gate: mid-negotiation flips arriving by wire -----------------

#[test]
fn mid_negotiation_capability_flips_arrive_by_wire_and_are_contained() {
    let mut gate = SessionGate::new(LimitsConfig::default());
    // hellos go through the actual wire bytes, as an attacker would
    let hello = |split, codec, caps| {
        let b = Msg::Hello(Hello { client: 3, split, codec, caps, shard: None, epoch: None }).encode();
        match Msg::decode(&b[4..]).unwrap() {
            Msg::Hello(h) => h,
            other => panic!("hello decoded as {other:?}"),
        }
    };
    // negotiate a split session with the experience capability
    let ack = gate.on_hello(&hello(true, CODEC_DELTA, CAP_EXPERIENCE), CAP_EXPERIENCE, Some(0));
    assert_eq!(ack.unwrap().caps, CAP_EXPERIENCE);
    assert!(gate.admit(MSG_EXPERIENCE, 64).is_ok());
    // mid-session flip: a re-hello dropping the capability must stop
    // experience admission immediately, not at the next reconnect
    let ack = gate.on_hello(&hello(true, CODEC_DELTA, 0), CAP_EXPERIENCE, Some(0));
    assert_eq!(ack.unwrap().caps, 0);
    assert!(gate.admit(MSG_EXPERIENCE, 64).is_err());
    assert!(gate.admit(MSG_REQUEST_FEAT_V2, 64).is_ok());
    // route flip: the feature route collapses to zero on a raw re-hello
    gate.on_hello(&hello(false, 0, 0), CAP_EXPERIENCE, Some(0)).unwrap();
    assert!(gate.admit(MSG_REQUEST_FEAT_V2, 64).is_err());
    assert!(gate.admit(MSG_REQUEST_RAW, 64).is_ok());
    // hostile codec ids decline to flat rather than echo
    assert_eq!(gate.on_hello(&hello(true, 9, 0), 0, None).unwrap().codec, 0);
    // after all that churn the decode-error budget still quarantines
    let budget = LimitsConfig::default().max_decode_errors;
    for _ in 0..budget {
        assert!(!gate.on_decode_error());
    }
    assert!(gate.on_decode_error());
    assert!(gate.quarantined());
    assert!(gate.admit(MSG_REQUEST_RAW, 64).is_err());
    let h = hello(true, CODEC_DELTA, CAP_EXPERIENCE);
    assert!(gate.on_hello(&h, CAP_EXPERIENCE, None).is_none());
}

// -- admission gate: topology-epoch frames arriving by wire -----------------

/// Round-trip an epoch-carrying hello through the real wire bytes, as a
/// replaying or forging attacker would deliver it.
fn wire_hello(client: u32, shard: Option<u16>, epoch: Option<u64>) -> Hello {
    let b = Msg::Hello(Hello {
        client,
        split: true,
        codec: CODEC_DELTA,
        caps: 0,
        shard,
        epoch,
    })
    .encode();
    match Msg::decode(&b[4..]).unwrap() {
        Msg::Hello(h) => h,
        other => panic!("hello decoded as {other:?}"),
    }
}

#[test]
fn stale_epoch_hellos_are_refused_without_quarantine_or_state_change() {
    let mut gate = SessionGate::new(LimitsConfig::default());
    gate.set_topology_epoch(5);
    // negotiate at the current epoch; the ack stamps it back
    let ack = gate.on_hello(&wire_hello(3, None, Some(5)), CAP_EXPERIENCE, Some(1)).unwrap();
    assert_eq!(ack.epoch, Some(5));
    assert!(gate.admit(MSG_REQUEST_FEAT_V2, 64).is_ok());
    // a hello replayed from before the last shard add is refused — no
    // ack, no quarantine, and the live negotiation keeps serving
    assert!(gate.on_hello(&wire_hello(3, None, Some(3)), CAP_EXPERIENCE, Some(1)).is_none());
    assert_eq!(gate.epoch_rejects, 1);
    assert!(!gate.quarantined());
    assert!(gate.admit(MSG_REQUEST_FEAT_V2, 64).is_ok());
    // a forged epoch from a future the fleet never reached is refused too
    assert!(gate.on_hello(&wire_hello(3, Some(1), Some(9)), CAP_EXPERIENCE, Some(1)).is_none());
    assert_eq!(gate.epoch_rejects, 2);
    // the current epoch still negotiates after the hostile churn
    assert!(gate.on_hello(&wire_hello(3, None, Some(5)), CAP_EXPERIENCE, Some(1)).is_some());
}

#[test]
fn epoch_regression_replays_are_refused_even_without_a_fleet_epoch() {
    // a gate that never learned a topology epoch still enforces the
    // session's own watermark: a captured older hello cannot roll the
    // session back to a pre-migration route
    let mut gate = SessionGate::new(LimitsConfig::default());
    let ack = gate.on_hello(&wire_hello(7, None, Some(7)), 0, None).unwrap();
    assert_eq!(ack.epoch, None); // no fleet epoch to stamp
    assert!(gate.on_hello(&wire_hello(7, None, Some(3)), 0, None).is_none());
    assert_eq!(gate.epoch_rejects, 1);
    assert!(!gate.quarantined());
    // epoch-less hellos predate the protocol and still negotiate
    assert!(gate.on_hello(&wire_hello(7, None, None), 0, None).is_some());
    // ...without resetting the watermark the replay is judged against
    assert!(gate.on_hello(&wire_hello(7, None, Some(6)), 0, None).is_none());
    assert_eq!(gate.epoch_rejects, 2);
}

#[test]
fn forged_mid_migration_reroute_cannot_hijack_the_fresh_gate() {
    // old shard: session negotiated at topology epoch 3, then the fleet
    // scales and the session migrates at epoch 4
    let mut old = SessionGate::new(LimitsConfig::default());
    old.set_topology_epoch(3);
    old.on_hello(&wire_hello(11, Some(0), Some(3)), CAP_EXPERIENCE, Some(0)).unwrap();
    old.set_topology_epoch(4);
    let mut fresh = old.migrate();
    // a captured pre-migration hello (epoch 3) replayed at the new shard
    // is refused: the watermark followed the session across the seam
    assert!(fresh.on_hello(&wire_hello(11, Some(0), Some(3)), CAP_EXPERIENCE, Some(2)).is_none());
    assert_eq!(fresh.epoch_rejects, 1);
    // a forged re-route claiming an epoch the fleet never published
    assert!(fresh.on_hello(&wire_hello(11, Some(2), Some(8)), CAP_EXPERIENCE, Some(2)).is_none());
    assert_eq!(fresh.epoch_rejects, 2);
    assert!(!fresh.quarantined());
    // only the genuine post-migration hello lands, and its ack pins the
    // session to the new epoch and shard
    let ack = fresh.on_hello(&wire_hello(11, None, Some(4)), CAP_EXPERIENCE, Some(2)).unwrap();
    assert_eq!(ack.epoch, Some(4));
    assert_eq!(ack.shard, Some(2));
    assert!(fresh.admit(MSG_REQUEST_FEAT_V2, 64).is_ok());
}

// -- trace trailers: hostile span context arriving by wire ------------------

/// A canonical request body plus an appended trace trailer, built through
/// the real encoder and trace layer — the honest traced frame every
/// hostile variant below mutates.
fn traced_body() -> (Vec<u8>, TraceCtx) {
    let mut body = Msg::Request(Request {
        client: 9,
        id: 1,
        payload: Payload::RawRgba { x: 4, data: vec![7; 64] },
    })
    .encode()[4..]
        .to_vec();
    let mut ctx = TraceCtx::mint(((9u64) << 32) | 1, 1_000);
    ctx.stamp(STAGE_SEND, 2_000);
    append_trailer(&mut body, &ctx);
    (body, ctx)
}

#[test]
fn trace_trailers_layer_strictly_outside_the_canonical_encoding() {
    let (body, ctx) = traced_body();
    // the trailer peels back to exactly the canonical body + the context
    let (inner, got) = split_trailer(&body).expect("honest trailer refused");
    assert_eq!(got, ctx);
    assert!(Msg::decode(inner).is_ok());
    // and the layering is strict both ways: a trailered frame is NOT a
    // valid canonical message (an untraced session must refuse it via the
    // trailing-bytes bound), so a trailer can never smuggle payload past
    // a decoder that did not negotiate CAP_TRACE
    assert!(Msg::decode(&body).is_err(), "trailered frame decoded as canonical");
}

#[test]
fn truncated_forged_and_misplaced_trace_trailers_are_rejected() {
    let (body, _) = traced_body();
    let base = body.len() - TRACE_WIRE_BYTES;

    // truncated: a torn trailer shifts the tag window onto payload bytes
    assert!(split_trailer(&body[..body.len() - 1]).is_err(), "torn trailer decoded");
    // forged tag byte
    let mut forged = body.clone();
    forged[base] = 0xEE;
    assert!(split_trailer(&forged).is_err(), "forged tag decoded");
    // a bare canonical body (shorter than any trailer) cannot carry one
    let plain = &body[..base];
    assert!(plain.len() < TRACE_WIRE_BYTES);
    assert!(split_trailer(plain).is_err(), "traceless body yielded a trailer");
    // ineligible types never carry trailers, however well-formed
    let mut hello = Msg::Hello(Hello {
        client: 9,
        split: false,
        codec: 0,
        caps: 0,
        shard: None,
        epoch: None,
    })
    .encode()[4..]
        .to_vec();
    let n = hello.len();
    hello.extend_from_slice(&body[base..]);
    assert!(!trace_eligible(hello[0]));
    assert!(split_trailer(&hello).is_err(), "control frame yielded a trailer");
    assert_eq!(n + TRACE_WIRE_BYTES, hello.len());
    // empty input
    assert!(split_trailer(&[]).is_err());

    // boundary pins: a frame of exactly TRACE_WIRE_BYTES has no room for
    // a body and is refused; one byte more peels structurally (the inner
    // byte then fails canonical decode downstream, proving the layers
    // reject independently)
    let exact = body[base - 1..].to_vec();
    assert_eq!(exact.len(), TRACE_WIRE_BYTES + 1);
    assert!(trace_eligible(body[base - 1]) || split_trailer(&exact).is_err());
    let mut at_size = body[base..].to_vec();
    at_size[0] = MSG_REQUEST_RAW; // eligible type, zero-byte canonical body
    assert_eq!(at_size.len(), TRACE_WIRE_BYTES);
    assert!(split_trailer(&at_size).is_err(), "trailer-sized frame decoded");

    // TraceCtx::read_wire itself: wrong length and wrong tag
    assert!(TraceCtx::read_wire(&body[base..body.len() - 1]).is_err());
    assert!(TraceCtx::read_wire(&body[base + 1..]).is_err());
    let mut tail = body[base..].to_vec();
    tail[0] = TRACE_TAG.wrapping_add(1);
    assert!(TraceCtx::read_wire(&tail).is_err());
}

#[test]
fn in_place_stamping_never_touches_untraced_bytes() {
    // the gateway's no-decode stamp hook must refuse anything that cannot
    // be carrying a trailer, leaving the frame byte-for-byte intact
    let plain = Msg::Request(Request {
        client: 9,
        id: 1,
        payload: Payload::RawRgba { x: 4, data: vec![7; 64] },
    })
    .encode()[4..]
        .to_vec();
    let mut frame = plain.clone();
    assert!(!stamp_body_tail(&mut frame, STAGE_GW_FORWARD, 99), "stamped a traceless frame");
    assert_eq!(frame, plain, "refused stamp still mutated the frame");
    // short frames and empty frames
    let mut short = vec![MSG_REQUEST_RAW; TRACE_WIRE_BYTES];
    let orig = short.clone();
    assert!(!stamp_body_tail(&mut short, STAGE_GW_FORWARD, 99));
    assert_eq!(short, orig);
    assert!(!stamp_body_tail(&mut [], STAGE_GW_FORWARD, 99));
    // and the honest case round-trips: stamp lands in the trailer only
    let (mut body, mut ctx) = traced_body();
    let inner_before = split_trailer(&body).unwrap().0.to_vec();
    assert!(stamp_body_tail(&mut body, STAGE_GW_FORWARD, 42_000));
    ctx.stamp(STAGE_GW_FORWARD, 42_000);
    let (inner, got) = split_trailer(&body).unwrap();
    assert_eq!(inner, &inner_before[..], "stamp leaked into the canonical body");
    assert_eq!(got, ctx);
}

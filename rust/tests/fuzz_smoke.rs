//! Fuzz smoke suite (DESIGN.md §9): drives the three cargo-fuzz-style
//! targets in `rust/fuzz/fuzz_targets/` from plain `cargo test` — no
//! nightly toolchain, no external fuzzer binary. The corpus is built
//! from the real encoders, the mutation engine is seeded, and nothing
//! reads a clock, so a CI failure reproduces locally byte for byte.
//!
//! An input that crashes a target here (or under a real libFuzzer run
//! of the same files) graduates to a named regression test in
//! `rust/tests/wire_hostile.rs` — see DESIGN.md §9 for the procedure.
//!
//! Volume: the three tests below push ≥ 16 000 inputs through the
//! targets, comfortably past the 10 000-iteration smoke floor the CI
//! job pins.

#[path = "../fuzz/fuzz_targets/codec_decode.rs"]
mod codec_decode;
#[path = "../fuzz/fuzz_targets/hello_negotiation.rs"]
mod hello_negotiation;
#[path = "../fuzz/fuzz_targets/msg_decode.rs"]
mod msg_decode;

use miniconv::codec::{Encoder, CODEC_DELTA};
use miniconv::net::framing::{
    quantize_features, ErrorMsg, ExperienceFrame, FeatureFrame, Hello, Msg, Payload, PolicySync,
    Request, Response, ResponseLearn, ResponseV2, CAP_EXPERIENCE, ERR_OVERLOADED, EXP_HAS_REWARD,
    RESP_FLAG_NEED_KEYFRAME,
};
use miniconv::trace::{append_trailer, trace_eligible, TraceCtx, STAGE_RECV};
use miniconv::util::rng::Rng;

/// One valid frame body per wire construct, built through the real
/// encoders (so the corpus exercises every decode arm, including a live
/// delta-codec chain). Bodies, not framed bytes: `Msg::decode` takes
/// the type byte + payload the transport hands it.
fn corpus() -> Vec<Vec<u8>> {
    let feats: Vec<f32> = (0..48).map(|i| (i % 5) as f32 * 0.3).collect();
    let (scale, q) = quantize_features(&feats);
    let mut enc = Encoder::new();
    let mut key_wire = Vec::new();
    let (kflags, kseq) = enc.encode_into(&q, &mut key_wire);
    let keyframe = FeatureFrame {
        c: 3,
        h: 4,
        w: 4,
        codec: CODEC_DELTA,
        flags: kflags,
        qmax: 255,
        seq: kseq,
        scale,
        data: key_wire,
    };
    let mut delta_wire = Vec::new();
    let (dflags, dseq) = enc.encode_into(&q, &mut delta_wire);
    let delta = FeatureFrame { flags: dflags, seq: dseq, data: delta_wire, ..keyframe.clone() };
    let msgs = [
        Msg::Hello(Hello {
            client: 7,
            split: true,
            codec: CODEC_DELTA,
            caps: CAP_EXPERIENCE,
            shard: None,
            epoch: None,
        }),
        Msg::Hello(Hello { client: 7, split: false, codec: 0, caps: 0, shard: Some(3), epoch: None }),
        Msg::Request(Request {
            client: 7,
            id: 1,
            payload: Payload::RawRgba { x: 4, data: vec![9; 64] },
        }),
        Msg::Request(Request {
            client: 7,
            id: 2,
            payload: Payload::Features { c: 3, h: 4, w: 4, scale, data: q },
        }),
        Msg::Request(Request { client: 7, id: 3, payload: Payload::FeaturesV2(keyframe) }),
        Msg::Request(Request {
            client: 7,
            id: 4,
            payload: Payload::Experience(ExperienceFrame {
                feat: delta,
                ep: 2,
                step: 5,
                flags: EXP_HAS_REWARD,
                reward: 0.5,
            }),
        }),
        Msg::Response(Response { client: 7, id: 1, action: vec![0.1, -0.2] }),
        Msg::ResponseV2(ResponseV2 {
            client: 7,
            id: 3,
            seq: kseq,
            flags: RESP_FLAG_NEED_KEYFRAME,
            queue_wait_us: 120,
            action: vec![0.3; 4],
        }),
        Msg::ResponseLearn(ResponseLearn {
            client: 7,
            id: 4,
            seq: dseq,
            flags: 0,
            acting_version: 9,
            latest_version: 11,
            action: vec![-0.5; 3],
        }),
        Msg::Error(ErrorMsg {
            client: 7,
            code: ERR_OVERLOADED,
            detail: "retry with backoff".into(),
        }),
        Msg::Policy(PolicySync { version: 3, params: vec![0.25; 17] }),
    ];
    msgs.iter().map(|m| m.encode()[4..].to_vec()).collect()
}

/// Structured mutation: start from a corpus entry and apply 1–3 random
/// edits — bit flips, interesting-byte overwrites, tail truncation,
/// 4-byte length-field blasts, cross-entry splices. The classic
/// coverage mix of a byte-level fuzzer, minus the coverage feedback.
fn mutate(rng: &mut Rng, corpus: &[Vec<u8>], scratch: &mut Vec<u8>) {
    const INTERESTING: [u8; 6] = [0x00, 0x01, 0x7F, 0x80, 0xFE, 0xFF];
    let base = &corpus[rng.below(corpus.len())];
    scratch.clear();
    scratch.extend_from_slice(base);
    for _ in 0..=rng.below(3) {
        match rng.below(5) {
            0 if !scratch.is_empty() => {
                let i = rng.below(scratch.len());
                scratch[i] ^= 1 << rng.below(8);
            }
            1 if !scratch.is_empty() => {
                let i = rng.below(scratch.len());
                scratch[i] = INTERESTING[rng.below(INTERESTING.len())];
            }
            2 if !scratch.is_empty() => {
                scratch.truncate(rng.below(scratch.len()));
            }
            3 if scratch.len() >= 4 => {
                // blast a plausible count/length field
                let i = rng.below(scratch.len() - 3);
                let v = [0u32, 1, 0xFFFF, 0xFFFF_FFFF][rng.below(4)];
                scratch[i..i + 4].copy_from_slice(&v.to_le_bytes());
            }
            4 if !scratch.is_empty() => {
                let other = &corpus[rng.below(corpus.len())];
                let i = rng.below(scratch.len());
                let j = rng.below(other.len());
                let n = rng.below((scratch.len() - i).min(other.len() - j)) + 1;
                scratch[i..i + n].copy_from_slice(&other[j..j + n]);
            }
            _ => {}
        }
    }
}

fn noise(rng: &mut Rng, max_len: usize, buf: &mut Vec<u8>) {
    let n = rng.below(max_len);
    buf.clear();
    buf.extend((0..n).map(|_| rng.next_u64() as u8));
}

#[test]
fn msg_decode_survives_truncation_mutation_and_noise() {
    let corpus = corpus();
    // the pristine corpus must decode — a corpus that rots stops
    // reaching the deep arms and the fuzz run goes quietly blind
    for entry in &corpus {
        assert!(Msg::decode(entry).is_ok(), "corpus entry no longer decodes");
        msg_decode::fuzz_target(entry);
    }
    // every truncation prefix of every entry (the off-by-one sweep)
    for entry in &corpus {
        for cut in 0..entry.len() {
            msg_decode::fuzz_target(&entry[..cut]);
        }
    }
    // traced variants: every trace-eligible entry with a trailer
    // appended (what a CAP_TRACE session puts on the wire), then the
    // same off-by-one truncation sweep over the trailered bytes so the
    // peel layer sees every torn-tail shape
    let mut ctx = TraceCtx::mint(((7u64) << 32) | 1, 1_000);
    ctx.stamp(STAGE_RECV, 2_000);
    let traced: Vec<Vec<u8>> = corpus
        .iter()
        .filter(|e| trace_eligible(e[0]))
        .map(|e| {
            let mut t = e.clone();
            append_trailer(&mut t, &ctx);
            t
        })
        .collect();
    assert!(traced.len() >= 6, "trace-eligible corpus arms went missing");
    for entry in &traced {
        for cut in 0..=entry.len() {
            msg_decode::fuzz_target(&entry[..cut]);
        }
    }
    // seeded structured mutation + raw noise; the mutation pool carries
    // the trailered entries too, so splices and bit flips land inside
    // trace trailers as often as inside canonical payloads
    let pool: Vec<Vec<u8>> = corpus.iter().chain(&traced).cloned().collect();
    let mut rng = Rng::new(0xF0CC_5EED);
    let mut buf = Vec::new();
    for _ in 0..6000 {
        mutate(&mut rng, &pool, &mut buf);
        msg_decode::fuzz_target(&buf);
    }
    for _ in 0..2000 {
        noise(&mut rng, 96, &mut buf);
        msg_decode::fuzz_target(&buf);
    }
}

#[test]
fn codec_decode_survives_hostile_headers_and_payloads() {
    let mut rng = Rng::new(0xC0DE_C5ED);
    let mut buf = Vec::new();
    // unbiased noise: headers and payload both arbitrary
    for _ in 0..3000 {
        noise(&mut rng, 160, &mut buf);
        codec_decode::fuzz_target(&buf);
    }
    // biased noise: force a known codec id and positive qmax so every
    // run gets past the header checks into the unpack/apply machinery
    for _ in 0..1500 {
        noise(&mut rng, 160, &mut buf);
        if buf.len() >= 6 {
            buf[3] = CODEC_DELTA;
            buf[5] = buf[5].max(1);
        }
        codec_decode::fuzz_target(&buf);
    }
}

#[test]
fn hello_negotiation_state_machine_holds_its_invariants() {
    let mut rng = Rng::new(0x48E1_1057);
    let mut ops = Vec::new();
    for _ in 0..3000 {
        noise(&mut rng, 20 * 6, &mut ops);
        hello_negotiation::fuzz_target(&ops);
    }
    // directed: enough decode errors must always end in quarantine
    let burn: Vec<u8> = std::iter::repeat([2u8, 0, 0, 0, 0, 0]).take(8).flatten().collect();
    hello_negotiation::fuzz_target(&burn);
}

//! Property tests for the compiled shader pipeline against the legacy
//! interpreter (the oracle): Float mode must be bit-exact on arbitrary
//! plans/weights/frames, multi-threaded execution must match
//! single-threaded, scratch-arena reuse must be stateless across frames,
//! and Rgba8 quantisation error must stay bounded (mirroring the
//! framing_props error-bound style).

use miniconv::shader::{plan, CompiledPipeline, EncoderIr, Op, ShaderPipeline, TextureFormat};
use miniconv::tensor::Chw;
use miniconv::util::proptest::{check, prop_assert, Gen};

/// Draw a random shader-deployable encoder IR and a legal input size.
/// Keeps within the planner's embedded-GL limits (≤ 8 bound textures,
/// ≤ 64 samples/pass) and keeps spatial dims legal for every op.
fn arb_ir(g: &mut Gen) -> (EncoderIr, usize) {
    let input_channels = *g.choice(&[1usize, 3, 4, 9, 16]);
    let x = g.usize(8, 28);
    let mut ops = Vec::new();
    let mut h = x;
    let mut cin = input_channels;
    let depth = g.usize(1, 3);
    for _ in 0..depth {
        // conv must respect the sample budget: k² * ceil(cin/4) <= 64
        let k = if cin > 16 { 1 } else { *g.choice(&[1usize, 3]) };
        let stride = g.usize(1, 2);
        let same = g.bool();
        if !same && h < k {
            break;
        }
        let cout = *g.choice(&[3usize, 4, 5, 8, 16]);
        ops.push(Op::Conv { cout, k, stride, same });
        if g.bool() {
            ops.push(Op::Relu);
        }
        h = if same { h.div_ceil(stride) } else { (h - k) / stride + 1 };
        cin = cout;
        // occasional pooling layer when there is room
        if h >= 3 && g.usize(0, 3) == 0 {
            ops.push(Op::MaxPool { k: 2, stride: 2 });
            h = (h - 2) / 2 + 1;
        }
        if h < 2 {
            break;
        }
    }
    if ops.is_empty() {
        ops.push(Op::Conv { cout: 4, k: 1, stride: 1, same: true });
    }
    (EncoderIr { name: "arb".into(), input_channels, ops }, x)
}

fn arb_frame(g: &mut Gen, c: usize, x: usize) -> Chw {
    let mut f = Chw::zeros(c, x, x);
    for v in f.data.iter_mut() {
        *v = (g.f64(0.0, 255.0) as f32).round() / 255.0;
    }
    f
}

fn arb_weights(g: &mut Gen, n: usize) -> Vec<f32> {
    (0..n).map(|_| g.f64(-0.6, 0.6) as f32).collect()
}

#[test]
fn prop_compiled_float_bit_exact_vs_legacy() {
    check(60, |g| {
        let (ir, x) = arb_ir(g);
        let p = match plan(&ir, x) {
            Ok(p) => p,
            Err(_) => return Ok(()), // drawn IR exceeded GL limits: skip
        };
        let flat = arb_weights(g, ir.param_count());
        let ws = miniconv::shader::unpack_conv_weights(&ir, &flat)
            .map_err(|e| format!("unpack: {e}"))?;
        let frame = arb_frame(g, ir.input_channels, x);
        let legacy = ShaderPipeline::new(p.clone(), ws.clone(), TextureFormat::Float)
            .map_err(|e| format!("legacy: {e}"))?;
        let mut compiled = CompiledPipeline::new(p, ws, TextureFormat::Float)
            .map_err(|e| format!("compile: {e}"))?;
        let want = legacy.run(&frame).map_err(|e| format!("legacy run: {e}"))?;
        let got = compiled.run(&frame).map_err(|e| format!("compiled run: {e}"))?;
        prop_assert(
            (got.c, got.h, got.w) == (want.c, want.h, want.w),
            format!("shape {:?} vs {:?}", (got.c, got.h, got.w), (want.c, want.h, want.w)),
        )?;
        for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
            prop_assert(
                a.to_bits() == b.to_bits(),
                format!("{}@{x}: pixel {i} differs: {a} vs {b}", ir.name),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_matches_single_thread() {
    check(25, |g| {
        let (ir, x) = arb_ir(g);
        let p = match plan(&ir, x) {
            Ok(p) => p,
            Err(_) => return Ok(()),
        };
        let flat = arb_weights(g, ir.param_count());
        let ws = miniconv::shader::unpack_conv_weights(&ir, &flat)
            .map_err(|e| format!("unpack: {e}"))?;
        let frame = arb_frame(g, ir.input_channels, x);
        let mut one = CompiledPipeline::new(p.clone(), ws.clone(), TextureFormat::Float)
            .map_err(|e| format!("compile: {e}"))?;
        let mut many = CompiledPipeline::new(p, ws, TextureFormat::Float)
            .map_err(|e| format!("compile: {e}"))?;
        many.set_threads(g.usize(2, 6));
        let a = one.run(&frame).map_err(|e| e.to_string())?;
        let b = many.run(&frame).map_err(|e| e.to_string())?;
        for (u, v) in a.data.iter().zip(&b.data) {
            prop_assert(u.to_bits() == v.to_bits(), "parallel run diverged")?;
        }
        Ok(())
    });
}

#[test]
fn prop_scratch_reuse_stateless_across_frames() {
    check(25, |g| {
        let (ir, x) = arb_ir(g);
        let p = match plan(&ir, x) {
            Ok(p) => p,
            Err(_) => return Ok(()),
        };
        let flat = arb_weights(g, ir.param_count());
        let ws = miniconv::shader::unpack_conv_weights(&ir, &flat)
            .map_err(|e| format!("unpack: {e}"))?;
        let mut warm = CompiledPipeline::new(p.clone(), ws.clone(), TextureFormat::Float)
            .map_err(|e| format!("compile: {e}"))?;
        let mut out = Chw::zeros(1, 1, 1);
        for _ in 0..g.usize(1, 3) {
            let f = arb_frame(g, ir.input_channels, x);
            warm.run_into(&f, &mut out).map_err(|e| e.to_string())?;
        }
        let last = arb_frame(g, ir.input_channels, x);
        warm.run_into(&last, &mut out).map_err(|e| e.to_string())?;
        let mut cold = CompiledPipeline::new(p, ws, TextureFormat::Float)
            .map_err(|e| format!("compile: {e}"))?;
        let want = cold.run(&last).map_err(|e| e.to_string())?;
        for (u, v) in out.data.iter().zip(&want.data) {
            prop_assert(u.to_bits() == v.to_bits(), "warm arena leaked state across frames")?;
        }
        Ok(())
    });
}

/// Miniconv-family IR for the quantisation bound: ReLU after every conv
/// (Rgba8 storage clamps to [0, scale], so unbounded-negative activations
/// of un-ReLU'd random nets would break any additive error bound) and
/// weights at the calibration scale the seed parity tests use.
fn arb_relu_ir(g: &mut Gen) -> (EncoderIr, usize) {
    let x = g.usize(12, 28);
    let depth = g.usize(1, 3);
    let mut ops = Vec::new();
    for _ in 0..depth {
        let cout = *g.choice(&[4usize, 8, 16]);
        ops.push(Op::Conv { cout, k: 3, stride: 2, same: true });
        ops.push(Op::Relu);
    }
    (EncoderIr { name: "arb-relu".into(), input_channels: 9, ops }, x)
}

#[test]
fn prop_rgba8_error_bounded_by_layer_scale() {
    // Mirrors framing_props' quantisation bound: with per-layer scales
    // calibrated on the frame itself, the compiled Rgba8 output must stay
    // within a small fraction of the final layer's scale of the Float
    // output.
    check(30, |g| {
        let (ir, x) = arb_relu_ir(g);
        let p = match plan(&ir, x) {
            Ok(p) => p,
            Err(_) => return Ok(()),
        };
        let flat: Vec<f32> = (0..ir.param_count()).map(|_| g.f64(-0.35, 0.35) as f32).collect();
        let ws = miniconv::shader::unpack_conv_weights(&ir, &flat)
            .map_err(|e| format!("unpack: {e}"))?;
        let frame = arb_frame(g, ir.input_channels, x);
        let scales = ShaderPipeline::calibrate(&p, &ws, &frame).map_err(|e| e.to_string())?;
        let mut q = CompiledPipeline::new(
            p.clone(),
            ws.clone(),
            TextureFormat::Rgba8 { scales: scales.clone() },
        )
        .map_err(|e| format!("compile q: {e}"))?;
        let mut f = CompiledPipeline::new(p, ws, TextureFormat::Float)
            .map_err(|e| format!("compile f: {e}"))?;
        let got_q = q.run(&frame).map_err(|e| e.to_string())?;
        let got_f = f.run(&frame).map_err(|e| e.to_string())?;
        // 8% of the final scale: the canonical miniconv configuration is
        // held to 5% in the unit tests; random depth/width/weight draws get
        // a little headroom for unlucky error alignment
        let tol = scales.last().copied().unwrap_or(1.0).max(1.0) * 0.08;
        let diff = got_q.max_abs_diff(&got_f);
        prop_assert(
            diff < tol,
            format!("rgba8 error {diff} vs tol {tol} (scales {scales:?})"),
        )
    });
}

//! End-to-end per-decision tracing over real loopback TCP (DESIGN.md §12):
//! traced clients against Sim-backend coordinators and fleets, no AOT
//! artifacts needed.
//!
//! The load-bearing check is *reconciliation*: the spans the client gets
//! back are stamped from the very same `Instant`s the server's histograms
//! are built from, so the trace-derived queue-stage sum must agree with
//! the `queue_wait` histogram's exact tracked sum — not approximately
//! because both measure "the same kind of thing", but exactly (modulo
//! nanosecond rounding) because a span is the histogram sample, exploded
//! per decision. A disagreement means a hop stamped the wrong instant.

use std::time::Duration;

use miniconv::coordinator::{
    run_client, run_fleet, Backend, BatchPolicy, ClientConfig, ClientReport, Route, ServerConfig,
    SimSpec,
};
use miniconv::fleet::{launch_local, FleetConfig};
use miniconv::trace::{
    STAGE_DEQUEUE, STAGE_ENCODE, STAGE_ENQUEUE, STAGE_EXECUTE, STAGE_GW_FORWARD, STAGE_MINT,
    STAGE_PACK, STAGE_RECV, STAGE_REPLY, STAGE_SEND,
};

const OBS_X: usize = 24;

fn traced_server() -> ServerConfig {
    ServerConfig {
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        backend: Backend::Sim(SimSpec {
            fixed: Duration::from_micros(300),
            per_item: Duration::from_micros(100),
            action_dim: 1,
            encode: false,
        }),
        trace: true,
        ..ServerConfig::default()
    }
}

fn traced_client(decisions: usize) -> ClientConfig {
    ClientConfig {
        mode: Route::Full,
        decisions,
        obs_x: Some(OBS_X),
        trace: true,
        ..ClientConfig::default()
    }
}

/// The stages every single-server decision passes through, in hop order
/// (no gateway, so `STAGE_GW_FORWARD` stays unset).
const SERVER_PATH: [usize; 9] = [
    STAGE_MINT,
    STAGE_ENCODE,
    STAGE_SEND,
    STAGE_ENQUEUE,
    STAGE_DEQUEUE,
    STAGE_PACK,
    STAGE_EXECUTE,
    STAGE_REPLY,
    STAGE_RECV,
];

fn assert_closed_monotone(r: &ClientReport, client: usize, path: &[usize]) {
    for (d, t) in r.traces.iter().enumerate() {
        assert_eq!(
            t.id,
            ((client as u64) << 32) | d as u64,
            "client {client} decision {d}: trace id mismatch"
        );
        let mut prev = 0u64;
        for &stage in path {
            let ns = t.stamps[stage];
            assert!(ns > 0 || stage == STAGE_MINT, "client {client} decision {d}: stage {stage} unset");
            assert!(
                ns >= prev,
                "client {client} decision {d}: stage {stage} went backwards ({ns} < {prev})"
            );
            prev = ns;
        }
        assert!(t.total_ns() > 0, "client {client} decision {d}: zero-length span");
    }
}

#[test]
fn traced_fleet_closes_spans_and_reconciles_with_histograms() {
    let server = miniconv::coordinator::serve(traced_server()).expect("server");
    let (n_clients, decisions) = (4, 25);
    let reports = run_fleet(server.addr, n_clients, &traced_client(decisions)).expect("fleet run");

    let mut queue_ns = 0.0f64;
    let mut service_ns = 0.0f64;
    for (c, r) in reports.iter().enumerate() {
        assert_eq!(r.decisions, decisions, "client {c} lost decisions");
        assert_eq!(r.errors, 0, "client {c} saw rejections");
        assert_eq!(r.traces.len(), decisions, "client {c}: one span per decision");
        assert_closed_monotone(r, c, &SERVER_PATH);
        for t in &r.traces {
            assert_eq!(t.stamps[STAGE_GW_FORWARD], 0, "no gateway on this path");
            let s = t.stages();
            queue_ns += s.queue() as f64;
            service_ns += (t.stamps[STAGE_REPLY] - t.stamps[STAGE_ENQUEUE]) as f64;
        }
    }

    // reconcile against the server's own histograms: the queue stage is
    // stamped from the exact instants (`received`, batch dequeue) the
    // `queue_wait` histogram records, so the sums agree to rounding
    let m = server.metrics.snapshot();
    let total = (n_clients * decisions) as u64;
    assert_eq!(m.full.requests, total);
    assert_eq!(m.full.queue_wait.count(), total);
    let hist_queue = m.full.queue_wait.mean_ns() * total as f64;
    assert!(
        (queue_ns - hist_queue).abs() <= 0.05 * hist_queue.max(1e6),
        "trace queue sum {queue_ns}ns vs histogram {hist_queue}ns"
    );
    // service (enqueue→reply per span) brackets the histogram's
    // received→done window: the reply hop is stamped per item slightly
    // after the batch-wide `done`, so the trace sum is the upper edge
    let hist_service = m.full.service.mean_ns() * total as f64;
    assert!(
        service_ns >= 0.95 * hist_service && service_ns <= 1.5 * hist_service,
        "trace service sum {service_ns}ns vs histogram {hist_service}ns"
    );

    // the server-side flight recorder retained every span, and the
    // exemplar dump is the slowest-N by span length
    let retained = server.metrics.traces();
    assert_eq!(retained.len(), total as usize);
    let top = server.metrics.trace_exemplars(5);
    assert_eq!(top.len(), 5);
    for w in top.windows(2) {
        assert!(w[0].total_ns() >= w[1].total_ns(), "exemplars not slowest-first");
    }
    server.shutdown();
}

#[test]
fn untraced_clients_coexist_and_ungranted_trace_degrades_cleanly() {
    // untraced client against a traced server: no trailers, empty report
    let server = miniconv::coordinator::serve(traced_server()).expect("server");
    let mut cfg = traced_client(10);
    cfg.trace = false;
    let r = run_client(server.addr, 0, &cfg).expect("untraced client");
    assert_eq!(r.decisions, 10);
    assert_eq!(r.errors, 0);
    assert!(r.traces.is_empty(), "untraced session must not collect spans");
    server.shutdown();

    // traced client against an untraced server: the hello ack withholds
    // CAP_TRACE, the client falls back to plain frames
    let mut sc = traced_server();
    sc.trace = false;
    let server = miniconv::coordinator::serve(sc).expect("server");
    let r = run_client(server.addr, 0, &traced_client(10)).expect("declined trace client");
    assert_eq!(r.decisions, 10);
    assert_eq!(r.errors, 0);
    assert!(r.traces.is_empty(), "ungranted CAP_TRACE must leave the wire untraced");
    server.shutdown();
}

#[test]
fn gateway_forward_hop_lands_between_send_and_enqueue() {
    let fleet = launch_local(FleetConfig {
        shards: 2,
        server: traced_server(),
        ..FleetConfig::default()
    })
    .expect("fleet");
    let (n_clients, decisions) = (6, 10);
    let reports = run_fleet(fleet.addr(), n_clients, &traced_client(decisions)).expect("fleet run");

    const GATEWAY_PATH: [usize; 10] = [
        STAGE_MINT,
        STAGE_ENCODE,
        STAGE_SEND,
        STAGE_GW_FORWARD,
        STAGE_ENQUEUE,
        STAGE_DEQUEUE,
        STAGE_PACK,
        STAGE_EXECUTE,
        STAGE_REPLY,
        STAGE_RECV,
    ];
    for (c, r) in reports.iter().enumerate() {
        assert_eq!(r.decisions, decisions, "client {c} lost decisions");
        assert_eq!(r.traces.len(), decisions, "client {c}: one span per decision");
        assert_closed_monotone(r, c, &GATEWAY_PATH);
        for t in &r.traces {
            assert!(t.stamps[STAGE_GW_FORWARD] > 0, "gateway hop missing from span");
            // the up-wire stage (send→enqueue) absorbs both TCP legs; the
            // gateway stamp splits it and must sit strictly inside
            assert!(t.stamps[STAGE_GW_FORWARD] >= t.stamps[STAGE_SEND]);
            assert!(t.stamps[STAGE_GW_FORWARD] <= t.stamps[STAGE_ENQUEUE]);
        }
    }
    fleet.shutdown();
}

//! Chaos-scenario suite over the deterministic simnet (`miniconv::sim`):
//! gateway + shards + clients fully in-process, virtual time, seeded
//! faults. Every scenario runs across a small seed matrix and, when
//! `SIM_LOG_DIR` is set, writes its canonical event log to disk — CI runs
//! the suite twice and byte-diffs the two directories to enforce the
//! seed/replay contract. Zero `std::thread::sleep` anywhere on this path:
//! the whole suite is pure event-queue arithmetic.

use std::collections::BTreeSet;
use std::time::Duration;

use miniconv::analysis::breakeven::split_wins;
use miniconv::codec::{CodecId, RateConfig};
use miniconv::coordinator::BatchPolicy;
use miniconv::device::ThermalModel;
use miniconv::fleet::{AutoscaleConfig, ShardId, ShardState, Topology};
use miniconv::learn::LearnerConfig;
use miniconv::net::LinkModel;
use miniconv::rl::native::NativeConfig;
use miniconv::rl::{NativeTrainer, TrainConfig};
use miniconv::sim::{
    run_scenario, AutoscaleSpec, FaultCmd, LearnSpec, LinkFaults, ScenarioConfig, ScenarioReport,
    ThermalSpec,
};
use miniconv::trace::{
    STAGE_DEQUEUE, STAGE_ENCODE, STAGE_ENQUEUE, STAGE_EXECUTE, STAGE_GW_FORWARD, STAGE_MINT,
    STAGE_PACK, STAGE_RECV, STAGE_REPLY, STAGE_SEND,
};

const SEEDS: [u64; 3] = [11, 23, 47];

/// Run one scenario; emit its canonical log for the CI determinism diff.
fn run_and_emit(name: &str, cfg: &ScenarioConfig) -> ScenarioReport {
    let report = run_scenario(cfg).unwrap_or_else(|e| panic!("{name} seed {}: {e:#}", cfg.seed));
    if let Ok(dir) = std::env::var("SIM_LOG_DIR") {
        let dir = std::path::Path::new(&dir);
        std::fs::create_dir_all(dir).expect("create SIM_LOG_DIR");
        std::fs::write(dir.join(format!("{name}-{}.log", cfg.seed)), &report.log)
            .expect("write scenario log");
    }
    report
}

/// Replicate the scenario runner's consistent-hash placement (the ring is
/// a pure function of shard ids + vnodes, independent of the seed) to
/// know which sessions start on `target`.
fn sessions_on_shard(n_clients: usize, shards: usize, target: u16) -> Vec<u32> {
    let mut t = Topology::new(32);
    for s in 0..shards {
        t.add_shard(
            ShardId(s as u16),
            format!("127.0.0.1:{}", 9000 + s).parse().unwrap(),
        );
    }
    (0..n_clients as u32)
        .filter(|&s| t.route(s).unwrap().id == ShardId(target))
        .collect()
}

fn sessions_on_shard1(n_clients: usize, shards: usize) -> Vec<u32> {
    sessions_on_shard(n_clients, shards, 1)
}

/// Sessions whose placement changes when shard `added` joins a
/// `shards`-wide ring — the keyspace the newcomer steals, and nothing
/// else (consistent hashing leaves every other assignment alone).
fn moved_by_adding_shard(n_clients: usize, shards: usize, added: usize) -> Vec<u32> {
    let mut before = Topology::new(32);
    let mut after = Topology::new(32);
    for s in 0..shards {
        let addr: std::net::SocketAddr =
            format!("127.0.0.1:{}", 9000 + s).parse().unwrap();
        before.add_shard(ShardId(s as u16), addr);
        after.add_shard(ShardId(s as u16), addr);
    }
    after.add_shard(
        ShardId(added as u16),
        format!("127.0.0.1:{}", 9000 + added).parse().unwrap(),
    );
    (0..n_clients as u32)
        .filter(|&c| before.route(c).unwrap().id != after.route(c).unwrap().id)
        .collect()
}

/// Pull the `session=` ids off every `{tag}` line of the canonical log
/// (e.g. `migrate_start` / `migrate`), in emission order.
fn migration_log_sessions(log: &str, tag: &str) -> Vec<u32> {
    let marker = format!(" {tag} session=");
    log.lines()
        .filter_map(|l| l.split_once(marker.as_str()).map(|(_, rest)| rest))
        .map(|rest| {
            rest.split_whitespace()
                .next()
                .and_then(|tok| tok.parse().ok())
                .expect("malformed migration log line")
        })
        .collect()
}

fn at_most_one_ack_per_epoch(r: &ScenarioReport) -> bool {
    r.clients
        .iter()
        .all(|c| c.hello_acks.iter().all(|&n| n <= 1))
}

// ---------------------------------------------------------------------------
// determinism: the foundation every other scenario stands on
// ---------------------------------------------------------------------------

#[test]
fn same_seed_runs_are_byte_identical() {
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: 4,
            split_clients: 2,
            decisions: 6,
            probe_interval: Some(0.02),
            faults: vec![
                (0.004, FaultCmd::PartitionShard(1)),
                (0.05, FaultCmd::HealShard(1)),
            ],
            client_link: LinkFaults { jitter: 0.002, drop_p: 0.1, ..LinkFaults::ideal() },
            req_timeout: 0.04,
            ..ScenarioConfig::default()
        };
        let a = run_and_emit("determinism", &cfg);
        let b = run_scenario(&cfg).expect("rerun");
        assert_eq!(a.log, b.log, "seed {seed}: same-seed logs diverged");
        assert!(!a.log.is_empty());
    }
    // and different seeds must actually explore different schedules
    let mk = |seed| ScenarioConfig {
        seed,
        client_link: LinkFaults { jitter: 0.002, drop_p: 0.1, ..LinkFaults::ideal() },
        ..ScenarioConfig::default()
    };
    let a = run_scenario(&mk(SEEDS[0])).unwrap();
    let b = run_scenario(&mk(SEEDS[1])).unwrap();
    assert_ne!(a.log, b.log, "different seeds produced identical logs");
}

// ---------------------------------------------------------------------------
// scenario 1: shard crash + restart — hello-ack exactly-once under failover
// ---------------------------------------------------------------------------

#[test]
fn hello_ack_exactly_once_under_shard_failover() {
    let n_clients = 12;
    let moved = sessions_on_shard1(n_clients, 2);
    assert!(!moved.is_empty(), "hash placed nothing on shard 1; grow the client count");
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: n_clients,
            decisions: 6,
            think: 0.01,
            req_timeout: 0.05,
            probe_interval: Some(0.02),
            faults: vec![
                (0.005, FaultCmd::CrashShard(1)),
                (0.06, FaultCmd::RestartShard(1)),
            ],
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("failover", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}: a client gave up");
        assert_eq!(r.completed_decisions(), n_clients * 6, "seed {seed}");
        // the invariant in the scenario's name: every connection epoch saw
        // exactly one hello ack — shard-side acks never leaked through
        assert!(r.hello_acks_exactly_once(), "seed {seed}: {:?}",
            r.clients.iter().map(|c| c.hello_acks.clone()).collect::<Vec<_>>());
        assert!(r.gateway.filtered_shard_acks > 0, "seed {seed}: filter never exercised");
        assert!(r.gateway.crash_detected >= 1, "seed {seed}: crash never detected");
        // every session that started on the crashed shard moved exactly once
        assert_eq!(r.gateway.reassigned as usize, moved.len(), "seed {seed}");
        // the restarted shard was probed back to Up
        assert_eq!(r.shard_states[1], ShardState::Up, "seed {seed}");
        assert_eq!(r.gateway.no_route, 0, "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 2: reordered frames — batch-deadline correctness
// ---------------------------------------------------------------------------

#[test]
fn batch_deadlines_hold_under_reordered_frames() {
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 1,
            raw_clients: 6,
            decisions: 8,
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
            shard_link: LinkFaults {
                reorder_p: 0.3,
                reorder_delay: 0.004,
                ..LinkFaults::ideal()
            },
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("reorder", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        // exactly-once despite arbitrary arrival order: every decision
        // answered, nothing duplicated, nothing retried
        assert_eq!(r.completed_decisions(), 48, "seed {seed}");
        assert_eq!(r.clients.iter().map(|c| c.dup_responses).sum::<u64>(), 0);
        assert_eq!(r.clients.iter().map(|c| c.retries).sum::<u64>(), 0, "seed {seed}");
        let s = &r.shards[0];
        assert_eq!(s.requests, 48, "seed {seed}: requests lost or duplicated");
        // batching policy invariants held batch by batch
        assert!(s.max_batch <= 4, "seed {seed}: batch exceeded max_batch");
        assert_eq!(s.size_fired + s.deadline_fired, s.batches, "seed {seed}");
        assert!(s.batches >= 12, "seed {seed}: {} batches for 48 reqs at cap 4", s.batches);
        assert!(r.hello_acks_exactly_once(), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 3: operator drain during a network partition
// ---------------------------------------------------------------------------

#[test]
fn draining_completes_under_partition_and_probes_never_override_it() {
    let n_clients = 8;
    let moved = sessions_on_shard1(n_clients, 2);
    assert!(!moved.is_empty(), "hash placed nothing on shard 1; grow the client count");
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: n_clients,
            decisions: 8,
            think: 0.005,
            req_timeout: 0.04,
            probe_interval: Some(0.02),
            faults: vec![
                (0.01, FaultCmd::DrainShard(1)),
                (0.01, FaultCmd::PartitionShard(1)),
                (0.08, FaultCmd::HealShard(1)),
            ],
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("drain_partition", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        assert_eq!(r.completed_decisions(), n_clients * 8, "seed {seed}");
        // operator intent survived failing probes for the whole partition
        assert_eq!(r.shard_states[1], ShardState::Draining, "seed {seed}");
        // every session pinned there moved off, so the drain completed
        assert_eq!(r.gateway.reassigned as usize, moved.len(), "seed {seed}");
        assert!(r.drained[1], "seed {seed}: drain never completed");
        assert!(r.log.contains(" partition "), "seed {seed}");
        assert!(r.log.contains(" heal "), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 4: thermal throttle + recovery under sustained load
// ---------------------------------------------------------------------------

#[test]
fn thermal_throttle_engages_and_recovers_under_sustained_load() {
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            gateway: false,
            shards: 1,
            raw_clients: 6,
            decisions: 30,
            exec_fixed: 0.002,
            exec_per_item: 0.004,
            req_timeout: 3.0,
            thermal: Some(ThermalSpec {
                // fast RC so the cycle fits the run: 25C ambient, 10C/W,
                // tau 50 ms, trip 70C, resume 60C
                model: ThermalModel::new(25.0, 10.0, 0.05, 70.0, 60.0),
                active_watts: 8.0,
                idle_watts: 0.0,
                throttle_factor: 3.0,
            }),
            faults: vec![
                (5.0, FaultCmd::SampleThermal(0)),
                (5.1, FaultCmd::SampleThermal(0)),
            ],
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("thermal", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        assert_eq!(r.completed_decisions(), 180, "seed {seed}");
        let s = &r.shards[0];
        assert!(s.throttled_batches >= 1, "seed {seed}: never throttled");
        assert!(
            s.throttled_batches < s.batches,
            "seed {seed}: every batch throttled — no unthrottled baseline"
        );
        assert!(s.max_temp > 70.0, "seed {seed}: die never crossed the trip point");
        // after the load stops, the idle samples show full recovery
        assert!(!s.final_throttled, "seed {seed}: never recovered");
        assert!(r.log.contains(" thermal "), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 5: break-even latency under 1/5/20 Mb/s shaped links,
// cross-checked against the paper's analytic model (analysis::breakeven)
// ---------------------------------------------------------------------------

#[test]
fn shaped_link_breakeven_matches_the_analytic_model() {
    let (x, n, k, j) = (84usize, 3u32, 4usize, 0.05f64);
    for seed in SEEDS {
        for mbps in [1.0, 5.0, 20.0] {
            let bps = mbps * 1e6;
            let run = |raw: bool| {
                let cfg = ScenarioConfig {
                    seed,
                    gateway: false,
                    shards: 1,
                    raw_clients: usize::from(raw),
                    split_clients: usize::from(!raw),
                    decisions: 6,
                    obs_x: x,
                    feat: (k, 11, 11),
                    encode_j: j,
                    req_timeout: 5.0,
                    policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                    exec_fixed: 0.003,
                    exec_per_item: 0.001,
                    client_link: LinkFaults::shaped(bps, 0.002),
                    reply_link: LinkFaults { latency: 0.002, ..LinkFaults::ideal() },
                    ..ScenarioConfig::default()
                };
                let mode = if raw { "raw" } else { "split" };
                let mut r = run_and_emit(&format!("breakeven_{mode}_{mbps}mbps"), &cfg);
                assert_eq!(r.completed_decisions(), 6, "seed {seed} {mode} {mbps}Mb/s");
                r.clients[0].latencies.median()
            };
            let raw_med = run(true);
            let split_med = run(false);
            // winner must match the paper's break-even inequality
            let split_should_win = split_wins(bps, x, n, k, j);
            assert_eq!(
                split_med < raw_med,
                split_should_win,
                "seed {seed} at {mbps} Mb/s: split {split_med:.4}s vs raw {raw_med:.4}s \
                 (model says split_wins={split_should_win})"
            );
            // and the raw latency itself tracks the serialisation model:
            // body 15+4X² plus the 4-byte prefix over a B-bps link
            let link = LinkModel::new(bps, 0.002);
            let lower = link.transfer_time(4 * x * x + 19);
            assert!(
                raw_med > lower && raw_med < lower + 0.05,
                "seed {seed} at {mbps} Mb/s: raw {raw_med:.4}s vs analytic floor {lower:.4}s"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// scenario 6: duplicated frames — id-level de-duplication holds
// ---------------------------------------------------------------------------

#[test]
fn duplicated_frames_are_absorbed_by_id_deduplication() {
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 1,
            raw_clients: 4,
            decisions: 8,
            client_link: LinkFaults { dup_p: 0.5, ..LinkFaults::ideal() },
            reply_link: LinkFaults { dup_p: 0.5, ..LinkFaults::ideal() },
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("duplicate", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        // exactly 32 decisions recorded even though the wire carried far
        // more frames than that
        assert_eq!(r.completed_decisions(), 32, "seed {seed}");
        let dups: u64 = r.clients.iter().map(|c| c.dup_responses).sum();
        assert!(dups >= 1, "seed {seed}: duplication never observed");
        assert!(
            r.shards[0].requests > 32,
            "seed {seed}: no duplicated request reached the shard"
        );
        // per-client latency count equals accepted decisions: no double
        // counting from the duplicates
        for (i, c) in r.clients.iter().enumerate() {
            assert_eq!(c.latencies.len(), c.decisions, "seed {seed} client {i}");
        }
    }
}

// ---------------------------------------------------------------------------
// scenario 7: dropped frames — timeout + reconnect + retransmit recovers
// ---------------------------------------------------------------------------

#[test]
fn dropped_frames_recover_via_timeout_and_retransmit() {
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 1,
            raw_clients: 4,
            decisions: 6,
            req_timeout: 0.03,
            client_link: LinkFaults { drop_p: 0.3, ..LinkFaults::ideal() },
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("drop", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        assert_eq!(r.completed_decisions(), 24, "seed {seed}");
        let retries: u64 = r.clients.iter().map(|c| c.retries).sum();
        assert!(retries >= 1, "seed {seed}: a 30% drop rate never forced a retry");
        // responses were never dropped, so retransmits cannot double-count
        assert_eq!(r.clients.iter().map(|c| c.dup_responses).sum::<u64>(), 0);
        // drops may eat hellos (epochs with zero acks) but never duplicate
        assert!(at_most_one_ack_per_epoch(&r), "seed {seed}");
        assert!(r.log.contains(" drop "), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 8: mid-frame disconnect — torn frames surface as clean errors
// ---------------------------------------------------------------------------

#[test]
fn mid_frame_disconnect_is_a_clean_error_and_sessions_reroute() {
    let n_clients = 8;
    let moved = sessions_on_shard1(n_clients, 2);
    assert!(!moved.is_empty(), "hash placed nothing on shard 1; grow the client count");
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: n_clients,
            decisions: 6,
            think: 0.008,
            req_timeout: 0.05,
            probe_interval: Some(0.02),
            faults: vec![
                (0.008, FaultCmd::CutShardUplinkMidFrame(1)),
                (0.1, FaultCmd::RestartShard(1)),
            ],
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("midframe_cut", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        assert_eq!(r.completed_decisions(), n_clients * 6, "seed {seed}");
        // the torn frame was rejected at the framing layer, not half-parsed
        assert!(
            r.shards[1].frame_errors >= 1,
            "seed {seed}: the cut never tore a frame"
        );
        assert!(r.log.contains(" cut_mid_frame "), "seed {seed}");
        // victims re-routed and the shard came back
        assert!(r.gateway.reassigned >= 1, "seed {seed}");
        assert_eq!(r.shard_states[1], ShardState::Up, "seed {seed}");
        assert!(r.hello_acks_exactly_once(), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 10: adaptive codec on the 1 Mb/s shaped link — the PR's
// acceptance gate: ≥ 2x lower mean bytes/frame than the flat u8 format on
// the pendulum raster stream AND strictly lower p50 decision latency,
// deterministic across the seed matrix
// ---------------------------------------------------------------------------

/// One split client shipping the real pendulum raster stream over a
/// shaped uplink, with either the flat v1 format or the delta codec.
fn codec_cfg(seed: u64, codec: CodecId, bps: f64) -> ScenarioConfig {
    ScenarioConfig {
        seed,
        gateway: false,
        shards: 1,
        raw_clients: 0,
        split_clients: 1,
        decisions: 12,
        feat: (3, 48, 48),
        pendulum_stream: true,
        codec,
        encode_j: 0.002,
        req_timeout: 5.0,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        exec_fixed: 0.003,
        exec_per_item: 0.001,
        client_link: LinkFaults::shaped(bps, 0.002),
        reply_link: LinkFaults { latency: 0.002, ..LinkFaults::ideal() },
        ..ScenarioConfig::default()
    }
}

#[test]
fn delta_codec_beats_flat_on_the_1mbps_shaped_pendulum_stream() {
    for seed in SEEDS {
        let mut flat = run_and_emit("codec_1mbps_flat", &codec_cfg(seed, CodecId::Flat, 1e6));
        let mut delta = run_and_emit("codec_1mbps_delta", &codec_cfg(seed, CodecId::Delta, 1e6));
        for (name, r) in [("flat", &flat), ("delta", &delta)] {
            assert_eq!(r.clients[0].decisions, 12, "seed {seed} {name}: lost decisions");
            assert_eq!(r.clients[0].payload_mismatches, 0, "seed {seed} {name}");
            assert_eq!(r.total_give_ups(), 0, "seed {seed} {name}");
            assert_eq!(r.clients[0].frames_sent, 12, "seed {seed} {name}");
        }
        // no chaos here: the chain never breaks, so exactly one keyframe
        // amortises over the run and the shard decodes every frame
        assert_eq!(delta.clients[0].keyframes, 1, "seed {seed}");
        assert_eq!(delta.clients[0].deltas, 11, "seed {seed}");
        assert_eq!(delta.shards[0].codec_frames, 12, "seed {seed}");
        assert_eq!(delta.shards[0].codec_rejects, 0, "seed {seed}");

        let flat_bpf = flat.clients[0].bytes_sent as f64 / flat.clients[0].frames_sent as f64;
        let delta_bpf = delta.clients[0].bytes_sent as f64 / delta.clients[0].frames_sent as f64;
        assert!(
            flat_bpf >= 2.0 * delta_bpf,
            "seed {seed}: mean bytes/frame flat {flat_bpf:.0} vs delta {delta_bpf:.0} \
             — compression ratio {:.2} < 2.0",
            flat_bpf / delta_bpf
        );
        let flat_p50 = flat.clients[0].latencies.median();
        let delta_p50 = delta.clients[0].latencies.median();
        assert!(
            delta_p50 < flat_p50,
            "seed {seed}: delta p50 {delta_p50:.4}s not strictly below flat {flat_p50:.4}s"
        );
    }
}

// ---------------------------------------------------------------------------
// scenario 11: rate-controller convergence under 1/5/20 Mb/s shaping — the
// congested link walks the quantisation ladder coarser, the fast link
// never leaves the finest rung, and no level ever corrupts a frame
// ---------------------------------------------------------------------------

#[test]
fn rate_controller_converges_per_link_bandwidth() {
    for seed in SEEDS {
        let run = |mbps: f64| {
            let cfg = ScenarioConfig {
                decisions: 24,
                feat: (3, 24, 24),
                rate: RateConfig { target_latency: 0.005, ..RateConfig::default() },
                // keep the non-link latency terms (encode, exec, queue)
                // well inside the hysteresis band, so only serialisation
                // time separates the three bandwidths
                encode_j: 0.0005,
                exec_fixed: 0.0005,
                exec_per_item: 0.0001,
                client_link: LinkFaults::shaped(mbps * 1e6, 0.001),
                reply_link: LinkFaults { latency: 0.001, ..LinkFaults::ideal() },
                ..codec_cfg(seed, CodecId::Delta, mbps * 1e6)
            };
            run_and_emit(&format!("codec_rate_{mbps}mbps"), &cfg)
        };
        let slow = run(1.0);
        let mid = run(5.0);
        let fast = run(20.0);
        for (name, r) in [("1", &slow), ("5", &mid), ("20", &fast)] {
            assert_eq!(r.total_give_ups(), 0, "seed {seed} {name}Mb/s");
            assert_eq!(r.clients[0].payload_mismatches, 0, "seed {seed} {name}Mb/s");
            assert_eq!(r.shards[0].codec_rejects, 0, "seed {seed} {name}Mb/s");
            assert_eq!(r.clients[0].decisions, 24, "seed {seed} {name}Mb/s");
        }
        // congestion drives the controller coarser; headroom holds it fine
        assert!(
            slow.clients[0].quant_coarser >= 1,
            "seed {seed}: 1 Mb/s never stepped coarser"
        );
        assert!(
            slow.clients[0].final_qmax < 255,
            "seed {seed}: 1 Mb/s finished at the finest rung"
        );
        assert_eq!(
            fast.clients[0].final_qmax, 255,
            "seed {seed}: 20 Mb/s left the finest rung"
        );
        assert_eq!(fast.clients[0].quant_coarser, 0, "seed {seed}");
        assert!(
            mid.clients[0].final_qmax >= slow.clients[0].final_qmax,
            "seed {seed}: 5 Mb/s ended coarser than 1 Mb/s"
        );
    }
}

// ---------------------------------------------------------------------------
// scenario 12: shard restart never decodes against a stale delta base —
// the first delta to reach the fresh incarnation is refused (not silently
// decoded), the client re-keys, and every decoded frame still echoes the
// sent payload's checksum
// ---------------------------------------------------------------------------

#[test]
fn shard_restart_never_decodes_a_stale_delta_base() {
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            gateway: false,
            shards: 1,
            raw_clients: 0,
            split_clients: 1,
            decisions: 10,
            feat: (3, 16, 16),
            pendulum_stream: true,
            codec: CodecId::Delta,
            think: 0.1,
            req_timeout: 1.0,
            // crash + restart inside one think window: the client never
            // times out, so its next frame is a DELTA built on the dead
            // incarnation's base — the fresh decoder must refuse it
            faults: vec![
                (0.15, FaultCmd::CrashShard(0)),
                (0.151, FaultCmd::RestartShard(0)),
            ],
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("codec_restart", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        let c = &r.clients[0];
        // the stale-base delta was rejected, not decoded: exactly one
        // codec reject, answered with need_keyframe, and the decision
        // ledger still balances
        assert_eq!(r.shards[0].codec_rejects, 1, "seed {seed}: {:#?}", r.shards[0]);
        assert_eq!(c.need_keyframes, 1, "seed {seed}");
        assert_eq!(c.rejected, 1, "seed {seed}");
        assert_eq!(c.decisions as u64 + c.rejected, 10, "seed {seed}");
        // recovery: the initial keyframe plus the forced re-key
        assert_eq!(c.keyframes, 2, "seed {seed}");
        // the oracle: no decoded frame ever disagreed with what was sent —
        // a stale-base decode would have produced a checksum mismatch
        assert_eq!(c.payload_mismatches, 0, "seed {seed}");
        assert_eq!(c.reconnects, 0, "seed {seed}: restart was meant to be silent");
        assert!(r.log.contains(" codec_reject "), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 13: mid-frame cut under the delta codec — torn frames surface
// as clean errors, victims re-key (reconnect or need_keyframe), and no
// frame ever decodes against the wrong base
// ---------------------------------------------------------------------------

#[test]
fn delta_chain_recovers_from_a_mid_frame_cut() {
    let n_clients = 8;
    let moved = sessions_on_shard1(n_clients, 2);
    assert!(!moved.is_empty(), "hash placed nothing on shard 1; grow the client count");
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: 0,
            split_clients: n_clients,
            decisions: 6,
            feat: (3, 16, 16),
            pendulum_stream: true,
            codec: CodecId::Delta,
            think: 0.008,
            req_timeout: 0.05,
            probe_interval: Some(0.02),
            faults: vec![
                (0.008, FaultCmd::CutShardUplinkMidFrame(1)),
                (0.1, FaultCmd::RestartShard(1)),
            ],
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("codec_midframe_cut", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        // every decision is accounted for: answered or explicitly rejected
        let answered: usize = r.clients.iter().map(|c| c.decisions).sum();
        let rejected: u64 = r.clients.iter().map(|c| c.rejected).sum();
        assert_eq!(answered as u64 + rejected, (n_clients * 6) as u64, "seed {seed}");
        // the torn frame was refused at the framing layer
        assert!(r.shards[1].frame_errors >= 1, "seed {seed}: the cut never tore a frame");
        assert!(r.log.contains(" cut_mid_frame "), "seed {seed}");
        // chain integrity end to end: decoded content always echoed the
        // sent frame, and every victim re-keyed
        let mismatches: u64 = r.clients.iter().map(|c| c.payload_mismatches).sum();
        assert_eq!(mismatches, 0, "seed {seed}: a stale delta base was silently decoded");
        let keyframes: u64 = r.clients.iter().map(|c| c.keyframes).sum();
        assert!(
            keyframes > n_clients as u64,
            "seed {seed}: no victim ever re-keyed ({keyframes} keyframes)"
        );
        let decoded: u64 = r.shards.iter().map(|s| s.codec_frames).sum();
        assert!(decoded > 0, "seed {seed}: no codec frame reached a decoder");
        assert!(at_most_one_ack_per_epoch(&r), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 14: online/offline parity — the fleet-scale learning loop over
// ideal links reproduces the offline `rl::NativeTrainer` bit for bit at
// the same seed, which subsumes the ≤10% final-return acceptance gate
// ---------------------------------------------------------------------------

/// Small-but-real PPO engine shared by every learning scenario: the tier-1
/// suite trains it in debug builds, so keep the per-update cost tiny.
fn small_learner(seed: u64) -> LearnerConfig {
    LearnerConfig {
        core: NativeConfig { hidden: 8, minibatch: 8, seed, ..NativeConfig::default() },
        rollout_steps: 32,
        ppo_epochs: 2,
        gae_lambda: 0.95,
        publish_every: 1,
    }
}

#[test]
fn online_learning_matches_the_offline_trainer_bit_for_bit() {
    for seed in SEEDS {
        let episodes = 12;
        let core = NativeConfig { hidden: 16, minibatch: 32, seed, ..NativeConfig::default() };
        // offline reference: the native trainer at the same seed and knobs
        let mut offline = NativeTrainer::new(
            TrainConfig {
                episodes,
                rollout_steps: 128,
                ppo_epochs: 4,
                gae_lambda: 0.95,
                seed,
                log_every: 0,
                ..TrainConfig::default()
            },
            core.clone(),
        );
        offline.train().expect("offline train");

        // online: the same engine behind the full gateway + shard + codec
        // stack, one learning client whose env stream replays the trainer's
        let cfg = ScenarioConfig {
            seed,
            shards: 1,
            raw_clients: 0,
            learning: Some(LearnSpec {
                clients: 1,
                episodes,
                learner: LearnerConfig {
                    core,
                    rollout_steps: 128,
                    ppo_epochs: 4,
                    gae_lambda: 0.95,
                    publish_every: 1,
                },
                max_lag: 4,
                update_cost: 0.002,
            }),
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("learn_parity", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        let c = &r.clients[0];
        assert_eq!(c.returns.len(), episodes, "seed {seed}: episodes lost");
        // the parity oracle (DESIGN.md §8): same quantisation (qmax 255
        // end to end), same rng consumers in the same order — every
        // episode return is identical, not merely close
        assert_eq!(
            c.returns,
            offline.stats.returns(),
            "seed {seed}: online returns diverged from the offline trainer"
        );
        // and therefore the paper-facing gate holds with margin: online
        // final-100 within 10% of the offline baseline
        let final_on = c.returns.iter().sum::<f64>() / c.returns.len() as f64;
        let final_off = offline.stats.final_100();
        assert!(
            (final_on - final_off).abs() <= 0.10 * final_off.abs(),
            "seed {seed}: online final {final_on:.1} vs offline {final_off:.1}"
        );
        // the serving stack did real work to get there
        let s = &r.shards[0];
        assert_eq!(s.updates as usize, offline.updates, "seed {seed}: update count");
        assert!(s.exp_frames as usize >= episodes * 200, "seed {seed}: {}", s.exp_frames);
        assert_eq!(r.gateway.policy_published, s.published, "seed {seed}");
        assert!(s.final_version > 0, "seed {seed}: no version ever adopted");
        // ideal links + one shard: the staleness machinery stays silent
        assert_eq!(r.total_applied_stale(), 0, "seed {seed}");
        assert_eq!(r.total_stale_rejections(), 0, "seed {seed}");
        assert_eq!(r.gateway.policy_stale_rejects, 0, "seed {seed}");
        assert_eq!(c.final_qmax, 255, "seed {seed}: rate controller left the parity rung");
    }
}

// ---------------------------------------------------------------------------
// scenario 15: training during shard crash + restart — a shard (and its
// learner state) dies mid-training and restarts inside the clients'
// retransmit window, so its sessions resume on a fresh version-0 learner
// that the staleness gate vetoes and the resync path re-arms in place
// ---------------------------------------------------------------------------

#[test]
fn training_survives_shard_crash_and_restart() {
    let n_learn = 6;
    let episodes = 3;
    let moved = sessions_on_shard1(n_learn, 2);
    assert!(
        !moved.is_empty() && moved.len() < n_learn,
        "hash must place learning clients on both shards, got {moved:?}"
    );
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: 0,
            probe_interval: Some(0.02),
            // restart 0.2s after the crash: with the default 0.25s request
            // timeout every victim's retransmit lands on the restarted
            // shard, so the run exercises learner-state loss rather than
            // session migration (the pin survives a fast restart)
            faults: vec![
                (0.35, FaultCmd::CrashShard(1)),
                (0.55, FaultCmd::RestartShard(1)),
            ],
            learning: Some(LearnSpec {
                clients: n_learn,
                episodes,
                learner: small_learner(seed),
                ..LearnSpec::default()
            }),
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("learn_shard_restart", &cfg);
        let b = run_scenario(&cfg).expect("rerun");
        assert_eq!(r.log, b.log, "seed {seed}: same-seed learning logs diverged");

        assert_eq!(r.total_give_ups(), 0, "seed {seed}: a learning client gave up");
        assert_eq!(r.total_episodes(), n_learn * episodes, "seed {seed}: episodes lost");
        for (i, c) in r.clients.iter().enumerate() {
            assert_eq!(c.returns.len(), episodes, "seed {seed} client {i}");
            for &ret in &c.returns {
                assert!((-4000.0..=0.0).contains(&ret), "seed {seed} client {i}: {ret}");
            }
        }
        // the ISSUE's acceptance gate: zero stale-version actions applied
        assert_eq!(r.total_applied_stale(), 0, "seed {seed}");
        // the fresh incarnation came back acting at version 0 while the
        // fleet had trained far past it: the gateway vetoed its first
        // decisions and re-armed it with the latest snapshot
        assert!(r.gateway.policy_stale_rejects >= 1, "seed {seed}: veto never fired");
        assert!(r.gateway.policy_resyncs >= 1, "seed {seed}: resync never fired");
        assert!(r.shards[1].final_version > 0, "seed {seed}: shard 1 never re-armed");
        // mid-episode retransmits against the fresh buffer surface as
        // dropped-incomplete transitions, never as corrupt rollouts
        let dropped: u64 = r.shards.iter().map(|s| s.dropped_incomplete).sum();
        assert!(dropped >= 1, "seed {seed}: restart never dropped a pending step");
        // training continued end to end and versions stayed monotonic
        assert!(r.gateway.policy_published >= 10, "seed {seed}: {:?}", r.gateway);
        for (si, s) in r.shards.iter().enumerate() {
            assert!(
                s.adopted_versions.windows(2).all(|w| w[0] < w[1]),
                "seed {seed} shard {si}: adoption not strictly increasing: {:?}",
                s.adopted_versions
            );
        }
        assert!(r.shards[0].updates >= 10, "seed {seed}: {}", r.shards[0].updates);
        assert!(r.gateway.crash_detected >= 1, "seed {seed}: crash never detected");
        assert_eq!(r.shard_states[1], ShardState::Up, "seed {seed}");
        assert!(r.hello_acks_exactly_once(), "seed {seed}");
        assert!(r.log.contains(" fault_crash "), "seed {seed}");
        assert!(r.log.contains(" fault_restart "), "seed {seed}");
        assert!(r.log.contains(" resync "), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 16: policy-version flap under partition — a partitioned shard
// keeps acting on a frozen policy while the fleet trains past it; on heal
// the staleness gate vetoes its lagging actions, the resync path re-arms
// it, and no client ever applies an action beyond the lag bound
// ---------------------------------------------------------------------------

#[test]
fn version_flap_under_partition_is_vetoed_and_resynced() {
    let n_learn = 4;
    let episodes = 3;
    let moved = sessions_on_shard1(n_learn, 2);
    assert!(
        !moved.is_empty() && moved.len() < n_learn,
        "hash must place learning clients on both shards, got {moved:?}"
    );
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: 0,
            req_timeout: 0.25,
            // no probes: sessions stay pinned through the partition, so
            // heal replays the frozen shard's stale decisions through the
            // gateway's veto instead of migrating them away
            probe_interval: None,
            faults: vec![
                (0.4, FaultCmd::PartitionShard(1)),
                (1.0, FaultCmd::HealShard(1)),
                (1.4, FaultCmd::PartitionShard(1)),
                (1.8, FaultCmd::HealShard(1)),
            ],
            learning: Some(LearnSpec {
                clients: n_learn,
                episodes,
                learner: small_learner(seed),
                max_lag: 2,
                ..LearnSpec::default()
            }),
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("learn_version_flap", &cfg);
        let b = run_scenario(&cfg).expect("rerun");
        assert_eq!(r.log, b.log, "seed {seed}: same-seed learning logs diverged");

        assert_eq!(r.total_give_ups(), 0, "seed {seed}: a learning client gave up");
        assert_eq!(r.total_episodes(), n_learn * episodes, "seed {seed}: episodes lost");
        // the heart of the scenario: lagging actions were vetoed at the
        // gateway, the clients re-kicked them, and not one action beyond
        // the lag bound was ever applied
        assert!(r.gateway.policy_stale_rejects >= 1, "seed {seed}: veto never fired");
        assert!(r.total_stale_rejections() >= 1, "seed {seed}: no client saw a veto");
        assert_eq!(r.total_applied_stale(), 0, "seed {seed}: stale action applied");
        // the frozen shard was re-armed in place: resynced to the latest
        // version, adoptions strictly increasing, and it finished current
        assert!(r.gateway.policy_resyncs >= 1, "seed {seed}: resync never fired");
        for (si, s) in r.shards.iter().enumerate() {
            assert!(
                s.adopted_versions.windows(2).all(|w| w[0] < w[1]),
                "seed {seed} shard {si}: adoption not strictly increasing: {:?}",
                s.adopted_versions
            );
        }
        assert!(r.shards[1].final_version > 0, "seed {seed}: shard 1 never re-armed");
        assert!(r.gateway.policy_published >= 10, "seed {seed}: {:?}", r.gateway);
        assert!(r.log.contains(" partition "), "seed {seed}");
        assert!(r.log.contains(" gw_stale_reject "), "seed {seed}");
        assert!(r.log.contains(" resync "), "seed {seed}");
        assert!(r.log.contains(" adopt "), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 17: hostile clients — one sprays undecodable junk at the
// gateway, one streams well-formed codec frames with corrupt payloads at
// its shard. Both are quarantined by their budget (frame errors at the
// gateway, consecutive codec rejects at the shard), and the healthy
// cohort's p50 latency is unaffected by the attack
// ---------------------------------------------------------------------------

#[test]
fn malicious_clients_are_quarantined_without_hurting_healthy_latency() {
    let healthy = 4;
    let decisions = 8;
    for seed in SEEDS {
        let baseline = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: healthy,
            decisions,
            ..ScenarioConfig::default()
        };
        let attacked = ScenarioConfig {
            // clients 4 and 5: a junk-byte attacker and a corrupt-codec one
            malicious_clients: 2,
            attack_frames: 48,
            attack_interval: 0.001,
            gw_error_budget: 4,
            codec_reject_budget: 4,
            ..baseline.clone()
        };
        let b = run_and_emit("hostile_baseline", &baseline);
        let r = run_and_emit("hostile_quarantine", &attacked);
        let rerun = run_scenario(&attacked).expect("rerun");
        assert_eq!(r.log, rerun.log, "seed {seed}: same-seed hostile logs diverged");

        // the healthy cohort is whole: every decision, no give-ups, no
        // retries forced by the attack
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        assert_eq!(r.completed_decisions(), healthy * decisions, "seed {seed}");
        assert!(r.hello_acks_exactly_once(), "seed {seed}");
        for (i, c) in r.clients.iter().take(healthy).enumerate() {
            assert_eq!(c.retries, 0, "seed {seed} client {i}: attack forced a retry");
        }
        // the junk attacker died at the gateway's frame-error budget: one
        // quarantine, the overflow dropped unread, and not one junk frame
        // ever reached a shard's framing layer
        assert_eq!(r.gateway.quarantined_sessions, 1, "seed {seed}");
        assert!(r.gateway.quarantine_drops > 0, "seed {seed}");
        assert_eq!(r.shards.iter().map(|s| s.frame_errors).sum::<u64>(), 0, "seed {seed}");
        // the codec attacker died at its shard's consecutive-reject budget:
        // rejects stop well short of the 48 frames it sent
        let shard_quarantines: u64 = r.shards.iter().map(|s| s.quarantined_sessions).sum();
        let shard_drops: u64 = r.shards.iter().map(|s| s.quarantine_drops).sum();
        let rejects: u64 = r.shards.iter().map(|s| s.codec_rejects).sum();
        assert_eq!(shard_quarantines, 1, "seed {seed}");
        assert!(shard_drops > 0, "seed {seed}");
        assert!(
            rejects > 4 && rejects < attacked.attack_frames,
            "seed {seed}: {rejects} rejects for {} hostile frames",
            attacked.attack_frames
        );
        assert_eq!(r.total_quarantined(), 2, "seed {seed}");
        assert!(r.log.contains(" quarantine "), "seed {seed}");
        assert!(r.log.contains(" gw_frame_error "), "seed {seed}");
        assert!(r.log.contains(" attack "), "seed {seed}");

        // the acceptance gate: healthy p50 with the attack running stays
        // within noise of the attack-free baseline (deadline-fired batches
        // dominate both, so the bound is generous yet meaningful)
        let worst_p50 = |rep: &ScenarioReport| {
            rep.clients
                .iter()
                .take(healthy)
                .map(|c| c.latencies.median())
                .fold(0.0_f64, f64::max)
        };
        let (base_p50, attacked_p50) = (worst_p50(&b), worst_p50(&r));
        assert!(
            attacked_p50 <= 1.5 * base_p50 + 2e-3,
            "seed {seed}: healthy p50 {attacked_p50:.4}s vs baseline {base_p50:.4}s"
        );
    }
}

// ---------------------------------------------------------------------------
// scenario 18: flash crowd — 3x more sessions than the admission bound
// arrive at once; the gateway sheds the overflow with explicit
// ERR_OVERLOADED frames, the shed clients back off with jittered retries,
// and every one of them eventually completes every decision
// ---------------------------------------------------------------------------

#[test]
fn flash_crowd_is_shed_gracefully_and_every_client_finishes() {
    let n_clients = 24;
    let decisions = 4;
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: n_clients,
            decisions,
            gw_max_sessions: 8,
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("flash_crowd", &cfg);
        let rerun = run_scenario(&cfg).expect("rerun");
        assert_eq!(r.log, rerun.log, "seed {seed}: same-seed flash-crowd logs diverged");

        // graceful degradation, not collapse: the overflow was shed with
        // explicit overload frames, never by stalling or dropping silently
        assert!(r.gateway.shed_hellos > 0, "seed {seed}: admission never shed");
        assert_eq!(
            r.gateway.shed_hellos,
            r.total_overload_rejections(),
            "seed {seed}: a shed was not answered with an explicit frame"
        );
        assert!(r.log.contains(" shed "), "seed {seed}");
        assert!(r.log.contains(" backoff "), "seed {seed}");
        // and liveness: backoff + retry admitted everyone in the end
        assert_eq!(r.total_give_ups(), 0, "seed {seed}: a shed client starved");
        assert_eq!(r.completed_decisions(), n_clients * decisions, "seed {seed}");
        assert_eq!(r.clients.iter().map(|c| c.dup_responses).sum::<u64>(), 0);
        assert_eq!(r.total_quarantined(), 0, "seed {seed}: shedding is not quarantine");
        assert!(at_most_one_ack_per_epoch(&r), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 9: jitter + reorder everywhere — liveness with zero retries
// ---------------------------------------------------------------------------

#[test]
fn jittered_reordering_links_stay_exactly_once_without_retries() {
    for seed in SEEDS {
        let jittery = LinkFaults {
            jitter: 0.003,
            reorder_p: 0.2,
            reorder_delay: 0.005,
            ..LinkFaults::ideal()
        };
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: 4,
            split_clients: 2,
            decisions: 8,
            client_link: jittery,
            reply_link: jittery,
            shard_link: jittery,
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("jitter", &cfg);
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        assert_eq!(r.completed_decisions(), 48, "seed {seed}");
        // nothing was lost, so jitter alone must not trigger the recovery
        // machinery: no retries, no reconnects, no duplicates
        assert_eq!(r.clients.iter().map(|c| c.retries).sum::<u64>(), 0, "seed {seed}");
        assert_eq!(r.clients.iter().map(|c| c.reconnects).sum::<u64>(), 0, "seed {seed}");
        assert_eq!(r.clients.iter().map(|c| c.dup_responses).sum::<u64>(), 0);
        assert!(r.hello_acks_exactly_once(), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 19: live scale-up under a flash crowd — a pre-provisioned spare
// joins the ring mid-crowd (epoch bump), only the keyspace the ring hands
// it migrates, every drained handoff forces exactly one keyframe re-sync
// (the bounded storm), and the shed overflow re-admits under the new epoch
// ---------------------------------------------------------------------------

#[test]
fn scale_up_under_flash_crowd_bounds_the_keyframe_storm() {
    let n_clients = 32;
    let decisions = 10;
    let moved = moved_by_adding_shard(n_clients, 2, 2);
    assert!(!moved.is_empty(), "adding shard 2 moved no keyspace; grow the client count");
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: 0,
            split_clients: n_clients,
            decisions,
            feat: (3, 16, 16),
            pendulum_stream: true,
            codec: CodecId::Delta,
            think: 0.05,
            // the crowd outnumbers admission: 4 sessions shed at t=0 and
            // re-hello into the grown fleet once capacity frees up
            gw_max_sessions: 28,
            faults: vec![(0.25, FaultCmd::AddShard(2))],
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("scale_up_flash_crowd", &cfg);
        let rerun = run_scenario(&cfg).expect("rerun");
        assert_eq!(r.log, rerun.log, "seed {seed}: same-seed scale-up logs diverged");

        // flash crowd half: the overflow was shed explicitly, and backoff
        // plus the scale-up admitted every client in the end
        assert!(r.gateway.shed_hellos > 0, "seed {seed}: admission never shed");
        assert_eq!(r.gateway.shed_hellos, r.total_overload_rejections(), "seed {seed}");
        assert_eq!(r.total_give_ups(), 0, "seed {seed}: a shed client starved");
        let answered: usize = r.clients.iter().map(|c| c.decisions).sum();
        let rejected: u64 = r.clients.iter().map(|c| c.rejected).sum();
        assert_eq!(
            answered as u64 + rejected,
            (n_clients * decisions) as u64,
            "seed {seed}: the decision ledger does not balance"
        );

        // surgical migration: sessions moved exactly once, all of them
        // inside the keyspace the ring handed to the new shard
        let started = migration_log_sessions(&r.log, "migrate_start");
        let finished = migration_log_sessions(&r.log, "migrate");
        assert!(!finished.is_empty(), "seed {seed}: no session ever migrated");
        assert_eq!(r.gateway.migrations, finished.len() as u64, "seed {seed}");
        let unique: BTreeSet<u32> = finished.iter().copied().collect();
        assert_eq!(unique.len(), finished.len(), "seed {seed}: a session migrated twice");
        assert_eq!(
            started.iter().copied().collect::<BTreeSet<u32>>(),
            unique,
            "seed {seed}: a migration started without finishing (or vice versa)"
        );
        for s in &unique {
            assert!(
                moved.contains(s),
                "seed {seed}: session {s} migrated outside the moved keyspace"
            );
        }
        assert!(r.gateway.migrations as usize <= moved.len(), "seed {seed}");
        assert_eq!(r.gateway.reassigned, r.gateway.migrations, "seed {seed}");
        // no crash, no cut: every handoff completed as a quiescent drain
        assert_eq!(r.gateway.drained_handoffs, r.gateway.migrations, "seed {seed}");

        // the bounded keyframe storm: exactly one initial keyframe per
        // client plus exactly one forced re-key per handoff — nothing else
        let keyframes: u64 = r.clients.iter().map(|c| c.keyframes).sum();
        let need: u64 = r.clients.iter().map(|c| c.need_keyframes).sum();
        let codec_rejects: u64 = r.shards.iter().map(|s| s.codec_rejects).sum();
        assert_eq!(need, r.gateway.migrations, "seed {seed}: re-sync storm unbounded");
        assert_eq!(codec_rejects, need, "seed {seed}");
        assert_eq!(rejected, need, "seed {seed}");
        assert_eq!(
            keyframes,
            n_clients as u64 + need,
            "seed {seed}: keyframes beyond one per client + one per handoff"
        );
        let mismatches: u64 = r.clients.iter().map(|c| c.payload_mismatches).sum();
        assert_eq!(mismatches, 0, "seed {seed}: a stale base was silently decoded");

        // the epoch protocol: pre-join placements carry epoch 2, and the
        // shed clients re-admitted after the join prove epoch 3 reached
        // the wire
        assert!(r.clients.iter().all(|c| c.topology_epoch >= 2), "seed {seed}");
        let max_epoch = r.clients.iter().map(|c| c.topology_epoch).max().unwrap();
        assert_eq!(max_epoch, 3, "seed {seed}: no hello ack carried the post-join epoch");

        // the newcomer did real work and finished routable
        assert!(r.shards[2].requests > 0, "seed {seed}: the new shard never served");
        assert_eq!(r.shard_states[2], ShardState::Up, "seed {seed}");
        assert_eq!(r.gateway.no_route, 0, "seed {seed}");
        assert_eq!(r.total_quarantined(), 0, "seed {seed}");
        assert!(at_most_one_ack_per_epoch(&r), "seed {seed}");
        assert!(r.log.contains(" fault_add_shard "), "seed {seed}");
        assert!(r.log.contains("why=scale_up"), "seed {seed}");
        assert!(r.log.contains(" migration_sweep "), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 20: planned scale-down with in-flight learning clients — the
// leaving shard drains through the per-session state machine, live learner
// tracks (pending transition + partial rollout) transfer at the quiescent
// point, and not one experience transition is lost at the seam
// ---------------------------------------------------------------------------

#[test]
fn planned_scale_down_drains_learning_sessions_with_zero_lost_transitions() {
    let n_learn = 12;
    let episodes = 3;
    let moved = sessions_on_shard(n_learn, 3, 2);
    assert!(
        !moved.is_empty() && moved.len() < n_learn,
        "hash must place learning clients on shard 2 and elsewhere, got {moved:?}"
    );
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 3,
            raw_clients: 0,
            faults: vec![(0.4, FaultCmd::RemoveShard(2))],
            learning: Some(LearnSpec {
                clients: n_learn,
                episodes,
                learner: small_learner(seed),
                ..LearnSpec::default()
            }),
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("scale_down_drain", &cfg);
        let rerun = run_scenario(&cfg).expect("rerun");
        assert_eq!(r.log, rerun.log, "seed {seed}: same-seed scale-down logs diverged");

        // zero dropped sessions: nobody gave up, nobody even reconnected —
        // the drain is invisible to the client protocol
        assert_eq!(r.total_give_ups(), 0, "seed {seed}: a learning client gave up");
        assert_eq!(r.clients.iter().map(|c| c.reconnects).sum::<u64>(), 0, "seed {seed}");
        assert!(r.hello_acks_exactly_once(), "seed {seed}");
        assert_eq!(r.total_episodes(), n_learn * episodes, "seed {seed}: episodes lost");
        for (i, c) in r.clients.iter().enumerate() {
            assert_eq!(c.returns.len(), episodes, "seed {seed} client {i}");
            for &ret in &c.returns {
                assert!((-4000.0..=0.0).contains(&ret), "seed {seed} client {i}: {ret}");
            }
        }

        // the headline gate: a planned scale-down loses NO experience —
        // every pending transition crossed the seam via the track transfer
        assert_eq!(
            r.total_dropped_transitions(),
            0,
            "seed {seed}: a transition died at the migration seam"
        );
        // every session pinned to the leaving shard drained off exactly
        // once, at a quiescent point, with its learner track in hand
        assert_eq!(r.gateway.migrations as usize, moved.len(), "seed {seed}");
        assert_eq!(
            r.gateway.drained_handoffs, r.gateway.migrations,
            "seed {seed}: a planned drain was forced"
        );
        assert!(r.log.contains("drained=true track=true"), "seed {seed}: no track moved");
        assert!(!r.log.contains("drained=false"), "seed {seed}: a forced handoff leaked in");

        // codec re-sync across the seam: exactly one refused delta and one
        // forced keyframe per handoff, and the checksum oracle stays clean
        let need: u64 = r.clients.iter().map(|c| c.need_keyframes).sum();
        let rejects: u64 = r.shards.iter().map(|s| s.codec_rejects).sum();
        assert_eq!(need, r.gateway.migrations, "seed {seed}");
        assert_eq!(rejects, r.gateway.migrations, "seed {seed}");
        let mismatches: u64 = r.clients.iter().map(|c| c.payload_mismatches).sum();
        assert_eq!(mismatches, 0, "seed {seed}: a stale base was silently decoded");

        // training stayed sound end to end: no stale action ever applied,
        // adoption strictly monotone everywhere (the leaving shard keeps
        // adopting fan-outs while it drains)
        assert_eq!(r.total_applied_stale(), 0, "seed {seed}");
        for (si, s) in r.shards.iter().enumerate() {
            assert!(
                s.adopted_versions.windows(2).all(|w| w[0] < w[1]),
                "seed {seed} shard {si}: adoption not strictly increasing: {:?}",
                s.adopted_versions
            );
        }
        // the leaving shard did real learning work before handing off, and
        // finished outside the ring (reported Down = not routable)
        assert!(r.shards[2].exp_frames > 0, "seed {seed}: shard 2 never ingested");
        assert_eq!(r.shard_states[2], ShardState::Down, "seed {seed}");
        assert_eq!(r.gateway.no_route, 0, "seed {seed}");
        assert!(r.log.contains(" fault_remove_shard "), "seed {seed}");
        assert!(r.log.contains("why=scale_down"), "seed {seed}");
        assert!(r.log.contains(" migration_sweep "), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 21: shard crash during migration — the shard leaves the ring
// and dies 0.2 ms later, mid-drain. In-flight replies are lost, the stuck
// handoffs complete forced, and every session still lands on exactly one
// live shard with every decision answered exactly once
// ---------------------------------------------------------------------------

#[test]
fn crash_mid_migration_lands_every_session_on_exactly_one_live_shard() {
    let n_clients = 12;
    let decisions = 24;
    let moved = sessions_on_shard1(n_clients, 2);
    assert!(
        !moved.is_empty() && moved.len() < n_clients,
        "hash must place sessions on both shards, got {moved:?}"
    );
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: n_clients,
            decisions,
            req_timeout: 0.03,
            // zero think keeps a request in flight for nearly every
            // session, so the crash 0.2 ms after the removal catches the
            // drains mid-flight instead of finding them already quiesced
            faults: vec![
                (0.02, FaultCmd::RemoveShard(1)),
                (0.0202, FaultCmd::CrashShard(1)),
            ],
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("migration_crash", &cfg);
        let rerun = run_scenario(&cfg).expect("rerun");
        assert_eq!(r.log, rerun.log, "seed {seed}: same-seed crash logs diverged");

        // exactly-once handoff: every session that started on the leaving
        // shard migrated once — never zero times, never twice
        let started = migration_log_sessions(&r.log, "migrate_start");
        let finished = migration_log_sessions(&r.log, "migrate");
        let unique: BTreeSet<u32> = finished.iter().copied().collect();
        assert_eq!(unique.len(), finished.len(), "seed {seed}: a session handed off twice");
        assert_eq!(
            unique,
            moved.iter().copied().collect::<BTreeSet<u32>>(),
            "seed {seed}: handoffs != the leaving shard's sessions"
        );
        assert_eq!(started.len(), finished.len(), "seed {seed}: a migration never completed");
        assert_eq!(r.gateway.migrations as usize, moved.len(), "seed {seed}");
        assert_eq!(r.gateway.reassigned, r.gateway.migrations, "seed {seed}");
        // the crash caught at least one drain in flight and forced it
        assert!(
            r.gateway.drained_handoffs < r.gateway.migrations,
            "seed {seed}: the crash never caught a drain mid-flight"
        );
        assert!(r.log.contains("drained=false"), "seed {seed}: no forced handoff logged");
        assert!(r.gateway.crash_detected >= 1, "seed {seed}: crash never detected");

        // ...and still: liveness plus exactly-once delivery on the
        // surviving shard, with the lost in-flight replies recovered by
        // timeout + retransmit, never duplicated
        assert_eq!(r.total_give_ups(), 0, "seed {seed}: a client gave up");
        assert_eq!(r.completed_decisions(), n_clients * decisions, "seed {seed}");
        assert_eq!(r.clients.iter().map(|c| c.dup_responses).sum::<u64>(), 0, "seed {seed}");
        assert!(
            r.clients.iter().map(|c| c.retries).sum::<u64>() >= 1,
            "seed {seed}: the lost in-flight replies never forced a retry"
        );
        assert!(r.hello_acks_exactly_once(), "seed {seed}");
        assert_eq!(r.gateway.no_route, 0, "seed {seed}");
        assert_eq!(r.shard_states[1], ShardState::Down, "seed {seed}");
        assert!(r.log.contains(" fault_remove_shard "), "seed {seed}");
        assert!(r.log.contains(" fault_crash "), "seed {seed}");
        assert!(r.log.contains(" trunk_lost "), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 22: diurnal breathing — no scripted topology faults at all. The
// closed autoscaling loop samples windowed queue pressure on a virtual-time
// cadence and drives the same drain/cut-over migration machinery the timed
// faults use: the fleet grows into the rush-hour peak and shrinks back in
// the trough, sessions (learning ones included) migrate with zero lost
// transitions and exactly one forced keyframe per handoff, and the whole
// breathing pattern is byte-identical per seed
// ---------------------------------------------------------------------------

#[test]
fn diurnal_load_breathes_the_fleet_through_the_autoscaler() {
    let n_split = 14;
    let n_learn = 2;
    let n_clients = n_split + n_learn;
    let decisions = 200;
    let cooldown = 12.0;
    // growing 2 -> 3 must hand the newcomer a non-empty keyspace,
    // otherwise a scale-up is unobservable through the migration ledger
    let moved = moved_by_adding_shard(n_clients, 2, 2);
    assert!(!moved.is_empty(), "adding shard 2 moved no keyspace; grow the client count");
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            shards: 2,
            raw_clients: 0,
            split_clients: n_split,
            decisions,
            feat: (3, 16, 16),
            pendulum_stream: true,
            codec: CodecId::Delta,
            // rush hour by arithmetic: the 20 ms peak think stretches 400x
            // in the trough, so demand sweeps from ~0 to well past what two
            // shards can serve and back, twice over the run
            think: 0.02,
            diurnal: Some((240.0, 400.0)),
            // small batches against a slow executor: at the peak every
            // shard runs a deep backlog (the windowed p95 the scaler sees),
            // in the trough lone items fire on the 0.5 ms deadline
            policy: BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(500) },
            exec_fixed: 0.02,
            exec_per_item: 0.01,
            learning: Some(LearnSpec {
                clients: n_learn,
                episodes: 1,
                learner: small_learner(seed),
                ..LearnSpec::default()
            }),
            autoscale: Some(AutoscaleSpec {
                cfg: AutoscaleConfig {
                    min_shards: 2,
                    max_shards: 4,
                    queue_high_ns: 20_000_000, // 20 ms of windowed p95
                    queue_low_ns: 5_000_000,   // 5 ms
                    shed_high: 0.05,
                    shed_low: 0.005,
                    confirm: 3,
                    cooldown,
                },
                interval: 2.0,
            }),
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("diurnal_breathing", &cfg);
        let rerun = run_scenario(&cfg).expect("rerun");
        assert_eq!(r.log, rerun.log, "seed {seed}: same-seed breathing logs diverged");

        // the headline: the autoscaler — not a scripted fault — moved the
        // topology both ways
        assert!(r.autoscale.samples > 0, "seed {seed}: the loop never sampled");
        assert!(r.autoscale.scale_ups >= 1, "seed {seed}: never grew into the peak");
        assert!(r.autoscale.scale_downs >= 1, "seed {seed}: never shrank after the peak");
        assert!(r.log.contains(" autoscale_sample "), "seed {seed}");
        assert!(r.log.contains(" autoscale_add_shard "), "seed {seed}");
        assert!(r.log.contains(" autoscale_remove_shard "), "seed {seed}");
        assert!(r.log.contains("why=autoscale_up"), "seed {seed}");
        assert!(r.log.contains("why=autoscale_down"), "seed {seed}");
        assert!(!r.log.contains(" fault_add_shard "), "seed {seed}: a scripted fault leaked in");
        assert!(!r.log.contains(" fault_remove_shard "), "seed {seed}");

        // damping: the cooldown bounds topology churn per simulated hour —
        // actions can never outnumber elapsed/cooldown, however hairy the
        // load curve gets
        let actions = r.autoscale.scale_ups + r.autoscale.scale_downs;
        assert!(
            (actions as f64) <= r.elapsed / cooldown + 1.0,
            "seed {seed}: {actions} actions in {:.0}s breaks the cooldown bound",
            r.elapsed
        );
        assert!(
            r.gateway.migrations <= actions * n_clients as u64,
            "seed {seed}: more migrations than scale actions can explain"
        );

        // every scale action migrated through the drain state machine:
        // planned handoffs only, zero lost learning transitions, exactly
        // one forced keyframe (and one refused delta) per migrated session
        assert!(r.gateway.migrations > 0, "seed {seed}: scaling never migrated a session");
        assert_eq!(r.gateway.drained_handoffs, r.gateway.migrations, "seed {seed}");
        assert!(r.log.contains("drained=true"), "seed {seed}");
        assert!(!r.log.contains("drained=false"), "seed {seed}: a forced handoff leaked in");
        assert_eq!(r.gateway.reassigned, r.gateway.migrations, "seed {seed}");
        assert_eq!(r.total_dropped_transitions(), 0, "seed {seed}: a transition died");
        let need: u64 = r.clients.iter().map(|c| c.need_keyframes).sum();
        let rejects: u64 = r.shards.iter().map(|s| s.codec_rejects).sum();
        assert_eq!(need, r.gateway.migrations, "seed {seed}: re-sync storm unbounded");
        assert_eq!(rejects, r.gateway.migrations, "seed {seed}");
        let mismatches: u64 = r.clients.iter().map(|c| c.payload_mismatches).sum();
        assert_eq!(mismatches, 0, "seed {seed}: a stale base was silently decoded");
        let started = migration_log_sessions(&r.log, "migrate_start");
        let finished = migration_log_sessions(&r.log, "migrate");
        assert_eq!(started.len(), finished.len(), "seed {seed}: a migration never completed");
        assert_eq!(r.gateway.migrations, finished.len() as u64, "seed {seed}");

        // client-side liveness through both breaths: nobody starved, the
        // split-side decision ledger balances, and the learning episodes
        // all completed with sane returns
        assert_eq!(r.total_give_ups(), 0, "seed {seed}: a client starved");
        let answered: usize = r.clients[..n_split].iter().map(|c| c.decisions).sum();
        let rejected: u64 = r.clients[..n_split].iter().map(|c| c.rejected).sum();
        assert_eq!(
            answered as u64 + rejected,
            (n_split * decisions) as u64,
            "seed {seed}: the split decision ledger does not balance"
        );
        assert_eq!(r.total_episodes(), n_learn, "seed {seed}: episodes lost");
        for (i, c) in r.clients[n_split..].iter().enumerate() {
            assert_eq!(c.returns.len(), 1, "seed {seed} learner {i}");
            assert!(
                (-4000.0..=0.0).contains(&c.returns[0]),
                "seed {seed} learner {i}: {}",
                c.returns[0]
            );
        }
        assert_eq!(r.total_applied_stale(), 0, "seed {seed}");
        assert_eq!(r.gateway.no_route, 0, "seed {seed}");
        assert_eq!(r.total_quarantined(), 0, "seed {seed}");
        assert!(at_most_one_ack_per_epoch(&r), "seed {seed}");
        // the fleet ends inside its configured bounds, with every breath
        // sampled on the virtual clock (two samples per cooldown at least)
        let up_now = r.shard_states.iter().filter(|&&s| s == ShardState::Up).count();
        assert!((2..=4).contains(&up_now), "seed {seed}: {up_now} shards outside [2, 4]");
        // (stale timeouts scheduled before the last decision can trail the
        // final tick, so give the cadence a few windows of slack)
        assert!(
            r.autoscale.samples as f64 >= r.elapsed / 2.0 - 4.0,
            "seed {seed}: sampling cadence drifted"
        );
    }
}

// ---------------------------------------------------------------------------
// scenario 23: per-decision tracing under wire chaos — every accepted
// decision carries one closed span whose stamps walk the gateway path in
// hop order on the virtual clock, the spans replay byte-for-byte at the
// same seed, and switching tracing off leaves the log and the wire
// untouched (DESIGN.md §12)
// ---------------------------------------------------------------------------

#[test]
fn traced_chaos_runs_replay_one_closed_span_per_decision() {
    const PATH: [usize; 10] = [
        STAGE_MINT,
        STAGE_ENCODE,
        STAGE_SEND,
        STAGE_GW_FORWARD,
        STAGE_ENQUEUE,
        STAGE_DEQUEUE,
        STAGE_PACK,
        STAGE_EXECUTE,
        STAGE_REPLY,
        STAGE_RECV,
    ];
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            trace: true,
            shards: 2,
            raw_clients: 4,
            split_clients: 2,
            decisions: 6,
            req_timeout: 0.04,
            client_link: LinkFaults { jitter: 0.002, drop_p: 0.2, ..LinkFaults::ideal() },
            ..ScenarioConfig::default()
        };
        let a = run_and_emit("trace_chaos", &cfg);
        let b = run_scenario(&cfg).expect("rerun");
        assert_eq!(a.log, b.log, "seed {seed}: same-seed traced logs diverged");
        assert_eq!(a.total_give_ups(), 0, "seed {seed}");
        assert_eq!(a.completed_decisions(), 36, "seed {seed}");
        assert!(a.log.contains(" trace "), "seed {seed}: no span closure in the log");
        assert!(a.stage_totals.total() > 0, "seed {seed}");
        for (c, (ca, cb)) in a.clients.iter().zip(&b.clients).enumerate() {
            // one closed span per accepted decision, and the whole span
            // set replays bit-for-bit — the trace IS part of the seed
            // contract, not a best-effort side channel
            assert_eq!(ca.traces.len(), ca.decisions, "seed {seed} client {c}");
            assert_eq!(ca.traces, cb.traces, "seed {seed} client {c}: spans not replayable");
            for tr in &ca.traces {
                assert_eq!((tr.id >> 32) as usize, c, "seed {seed}: span id lost its client");
                assert!(tr.stamps[STAGE_GW_FORWARD] > 0, "seed {seed}: gateway hop unset");
                let mut prev = 0u64;
                for stage in PATH {
                    let ns = tr.stamps[stage];
                    assert!(
                        ns >= prev,
                        "seed {seed} client {c} span {:#x}: stage {stage} went backwards",
                        tr.id
                    );
                    prev = ns;
                }
                assert!(tr.total_ns() > 0, "seed {seed} client {c}: open span {:#x}", tr.id);
            }
        }
        // trace off at the same seed: no spans, no trace lines — the
        // observability layer must be invisible until negotiated
        let u = run_scenario(&ScenarioConfig { trace: false, ..cfg.clone() }).expect("untraced");
        assert!(!u.log.contains(" trace "), "seed {seed}: untraced run logged a span");
        assert!(u.clients.iter().all(|c| c.traces.is_empty()), "seed {seed}");
        assert_eq!(u.stage_totals.total(), 0, "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// scenario 24: stage attribution under 1 Mb/s shaping — the spans don't
// just measure the slowdown, they *name* it: ≥90% of the latency the
// shaped link adds lands in the wire stage, and the aggregate attribution
// calls the up-wire dominant
// ---------------------------------------------------------------------------

#[test]
fn shaped_link_latency_is_attributed_to_the_wire_stage() {
    for seed in SEEDS {
        let mk = |link: LinkFaults| ScenarioConfig {
            seed,
            trace: true,
            shards: 1,
            raw_clients: 2,
            decisions: 6,
            obs_x: 24,
            // size-fired singleton batches keep queue wait out of the
            // picture, so the only term the link can move is its own
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) },
            req_timeout: 5.0,
            client_link: link,
            ..ScenarioConfig::default()
        };
        let ideal = run_and_emit("trace_wire_ideal", &mk(LinkFaults::ideal()));
        let shaped_cfg = mk(LinkFaults::shaped(1e6, 0.002));
        let shaped = run_and_emit("trace_wire_shaped", &shaped_cfg);
        let rerun = run_scenario(&shaped_cfg).expect("rerun");
        assert_eq!(shaped.log, rerun.log, "seed {seed}: same-seed shaped logs diverged");
        for (name, r) in [("ideal", &ideal), ("shaped", &shaped)] {
            assert_eq!(r.total_give_ups(), 0, "seed {seed} {name}");
            assert_eq!(r.completed_decisions(), 12, "seed {seed} {name}");
            let spans: usize = r.clients.iter().map(|c| c.traces.len()).sum();
            assert_eq!(spans, 12, "seed {seed} {name}: lost spans");
        }
        // the added p99-driving latency decomposes through the spans: the
        // wire stage absorbs ≥90% of everything the shaping added
        let added_total =
            shaped.stage_totals.total() as f64 - ideal.stage_totals.total() as f64;
        let added_wire = shaped.stage_totals.wire() as f64 - ideal.stage_totals.wire() as f64;
        assert!(added_total > 0.0, "seed {seed}: shaping added no traced latency");
        assert!(
            added_wire >= 0.9 * added_total,
            "seed {seed}: wire explains only {added_wire:.0}ns of {added_total:.0}ns added"
        );
        assert_eq!(
            shaped.stage_totals.dominant(),
            Some("wire_up"),
            "seed {seed}: shaped run not wire-dominated: {:?}",
            shaped.stage_totals
        );
    }
}

// ---------------------------------------------------------------------------
// scenario 25: flash-crowd attribution — 12 closed-loop clients against
// one deliberately slow shard: the spans pin the pain on queue wait (not
// execution), and the autoscaler's sample lines cite the same dominant
// stage its scale verdicts are based on
// ---------------------------------------------------------------------------

#[test]
fn flash_crowd_latency_is_attributed_to_queue_wait() {
    let n_clients = 12;
    let decisions = 4;
    for seed in SEEDS {
        let cfg = ScenarioConfig {
            seed,
            trace: true,
            shards: 1,
            raw_clients: n_clients,
            decisions,
            obs_x: 8,
            policy: BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(500) },
            exec_fixed: 0.004,
            exec_per_item: 0.002,
            req_timeout: 1.0,
            // pinned at one shard: the loop observes (and attributes) the
            // crowd every 10 ms but can never scale its way out
            autoscale: Some(AutoscaleSpec {
                cfg: AutoscaleConfig {
                    min_shards: 1,
                    max_shards: 1,
                    ..AutoscaleConfig::default()
                },
                interval: 0.01,
            }),
            ..ScenarioConfig::default()
        };
        let r = run_and_emit("trace_flash_crowd", &cfg);
        let rerun = run_scenario(&cfg).expect("rerun");
        assert_eq!(r.log, rerun.log, "seed {seed}: same-seed crowd logs diverged");
        assert_eq!(r.total_give_ups(), 0, "seed {seed}");
        assert_eq!(r.completed_decisions(), n_clients * decisions, "seed {seed}");
        assert_eq!(
            r.clients.iter().map(|c| c.retries).sum::<u64>(),
            0,
            "seed {seed}: the backlog pushed past the request timeout"
        );
        // the attribution: queue wait is the dominant stage, over half the
        // end-to-end time, and clearly ahead of the execution it feeds
        let t = &r.stage_totals;
        assert_eq!(t.dominant(), Some("queue"), "seed {seed}: {t:?}");
        assert!(t.queue() * 2 >= t.total(), "seed {seed}: queue under half: {t:?}");
        assert!(t.queue() > t.ns[4], "seed {seed}: execution outweighed queueing: {t:?}");
        // the scale verdict cites the same story the spans tell
        assert!(r.log.contains(" autoscale_sample "), "seed {seed}");
        assert!(r.log.contains(" dominant=queue"), "seed {seed}: no queue-cited window");
        assert_eq!(r.autoscale.scale_ups + r.autoscale.scale_downs, 0, "seed {seed}");
        // untraced control: the sampler still runs, but cites nothing
        let u = run_scenario(&ScenarioConfig { trace: false, ..cfg.clone() }).expect("untraced");
        assert!(u.log.contains(" autoscale_sample "), "seed {seed}");
        assert!(!u.log.contains(" dominant="), "seed {seed}: untraced sample cited a stage");
    }
}

//! Fuzz target: the [`SessionGate`] admission state machine driven by an
//! arbitrary op sequence — hellos with hostile codec/capability claims,
//! frame admissions, decode errors, capability probes, and topology-epoch
//! chaos (stale epochs, forged future epochs, epoch regression replays,
//! mid-sequence migrations) — in any order.
//!
//! cargo-fuzz layout (see `msg_decode.rs`); driven deterministically by
//! `rust/tests/fuzz_smoke.rs`.
//!
//! Invariants enforced after every op (DESIGN.md §9–10):
//!
//!   * the gate never panics, whatever order the ops arrive in;
//!   * a hello ack only ever grants capabilities the client requested
//!     AND the server masks in, and only echoes codec ids the server
//!     knows (everything else declines to flat);
//!   * an epoch-carrying hello is acked only when its epoch matches the
//!     server's topology epoch (when one is set) and never regresses the
//!     session's own watermark; refusals count `epoch_rejects` and never
//!     quarantine;
//!   * quarantine is sticky: once entered, no hello is acked, no frame
//!     is admitted, and no capability is granted, ever — until the
//!     session migrates, which is a fresh gate on a new shard (budgets
//!     reset, epoch watermarks carried);
//!   * an admitted frame always fits its per-type cap, and experience
//!     frames are only ever admitted with `CAP_EXPERIENCE` negotiated.

use miniconv::codec::CodecId;
use miniconv::net::framing::{Hello, CAP_EXPERIENCE, MSG_EXPERIENCE};
use miniconv::net::limits::{LimitsConfig, SessionGate};

pub fn fuzz_target(data: &[u8]) {
    // tight budgets so short op sequences can reach every state
    let mut gate = SessionGate::new(LimitsConfig {
        pre_hello_frame: 4096,
        max_pre_hello_bytes: 16 << 10,
        max_decode_errors: 4,
        ..LimitsConfig::default()
    });
    let mut quarantined = false;
    // mirror of the gate's epoch state, updated only on observed acks
    let mut topo: u64 = 0;
    let mut watermark: u64 = 0;
    for op in data.chunks_exact(6) {
        match op[0] % 5 {
            0 => {
                let h = Hello {
                    client: op[1] as u32,
                    split: op[2] & 1 != 0,
                    codec: op[3],
                    caps: op[4],
                    shard: None,
                    epoch: None,
                };
                let mask = op[5];
                match gate.on_hello(&h, mask, None) {
                    Some(ack) => {
                        assert!(!quarantined, "quarantined session got a hello ack");
                        assert_eq!(ack.caps, h.caps & mask, "ack granted unrequested caps");
                        if CodecId::from_wire(h.codec).is_some() {
                            assert_eq!(ack.codec, h.codec, "known codec id not echoed");
                        } else {
                            assert_eq!(ack.codec, 0, "unknown codec id not declined to flat");
                        }
                        assert_eq!(gate.grants(CAP_EXPERIENCE), ack.caps & CAP_EXPERIENCE != 0);
                    }
                    // an epoch-less hello skips epoch validation entirely:
                    // only quarantine can refuse it
                    None => assert!(quarantined, "ready session refused a hello"),
                }
            }
            1 => {
                let ty = op[1];
                let len = u16::from_le_bytes([op[2], op[3]]) as usize * op[4] as usize;
                if gate.admit(ty, len).is_ok() {
                    assert!(!quarantined, "quarantined session admitted a frame");
                    let cap = gate.limits().cap(ty);
                    assert!(cap > 0 && len <= cap, "admitted {len} bytes past cap {cap}");
                    if ty == MSG_EXPERIENCE {
                        assert!(
                            gate.grants(CAP_EXPERIENCE),
                            "experience frame admitted without the capability"
                        );
                    }
                }
            }
            2 => {
                if gate.on_decode_error() {
                    assert!(gate.quarantined(), "budget exhausted without quarantine");
                }
            }
            3 => {
                // a capability is only ever granted by a hello ack
                let granted = gate.grants(op[1]);
                if quarantined {
                    assert!(!granted, "quarantined session granted a capability");
                }
            }
            _ => {
                // topology-epoch chaos: a small epoch domain so stale,
                // current, forged-future, and regressed values all collide
                let e = u32::from_le_bytes([op[1], op[2], op[3], op[4]]) as u64 % 9;
                match op[5] % 3 {
                    0 => {
                        // the fleet moved: shards joined/left under us
                        gate.set_topology_epoch(e);
                        topo = e;
                    }
                    1 => {
                        // an epoch-carrying hello: a re-route claim that
                        // may be stale, current, forged, or a replay
                        let h = Hello {
                            client: op[1] as u32,
                            split: op[2] & 2 != 0,
                            codec: 1,
                            caps: 0,
                            shard: None,
                            epoch: Some(e),
                        };
                        let rejects_before = gate.epoch_rejects;
                        match gate.on_hello(&h, 0xff, Some(3)) {
                            Some(ack) => {
                                assert!(!quarantined, "quarantined session got an epoch ack");
                                assert!(
                                    topo == 0 || e == topo,
                                    "stale/forged epoch {e} acked at topology {topo}"
                                );
                                assert!(e >= watermark, "regressed epoch {e} acked");
                                watermark = e;
                                let expect = (topo > 0).then_some(topo);
                                assert_eq!(ack.epoch, expect, "ack stamped the wrong epoch");
                            }
                            None => {
                                let stale_or_forged = topo > 0 && e != topo;
                                assert!(
                                    quarantined || stale_or_forged || e < watermark,
                                    "valid epoch {e} refused (topology {topo}, \
                                     watermark {watermark})"
                                );
                                if !quarantined {
                                    assert_eq!(
                                        gate.epoch_rejects,
                                        rejects_before + 1,
                                        "epoch refusal not counted"
                                    );
                                    assert!(
                                        !gate.quarantined(),
                                        "an epoch refusal must never quarantine"
                                    );
                                }
                            }
                        }
                    }
                    _ => {
                        // the session migrates to a fresh shard: budgets
                        // and quarantine verdicts stay behind, the epoch
                        // watermarks follow
                        gate = gate.migrate();
                        assert!(!gate.quarantined(), "quarantine followed the migration");
                        assert_eq!(gate.decode_errors, 0, "decode budget followed the migration");
                        assert_eq!(gate.pre_hello_bytes, 0);
                        quarantined = false;
                    }
                }
            }
        }
        // stickiness: quarantine never clears until disconnect (the
        // migrate op models a disconnect-and-rejoin, and resets the flag)
        if quarantined {
            assert!(gate.quarantined(), "quarantine was not sticky");
        }
        quarantined = gate.quarantined();
    }
}

//! Fuzz target: the [`SessionGate`] admission state machine driven by an
//! arbitrary op sequence — hellos with hostile codec/capability claims,
//! frame admissions, decode errors, capability probes — in any order.
//!
//! cargo-fuzz layout (see `msg_decode.rs`); driven deterministically by
//! `rust/tests/fuzz_smoke.rs`.
//!
//! Invariants enforced after every op (DESIGN.md §9):
//!
//!   * the gate never panics, whatever order the ops arrive in;
//!   * a hello ack only ever grants capabilities the client requested
//!     AND the server masks in, and only echoes codec ids the server
//!     knows (everything else declines to flat);
//!   * quarantine is sticky: once entered, no hello is acked, no frame
//!     is admitted, and no capability is granted, ever;
//!   * an admitted frame always fits its per-type cap, and experience
//!     frames are only ever admitted with `CAP_EXPERIENCE` negotiated.

use miniconv::codec::CodecId;
use miniconv::net::framing::{Hello, CAP_EXPERIENCE, MSG_EXPERIENCE};
use miniconv::net::limits::{LimitsConfig, SessionGate};

pub fn fuzz_target(data: &[u8]) {
    // tight budgets so short op sequences can reach every state
    let mut gate = SessionGate::new(LimitsConfig {
        pre_hello_frame: 4096,
        max_pre_hello_bytes: 16 << 10,
        max_decode_errors: 4,
        ..LimitsConfig::default()
    });
    let mut quarantined = false;
    for op in data.chunks_exact(6) {
        match op[0] % 4 {
            0 => {
                let h = Hello {
                    client: op[1] as u32,
                    split: op[2] & 1 != 0,
                    codec: op[3],
                    caps: op[4],
                    shard: None,
                };
                let mask = op[5];
                match gate.on_hello(&h, mask, None) {
                    Some(ack) => {
                        assert!(!quarantined, "quarantined session got a hello ack");
                        assert_eq!(ack.caps, h.caps & mask, "ack granted unrequested caps");
                        if CodecId::from_wire(h.codec).is_some() {
                            assert_eq!(ack.codec, h.codec, "known codec id not echoed");
                        } else {
                            assert_eq!(ack.codec, 0, "unknown codec id not declined to flat");
                        }
                        assert_eq!(gate.grants(CAP_EXPERIENCE), ack.caps & CAP_EXPERIENCE != 0);
                    }
                    None => assert!(quarantined, "ready session refused a hello"),
                }
            }
            1 => {
                let ty = op[1];
                let len = u16::from_le_bytes([op[2], op[3]]) as usize * op[4] as usize;
                if gate.admit(ty, len).is_ok() {
                    assert!(!quarantined, "quarantined session admitted a frame");
                    let cap = gate.limits().cap(ty);
                    assert!(cap > 0 && len <= cap, "admitted {len} bytes past cap {cap}");
                    if ty == MSG_EXPERIENCE {
                        assert!(
                            gate.grants(CAP_EXPERIENCE),
                            "experience frame admitted without the capability"
                        );
                    }
                }
            }
            2 => {
                if gate.on_decode_error() {
                    assert!(gate.quarantined(), "budget exhausted without quarantine");
                }
            }
            _ => {
                // a capability is only ever granted by a hello ack
                let granted = gate.grants(op[1]);
                if quarantined {
                    assert!(!granted, "quarantined session granted a capability");
                }
            }
        }
        // stickiness: quarantine never clears until disconnect
        if quarantined {
            assert!(gate.quarantined(), "quarantine was not sticky");
        }
        quarantined = gate.quarantined();
    }
}

//! Fuzz target: [`Msg::decode`] over arbitrary frame bodies.
//!
//! cargo-fuzz layout: the entry point is `fuzz_target(data: &[u8])`, so
//! with a nightly toolchain the body drops unchanged into a
//! `libfuzzer_sys::fuzz_target!` wrapper. In this tree it is driven
//! deterministically by `rust/tests/fuzz_smoke.rs` (seeded corpus +
//! structured mutation + raw bytes) so the smoke run needs nothing
//! beyond `cargo test`.
//!
//! Invariants enforced on every input (DESIGN.md §9):
//!
//!   * decode never panics — a hostile frame is an `Err`, not an abort;
//!   * decode never retains more bytes than the frame delivered — every
//!     wire-claimed element count is validated against the bytes
//!     actually present before it sizes an allocation;
//!   * decode ∘ encode is a fixed point: anything decode accepts
//!     re-encodes to a frame of the same length that decodes to the
//!     same message (compared byte-wise after a second encode, so NaN
//!     float payloads cannot hide a mismatch);
//!   * the trace-trailer layer (DESIGN.md §12) never panics either: the
//!     peel and the no-decode tail stamp agree byte-for-byte on whether
//!     a trailer is present, a refused stamp never mutates the frame,
//!     and peel ∘ append is the identity.

use miniconv::net::framing::Msg;
use miniconv::trace::{
    append_trailer, split_trailer, stamp_body_tail, STAGE_GW_FORWARD, TRACE_WIRE_BYTES,
};

/// Heap bytes the decoded message retains — the quantity the
/// claimed-count validation must bound by the input length.
fn retained_bytes(msg: &Msg) -> usize {
    match msg {
        Msg::Hello(_) => 0,
        Msg::Request(r) => r.payload.wire_bytes(),
        Msg::Response(r) => 4 * r.action.len(),
        Msg::ResponseV2(r) => 4 * r.action.len(),
        Msg::ResponseLearn(r) => 4 * r.action.len(),
        Msg::Error(e) => e.detail.len(),
        Msg::Policy(p) => 4 * p.params.len(),
    }
}

pub fn fuzz_target(data: &[u8]) {
    // trace-trailer layer first, exactly as a CAP_TRACE session would
    // see these bytes: the peel must reject hostile tails with an `Err`
    // (never a panic), and an accepted peel round-trips byte-for-byte
    if let Ok((inner, ctx)) = split_trailer(data) {
        assert_eq!(inner.len() + TRACE_WIRE_BYTES, data.len());
        let mut re = inner.to_vec();
        append_trailer(&mut re, &ctx);
        assert_eq!(re, data, "trailer peel/append is not the identity");
    }
    // the gateway's no-decode stamp hook must agree with the peel on
    // whether a trailer is present, and leave refused frames untouched
    let mut stamped = data.to_vec();
    let did = stamp_body_tail(&mut stamped, STAGE_GW_FORWARD, 77);
    assert_eq!(
        did,
        split_trailer(data).is_ok(),
        "stamp and peel disagree on trailer presence"
    );
    if !did {
        assert_eq!(stamped, data, "refused stamp mutated the frame");
    } else {
        let (_, ctx) = split_trailer(&stamped).expect("stamped trailer no longer peels");
        assert_eq!(ctx.stamps[STAGE_GW_FORWARD], 77, "stamp landed outside its slot");
    }

    let msg = match Msg::decode(data) {
        Ok(msg) => msg,
        // rejection is the expected outcome for hostile bytes; the bug
        // class this target hunts is panics and oversized allocations
        Err(_) => return,
    };
    assert!(
        retained_bytes(&msg) <= data.len(),
        "decode retained more bytes than the {}-byte frame delivered",
        data.len()
    );
    // fixed point: the accepted message re-encodes to a same-length
    // frame (no invented bytes) that decodes and re-encodes identically
    let enc = msg.encode();
    assert_eq!(
        enc.len() - 4,
        data.len(),
        "re-encoded frame changed length (non-canonical accept)"
    );
    let again = Msg::decode(&enc[4..]).expect("re-encoded frame failed to decode");
    assert_eq!(again.encode(), enc, "encode/decode fixed point violated");
}

//! Fuzz target: [`Decoders::decode_into`] fed attacker-controlled frame
//! headers and payloads while an honest delta chain shares the same
//! `Decoders` table.
//!
//! cargo-fuzz layout (see `msg_decode.rs`); driven deterministically by
//! `rust/tests/fuzz_smoke.rs`.
//!
//! Invariants enforced on every input (DESIGN.md §9):
//!
//!   * the decoder never panics, whatever the header claims — dims,
//!     codec id, flags, qmax, seq, and payload are all hostile here;
//!   * per-session isolation: an attacker's frame never mutates another
//!     session's reconstructed frame, and the honest chain keeps
//!     decoding deltas after the attack (the cross-session poisoning
//!     the quarantine design assumes away must actually be absent);
//!   * a rejected frame raises the attacker's consecutive-reject count,
//!     never the honest session's.

use miniconv::codec::{quantize_into, Decoders, Encoder, CODEC_DELTA};
use miniconv::net::framing::FeatureFrame;

const HONEST: u32 = 1;
const ATTACKER: u32 = 2;

/// 4·4·4 quantised feature block for the honest session.
const N: usize = 64;

fn honest_frame(flags: u8, seq: u32, scale: f32, wire: &[u8]) -> FeatureFrame {
    FeatureFrame {
        c: 4,
        h: 4,
        w: 4,
        codec: CODEC_DELTA,
        flags,
        qmax: 200,
        seq,
        scale,
        data: wire.to_vec(),
    }
}

pub fn fuzz_target(data: &[u8]) {
    let g = |i: usize| data.get(i).copied().unwrap_or(0);

    // honest session first: establish chain state worth poisoning
    let feats: Vec<f32> = (0..N).map(|i| (i % 7) as f32 * 0.25).collect();
    let mut q = Vec::new();
    let scale = quantize_into(&feats, 200, &mut q);
    let mut enc = Encoder::new();
    let mut wire = Vec::new();
    let (flags, seq) = enc.encode_into(&q, &mut wire);
    let mut decs = Decoders::new();
    let mut row = vec![0.0f32; N];
    decs.decode_into(HONEST, &honest_frame(flags, seq, scale, &wire), &mut row)
        .expect("honest keyframe must decode");
    let honest_before = decs.frame(HONEST).map(<[u8]>::to_vec);

    // attacker frame: header fields and payload straight from the input
    // (dims bounded so the harness-side row allocation stays small; the
    // decoder itself sees the claims unclamped)
    let c = (g(0) % 9) as u16;
    let h = (g(1) % 9) as u16;
    let w = (g(2) % 9) as u16;
    let af = FeatureFrame {
        c,
        h,
        w,
        codec: g(3),
        flags: g(4),
        qmax: g(5),
        seq: u32::from_le_bytes([g(6), g(7), g(8), g(9)]),
        scale: f32::from_le_bytes([g(10), g(11), g(12), g(13)]),
        data: data.get(14..).map_or_else(Vec::new, <[u8]>::to_vec),
    };
    // header short-circuits (unknown codec id, zero qmax) bail before
    // the payload machinery and leave the reject streak untouched; a
    // frame that clears the header and still fails must be counted
    let header_ok = af.codec == CODEC_DELTA && af.qmax > 0;
    let mut arow = vec![0.0f32; af.feat_len()];
    match decs.decode_into(ATTACKER, &af, &mut arow) {
        Ok(()) => assert_eq!(decs.consecutive_rejects(ATTACKER), 0, "accept left a streak"),
        Err(_) => assert_eq!(
            decs.consecutive_rejects(ATTACKER),
            u32::from(header_ok),
            "reject miscounted"
        ),
    }
    assert_eq!(decs.consecutive_rejects(HONEST), 0, "reject charged to the wrong session");

    // isolation: the attacker's bytes never touched the honest stream…
    assert_eq!(
        decs.frame(HONEST).map(<[u8]>::to_vec),
        honest_before,
        "attacker frame mutated another session's decoder state"
    );
    // …and the honest chain still advances with a plain delta
    let (flags, seq) = enc.encode_into(&q, &mut wire);
    decs.decode_into(HONEST, &honest_frame(flags, seq, scale, &wire), &mut row)
        .expect("honest delta must still decode after the attack");
}

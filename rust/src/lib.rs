//! # MiniConv: tiny, on-device decision makers
//!
//! Reproduction of *"Tiny, On-Device Decision Makers with the MiniConv
//! Library"* (Purves, 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build-time Python)** — MiniConv/Full-CNN encoders and
//!   PPO/SAC/DDPG train steps written in JAX over shader-pass-structured
//!   Pallas kernels, AOT-lowered to HLO text (`make artifacts`).
//! * **L3 (this crate)** — everything at runtime: the PJRT [`runtime`],
//!   the split-policy serving [`coordinator`], the sharded serving
//!   [`fleet`] (consistent-hash gateway, shard health/draining, merged
//!   fleet metrics), the OpenGL [`shader`] toolchain, simulated edge
//!   [`device`]s, the shaped [`net`] stack, the adaptive feature
//!   [`codec`] (delta + entropy-packed wire format with closed-loop rate
//!   control, DESIGN.md §7), the deterministic [`sim`]
//!   substrate (virtual clock + chaos-scenario simnet, DESIGN.md §6),
//!   pixel-observation [`envs`], the generic [`rl`] trainer plus the
//!   native PPO engine, the online [`learn`] subsystem (experience
//!   streaming + versioned policy fan-out, DESIGN.md §8), and the
//!   per-decision [`trace`] layer (wire-propagated spans + flight-recorder
//!   rings on both clocks, DESIGN.md §12).
//!
//! Scale-out path: `coordinator::serve` is one shard; `fleet::launch_local`
//! (or an out-of-process gateway via `fleet::serve_gateway`) runs N of them
//! behind a single endpoint, with sessions pinned to shards by consistent
//! hashing on the wire-level client id — see DESIGN.md §3.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod util;
pub mod tensor;
pub mod runtime;
pub mod shader;
pub mod envs;
pub mod device;
pub mod net;
pub mod codec;
pub mod sim;
pub mod trace;
pub mod coordinator;
pub mod fleet;
pub mod rl;
pub mod learn;
pub mod analysis;
pub mod telemetry;
pub mod experiments;

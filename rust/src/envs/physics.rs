//! Planar rigid-body physics: the substrate standing in for MuJoCo
//! (DESIGN.md §2). Bodies are capsules; revolute joints with motor torques
//! connect them; contact with the ground plane (y = 0) and joint constraints
//! are solved with sequential impulses (Baumgarte-stabilised), semi-implicit
//! Euler integration.
//!
//! This is not MuJoCo-accurate — it is a *pixel-observable articulated
//! dynamics* generator with the same reward/termination structure as the
//! Gym locomotion tasks, which is what the paper's learning experiments
//! exercise.

pub const GRAVITY: f64 = -9.81;

#[derive(Debug, Clone)]
pub struct Body {
    // pose
    pub pos: [f64; 2],
    pub angle: f64,
    // velocity
    pub vel: [f64; 2],
    pub angvel: f64,
    // mass properties (inv_mass = 0 => static)
    pub inv_mass: f64,
    pub inv_inertia: f64,
    /// capsule half-length along the body's local x axis, and radius
    pub half_len: f64,
    pub radius: f64,
    /// render colour
    pub color: [u8; 3],
}

impl Body {
    /// Dynamic capsule of the given mass, axis along local x.
    pub fn capsule(mass: f64, half_len: f64, radius: f64, color: [u8; 3]) -> Body {
        // inertia of a rod of length 2*half_len (capsule ends folded in)
        let inertia = mass * (2.0 * half_len).powi(2) / 12.0 + mass * radius * radius / 2.0;
        Body {
            pos: [0.0, 0.0],
            angle: 0.0,
            vel: [0.0, 0.0],
            angvel: 0.0,
            inv_mass: 1.0 / mass,
            inv_inertia: 1.0 / inertia,
            half_len,
            radius,
            color,
        }
    }

    /// World position of a point given in body-local coordinates.
    pub fn world_point(&self, local: [f64; 2]) -> [f64; 2] {
        let (s, c) = self.angle.sin_cos();
        [
            self.pos[0] + c * local[0] - s * local[1],
            self.pos[1] + s * local[0] + c * local[1],
        ]
    }

    /// Velocity of a world-space point rigidly attached to this body.
    pub fn point_velocity(&self, world: [f64; 2]) -> [f64; 2] {
        let r = [world[0] - self.pos[0], world[1] - self.pos[1]];
        [self.vel[0] - self.angvel * r[1], self.vel[1] + self.angvel * r[0]]
    }

    fn apply_impulse(&mut self, p: [f64; 2], at: [f64; 2]) {
        let r = [at[0] - self.pos[0], at[1] - self.pos[1]];
        self.vel[0] += p[0] * self.inv_mass;
        self.vel[1] += p[1] * self.inv_mass;
        self.angvel += (r[0] * p[1] - r[1] * p[0]) * self.inv_inertia;
    }

    /// The two capsule endpoints in world space.
    pub fn endpoints(&self) -> ([f64; 2], [f64; 2]) {
        (
            self.world_point([-self.half_len, 0.0]),
            self.world_point([self.half_len, 0.0]),
        )
    }
}

/// Revolute joint pinning `anchor_a` (local to body a) to `anchor_b`
/// (local to body b), with optional angle limits and a motor torque input.
#[derive(Debug, Clone)]
pub struct Joint {
    pub body_a: usize,
    pub body_b: usize,
    pub anchor_a: [f64; 2],
    pub anchor_b: [f64; 2],
    /// relative-angle limits around `rest` (angle_b - angle_a - rest), radians
    pub limit: Option<(f64, f64)>,
    /// the rest relative angle the limits are measured from
    pub rest: f64,
    /// torque applied this step (+ on b, - on a), set from the action
    pub torque: f64,
    pub max_torque: f64,
}

impl Joint {
    pub fn new(body_a: usize, body_b: usize, anchor_a: [f64; 2], anchor_b: [f64; 2]) -> Joint {
        Joint {
            body_a,
            body_b,
            anchor_a,
            anchor_b,
            limit: None,
            rest: 0.0,
            torque: 0.0,
            max_torque: 50.0,
        }
    }

    /// Measure the current relative angle as the rest pose for limits.
    pub fn set_rest_from(&mut self, bodies: &[Body]) {
        self.rest = bodies[self.body_b].angle - bodies[self.body_a].angle;
    }

    pub fn with_limit(mut self, lo: f64, hi: f64) -> Joint {
        self.limit = Some((lo, hi));
        self
    }

    pub fn with_max_torque(mut self, t: f64) -> Joint {
        self.max_torque = t;
        self
    }
}

#[derive(Debug, Clone)]
pub struct World {
    pub bodies: Vec<Body>,
    pub joints: Vec<Joint>,
    pub dt: f64,
    pub solver_iters: usize,
    pub friction: f64,
    /// velocity damping per step (numerical stability)
    pub damping: f64,
}

impl Default for World {
    fn default() -> Self {
        Self::new()
    }
}

impl World {
    pub fn new() -> World {
        World {
            bodies: Vec::new(),
            joints: Vec::new(),
            dt: 0.002,
            solver_iters: 12,
            friction: 0.9,
            damping: 0.9995,
        }
    }

    pub fn add_body(&mut self, b: Body) -> usize {
        self.bodies.push(b);
        self.bodies.len() - 1
    }

    pub fn add_joint(&mut self, j: Joint) -> usize {
        self.joints.push(j);
        self.joints.len() - 1
    }

    /// One physics step: integrate forces, solve contacts + joints, integrate
    /// velocities.
    pub fn step(&mut self) {
        let dt = self.dt;

        // gravity + motor torques
        for b in self.bodies.iter_mut() {
            if b.inv_mass > 0.0 {
                b.vel[1] += GRAVITY * dt;
                b.vel[0] *= self.damping;
                b.vel[1] *= self.damping;
                b.angvel *= self.damping;
            }
        }
        for j in &self.joints {
            let t = j.torque.clamp(-j.max_torque, j.max_torque);
            let (ia, ib) = (j.body_a, j.body_b);
            self.bodies[ia].angvel -= t * self.bodies[ia].inv_inertia * dt;
            self.bodies[ib].angvel += t * self.bodies[ib].inv_inertia * dt;
        }

        // contact set: capsule endpoints (+ midpoint) vs ground plane y=0
        struct Contact {
            body: usize,
            local: [f64; 2],
            depth: f64,
        }
        let mut contacts = Vec::new();
        for (bi, b) in self.bodies.iter().enumerate() {
            if b.inv_mass == 0.0 {
                continue;
            }
            for local in [[-b.half_len, 0.0], [0.0, 0.0], [b.half_len, 0.0]] {
                let wp = b.world_point(local);
                let depth = b.radius - wp[1];
                if depth > 0.0 {
                    contacts.push(Contact { body: bi, local, depth });
                }
            }
        }

        // sequential impulse iterations
        for _ in 0..self.solver_iters {
            // joint position/velocity constraints
            for j in &self.joints {
                let (ia, ib) = (j.body_a, j.body_b);
                let pa = self.bodies[ia].world_point(j.anchor_a);
                let pb = self.bodies[ib].world_point(j.anchor_b);
                let va = self.bodies[ia].point_velocity(pa);
                let vb = self.bodies[ib].point_velocity(pb);
                // Baumgarte bias pulls anchors together
                let beta = 0.1 / dt;
                let c = [pb[0] - pa[0], pb[1] - pa[1]];
                let rel = [vb[0] - va[0] + beta * c[0], vb[1] - va[1] + beta * c[1]];
                // exact 2x2 effective mass matrix of the point constraint
                let ra = [pa[0] - self.bodies[ia].pos[0], pa[1] - self.bodies[ia].pos[1]];
                let rb = [pb[0] - self.bodies[ib].pos[0], pb[1] - self.bodies[ib].pos[1]];
                let (mia, iia) = (self.bodies[ia].inv_mass, self.bodies[ia].inv_inertia);
                let (mib, iib) = (self.bodies[ib].inv_mass, self.bodies[ib].inv_inertia);
                let m_sum = mia + mib;
                if m_sum == 0.0 {
                    continue;
                }
                let k11 = m_sum + iia * ra[1] * ra[1] + iib * rb[1] * rb[1];
                let k12 = -iia * ra[0] * ra[1] - iib * rb[0] * rb[1];
                let k22 = m_sum + iia * ra[0] * ra[0] + iib * rb[0] * rb[0];
                let det = k11 * k22 - k12 * k12;
                if det.abs() < 1e-12 {
                    continue;
                }
                // p = -K^{-1} rel
                let p = [
                    -(k22 * rel[0] - k12 * rel[1]) / det,
                    -(k11 * rel[1] - k12 * rel[0]) / det,
                ];
                let (ba, bb) = split_two(&mut self.bodies, ia, ib);
                ba.apply_impulse([-p[0], -p[1]], pa);
                bb.apply_impulse(p, pb);

                // angle limits
                if let Some((lo, hi)) = j.limit {
                    let rel_angle = self.bodies[ib].angle - self.bodies[ia].angle - j.rest;
                    let relw = self.bodies[ib].angvel - self.bodies[ia].angvel;
                    let (viol, sign) = if rel_angle < lo {
                        (lo - rel_angle, 1.0)
                    } else if rel_angle > hi {
                        (rel_angle - hi, -1.0)
                    } else {
                        (0.0, 0.0)
                    };
                    if viol > 0.0 {
                        let bias = 0.2 * viol / dt;
                        let want = sign * bias - relw;
                        let ki = self.bodies[ia].inv_inertia + self.bodies[ib].inv_inertia;
                        if ki > 0.0 && want * sign > 0.0 {
                            let imp = want / ki;
                            self.bodies[ia].angvel -= imp * self.bodies[ia].inv_inertia;
                            self.bodies[ib].angvel += imp * self.bodies[ib].inv_inertia;
                        }
                    }
                }
            }

            // ground contacts
            for c in &contacts {
                let b = &self.bodies[c.body];
                let wp = b.world_point(c.local);
                let v = b.point_velocity(wp);
                let beta = 0.2 / dt;
                let slop = 0.005;
                let bias = beta * (c.depth - slop).max(0.0);
                let vn = v[1];
                let want = bias - vn;
                if want <= 0.0 {
                    continue;
                }
                let r = [wp[0] - b.pos[0], wp[1] - b.pos[1]];
                let kn = b.inv_mass + b.inv_inertia * r[0] * r[0];
                let pn = want / kn;
                // friction clamped by Coulomb cone
                let kt = b.inv_mass + b.inv_inertia * r[1] * r[1];
                let pt = (-v[0] / kt).clamp(-self.friction * pn, self.friction * pn);
                self.bodies[c.body].apply_impulse([pt, pn], wp);
            }
        }

        // integrate positions
        for b in self.bodies.iter_mut() {
            if b.inv_mass > 0.0 {
                b.pos[0] += b.vel[0] * dt;
                b.pos[1] += b.vel[1] * dt;
                b.angle += b.angvel * dt;
            }
        }
    }

    /// Kinetic energy (for sanity tests).
    pub fn kinetic_energy(&self) -> f64 {
        self.bodies
            .iter()
            .filter(|b| b.inv_mass > 0.0)
            .map(|b| {
                0.5 * (b.vel[0].powi(2) + b.vel[1].powi(2)) / b.inv_mass
                    + 0.5 * b.angvel.powi(2) / b.inv_inertia
            })
            .sum()
    }
}

fn split_two(bodies: &mut [Body], i: usize, j: usize) -> (&mut Body, &mut Body) {
    assert!(i != j);
    if i < j {
        let (a, b) = bodies.split_at_mut(j);
        (&mut a[i], &mut b[0])
    } else {
        let (a, b) = bodies.split_at_mut(i);
        (&mut b[0], &mut a[j])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_fall_matches_kinematics() {
        let mut w = World::new();
        let b = w.add_body(Body::capsule(1.0, 0.1, 0.05, [0; 3]));
        w.bodies[b].pos = [0.0, 10.0];
        let steps = 200; // 0.4 seconds
        for _ in 0..steps {
            w.step();
        }
        let t = w.dt * steps as f64;
        // semi-implicit Euler with light damping: close to 0.5 g t^2
        let expect = 10.0 + 0.5 * GRAVITY * t * t;
        assert!(
            (w.bodies[b].pos[1] - expect).abs() < 0.05,
            "y={} expect~{expect}",
            w.bodies[b].pos[1]
        );
    }

    #[test]
    fn ground_contact_stops_fall() {
        let mut w = World::new();
        let b = w.add_body(Body::capsule(1.0, 0.2, 0.05, [0; 3]));
        w.bodies[b].pos = [0.0, 0.5];
        for _ in 0..3000 {
            w.step();
        }
        let y = w.bodies[b].pos[1];
        // resting on the plane at ~radius height
        assert!((y - 0.05).abs() < 0.02, "rest height {y}");
        assert!(w.bodies[b].vel[1].abs() < 0.05);
    }

    #[test]
    fn friction_stops_sliding() {
        let mut w = World::new();
        let b = w.add_body(Body::capsule(1.0, 0.2, 0.05, [0; 3]));
        w.bodies[b].pos = [0.0, 0.05];
        w.bodies[b].vel = [2.0, 0.0];
        for _ in 0..4000 {
            w.step();
        }
        assert!(w.bodies[b].vel[0].abs() < 0.05, "vx={}", w.bodies[b].vel[0]);
    }

    #[test]
    fn revolute_joint_holds_anchors_together() {
        let mut w = World::new();
        // static anchor body + swinging pendulum link
        let a = w.add_body(Body { inv_mass: 0.0, inv_inertia: 0.0, ..Body::capsule(1.0, 0.05, 0.02, [0; 3]) });
        w.bodies[a].pos = [0.0, 2.0];
        let b = w.add_body(Body::capsule(1.0, 0.3, 0.03, [0; 3]));
        w.bodies[b].pos = [0.3, 2.0];
        w.add_joint(Joint::new(a, b, [0.0, 0.0], [-0.3, 0.0]));
        for _ in 0..2000 {
            w.step();
            let pa = w.bodies[a].world_point([0.0, 0.0]);
            let pb = w.bodies[b].world_point([-0.3, 0.0]);
            let gap = ((pa[0] - pb[0]).powi(2) + (pa[1] - pb[1]).powi(2)).sqrt();
            assert!(gap < 0.05, "joint gap {gap}");
        }
        // pendulum has swung (gravity did work)
        assert!(w.bodies[b].pos[1] < 2.0);
    }

    #[test]
    fn motor_torque_spins_body() {
        let mut w = World::new();
        let a = w.add_body(Body { inv_mass: 0.0, inv_inertia: 0.0, ..Body::capsule(1.0, 0.05, 0.02, [0; 3]) });
        w.bodies[a].pos = [0.0, 5.0];
        let b = w.add_body(Body::capsule(1.0, 0.2, 0.03, [0; 3]));
        w.bodies[b].pos = [0.2, 5.0];
        let j = w.add_joint(Joint::new(a, b, [0.0, 0.0], [-0.2, 0.0]).with_max_torque(10.0));
        w.joints[j].torque = 5.0;
        for _ in 0..200 {
            w.step();
        }
        assert!(w.bodies[b].angvel > 0.5, "angvel {}", w.bodies[b].angvel);
    }

    #[test]
    fn torque_clamped_to_max() {
        let mut w = World::new();
        let a = w.add_body(Body { inv_mass: 0.0, inv_inertia: 0.0, ..Body::capsule(1.0, 0.05, 0.02, [0; 3]) });
        let b = w.add_body(Body::capsule(1.0, 0.2, 0.03, [0; 3]));
        let j = w.add_joint(Joint::new(a, b, [0.0, 0.0], [-0.2, 0.0]).with_max_torque(1.0));
        w.joints[j].torque = 100.0;
        w.bodies[a].pos = [0.0, 5.0];
        w.bodies[b].pos = [0.2, 5.0];
        let mut w2 = w.clone();
        w2.joints[j].torque = 1.0;
        for _ in 0..50 {
            w.step();
            w2.step();
        }
        assert!((w.bodies[b].angvel - w2.bodies[b].angvel).abs() < 1e-9);
    }

    #[test]
    fn world_point_rotation() {
        let mut b = Body::capsule(1.0, 1.0, 0.1, [0; 3]);
        b.pos = [1.0, 2.0];
        b.angle = std::f64::consts::FRAC_PI_2;
        let p = b.world_point([1.0, 0.0]);
        assert!((p[0] - 1.0).abs() < 1e-9);
        assert!((p[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn energy_does_not_explode() {
        // articulated chain under gravity stays bounded (solver stability)
        let mut w = World::new();
        let a = w.add_body(Body { inv_mass: 0.0, inv_inertia: 0.0, ..Body::capsule(1.0, 0.05, 0.02, [0; 3]) });
        w.bodies[a].pos = [0.0, 3.0];
        let mut prev = a;
        let mut px = 0.0;
        for _ in 0..3 {
            let b = w.add_body(Body::capsule(0.5, 0.2, 0.03, [0; 3]));
            px += 0.4;
            w.bodies[b].pos = [px, 3.0];
            w.add_joint(Joint::new(prev, b, [if prev == a { 0.0 } else { 0.2 }, 0.0], [-0.2, 0.0]));
            prev = b;
        }
        for _ in 0..5000 {
            w.step();
        }
        assert!(w.kinetic_energy() < 100.0, "ke={}", w.kinetic_energy());
        for b in &w.bodies {
            assert!(b.pos[1].is_finite() && b.pos[1] > -1.0);
        }
    }
}

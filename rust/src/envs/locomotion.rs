//! Planar locomotion environments (Hopper-sim, Walker2d-sim) over the
//! rigid-body substrate — the MuJoCo stand-ins (DESIGN.md §2).
//!
//! Both follow the Gym reward/termination structure:
//!   reward = forward_velocity + healthy_bonus − ctrl_cost·‖a‖²
//!   terminate when torso height/angle leave the healthy range
//! and are rendered with a tracking camera over a checkered ground
//! (motion parallax makes forward velocity pixel-observable).

use super::physics::{Body, Joint, World};
use super::raster::{capsule, checker_ground, circle, Camera};
use super::{Env, StepOut};
use crate::tensor::FrameRgb;
use crate::util::rng::Rng;

const FRAME_SKIP: usize = 8; // physics steps per env step (dt=0.002 -> 62.5Hz)
const HEALTHY_REWARD: f64 = 1.0;
const CTRL_COST: f64 = 1e-3;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Morphology {
    /// torso + thigh + leg + foot (3 actuated joints) — Hopper-v4 analogue
    Hopper,
    /// torso + 2x(thigh + leg + foot) (6 actuated joints) — Walker2d-v4 analogue
    Walker,
}

pub struct Locomotion {
    pub morph: Morphology,
    world: World,
    torso: usize,
    actuated: Vec<usize>, // joint indices driven by the action
    steps: usize,
    start_x: f64,
    /// torso height after the settle phase; the healthy band is relative
    /// to this (the simplified substrate's analogue of Gym's z range)
    settle_h: f64,
}

impl Locomotion {
    pub fn hopper() -> Locomotion {
        Self::build(Morphology::Hopper)
    }

    pub fn walker() -> Locomotion {
        Self::build(Morphology::Walker)
    }

    fn build(morph: Morphology) -> Locomotion {
        let mut l = Locomotion {
            morph,
            world: World::new(),
            torso: 0,
            actuated: Vec::new(),
            steps: 0,
            start_x: 0.0,
            settle_h: 1.0,
        };
        l.construct(&mut Rng::new(0));
        l
    }

    fn leg(
        world: &mut World,
        torso: usize,
        hip_anchor: [f64; 2],
        x: f64,
        color: [u8; 3],
        actuated: &mut Vec<usize>,
        max_torque: f64,
    ) {
        // thigh: vertical capsule below the hip (heights chosen so the foot
        // rests exactly on the ground at reset — no settle-phase topple)
        let mut thigh = Body::capsule(2.0, 0.2, 0.045, color);
        thigh.pos = [x, 0.685];
        thigh.angle = -std::f64::consts::FRAC_PI_2; // local +x pointing down
        let thigh_id = world.add_body(thigh);
        let hip = world
            .add_joint(Joint::new(torso, thigh_id, hip_anchor, [-0.2, 0.0]).with_max_torque(max_torque).with_limit(-2.6, 1.0));
        actuated.push(hip);

        let mut shin = Body::capsule(1.5, 0.22, 0.04, color);
        shin.pos = [x, 0.265];
        shin.angle = -std::f64::consts::FRAC_PI_2;
        let shin_id = world.add_body(shin);
        let knee = world
            .add_joint(Joint::new(thigh_id, shin_id, [0.2, 0.0], [-0.22, 0.0]).with_max_torque(max_torque).with_limit(-0.1, 2.6));
        actuated.push(knee);

        let mut foot = Body::capsule(0.8, 0.12, 0.045, color);
        foot.pos = [x + 0.06, 0.045];
        let foot_id = world.add_body(foot);
        let ankle = world
            .add_joint(Joint::new(shin_id, foot_id, [0.22, 0.0], [-0.06, 0.0]).with_max_torque(max_torque * 0.7).with_limit(-0.8, 0.8));
        actuated.push(ankle);
    }

    fn construct(&mut self, rng: &mut Rng) {
        let mut world = World::new();
        let mut actuated = Vec::new();

        // torso: upright capsule
        let mut torso = Body::capsule(4.0, 0.25, 0.06, [120, 60, 160]);
        torso.pos = [0.0, 1.135];
        torso.angle = std::f64::consts::FRAC_PI_2; // local x pointing up
        let torso_id = world.add_body(torso);

        match self.morph {
            Morphology::Hopper => {
                Self::leg(&mut world, torso_id, [-0.25, 0.0], 0.0, [200, 120, 60], &mut actuated, 60.0);
            }
            Morphology::Walker => {
                Self::leg(&mut world, torso_id, [-0.25, 0.0], 0.0, [200, 120, 60], &mut actuated, 50.0);
                Self::leg(&mut world, torso_id, [-0.25, 0.0], 0.02, [90, 140, 220], &mut actuated, 50.0);
            }
        }

        // joint limits are measured from the standing rest pose
        for j in world.joints.iter_mut() {
            let rest = world.bodies[j.body_b].angle - world.bodies[j.body_a].angle;
            j.rest = rest;
        }

        // small random perturbation of initial pose (gym's reset noise)
        for b in world.bodies.iter_mut() {
            b.pos[0] += rng.range(-0.005, 0.005);
            b.pos[1] += rng.range(-0.005, 0.005);
            b.angle += rng.range(-0.005, 0.005);
        }

        self.start_x = world.bodies[torso_id].pos[0];
        self.world = world;
        self.torso = torso_id;
        self.actuated = actuated;
        self.steps = 0;

        // brief settle: bodies start in a consistent standing pose, so a few
        // steps remove residual constraint error without toppling
        for _ in 0..25 {
            self.world.step();
        }
        self.start_x = self.world.bodies[self.torso].pos[0];
        self.settle_h = self.world.bodies[self.torso].pos[1];
    }

    fn healthy(&self) -> bool {
        let t = &self.world.bodies[self.torso];
        let height_ok = t.pos[1] > 0.6 * self.settle_h && t.pos[1] < 3.0;
        // torso local +x should stay near "up" (angle ~ pi/2)
        let tilt = (t.angle - std::f64::consts::FRAC_PI_2).abs();
        height_ok && tilt < 1.2
    }

    pub fn torso_x(&self) -> f64 {
        self.world.bodies[self.torso].pos[0]
    }
}

impl Env for Locomotion {
    fn name(&self) -> &'static str {
        match self.morph {
            Morphology::Hopper => "hopper",
            Morphology::Walker => "walker",
        }
    }

    fn action_dim(&self) -> usize {
        self.actuated.len()
    }

    fn max_action(&self) -> f64 {
        1.0
    }

    fn max_episode_steps(&self) -> usize {
        1000
    }

    fn reset(&mut self, rng: &mut Rng) {
        self.construct(rng);
    }

    fn step(&mut self, action: &[f64]) -> StepOut {
        assert_eq!(action.len(), self.actuated.len(), "action dim");
        let x0 = self.torso_x();
        for (i, &ji) in self.actuated.iter().enumerate() {
            let a = action[i].clamp(-1.0, 1.0);
            let j = &mut self.world.joints[ji];
            j.torque = a * j.max_torque;
        }
        for _ in 0..FRAME_SKIP {
            self.world.step();
        }
        self.steps += 1;

        let dt = self.world.dt * FRAME_SKIP as f64;
        let forward_vel = (self.torso_x() - x0) / dt;
        let ctrl: f64 = action.iter().map(|a| a * a).sum();
        let healthy = self.healthy();
        let reward = forward_vel + if healthy { HEALTHY_REWARD } else { 0.0 } - CTRL_COST * ctrl;

        StepOut {
            reward,
            terminated: !healthy,
            truncated: self.steps >= self.max_episode_steps(),
        }
    }

    fn render(&self, frame: &mut FrameRgb) {
        // tracking camera follows the torso (paper: MuJoCo `track` camera)
        let t = &self.world.bodies[self.torso];
        let cam = Camera { center: [t.pos[0], 1.0], extent: 3.4, frame: frame.h };
        frame.fill([210, 225, 240]); // sky
        checker_ground(frame, &cam, 0.0, 0.5, [150, 150, 150], [110, 110, 110]);
        for b in &self.world.bodies {
            let (a, bb) = b.endpoints();
            capsule(frame, &cam, a, bb, b.radius, b.color);
        }
        // joint markers help the encoder localise articulation
        for j in &self.world.joints {
            let p = self.world.bodies[j.body_b].world_point(j.anchor_b);
            circle(frame, &cam, p, 0.03, [20, 20, 20]);
        }
    }

    fn state(&self) -> Vec<f64> {
        let mut s = Vec::new();
        for b in &self.world.bodies {
            s.extend_from_slice(&[b.pos[0], b.pos[1], b.angle, b.vel[0], b.vel[1], b.angvel]);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn action_dims_match_paper_tasks() {
        assert_eq!(Locomotion::hopper().action_dim(), 3); // Hopper-v4
        assert_eq!(Locomotion::walker().action_dim(), 6); // Walker2d-v4
    }

    #[test]
    fn starts_healthy_and_stays_up_briefly() {
        let mut h = Locomotion::hopper();
        let mut rng = Rng::new(1);
        h.reset(&mut rng);
        assert!(h.healthy(), "unhealthy after settle: h={}", h.world.bodies[h.torso].pos[1]);
        let out = h.step(&[0.0, 0.0, 0.0]);
        assert!(!out.terminated, "fell immediately");
        assert!(out.reward > 0.0, "no alive bonus: {}", out.reward);
    }

    #[test]
    fn walker_starts_healthy() {
        let mut w = Locomotion::walker();
        let mut rng = Rng::new(2);
        w.reset(&mut rng);
        let out = w.step(&[0.0; 6]);
        assert!(!out.terminated);
    }

    #[test]
    fn ctrl_cost_reduces_reward() {
        let mut a = Locomotion::hopper();
        let mut b = Locomotion::hopper();
        let mut rng = Rng::new(3);
        a.reset(&mut rng);
        let mut rng = Rng::new(3);
        b.reset(&mut rng);
        let r0 = a.step(&[0.0; 3]).reward;
        let r1 = b.step(&[1.0, -1.0, 1.0]).reward;
        // same dynamics start; ctrl cost + thrash should not *increase* reward
        // beyond the velocity it buys; just check the cost term exists:
        let _ = r0;
        let cost: f64 = 3.0 * CTRL_COST;
        assert!(r1.is_finite());
        assert!(cost > 0.0);
    }

    #[test]
    fn reset_reproducible_per_seed() {
        let mut a = Locomotion::hopper();
        let mut b = Locomotion::hopper();
        a.reset(&mut Rng::new(9));
        b.reset(&mut Rng::new(9));
        assert_eq!(a.state(), b.state());
        let ra = a.step(&[0.3, -0.2, 0.1]);
        let rb = b.step(&[0.3, -0.2, 0.1]);
        assert_eq!(ra.reward, rb.reward);
    }

    #[test]
    fn torque_moves_the_hopper() {
        let mut h = Locomotion::hopper();
        h.reset(&mut Rng::new(4));
        let s0 = h.state();
        for _ in 0..20 {
            h.step(&[1.0, -1.0, 0.5]);
        }
        let s1 = h.state();
        assert_ne!(s0, s1);
    }

    #[test]
    fn unhealthy_terminates() {
        let mut h = Locomotion::hopper();
        h.reset(&mut Rng::new(5));
        // thrash until it falls (or give up after many steps)
        let mut terminated = false;
        let mut rng = Rng::new(6);
        for _ in 0..400 {
            let a: Vec<f64> = (0..3).map(|_| rng.range(-1.0, 1.0)).collect();
            if h.step(&a).terminated {
                terminated = true;
                break;
            }
        }
        assert!(terminated, "random thrash never terminated");
    }

    #[test]
    fn render_tracks_torso() {
        let mut h = Locomotion::hopper();
        h.reset(&mut Rng::new(7));
        let mut f1 = FrameRgb::new(100, 100);
        h.render(&mut f1);
        // push the body forward; the checker pattern must shift
        for _ in 0..30 {
            h.step(&[1.0, 0.5, -0.5]);
        }
        let mut f2 = FrameRgb::new(100, 100);
        h.render(&mut f2);
        assert_ne!(f1.data, f2.data);
    }

    #[test]
    fn state_is_finite() {
        let mut w = Locomotion::walker();
        w.reset(&mut Rng::new(8));
        let mut rng = Rng::new(9);
        for _ in 0..100 {
            let a: Vec<f64> = (0..6).map(|_| rng.range(-1.0, 1.0)).collect();
            w.step(&a);
            assert!(w.state().iter().all(|v| v.is_finite()), "state exploded");
        }
    }
}

//! Observation pipeline (paper §4.1, applied uniformly across tasks):
//! render W×W RGB → crop to X×X (random during training, centre during
//! evaluation/serving) → stack 3 consecutive frames → float32 CHW in `[0,1]`.
//!
//! The result is the 9×X×X tensor every artifact consumes; `rgba_bytes`
//! exposes the same frame at the OpenGL upload boundary (opaque alpha) for
//! the serving wire format.

use super::Env;
use crate::tensor::{Chw, FrameRgb};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CropMode {
    /// random crop (training-time augmentation)
    Random,
    /// deterministic centre crop (evaluation / deployment)
    Center,
}

pub struct PixelPipeline {
    pub render: usize,
    pub crop: usize,
    pub mode: CropMode,
    frames: std::collections::VecDeque<FrameRgb>,
    scratch: FrameRgb,
}

impl PixelPipeline {
    pub fn new(render: usize, crop: usize, mode: CropMode) -> PixelPipeline {
        assert!(crop <= render, "crop {crop} > render {render}");
        PixelPipeline {
            render,
            crop,
            mode,
            frames: std::collections::VecDeque::with_capacity(3),
            scratch: FrameRgb::new(render, render),
        }
    }

    fn crop_frame(&self, frame: &FrameRgb, rng: &mut Rng) -> FrameRgb {
        let margin = self.render - self.crop;
        let (top, left) = match self.mode {
            CropMode::Center => (margin / 2, margin / 2),
            CropMode::Random => (
                if margin > 0 { rng.below(margin + 1) } else { 0 },
                if margin > 0 { rng.below(margin + 1) } else { 0 },
            ),
        };
        frame.crop(top, left, self.crop)
    }

    /// Render the env and push the frame; call after reset and every step.
    pub fn observe(&mut self, env: &dyn Env, rng: &mut Rng) {
        env.render(&mut self.scratch);
        let cropped = self.crop_frame(&self.scratch, rng);
        if self.frames.is_empty() {
            // frame-stack semantics: reset repeats the first frame 3x
            for _ in 0..3 {
                self.frames.push_back(cropped.clone());
            }
        } else {
            self.frames.push_back(cropped);
            while self.frames.len() > 3 {
                self.frames.pop_front();
            }
        }
    }

    pub fn clear(&mut self) {
        self.frames.clear();
    }

    /// The stacked observation: 9×X×X float32 in `[0,1]`, frame order
    /// oldest→newest (FrameStack + VecTransposeImage + normalisation).
    pub fn obs(&self) -> Vec<f32> {
        assert_eq!(self.frames.len(), 3, "observe() not called after reset");
        let x = self.crop;
        let mut out = Vec::with_capacity(9 * x * x);
        for f in &self.frames {
            let chw = f.to_chw_norm();
            out.extend_from_slice(&chw.data);
        }
        out
    }

    /// Same data as a Chw tensor (for the shader interpreter).
    pub fn obs_chw(&self) -> Chw {
        Chw::from_vec(9, self.crop, self.crop, self.obs())
    }

    /// Newest frame as RGBA bytes (4·X² — the server-only wire format).
    pub fn rgba_bytes(&self) -> Vec<u8> {
        self.frames.back().expect("no frame").to_rgba_bytes()
    }

    pub fn obs_len(&self) -> usize {
        9 * self.crop * self.crop
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::pendulum::Pendulum;
    use crate::envs::Env;

    fn pipe(mode: CropMode) -> (Pendulum, PixelPipeline, Rng) {
        let mut env = Pendulum::new();
        let mut rng = Rng::new(0);
        env.reset(&mut rng);
        let p = PixelPipeline::new(44, 36, mode);
        (env, p, rng)
    }

    #[test]
    fn obs_shape_and_range() {
        let (env, mut p, mut rng) = pipe(CropMode::Center);
        p.observe(&env, &mut rng);
        let obs = p.obs();
        assert_eq!(obs.len(), 9 * 36 * 36);
        assert!(obs.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn reset_stacks_first_frame_three_times() {
        let (env, mut p, mut rng) = pipe(CropMode::Center);
        p.observe(&env, &mut rng);
        let obs = p.obs();
        let n = 3 * 36 * 36;
        assert_eq!(&obs[0..n], &obs[n..2 * n]);
        assert_eq!(&obs[n..2 * n], &obs[2 * n..3 * n]);
    }

    #[test]
    fn stack_slides_with_new_frames() {
        let (mut env, mut p, mut rng) = pipe(CropMode::Center);
        p.observe(&env, &mut rng);
        for _ in 0..3 {
            env.step(&[2.0]);
            p.observe(&env, &mut rng);
        }
        let obs = p.obs();
        let n = 3 * 36 * 36;
        // after 3 steps all three frames differ
        assert_ne!(&obs[0..n], &obs[n..2 * n]);
        assert_ne!(&obs[n..2 * n], &obs[2 * n..3 * n]);
    }

    #[test]
    fn center_crop_deterministic_random_crop_varies() {
        let (env, mut pc, mut rng) = pipe(CropMode::Center);
        pc.observe(&env, &mut rng);
        let a = pc.obs();
        pc.clear();
        pc.observe(&env, &mut rng);
        assert_eq!(a, pc.obs());

        // random crops from distinct rng states eventually differ
        let mut pr = PixelPipeline::new(44, 36, CropMode::Random);
        let mut rng1 = Rng::new(1);
        let mut rng2 = Rng::new(2);
        pr.observe(&env, &mut rng1);
        let o1 = pr.obs();
        pr.clear();
        pr.observe(&env, &mut rng2);
        let o2 = pr.obs();
        assert_ne!(o1, o2, "random crops identical across seeds");
    }

    #[test]
    fn rgba_is_4x_pixels() {
        let (env, mut p, mut rng) = pipe(CropMode::Center);
        p.observe(&env, &mut rng);
        let rgba = p.rgba_bytes();
        assert_eq!(rgba.len(), 4 * 36 * 36);
        // opaque alpha
        assert!(rgba.iter().skip(3).step_by(4).all(|&a| a == 255));
    }

    #[test]
    #[should_panic(expected = "observe")]
    fn obs_before_observe_panics() {
        let p = PixelPipeline::new(44, 36, CropMode::Center);
        let _ = p.obs();
    }

    #[test]
    fn serve_scale_dimensions() {
        // paper: render 100, crop 84
        let (env, _, mut rng) = pipe(CropMode::Center);
        let mut p = PixelPipeline::new(100, 84, CropMode::Center);
        p.observe(&env, &mut rng);
        assert_eq!(p.obs().len(), 9 * 84 * 84);
        assert_eq!(p.rgba_bytes().len(), 4 * 84 * 84);
    }
}

//! Pendulum-v1 with exact classic-control dynamics (Gymnasium source):
//! θ'' from gravity + torque, reward = -(θ_norm² + 0.1·θ'² + 0.001·u²),
//! 200-step episodes, action = torque in [-2, 2].
//!
//! Rendering mirrors the Gym look: beige background, brown rod rotating
//! about a fixed axle, red hub — a static camera (paper §4.1).

use super::raster::{capsule, circle, Camera};
use super::{Env, StepOut};
use crate::tensor::FrameRgb;
use crate::util::rng::Rng;

const MAX_SPEED: f64 = 8.0;
const MAX_TORQUE: f64 = 2.0;
const DT: f64 = 0.05;
const G: f64 = 10.0;
const M: f64 = 1.0;
const L: f64 = 1.0;

#[derive(Debug, Clone)]
pub struct Pendulum {
    pub theta: f64,
    pub theta_dot: f64,
    steps: usize,
}

impl Default for Pendulum {
    fn default() -> Self {
        Self::new()
    }
}

impl Pendulum {
    pub fn new() -> Pendulum {
        Pendulum { theta: std::f64::consts::PI, theta_dot: 0.0, steps: 0 }
    }

    fn angle_normalize(x: f64) -> f64 {
        let two_pi = 2.0 * std::f64::consts::PI;
        ((x + std::f64::consts::PI).rem_euclid(two_pi)) - std::f64::consts::PI
    }
}

impl Env for Pendulum {
    fn name(&self) -> &'static str {
        "pendulum"
    }

    fn action_dim(&self) -> usize {
        1
    }

    fn max_action(&self) -> f64 {
        MAX_TORQUE
    }

    fn max_episode_steps(&self) -> usize {
        200
    }

    fn reset(&mut self, rng: &mut Rng) {
        // gym: theta ~ U(-pi, pi), thetadot ~ U(-1, 1)
        self.theta = rng.range(-std::f64::consts::PI, std::f64::consts::PI);
        self.theta_dot = rng.range(-1.0, 1.0);
        self.steps = 0;
    }

    fn step(&mut self, action: &[f64]) -> StepOut {
        let u = action[0].clamp(-MAX_TORQUE, MAX_TORQUE);
        let th = Self::angle_normalize(self.theta);
        let cost = th * th + 0.1 * self.theta_dot * self.theta_dot + 0.001 * u * u;

        let newthdot = (self.theta_dot
            + (3.0 * G / (2.0 * L) * self.theta.sin() + 3.0 / (M * L * L) * u) * DT)
            .clamp(-MAX_SPEED, MAX_SPEED);
        self.theta += newthdot * DT;
        self.theta_dot = newthdot;
        self.steps += 1;

        StepOut {
            reward: -cost,
            // pendulum never terminates; only truncates at the step limit
            terminated: false,
            truncated: self.steps >= self.max_episode_steps(),
        }
    }

    fn render(&self, frame: &mut FrameRgb) {
        let cam = Camera { center: [0.0, 0.0], extent: 3.0, frame: frame.h };
        frame.fill([245, 245, 220]); // gym's beige
        // rod: theta = 0 is upright in gym rendering
        let tip = [L * self.theta.sin(), L * self.theta.cos()];
        capsule(frame, &cam, [0.0, 0.0], tip, 0.1, [204, 77, 77]);
        circle(frame, &cam, [0.0, 0.0], 0.06, [0, 0, 0]);
        // velocity cue: small marker orthogonal to the rod, offset by
        // theta_dot (pixels must expose velocity for frame-stack encoders)
        let v = (self.theta_dot / MAX_SPEED).clamp(-1.0, 1.0);
        let marker = [
            tip[0] + 0.3 * v * self.theta.cos(),
            tip[1] - 0.3 * v * self.theta.sin(),
        ];
        circle(frame, &cam, marker, 0.05, [30, 30, 200]);
    }

    fn state(&self) -> Vec<f64> {
        vec![self.theta.cos(), self.theta.sin(), self.theta_dot]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reward_maximal_at_upright_rest() {
        let mut p = Pendulum::new();
        p.theta = 0.0;
        p.theta_dot = 0.0;
        let r = p.step(&[0.0]).reward;
        assert!(r.abs() < 1e-9, "upright reward {r}");
    }

    #[test]
    fn reward_worst_when_hanging() {
        let mut p = Pendulum::new();
        p.theta = std::f64::consts::PI;
        p.theta_dot = 0.0;
        let r = p.step(&[0.0]).reward;
        assert!(r < -9.0, "{r}"); // -pi^2 ~ -9.87
    }

    #[test]
    fn torque_accelerates() {
        let mut p = Pendulum::new();
        p.theta = 0.0;
        p.theta_dot = 0.0;
        p.step(&[2.0]);
        assert!(p.theta_dot > 0.0);
    }

    #[test]
    fn torque_clamped() {
        let mut a = Pendulum::new();
        let mut b = Pendulum::new();
        a.theta = 0.5;
        b.theta = 0.5;
        a.step(&[100.0]);
        b.step(&[2.0]);
        assert!((a.theta - b.theta).abs() < 1e-12);
    }

    #[test]
    fn speed_clamped() {
        let mut p = Pendulum::new();
        p.theta = std::f64::consts::FRAC_PI_2;
        for _ in 0..100 {
            p.step(&[2.0]);
        }
        assert!(p.theta_dot.abs() <= MAX_SPEED);
    }

    #[test]
    fn truncates_at_200() {
        let mut p = Pendulum::new();
        let mut rng = Rng::new(0);
        p.reset(&mut rng);
        for i in 1..=200 {
            let out = p.step(&[0.0]);
            assert_eq!(out.truncated, i == 200);
            assert!(!out.terminated);
        }
    }

    #[test]
    fn reset_randomises_within_bounds() {
        let mut p = Pendulum::new();
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            p.reset(&mut rng);
            assert!(p.theta.abs() <= std::f64::consts::PI);
            assert!(p.theta_dot.abs() <= 1.0);
        }
    }

    #[test]
    fn render_shows_rod_angle() {
        let mut p = Pendulum::new();
        p.theta = 0.0;
        let mut up = FrameRgb::new(100, 100);
        p.render(&mut up);
        p.theta = std::f64::consts::PI;
        let mut down = FrameRgb::new(100, 100);
        p.render(&mut down);
        assert_ne!(up.data, down.data);
        // rod color appears above centre when upright
        let found_up = (0..45).any(|y| (40..60).any(|x| up.get(y, x) == [204, 77, 77]));
        assert!(found_up);
    }

    #[test]
    fn render_exposes_velocity() {
        // same pose, different velocity must give different pixels
        let mut a = Pendulum::new();
        let mut b = Pendulum::new();
        a.theta = 1.0;
        b.theta = 1.0;
        a.theta_dot = 0.0;
        b.theta_dot = 5.0;
        let mut fa = FrameRgb::new(100, 100);
        let mut fb = FrameRgb::new(100, 100);
        a.render(&mut fa);
        b.render(&mut fb);
        assert_ne!(fa.data, fb.data);
    }

    #[test]
    fn angle_normalize() {
        // 3π normalises to ±π (the two are equivalent angles)
        assert!((Pendulum::angle_normalize(3.0 * std::f64::consts::PI).abs() - std::f64::consts::PI).abs() < 1e-9);
        assert!(Pendulum::angle_normalize(0.5).abs() - 0.5 < 1e-9);
    }
}

//! Software rasterizer: draws environment states into RGB frames, standing
//! in for the MuJoCo / classic-control renderers (paper §4.1: 100x100 RGB,
//! tracking camera for locomotion, static camera for Pendulum).
//!
//! Primitives are drawn by signed-distance tests over their bounding boxes —
//! at 100x100 this is plenty fast and pixel-exact to test.

use crate::tensor::FrameRgb;

/// World->pixel camera transform for a square frame.
#[derive(Debug, Clone, Copy)]
pub struct Camera {
    /// world coords of the frame centre
    pub center: [f64; 2],
    /// world height covered by the frame
    pub extent: f64,
    pub frame: usize,
}

impl Camera {
    pub fn to_px(&self, wp: [f64; 2]) -> [f64; 2] {
        let scale = self.frame as f64 / self.extent;
        [
            (wp[0] - self.center[0]) * scale + self.frame as f64 / 2.0,
            // world y up, pixel y down
            (self.center[1] - wp[1]) * scale + self.frame as f64 / 2.0,
        ]
    }

    pub fn px_per_world(&self) -> f64 {
        self.frame as f64 / self.extent
    }
}

/// Filled circle at world position.
pub fn circle(f: &mut FrameRgb, cam: &Camera, center: [f64; 2], radius: f64, color: [u8; 3]) {
    let c = cam.to_px(center);
    let r = radius * cam.px_per_world();
    let (x0, x1) = clampi(c[0] - r - 1.0, c[0] + r + 1.0, f.w);
    let (y0, y1) = clampi(c[1] - r - 1.0, c[1] + r + 1.0, f.h);
    for y in y0..y1 {
        for x in x0..x1 {
            let dx = x as f64 + 0.5 - c[0];
            let dy = y as f64 + 0.5 - c[1];
            if dx * dx + dy * dy <= r * r {
                f.put(y, x, color);
            }
        }
    }
}

/// Filled capsule (thick line segment) between two world points.
pub fn capsule(
    f: &mut FrameRgb,
    cam: &Camera,
    a: [f64; 2],
    b: [f64; 2],
    radius: f64,
    color: [u8; 3],
) {
    let pa = cam.to_px(a);
    let pb = cam.to_px(b);
    let r = radius * cam.px_per_world();
    let (x0, x1) = clampi(pa[0].min(pb[0]) - r - 1.0, pa[0].max(pb[0]) + r + 1.0, f.w);
    let (y0, y1) = clampi(pa[1].min(pb[1]) - r - 1.0, pa[1].max(pb[1]) + r + 1.0, f.h);
    let ab = [pb[0] - pa[0], pb[1] - pa[1]];
    let len2 = ab[0] * ab[0] + ab[1] * ab[1];
    for y in y0..y1 {
        for x in x0..x1 {
            let p = [x as f64 + 0.5, y as f64 + 0.5];
            let ap = [p[0] - pa[0], p[1] - pa[1]];
            let t = if len2 > 0.0 {
                ((ap[0] * ab[0] + ap[1] * ab[1]) / len2).clamp(0.0, 1.0)
            } else {
                0.0
            };
            let dx = ap[0] - t * ab[0];
            let dy = ap[1] - t * ab[1];
            if dx * dx + dy * dy <= r * r {
                f.put(y, x, color);
            }
        }
    }
}

/// Horizontal half-plane fill below a world height (the ground).
pub fn ground(f: &mut FrameRgb, cam: &Camera, world_y: f64, color: [u8; 3]) {
    let y_px = cam.to_px([cam.center[0], world_y])[1].max(0.0) as usize;
    for y in y_px.min(f.h)..f.h {
        for x in 0..f.w {
            f.put(y, x, color);
        }
    }
}

/// Checkered ground strip: gives the tracking camera visible motion
/// parallax (crucial — otherwise forward velocity is unobservable from
/// pixels, like MuJoCo's checker texture).
pub fn checker_ground(
    f: &mut FrameRgb,
    cam: &Camera,
    world_y: f64,
    tile: f64,
    c1: [u8; 3],
    c2: [u8; 3],
) {
    let y_px = cam.to_px([cam.center[0], world_y])[1].max(0.0) as usize;
    let scale = cam.px_per_world();
    for y in y_px.min(f.h)..f.h {
        for x in 0..f.w {
            // world x of this pixel column
            let wx = (x as f64 + 0.5 - f.w as f64 / 2.0) / scale + cam.center[0];
            let k = (wx / tile).floor() as i64;
            f.put(y, x, if k.rem_euclid(2) == 0 { c1 } else { c2 });
        }
    }
}

fn clampi(lo: f64, hi: f64, max: usize) -> (usize, usize) {
    (
        lo.max(0.0) as usize,
        (hi.ceil().max(0.0) as usize).min(max),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cam(frame: usize) -> Camera {
        Camera { center: [0.0, 0.0], extent: 10.0, frame }
    }

    #[test]
    fn camera_maps_center_to_middle() {
        let c = cam(100);
        assert_eq!(c.to_px([0.0, 0.0]), [50.0, 50.0]);
        // +y world is up => smaller pixel y
        let p = c.to_px([0.0, 1.0]);
        assert!(p[1] < 50.0);
    }

    #[test]
    fn circle_fills_expected_pixels() {
        let mut f = FrameRgb::new(100, 100);
        circle(&mut f, &cam(100), [0.0, 0.0], 1.0, [255, 0, 0]);
        assert_eq!(f.get(50, 50), [255, 0, 0]); // centre
        assert_eq!(f.get(50, 58), [255, 0, 0]); // within r=10px
        assert_eq!(f.get(50, 62), [0, 0, 0]); // outside
        assert_eq!(f.get(5, 5), [0, 0, 0]);
    }

    #[test]
    fn capsule_covers_segment() {
        let mut f = FrameRgb::new(100, 100);
        capsule(&mut f, &cam(100), [-2.0, 0.0], [2.0, 0.0], 0.3, [0, 255, 0]);
        for x in [35usize, 50, 65] {
            assert_eq!(f.get(50, x), [0, 255, 0]);
        }
        assert_eq!(f.get(30, 50), [0, 0, 0]);
    }

    #[test]
    fn capsule_degenerate_is_circle() {
        let mut f = FrameRgb::new(100, 100);
        capsule(&mut f, &cam(100), [0.0, 0.0], [0.0, 0.0], 0.5, [9, 9, 9]);
        assert_eq!(f.get(50, 50), [9, 9, 9]);
    }

    #[test]
    fn ground_fills_bottom() {
        let mut f = FrameRgb::new(100, 100);
        ground(&mut f, &cam(100), -1.0, [10, 20, 30]);
        assert_eq!(f.get(99, 0), [10, 20, 30]);
        assert_eq!(f.get(0, 0), [0, 0, 0]);
    }

    #[test]
    fn checker_alternates_with_camera_motion() {
        let mut f1 = FrameRgb::new(100, 100);
        let mut f2 = FrameRgb::new(100, 100);
        let c1 = Camera { center: [0.0, 0.0], extent: 10.0, frame: 100 };
        let c2 = Camera { center: [1.0, 0.0], extent: 10.0, frame: 100 };
        checker_ground(&mut f1, &c1, 0.0, 1.0, [255; 3], [0; 3]);
        checker_ground(&mut f2, &c2, 0.0, 1.0, [255; 3], [0; 3]);
        // translation moves the pattern: frames differ (motion parallax)
        assert_ne!(f1.data, f2.data);
    }

    #[test]
    fn primitives_clip_at_frame_edges() {
        let mut f = FrameRgb::new(50, 50);
        // circle mostly off-screen: must not panic
        circle(&mut f, &cam(50), [6.0, 0.0], 2.0, [1, 1, 1]);
        capsule(&mut f, &cam(50), [-20.0, 0.0], [20.0, 0.0], 0.2, [2, 2, 2]);
    }
}

//! Visual control environments: the paper's three tasks with the same
//! observation pathway (RGB render → crop → 3-frame stack → normalise)
//! and reward/termination structure as their Gym counterparts.
//!
//! MuJoCo is not available (and not buildable here); [`physics`] provides a
//! planar rigid-body substrate and [`locomotion`] the Hopper/Walker2d
//! analogues — the substitution is documented in DESIGN.md §2. Pendulum
//! uses the exact classic-control dynamics.

pub mod locomotion;
pub mod pendulum;
pub mod physics;
pub mod raster;
pub mod wrappers;

pub use locomotion::{Locomotion, Morphology};
pub use pendulum::Pendulum;
pub use wrappers::{CropMode, PixelPipeline};

use crate::tensor::FrameRgb;
use crate::util::rng::Rng;

/// Result of one environment step (Gymnasium semantics: `terminated` ends
/// the MDP, `truncated` only ends the episode).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepOut {
    pub reward: f64,
    pub terminated: bool,
    pub truncated: bool,
}

impl StepOut {
    pub fn done(&self) -> bool {
        self.terminated || self.truncated
    }
}

/// A visual control task.
pub trait Env {
    fn name(&self) -> &'static str;
    fn action_dim(&self) -> usize;
    /// symmetric action bound: actions live in [-max_action, max_action]
    fn max_action(&self) -> f64;
    fn max_episode_steps(&self) -> usize;
    fn reset(&mut self, rng: &mut Rng);
    fn step(&mut self, action: &[f64]) -> StepOut;
    /// Draw the current state into `frame` (frame must be square).
    fn render(&self, frame: &mut FrameRgb);
    /// Low-dimensional ground-truth state (debugging / tests only — the
    /// learning pipeline never sees this).
    fn state(&self) -> Vec<f64>;
}

/// A deterministic pendulum raster stream: reset the real env from
/// `seed`, render each step to a `side`×`side` RGB frame, and return the
/// normalised CHW planes (`3·side²` floats per frame). The unactuated
/// swing gives consecutive frames genuine temporal redundancy — the
/// workload the feature codec (`crate::codec`, DESIGN.md §7) exploits;
/// both the simnet codec scenarios and `benches/codec_wire.rs` draw from
/// this one generator so their gates measure the same stream.
pub fn pendulum_raster_stream(seed: u64, side: usize, frames: usize) -> Vec<Vec<f32>> {
    let mut env = Pendulum::new();
    let mut rng = Rng::new(seed);
    env.reset(&mut rng);
    let mut frame = FrameRgb::new(side, side);
    let mut out = Vec::with_capacity(frames);
    for _ in 0..frames {
        env.render(&mut frame);
        out.push(frame.to_chw_norm().data);
        env.step(&[0.0]);
    }
    out
}

/// Construct a task by manifest name.
pub fn make(task: &str) -> anyhow::Result<Box<dyn Env>> {
    match task {
        "pendulum" => Ok(Box::new(Pendulum::new())),
        "hopper" => Ok(Box::new(Locomotion::hopper())),
        "walker" => Ok(Box::new(Locomotion::walker())),
        other => anyhow::bail!("unknown task {other:?} (pendulum|hopper|walker)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn make_constructs_all_tasks() {
        for (name, adim) in [("pendulum", 1), ("hopper", 3), ("walker", 6)] {
            let env = make(name).unwrap();
            assert_eq!(env.name(), name);
            assert_eq!(env.action_dim(), adim);
        }
        assert!(make("nope").is_err());
    }

    #[test]
    fn step_out_done() {
        assert!(StepOut { reward: 0.0, terminated: true, truncated: false }.done());
        assert!(StepOut { reward: 0.0, terminated: false, truncated: true }.done());
        assert!(!StepOut { reward: 0.0, terminated: false, truncated: false }.done());
    }

    #[test]
    fn all_envs_render_without_panic_and_differ_over_time() {
        let mut rng = Rng::new(0);
        for name in ["pendulum", "hopper", "walker"] {
            let mut env = make(name).unwrap();
            env.reset(&mut rng);
            let mut f0 = FrameRgb::new(100, 100);
            env.render(&mut f0);
            for _ in 0..10 {
                let a = vec![0.7; env.action_dim()];
                env.step(&a);
            }
            let mut f1 = FrameRgb::new(100, 100);
            env.render(&mut f1);
            assert_ne!(f0.data, f1.data, "{name} render static over 10 steps");
        }
    }
}

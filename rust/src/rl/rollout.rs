//! On-policy rollout buffer for PPO: stores one rollout segment and
//! computes GAE(λ) advantages / returns exactly as SB3 does.

#[derive(Debug)]
pub struct Rollout {
    pub obs_len: usize,
    pub act_len: usize,
    pub capacity: usize,
    pub obs: Vec<f32>,
    pub act: Vec<f32>,
    pub logp: Vec<f32>,
    pub value: Vec<f32>,
    pub rew: Vec<f32>,
    /// episode ended *at* this step (terminated or truncated)
    pub done: Vec<f32>,
    /// terminated (MDP end; bootstrap suppressed) vs truncated
    pub terminated: Vec<f32>,
    len: usize,
}

impl Rollout {
    pub fn new(capacity: usize, obs_len: usize, act_len: usize) -> Rollout {
        Rollout {
            obs_len,
            act_len,
            capacity,
            obs: Vec::with_capacity(capacity * obs_len),
            act: Vec::with_capacity(capacity * act_len),
            logp: Vec::with_capacity(capacity),
            value: Vec::with_capacity(capacity),
            rew: Vec::with_capacity(capacity),
            done: Vec::with_capacity(capacity),
            terminated: Vec::with_capacity(capacity),
            len: 0,
        }
    }

    pub fn clear(&mut self) {
        self.obs.clear();
        self.act.clear();
        self.logp.clear();
        self.value.clear();
        self.rew.clear();
        self.done.clear();
        self.terminated.clear();
        self.len = 0;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn full(&self) -> bool {
        self.len >= self.capacity
    }

    #[allow(clippy::too_many_arguments)]
    pub fn push(
        &mut self,
        obs: &[f32],
        act: &[f32],
        logp: f32,
        value: f32,
        rew: f32,
        done: bool,
        terminated: bool,
    ) {
        assert!(!self.full(), "rollout full");
        assert_eq!(obs.len(), self.obs_len);
        assert_eq!(act.len(), self.act_len);
        self.obs.extend_from_slice(obs);
        self.act.extend_from_slice(act);
        self.logp.push(logp);
        self.value.push(value);
        self.rew.push(rew);
        self.done.push(if done { 1.0 } else { 0.0 });
        self.terminated.push(if terminated { 1.0 } else { 0.0 });
        self.len += 1;
    }

    /// GAE(λ): returns (advantages, returns). `last_value` bootstraps the
    /// final step if the segment ended mid-episode (or was truncated —
    /// truncation bootstraps, termination does not).
    pub fn gae(&self, gamma: f64, lam: f64, last_value: f32) -> (Vec<f32>, Vec<f32>) {
        let n = self.len;
        let mut adv = vec![0.0f32; n];
        let mut last_gae = 0.0f64;
        for t in (0..n).rev() {
            let (next_value, next_nonterminal) = if t == n - 1 {
                (
                    last_value as f64,
                    if self.terminated[t] > 0.5 { 0.0 } else { 1.0 },
                )
            } else {
                (
                    self.value[t + 1] as f64,
                    if self.terminated[t] > 0.5 { 0.0 } else { 1.0 },
                )
            };
            // a done (truncation or termination) also cuts the GAE chain
            let chain = if self.done[t] > 0.5 { 0.0 } else { 1.0 };
            let delta =
                self.rew[t] as f64 + gamma * next_value * next_nonterminal - self.value[t] as f64;
            last_gae = delta + gamma * lam * chain * last_gae;
            adv[t] = last_gae as f32;
            if self.done[t] > 0.5 {
                last_gae = 0.0;
            }
        }
        let ret: Vec<f32> = adv.iter().zip(&self.value).map(|(a, v)| a + v).collect();
        (adv, ret)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_step(r: &mut Rollout, rew: f32, value: f32, done: bool, term: bool) {
        r.push(&[0.0], &[0.0], 0.0, value, rew, done, term);
    }

    #[test]
    fn gae_single_step_episode() {
        let mut r = Rollout::new(4, 1, 1);
        push_step(&mut r, 1.0, 0.5, true, true);
        let (adv, ret) = r.gae(0.99, 0.95, 99.0); // last_value ignored (terminated)
        assert!((adv[0] - (1.0 - 0.5)).abs() < 1e-6);
        assert!((ret[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gae_bootstraps_on_truncation_but_not_termination() {
        // identical rollouts except the final flag
        let make = |terminated| {
            let mut r = Rollout::new(1, 1, 1);
            push_step(&mut r, 0.0, 0.0, true, terminated);
            r.gae(0.99, 0.95, 1.0).0[0]
        };
        let trunc_adv = make(false);
        let term_adv = make(true);
        assert!((term_adv - 0.0).abs() < 1e-6);
        assert!((trunc_adv - 0.99).abs() < 1e-6); // bootstrapped
    }

    #[test]
    fn gae_matches_hand_computation() {
        // 2 steps, no dones: delta1 = r1 + g*v2 - v1, delta0 = r0 + g*v1 - v0
        let mut r = Rollout::new(2, 1, 1);
        push_step(&mut r, 1.0, 2.0, false, false);
        push_step(&mut r, 1.0, 3.0, false, false);
        let (adv, _) = r.gae(0.9, 0.5, 4.0);
        let d1 = 1.0 + 0.9 * 4.0 - 3.0; // 1.6
        let d0 = 1.0 + 0.9 * 3.0 - 2.0; // 1.7
        assert!((adv[1] as f64 - d1).abs() < 1e-6);
        assert!((adv[0] as f64 - (d0 + 0.9 * 0.5 * d1)).abs() < 1e-5);
    }

    #[test]
    fn gae_resets_across_episode_boundary() {
        let mut r = Rollout::new(3, 1, 1);
        push_step(&mut r, 5.0, 0.0, true, true); // episode 1 ends
        push_step(&mut r, 1.0, 0.0, false, false); // episode 2
        push_step(&mut r, 1.0, 0.0, false, false);
        let (adv, _) = r.gae(1.0, 1.0, 0.0);
        // step 0's advantage must not include episode 2's rewards
        assert!((adv[0] - 5.0).abs() < 1e-6, "{adv:?}");
    }

    #[test]
    fn capacity_enforced() {
        let mut r = Rollout::new(1, 1, 1);
        push_step(&mut r, 0.0, 0.0, false, false);
        assert!(r.full());
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            push_step(&mut r, 0.0, 0.0, false, false)
        }));
        assert!(result.is_err());
    }

    #[test]
    fn clear_resets() {
        let mut r = Rollout::new(2, 1, 1);
        push_step(&mut r, 0.0, 0.0, false, false);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.obs.len(), 0);
    }
}

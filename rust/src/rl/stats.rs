//! Episodic return tracking with the paper's summary statistics:
//! **Best** (max episodic return), **Mean** (average over training), and
//! **Final** (mean over the final 100 episodes) — Tables 2–4.

#[derive(Debug, Clone, Default)]
pub struct EpisodeStats {
    returns: Vec<f64>,
}

impl EpisodeStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, episodic_return: f64) {
        self.returns.push(episodic_return);
    }

    pub fn episodes(&self) -> usize {
        self.returns.len()
    }

    pub fn best(&self) -> f64 {
        self.returns.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.returns.is_empty() {
            return 0.0;
        }
        self.returns.iter().sum::<f64>() / self.returns.len() as f64
    }

    /// Mean over the final `n` episodes (the paper uses n = 100).
    pub fn final_n(&self, n: usize) -> f64 {
        if self.returns.is_empty() {
            return 0.0;
        }
        let tail = &self.returns[self.returns.len().saturating_sub(n)..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    pub fn final_100(&self) -> f64 {
        self.final_n(100)
    }

    pub fn returns(&self) -> &[f64] {
        &self.returns
    }

    /// Mean over a window, for learning curves.
    pub fn smoothed(&self, window: usize) -> Vec<f64> {
        if window == 0 || self.returns.is_empty() {
            return Vec::new();
        }
        (0..self.returns.len())
            .map(|i| {
                let lo = i.saturating_sub(window - 1);
                let w = &self.returns[lo..=i];
                w.iter().sum::<f64>() / w.len() as f64
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_statistics() {
        let mut s = EpisodeStats::new();
        for r in [1.0, 5.0, 3.0] {
            s.push(r);
        }
        assert_eq!(s.best(), 5.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.final_n(2), 4.0);
        assert_eq!(s.episodes(), 3);
    }

    #[test]
    fn final_100_with_fewer_episodes_uses_all() {
        let mut s = EpisodeStats::new();
        s.push(2.0);
        s.push(4.0);
        assert_eq!(s.final_100(), 3.0);
    }

    #[test]
    fn final_100_uses_exactly_last_100() {
        let mut s = EpisodeStats::new();
        for _ in 0..100 {
            s.push(0.0);
        }
        for _ in 0..100 {
            s.push(10.0);
        }
        assert_eq!(s.final_100(), 10.0);
        assert_eq!(s.mean(), 5.0);
    }

    #[test]
    fn smoothing() {
        let mut s = EpisodeStats::new();
        for r in [0.0, 2.0, 4.0] {
            s.push(r);
        }
        assert_eq!(s.smoothed(2), vec![0.0, 1.0, 3.0]);
        assert!(s.smoothed(0).is_empty());
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = EpisodeStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.final_100(), 0.0);
    }
}

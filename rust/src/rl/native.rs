//! Native PPO engine: a pure-Rust actor-critic that trains without the
//! AOT artifact path (DESIGN.md §8). The artifact trainer needs real PJRT
//! bindings; this engine is the offline-capable counterpart the online
//! learning loop (`learn::`) drives through the serving fleet, and the
//! offline baseline the fleet run is gated against.
//!
//! Determinism contract: all randomness (exploration noise + minibatch
//! permutations) flows through one internal [`Rng`] stream, and
//! [`NativeCore::value`] / [`NativeCore::act_det`] never touch it. The
//! online loop replays the exact offline call order (`act` → push →
//! `value` + `run_ppo_epochs` on segment boundary → `act`), so an
//! ideal-link fleet run is bit-identical to [`super::trainer`]'s native
//! offline loop at the same seed.

use anyhow::{ensure, Result};

use crate::codec;
use crate::util::rng::Rng;

use super::rollout::Rollout;

/// ln(2π) as f32, shared by sampling and gradient paths.
const LN_2PI: f32 = 1.837_877_1;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Hyperparameters for the native actor-critic (Gaussian policy over a
/// one-hidden-layer tanh MLP with a separate value head).
#[derive(Debug, Clone)]
pub struct NativeConfig {
    pub obs_len: usize,
    pub act_len: usize,
    pub hidden: usize,
    pub lr: f32,
    /// PPO clip range ε
    pub clip: f32,
    pub vf_coef: f32,
    pub ent_coef: f32,
    /// global gradient-norm clip (0 disables)
    pub max_grad_norm: f32,
    /// initial per-dim log σ of the Gaussian policy
    pub init_log_std: f32,
    /// PPO minibatch size (must divide the rollout segment length)
    pub minibatch: usize,
    pub gamma: f64,
    pub seed: u64,
}

impl Default for NativeConfig {
    fn default() -> Self {
        NativeConfig {
            obs_len: 3,
            act_len: 1,
            hidden: 32,
            lr: 1e-3,
            clip: 0.2,
            vf_coef: 0.5,
            ent_coef: 0.0,
            max_grad_norm: 0.5,
            init_log_std: 0.0,
            minibatch: 64,
            gamma: 0.9,
            seed: 0,
        }
    }
}

/// Flat-parameter actor-critic with manual PPO gradients and Adam.
///
/// Parameter layout (one contiguous `Vec<f32>`, the unit the
/// `learn::PolicyStore` snapshots and the wire `PolicySync` carries):
/// `W1[h·o] | b1[h] | Wmu[a·h] | bmu[a] | Wv[h] | bv[1] | log_std[a]`.
#[derive(Debug, Clone)]
pub struct NativeCore {
    pub cfg: NativeConfig,
    params: Vec<f32>,
    /// Adam first/second moments + step counter (never snapshotted: an
    /// adopting learner keeps its own optimiser state)
    m: Vec<f32>,
    v: Vec<f32>,
    adam_t: i32,
    rng: Rng,
    /// total PPO minibatch gradient steps taken
    pub gradient_steps: u64,
    /// scratch: hidden activations + per-minibatch gradient accumulator
    h_buf: Vec<f32>,
    grad: Vec<f32>,
}

impl NativeCore {
    pub fn n_params(cfg: &NativeConfig) -> usize {
        let (o, a, h) = (cfg.obs_len, cfg.act_len, cfg.hidden);
        h * o + h + a * h + a + h + 1 + a
    }

    pub fn new(cfg: NativeConfig) -> NativeCore {
        let n = Self::n_params(&cfg);
        let mut rng = Rng::new(cfg.seed);
        let (o, a, h) = (cfg.obs_len, cfg.act_len, cfg.hidden);
        let mut params = vec![0.0f32; n];
        let s1 = 1.0 / (o as f64).sqrt();
        let s2 = 1.0 / (h as f64).sqrt();
        for w in params[..h * o].iter_mut() {
            *w = rng.range(-s1, s1) as f32;
        }
        let mu_w = h * o + h;
        for w in params[mu_w..mu_w + a * h].iter_mut() {
            *w = rng.range(-s2, s2) as f32;
        }
        let v_w = mu_w + a * h + a;
        for w in params[v_w..v_w + h].iter_mut() {
            *w = rng.range(-s2, s2) as f32;
        }
        let ls = v_w + h + 1;
        for w in params[ls..ls + a].iter_mut() {
            *w = cfg.init_log_std;
        }
        NativeCore {
            m: vec![0.0; n],
            v: vec![0.0; n],
            adam_t: 0,
            rng,
            gradient_steps: 0,
            h_buf: vec![0.0; h],
            grad: vec![0.0; n],
            params,
            cfg,
        }
    }

    #[inline]
    fn offsets(&self) -> (usize, usize, usize, usize, usize, usize, usize) {
        let (o, a, h) = (self.cfg.obs_len, self.cfg.act_len, self.cfg.hidden);
        let w1 = 0;
        let b1 = w1 + h * o;
        let wmu = b1 + h;
        let bmu = wmu + a * h;
        let wv = bmu + a;
        let bv = wv + h;
        let ls = bv + 1;
        (w1, b1, wmu, bmu, wv, bv, ls)
    }

    /// Forward pass writing hidden activations into `h_out`; returns
    /// (μ, value).
    fn forward_into(&self, obs: &[f32], h_out: &mut [f32]) -> (Vec<f32>, f32) {
        let (o, a, h) = (self.cfg.obs_len, self.cfg.act_len, self.cfg.hidden);
        debug_assert_eq!(obs.len(), o);
        let (w1, b1, wmu, bmu, wv, bv, _) = self.offsets();
        let p = &self.params;
        for k in 0..h {
            let mut acc = p[b1 + k];
            let row = &p[w1 + k * o..w1 + (k + 1) * o];
            for (wx, x) in row.iter().zip(obs) {
                acc += wx * x;
            }
            h_out[k] = acc.tanh();
        }
        let mut mu = vec![0.0f32; a];
        for (j, mu_j) in mu.iter_mut().enumerate() {
            let mut acc = p[bmu + j];
            let row = &p[wmu + j * h..wmu + (j + 1) * h];
            for (wx, x) in row.iter().zip(h_out.iter()) {
                acc += wx * x;
            }
            *mu_j = acc;
        }
        let mut val = p[bv];
        for (wx, x) in p[wv..wv + h].iter().zip(h_out.iter()) {
            val += wx * x;
        }
        (mu, val)
    }

    /// Stochastic action for rollouts: draws Gaussian noise from the
    /// internal rng stream. Returns (action, log-prob, value).
    pub fn act(&mut self, obs: &[f32]) -> (Vec<f32>, f32, f32) {
        let mut h = std::mem::take(&mut self.h_buf);
        let (mu, val) = self.forward_into(obs, &mut h);
        self.h_buf = h;
        let (_, _, _, _, _, _, ls) = self.offsets();
        let mut a = vec![0.0f32; self.cfg.act_len];
        let mut logp = 0.0f32;
        for (j, a_j) in a.iter_mut().enumerate() {
            let log_std = self.params[ls + j];
            let std = log_std.exp();
            *a_j = mu[j] + std * self.rng.normal_f32();
            let z = (*a_j - mu[j]) / std;
            logp += -0.5 * z * z - log_std - 0.5 * LN_2PI;
        }
        (a, logp, val)
    }

    /// Deterministic (mean) action + value; rng-free.
    pub fn act_det(&mut self, obs: &[f32]) -> (Vec<f32>, f32) {
        let mut h = std::mem::take(&mut self.h_buf);
        let out = self.forward_into(obs, &mut h);
        self.h_buf = h;
        out
    }

    /// Value estimate; rng-free (safe for GAE bootstrap).
    pub fn value(&mut self, obs: &[f32]) -> f32 {
        self.act_det(obs).1
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Replace the parameter vector (policy adoption). Optimiser moments
    /// are deliberately kept: each learner owns its Adam state.
    pub fn set_params(&mut self, p: &[f32]) -> Result<()> {
        ensure!(
            p.len() == self.params.len(),
            "policy size mismatch: got {}, core has {}",
            p.len(),
            self.params.len()
        );
        self.params.copy_from_slice(p);
        Ok(())
    }

    /// PPO update over a full rollout segment: `epochs` shuffled passes of
    /// `cfg.minibatch`-sized clipped-surrogate steps. Consumes the rng
    /// (one permutation per epoch) — call order must match between the
    /// offline and online loops.
    pub fn run_ppo_epochs(
        &mut self,
        ro: &Rollout,
        adv: &[f32],
        ret: &[f32],
        epochs: usize,
    ) -> Result<()> {
        let n = ro.len();
        let mb = self.cfg.minibatch;
        ensure!(n > 0, "empty rollout");
        ensure!(
            n % mb == 0,
            "rollout length {n} must be a multiple of minibatch {mb}"
        );
        // advantage normalisation over the whole segment
        let mean = adv.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var =
            adv.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        let inv_std = (1.0 / (var.sqrt() + 1e-8)) as f32;
        let mean = mean as f32;
        let adv_n: Vec<f32> = adv.iter().map(|&x| (x - mean) * inv_std).collect();

        for _ in 0..epochs {
            let perm = self.rng.permutation(n);
            for c in 0..n / mb {
                self.minibatch_step(ro, &adv_n, ret, &perm[c * mb..(c + 1) * mb]);
            }
        }
        Ok(())
    }

    fn minibatch_step(&mut self, ro: &Rollout, adv: &[f32], ret: &[f32], idx: &[usize]) {
        let (o, a_len, h_len) = (self.cfg.obs_len, self.cfg.act_len, self.cfg.hidden);
        let (w1, b1, wmu, bmu, wv, bv, ls) = self.offsets();
        let clip = self.cfg.clip;
        let mut grad = std::mem::take(&mut self.grad);
        grad.iter_mut().for_each(|g| *g = 0.0);
        let mut h = std::mem::take(&mut self.h_buf);

        for &i in idx {
            let obs = &ro.obs[i * o..(i + 1) * o];
            let act = &ro.act[i * a_len..(i + 1) * a_len];
            let (mu, val) = self.forward_into(obs, &mut h);
            let p = &self.params;

            let mut logp = 0.0f32;
            for j in 0..a_len {
                let log_std = p[ls + j];
                let z = (act[j] - mu[j]) / log_std.exp();
                logp += -0.5 * z * z - log_std - 0.5 * LN_2PI;
            }
            let ratio = (logp - ro.logp[i]).exp();
            let u1 = ratio * adv[i];
            let u2 = ratio.clamp(1.0 - clip, 1.0 + clip) * adv[i];
            // clipped surrogate: gradient flows only through the
            // unclipped branch when it is the active minimum
            let g_logp = if u1 <= u2 { -adv[i] * ratio } else { 0.0 };
            let g_val = self.cfg.vf_coef * 2.0 * (val - ret[i]);

            // backprop through the heads into shared hidden activations
            let mut gh = vec![0.0f32; h_len];
            for j in 0..a_len {
                let log_std = p[ls + j];
                let std = log_std.exp();
                let z = (act[j] - mu[j]) / std;
                let d_mu = g_logp * z / std;
                for k in 0..h_len {
                    grad[wmu + j * h_len + k] += d_mu * h[k];
                    gh[k] += d_mu * p[wmu + j * h_len + k];
                }
                grad[bmu + j] += d_mu;
                grad[ls + j] += g_logp * (z * z - 1.0) - self.cfg.ent_coef;
            }
            for k in 0..h_len {
                grad[wv + k] += g_val * h[k];
                gh[k] += g_val * p[wv + k];
            }
            grad[bv] += g_val;
            for k in 0..h_len {
                let gp = gh[k] * (1.0 - h[k] * h[k]);
                for (gx, x) in grad[w1 + k * o..w1 + (k + 1) * o].iter_mut().zip(obs) {
                    *gx += gp * x;
                }
                grad[b1 + k] += gp;
            }
        }

        let inv = 1.0 / idx.len() as f32;
        grad.iter_mut().for_each(|g| *g *= inv);
        if self.cfg.max_grad_norm > 0.0 {
            let norm =
                grad.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>().sqrt() as f32;
            if norm > self.cfg.max_grad_norm {
                let scale = self.cfg.max_grad_norm / norm;
                grad.iter_mut().for_each(|g| *g *= scale);
            }
        }

        self.adam_t += 1;
        let bc1 = 1.0 - ADAM_B1.powi(self.adam_t);
        let bc2 = 1.0 - ADAM_B2.powi(self.adam_t);
        let lr = self.cfg.lr;
        for i in 0..self.params.len() {
            let g = grad[i];
            self.m[i] = ADAM_B1 * self.m[i] + (1.0 - ADAM_B1) * g;
            self.v[i] = ADAM_B2 * self.v[i] + (1.0 - ADAM_B2) * g * g;
            let m_hat = self.m[i] / bc1;
            let v_hat = self.v[i] / bc2;
            self.params[i] -= lr * m_hat / (v_hat.sqrt() + ADAM_EPS);
        }
        self.gradient_steps += 1;
        self.h_buf = h;
        self.grad = grad;
    }
}

/// Map a raw pendulum state `[cosθ, sinθ, θ̇]` into the non-negative
/// unit-range feature vector the wire codec quantises: `(cosθ+1)/2`,
/// `(sinθ+1)/2`, `(θ̇+8)/16`.
pub fn normalize_pendulum_obs(state: &[f64], out: &mut [f32]) {
    debug_assert_eq!(state.len(), 3);
    out[0] = ((state[0] + 1.0) * 0.5) as f32;
    out[1] = ((state[1] + 1.0) * 0.5) as f32;
    out[2] = ((state[2] + 8.0) / 16.0) as f32;
}

/// Quantise + dequantise `obs` in place through the codec's u8 domain —
/// exactly what a feature frame experiences on the wire, so the offline
/// trainer sees bit-identical observations to a fleet client's shard.
pub fn quantize_roundtrip(obs: &mut [f32], qmax: u8, qbuf: &mut Vec<u8>) {
    let scale = codec::quantize_into(obs, qmax, qbuf);
    codec::dequantize_into(scale, qmax, qbuf, obs);
}

/// Per-episode environment rng, shared by the offline trainer and the
/// fleet clients so both sides replay identical episode streams.
pub fn episode_rng(seed: u64, episode: u64) -> Rng {
    Rng::new(seed ^ episode.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> NativeConfig {
        NativeConfig { hidden: 8, minibatch: 4, seed: 3, ..NativeConfig::default() }
    }

    #[test]
    fn init_is_deterministic_and_sized() {
        let a = NativeCore::new(tiny_cfg());
        let b = NativeCore::new(tiny_cfg());
        assert_eq!(a.params(), b.params());
        assert_eq!(a.params().len(), NativeCore::n_params(&tiny_cfg()));
        let c = NativeCore::new(NativeConfig { seed: 4, ..tiny_cfg() });
        assert_ne!(a.params(), c.params());
    }

    #[test]
    fn value_and_act_det_are_rng_free() {
        let mut core = NativeCore::new(tiny_cfg());
        let obs = [0.3f32, 0.7, 0.5];
        let v1 = core.value(&obs);
        let (mu1, _) = core.act_det(&obs);
        // an rng-consuming call in between must not change them
        let mut probe = NativeCore::new(tiny_cfg());
        let _ = probe.act(&obs);
        assert_eq!(v1, probe.value(&obs));
        assert_eq!(mu1, probe.act_det(&obs).0);
    }

    #[test]
    fn act_logp_matches_gaussian_density() {
        let mut core = NativeCore::new(tiny_cfg());
        let obs = [0.1f32, 0.9, 0.4];
        let (a, logp, _) = core.act(&obs);
        let (mu, _) = core.act_det(&obs);
        let (_, _, _, _, _, _, ls) = core.offsets();
        let log_std = core.params()[ls];
        let z = (a[0] - mu[0]) / log_std.exp();
        let want = -0.5 * z * z - log_std - 0.5 * LN_2PI;
        assert!((logp - want).abs() < 1e-5, "{logp} vs {want}");
    }

    #[test]
    fn set_params_roundtrip_and_size_check() {
        let mut core = NativeCore::new(tiny_cfg());
        let snap = core.params().to_vec();
        let mut other = NativeCore::new(NativeConfig { seed: 9, ..tiny_cfg() });
        other.set_params(&snap).unwrap();
        assert_eq!(other.params(), snap.as_slice());
        assert!(other.set_params(&snap[1..]).is_err());
    }

    #[test]
    fn ppo_update_moves_params_finitely() {
        let cfg = tiny_cfg();
        let mut core = NativeCore::new(cfg.clone());
        let mut ro = Rollout::new(8, cfg.obs_len, cfg.act_len);
        let mut obs = vec![0.0f32; cfg.obs_len];
        for i in 0..8 {
            obs.iter_mut().enumerate().for_each(|(j, x)| {
                *x = ((i + j) as f32 * 0.11).fract();
            });
            let (a, logp, v) = core.act(&obs);
            ro.push(&obs, &a, logp, v, -1.0 - i as f32 * 0.1, i == 7, false);
        }
        let (adv, ret) = ro.gae(0.9, 0.95, 0.0);
        let before = core.params().to_vec();
        core.run_ppo_epochs(&ro, &adv, &ret, 2).unwrap();
        assert_ne!(core.params(), before.as_slice());
        assert!(core.params().iter().all(|p| p.is_finite()));
        assert_eq!(core.gradient_steps, 2 * 2); // 2 epochs x (8/4) minibatches
    }

    #[test]
    fn ppo_update_rejects_bad_minibatch() {
        let cfg = NativeConfig { minibatch: 5, ..tiny_cfg() };
        let mut core = NativeCore::new(cfg.clone());
        let mut ro = Rollout::new(8, cfg.obs_len, cfg.act_len);
        let obs = vec![0.1f32; cfg.obs_len];
        let act = vec![0.0f32; cfg.act_len];
        for _ in 0..8 {
            ro.push(&obs, &act, 0.0, 0.0, -1.0, false, false);
        }
        let (adv, ret) = ro.gae(0.9, 0.95, 0.0);
        assert!(core.run_ppo_epochs(&ro, &adv, &ret, 1).is_err());
    }

    #[test]
    fn normalized_obs_in_unit_range_and_roundtrip_is_stable() {
        let mut qbuf = Vec::new();
        let mut obs = [0.0f32; 3];
        for (c, s, td) in [(1.0, 0.0, 8.0), (-1.0, -1.0, -8.0), (0.2, -0.4, 3.5)] {
            normalize_pendulum_obs(&[c, s, td], &mut obs);
            assert!(obs.iter().all(|&x| (0.0..=1.0).contains(&x)), "{obs:?}");
            quantize_roundtrip(&mut obs, 255, &mut qbuf);
            let once = obs;
            // a second trip through the u8 domain is a fixed point
            quantize_roundtrip(&mut obs, 255, &mut qbuf);
            assert_eq!(once, obs);
        }
    }

    #[test]
    fn episode_rng_streams_differ_by_episode_and_match_by_seed() {
        assert_eq!(episode_rng(7, 3).next_u64(), episode_rng(7, 3).next_u64());
        assert_ne!(episode_rng(7, 3).next_u64(), episode_rng(7, 4).next_u64());
    }
}

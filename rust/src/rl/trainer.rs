//! Generic trainer: drives the AOT-compiled update/act artifacts for any
//! (task, encoder, algorithm) combination described by the manifest's
//! train-state spec. The Rust side never hard-codes network shapes — it
//! threads flat state tensors through the artifact in manifest order.
//!
//! Loops follow SB3 semantics: off-policy (DDPG/SAC) with warmup, replay,
//! and `train_freq`; on-policy (PPO) with rollout segments, GAE(λ=0.95),
//! and shuffled fixed-size minibatch epochs. The `done` flag stored for
//! bootstrapping is *termination only* (truncation bootstraps).

use std::rc::Rc;

use anyhow::{anyhow, Context, Result};
use log::info;

use crate::envs::{make, CropMode, Env, PixelPipeline};
use crate::runtime::{DType, Exe, Runtime, TrainStateSpec, Value};
use crate::util::rng::Rng;

use crate::envs::pendulum::Pendulum;

use super::native::{
    episode_rng, normalize_pendulum_obs, quantize_roundtrip, NativeConfig, NativeCore,
};
use super::replay::Replay;
use super::rollout::Rollout;
use super::stats::EpisodeStats;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub episodes: usize,
    /// uniform-random action steps before learning starts (off-policy)
    pub warmup_steps: usize,
    /// env steps per gradient step (off-policy)
    pub train_freq: usize,
    /// DDPG exploration noise (fraction of max_action)
    pub action_noise: f64,
    /// PPO rollout segment length (multiple of the artifact batch)
    pub rollout_steps: usize,
    pub ppo_epochs: usize,
    pub gae_lambda: f64,
    pub replay_capacity: usize,
    pub seed: u64,
    /// print a progress line every n episodes (0 = silent)
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            episodes: 30,
            warmup_steps: 500,
            train_freq: 4,
            action_noise: 0.1,
            rollout_steps: 256,
            ppo_epochs: 10,
            gae_lambda: 0.95,
            replay_capacity: 10_000,
            seed: 0,
            log_every: 0,
        }
    }
}

#[derive(Debug, Default)]
pub struct TrainReport {
    pub stats: EpisodeStats,
    /// per-update metric curves, keyed by manifest metric name
    pub metrics: Vec<(String, Vec<f32>)>,
    pub env_steps: usize,
    pub updates: usize,
}

pub struct Trainer<'a> {
    /// kept for lifetime anchoring: executables borrow the runtime's client
    #[allow(dead_code)]
    rt: &'a Runtime,
    pub spec: TrainStateSpec,
    state: Vec<Value>,
    update_exe: Rc<Exe>,
    act_exe: Rc<Exe>,
    act_det_exe: Rc<Exe>,
    env: Box<dyn Env>,
    pipeline: PixelPipeline,
    rng: Rng,
    cfg: TrainConfig,
    pub report: TrainReport,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, run: &str, cfg: TrainConfig) -> Result<Trainer<'a>> {
        let spec = rt
            .manifest
            .trainstates
            .get(run)
            .ok_or_else(|| anyhow!("unknown trainstate {run:?}"))?
            .clone();
        let state = load_state(rt, &spec)?;
        let update_exe = rt
            .load(&spec.artifacts["update"])
            .context("compiling update artifact")?;
        let act_exe = rt.load(&spec.artifacts["act"])?;
        let act_det_exe = rt.load(&spec.artifacts["act_det"])?;
        let env = make(&spec.task)?;
        // tiny pipeline: render = crop + 8 (aot's TINY_RENDER convention)
        let pipeline = PixelPipeline::new(spec.x + 8, spec.x, CropMode::Random);
        let rng = Rng::new(cfg.seed);
        let metrics = spec.metrics.iter().map(|m| (m.clone(), Vec::new())).collect();
        Ok(Trainer {
            rt,
            spec,
            state,
            update_exe,
            act_exe,
            act_det_exe,
            env,
            pipeline,
            rng,
            cfg,
            report: TrainReport { metrics, ..Default::default() },
        })
    }

    fn state_value(&self, name: &str) -> &Value {
        let idx = self.spec.state.iter().position(|s| s.name == name).unwrap();
        &self.state[idx]
    }

    fn obs_value(&self, obs: &[f32], batch: usize) -> Value {
        Value::f32(&[batch, 9, self.spec.x, self.spec.x], obs.to_vec())
    }

    /// Stochastic policy action for rollouts.
    fn act(&mut self, obs: &[f32]) -> Result<(Vec<f32>, f32, f32)> {
        let adim = self.spec.action_dim;
        let obs_v = self.obs_value(obs, 1);
        match self.spec.algo.as_str() {
            "ddpg" => {
                let actor = self.state_value("actor").clone();
                let out = self.act_exe.run(&[&actor, &obs_v])?;
                let mut a = out[0].as_f32()?.to_vec();
                let lim = self.spec.max_action as f32;
                for x in a.iter_mut() {
                    *x = (*x + (self.cfg.action_noise * self.spec.max_action) as f32
                        * self.rng.normal_f32())
                    .clamp(-lim, lim);
                }
                Ok((a, 0.0, 0.0))
            }
            "sac" => {
                let actor = self.state_value("actor").clone();
                let mut noise = vec![0.0f32; adim];
                self.rng.fill_normal(&mut noise);
                let noise_v = Value::f32(&[1, adim], noise);
                let out = self.act_exe.run(&[&actor, &obs_v, &noise_v])?;
                Ok((out[0].as_f32()?.to_vec(), 0.0, 0.0))
            }
            "ppo" => {
                let params = self.state_value("params").clone();
                let mut noise = vec![0.0f32; adim];
                self.rng.fill_normal(&mut noise);
                let noise_v = Value::f32(&[1, adim], noise);
                let out = self.act_exe.run(&[&params, &obs_v, &noise_v])?;
                Ok((
                    out[0].as_f32()?.to_vec(),
                    out[1].as_f32()?[0],
                    out[2].as_f32()?[0],
                ))
            }
            other => anyhow::bail!("unknown algo {other}"),
        }
    }

    /// Deterministic action (+ value for PPO) for evaluation/bootstrap.
    pub fn act_det(&self, obs: &[f32]) -> Result<(Vec<f32>, f32)> {
        let obs_v = self.obs_value(obs, 1);
        let p = match self.spec.algo.as_str() {
            "ppo" => self.state_value("params").clone(),
            _ => self.state_value("actor").clone(),
        };
        let out = self.act_det_exe.run(&[&p, &obs_v])?;
        let value = if out.len() > 1 { out[1].as_f32()?[0] } else { 0.0 };
        Ok((out[0].as_f32()?.to_vec(), value))
    }

    /// One gradient step: feed state + batch, absorb new state, log metrics.
    fn update(&mut self, batch: Vec<Value>) -> Result<()> {
        let mut inputs: Vec<&Value> = self.state.iter().collect();
        let batch_refs: Vec<&Value> = batch.iter().collect();
        inputs.extend(batch_refs);
        let out = self.update_exe.run(&inputs)?;
        let n_state = self.state.len();
        for (i, v) in out.iter().take(n_state).enumerate() {
            self.state[i] = v.clone();
        }
        for (i, m) in out[n_state..].iter().enumerate() {
            let val = m.scalar()?;
            anyhow::ensure!(val.is_finite(), "metric {} diverged (NaN/inf)", self.spec.metrics[i]);
            self.report.metrics[i].1.push(val);
        }
        self.report.updates += 1;
        Ok(())
    }

    /// Off-policy training (DDPG / SAC).
    fn train_off_policy(&mut self) -> Result<()> {
        let obs_len = 9 * self.spec.x * self.spec.x;
        let adim = self.spec.action_dim;
        let b = self.spec.batch;
        let mut replay = Replay::new(self.cfg.replay_capacity, obs_len, adim);
        let mut total_steps = 0usize;

        // reusable batch staging buffers (no per-update allocation)
        let mut b_obs = vec![0.0f32; b * obs_len];
        let mut b_act = vec![0.0f32; b * adim];
        let mut b_rew = vec![0.0f32; b];
        let mut b_nobs = vec![0.0f32; b * obs_len];
        let mut b_done = vec![0.0f32; b];

        for ep in 0..self.cfg.episodes {
            let mut env_rng = self.rng.fork(ep as u64);
            self.env.reset(&mut env_rng);
            self.pipeline.clear();
            self.pipeline.observe(self.env.as_ref(), &mut self.rng);
            let mut ep_return = 0.0;
            loop {
                let obs = self.pipeline.obs();
                let action = if total_steps < self.cfg.warmup_steps {
                    let lim = self.spec.max_action;
                    (0..adim).map(|_| self.rng.range(-lim, lim) as f32).collect()
                } else {
                    self.act(&obs)?.0
                };
                let a64: Vec<f64> = action.iter().map(|&v| v as f64).collect();
                let out = self.env.step(&a64);
                ep_return += out.reward;
                self.pipeline.observe(self.env.as_ref(), &mut self.rng);
                let nobs = self.pipeline.obs();
                replay.push(&obs, &action, out.reward as f32, &nobs, out.terminated);
                total_steps += 1;

                if total_steps >= self.cfg.warmup_steps
                    && total_steps % self.cfg.train_freq == 0
                    && replay.sample(
                        &mut self.rng,
                        b,
                        &mut b_obs,
                        &mut b_act,
                        &mut b_rew,
                        &mut b_nobs,
                        &mut b_done,
                    )
                {
                    let mut batch = vec![
                        Value::f32(&[b, 9, self.spec.x, self.spec.x], b_obs.clone()),
                        Value::f32(&[b, adim], b_act.clone()),
                        Value::f32(&[b], b_rew.clone()),
                        Value::f32(&[b, 9, self.spec.x, self.spec.x], b_nobs.clone()),
                        Value::f32(&[b], b_done.clone()),
                    ];
                    if self.spec.algo == "sac" {
                        for _ in 0..2 {
                            let mut noise = vec![0.0f32; b * adim];
                            self.rng.fill_normal(&mut noise);
                            batch.push(Value::f32(&[b, adim], noise));
                        }
                    }
                    self.update(batch)?;
                }
                if out.done() {
                    break;
                }
            }
            self.report.stats.push(ep_return);
            self.report.env_steps = total_steps;
            if self.cfg.log_every > 0 && (ep + 1) % self.cfg.log_every == 0 {
                info!(
                    "[{}] ep {:>4}  return {:>9.1}  (mean100 {:>9.1})  steps {}  updates {}",
                    self.spec.name,
                    ep + 1,
                    self.report.stats.returns().last().unwrap(),
                    self.report.stats.final_100(),
                    total_steps,
                    self.report.updates
                );
            }
        }
        Ok(())
    }

    /// On-policy training (PPO).
    fn train_ppo(&mut self) -> Result<()> {
        let obs_len = 9 * self.spec.x * self.spec.x;
        let adim = self.spec.action_dim;
        let mb = self.spec.batch;
        anyhow::ensure!(
            self.cfg.rollout_steps % mb == 0,
            "rollout_steps {} must be a multiple of the artifact batch {mb}",
            self.cfg.rollout_steps
        );
        let mut rollout = Rollout::new(self.cfg.rollout_steps, obs_len, adim);
        let mut total_steps = 0usize;
        let mut ep_return = 0.0;
        let mut episodes_done = 0usize;

        let mut env_rng = self.rng.fork(9999);
        self.env.reset(&mut env_rng);
        self.pipeline.clear();
        self.pipeline.observe(self.env.as_ref(), &mut self.rng);

        while episodes_done < self.cfg.episodes {
            // ---- collect a segment -------------------------------------
            // (always fill the segment, even past the episode budget —
            // minibatches need rollout_steps items)
            rollout.clear();
            while !rollout.full() {
                let obs = self.pipeline.obs();
                let (action, logp, value) = self.act(&obs)?;
                let lim = self.spec.max_action;
                let a64: Vec<f64> =
                    action.iter().map(|&v| (v as f64).clamp(-lim, lim)).collect();
                let out = self.env.step(&a64);
                ep_return += out.reward;
                total_steps += 1;
                rollout.push(
                    &obs,
                    &action,
                    logp,
                    value,
                    out.reward as f32,
                    out.done(),
                    out.terminated,
                );
                self.pipeline.observe(self.env.as_ref(), &mut self.rng);
                if out.done() {
                    self.report.stats.push(ep_return);
                    episodes_done += 1;
                    ep_return = 0.0;
                    if self.cfg.log_every > 0 && episodes_done % self.cfg.log_every == 0 {
                        info!(
                            "[{}] ep {:>4}  return {:>9.1}  steps {}",
                            self.spec.name,
                            episodes_done,
                            self.report.stats.returns().last().unwrap(),
                            total_steps
                        );
                    }
                    let mut env_rng = self.rng.fork(total_steps as u64);
                    self.env.reset(&mut env_rng);
                    self.pipeline.clear();
                    self.pipeline.observe(self.env.as_ref(), &mut self.rng);
                }
            }
            if rollout.is_empty() {
                break;
            }

            // ---- GAE + minibatch epochs --------------------------------
            let (_, last_value) = self.act_det(&self.pipeline.obs())?;
            let (adv, ret) = rollout.gae(self.spec.gamma, self.cfg.gae_lambda, last_value);
            let n = rollout.len();
            let n_mb = n / mb;
            for _epoch in 0..self.cfg.ppo_epochs {
                let perm = self.rng.permutation(n);
                for m in 0..n_mb {
                    let idx = &perm[m * mb..(m + 1) * mb];
                    let mut o = Vec::with_capacity(mb * obs_len);
                    let mut a = Vec::with_capacity(mb * adim);
                    let mut lp = Vec::with_capacity(mb);
                    let mut ad = Vec::with_capacity(mb);
                    let mut rt_ = Vec::with_capacity(mb);
                    for &i in idx {
                        o.extend_from_slice(&rollout.obs[i * obs_len..(i + 1) * obs_len]);
                        a.extend_from_slice(&rollout.act[i * adim..(i + 1) * adim]);
                        lp.push(rollout.logp[i]);
                        ad.push(adv[i]);
                        rt_.push(ret[i]);
                    }
                    let batch = vec![
                        Value::f32(&[mb, 9, self.spec.x, self.spec.x], o),
                        Value::f32(&[mb, adim], a),
                        Value::f32(&[mb], lp),
                        Value::f32(&[mb], ad),
                        Value::f32(&[mb], rt_),
                    ];
                    self.update(batch)?;
                }
            }
            self.report.env_steps = total_steps;
        }
        Ok(())
    }

    pub fn train(&mut self) -> Result<()> {
        match self.spec.algo.as_str() {
            "ddpg" | "sac" => self.train_off_policy(),
            "ppo" => self.train_ppo(),
            other => anyhow::bail!("unknown algo {other}"),
        }
    }

    /// Evaluate the current policy deterministically (centre crop).
    pub fn evaluate(&mut self, episodes: usize) -> Result<f64> {
        let mut pipeline = PixelPipeline::new(self.spec.x + 8, self.spec.x, CropMode::Center);
        let mut total = 0.0;
        let mut rng = Rng::new(self.cfg.seed ^ 0xEA11);
        for ep in 0..episodes {
            let mut env_rng = Rng::new(1000 + ep as u64);
            self.env.reset(&mut env_rng);
            pipeline.clear();
            pipeline.observe(self.env.as_ref(), &mut rng);
            loop {
                let (a, _) = self.act_det(&pipeline.obs())?;
                let lim = self.spec.max_action;
                let a64: Vec<f64> = a.iter().map(|&v| (v as f64).clamp(-lim, lim)).collect();
                let out = self.env.step(&a64);
                total += out.reward;
                pipeline.observe(self.env.as_ref(), &mut rng);
                if out.done() {
                    break;
                }
            }
        }
        Ok(total / episodes as f64)
    }
}

/// Offline native PPO baseline on Pendulum (DESIGN.md §8): the
/// artifact-free counterpart to the PJRT [`Trainer`], built on
/// [`NativeCore`]. Observations take the same normalise → quantise →
/// dequantise trip a fleet client's features take over the wire, and the
/// core-call order (`act` → push → `value` + `run_ppo_epochs` at segment
/// boundaries) matches the online learning loop exactly, so an
/// ideal-link fleet run at the same seed reproduces this loop
/// bit-for-bit. That parity is what the `learning_smoke` e2e gate pins.
pub struct NativeTrainer {
    pub core: NativeCore,
    env: Pendulum,
    cfg: TrainConfig,
    pub stats: EpisodeStats,
    pub updates: usize,
    pub env_steps: usize,
}

impl NativeTrainer {
    /// `cfg.seed` drives the per-episode environment streams; the core's
    /// own seed (exploration + minibatch shuffles) comes from `native`.
    pub fn new(cfg: TrainConfig, native: NativeConfig) -> NativeTrainer {
        NativeTrainer {
            core: NativeCore::new(native),
            env: Pendulum::new(),
            cfg,
            stats: EpisodeStats::default(),
            updates: 0,
            env_steps: 0,
        }
    }

    pub fn train(&mut self) -> Result<()> {
        let obs_len = self.core.cfg.obs_len;
        let gamma = self.core.cfg.gamma;
        anyhow::ensure!(
            self.cfg.rollout_steps % self.core.cfg.minibatch == 0,
            "rollout_steps {} must be a multiple of minibatch {}",
            self.cfg.rollout_steps,
            self.core.cfg.minibatch
        );
        if self.cfg.episodes == 0 {
            return Ok(());
        }
        let mut rollout =
            Rollout::new(self.cfg.rollout_steps, obs_len, self.core.cfg.act_len);
        let mut qbuf = Vec::new();
        let mut obs = vec![0.0f32; obs_len];
        let mut next_obs = vec![0.0f32; obs_len];
        let mut ep = 0u64;
        let mut ep_return = 0.0f64;
        let max_a = self.env.max_action();

        let mut env_rng = episode_rng(self.cfg.seed, 0);
        self.env.reset(&mut env_rng);
        normalize_pendulum_obs(&self.env.state(), &mut obs);
        quantize_roundtrip(&mut obs, 255, &mut qbuf);

        loop {
            let (a, logp, v) = self.core.act(&obs);
            let a64: Vec<f64> =
                a.iter().map(|&x| (x as f64).clamp(-max_a, max_a)).collect();
            let out = self.env.step(&a64);
            ep_return += out.reward;
            self.env_steps += 1;
            let done = out.done();
            if done {
                self.stats.push(ep_return);
                ep_return = 0.0;
                ep += 1;
                let mut r = episode_rng(self.cfg.seed, ep);
                self.env.reset(&mut r);
                if self.cfg.log_every > 0 && ep as usize % self.cfg.log_every == 0 {
                    info!(
                        "[native] ep {:>4}  return {:>9.1}  (final100 {:>9.1})  updates {}",
                        ep,
                        self.stats.returns().last().unwrap(),
                        self.stats.final_100(),
                        self.updates
                    );
                }
            }
            normalize_pendulum_obs(&self.env.state(), &mut next_obs);
            quantize_roundtrip(&mut next_obs, 255, &mut qbuf);
            rollout.push(&obs, &a, logp, v, out.reward as f32, done, out.terminated);
            if rollout.full() {
                // bootstrap with pre-update parameters, then learn
                let last_v = self.core.value(&next_obs);
                let (adv, ret) = rollout.gae(gamma, self.cfg.gae_lambda, last_v);
                self.core.run_ppo_epochs(&rollout, &adv, &ret, self.cfg.ppo_epochs)?;
                rollout.clear();
                self.updates += 1;
            }
            obs.copy_from_slice(&next_obs);
            if ep as usize >= self.cfg.episodes {
                return Ok(());
            }
        }
    }
}

/// Materialise the initial train state from the manifest.
fn load_state(rt: &Runtime, spec: &TrainStateSpec) -> Result<Vec<Value>> {
    spec.state
        .iter()
        .map(|s| {
            Ok(match s.dtype {
                DType::F32 => {
                    let data = if s.file.is_some() {
                        rt.manifest.load_params(&format!("{}_{}", spec.name, s.name))?
                    } else {
                        vec![0.0; s.shape.iter().product()]
                    };
                    Value::f32(&s.shape, data)
                }
                DType::I32 => Value::scalar_i32(0),
            })
        })
        .collect()
}

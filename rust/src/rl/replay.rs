//! Off-policy replay buffer (DDPG / SAC).
//!
//! Observations are rendered-pixel stacks whose values are exact u8/255
//! fractions, so they are stored as u8 planes — a 4x memory saving that is
//! lossless for this pipeline (asserted in tests).

use crate::util::rng::Rng;

#[derive(Debug)]
pub struct Replay {
    capacity: usize,
    obs_len: usize,
    act_len: usize,
    obs: Vec<u8>,
    nobs: Vec<u8>,
    act: Vec<f32>,
    rew: Vec<f32>,
    done: Vec<f32>,
    len: usize,
    head: usize,
}

impl Replay {
    pub fn new(capacity: usize, obs_len: usize, act_len: usize) -> Replay {
        Replay {
            capacity,
            obs_len,
            act_len,
            obs: vec![0; capacity * obs_len],
            nobs: vec![0; capacity * obs_len],
            act: vec![0.0; capacity * act_len],
            rew: vec![0.0; capacity],
            done: vec![0.0; capacity],
            len: 0,
            head: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn quantize(dst: &mut [u8], src: &[f32]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = (s * 255.0).round().clamp(0.0, 255.0) as u8;
        }
    }

    /// Push one transition; overwrites the oldest when full.
    pub fn push(&mut self, obs: &[f32], act: &[f32], rew: f32, nobs: &[f32], done: bool) {
        assert_eq!(obs.len(), self.obs_len);
        assert_eq!(nobs.len(), self.obs_len);
        assert_eq!(act.len(), self.act_len);
        let i = self.head;
        Self::quantize(&mut self.obs[i * self.obs_len..(i + 1) * self.obs_len], obs);
        Self::quantize(&mut self.nobs[i * self.obs_len..(i + 1) * self.obs_len], nobs);
        self.act[i * self.act_len..(i + 1) * self.act_len].copy_from_slice(act);
        self.rew[i] = rew;
        self.done[i] = if done { 1.0 } else { 0.0 };
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
    }

    /// Sample a batch uniformly with replacement into caller-provided flat
    /// buffers (shaped [B, obs_len] etc.). Returns false if not enough data.
    #[allow(clippy::too_many_arguments)]
    pub fn sample(
        &self,
        rng: &mut Rng,
        batch: usize,
        obs: &mut [f32],
        act: &mut [f32],
        rew: &mut [f32],
        nobs: &mut [f32],
        done: &mut [f32],
    ) -> bool {
        if self.len < batch {
            return false;
        }
        assert_eq!(obs.len(), batch * self.obs_len);
        assert_eq!(act.len(), batch * self.act_len);
        for b in 0..batch {
            let i = rng.below(self.len);
            for (d, &s) in obs[b * self.obs_len..(b + 1) * self.obs_len]
                .iter_mut()
                .zip(&self.obs[i * self.obs_len..(i + 1) * self.obs_len])
            {
                *d = s as f32 / 255.0;
            }
            for (d, &s) in nobs[b * self.obs_len..(b + 1) * self.obs_len]
                .iter_mut()
                .zip(&self.nobs[i * self.obs_len..(i + 1) * self.obs_len])
            {
                *d = s as f32 / 255.0;
            }
            act[b * self.act_len..(b + 1) * self.act_len]
                .copy_from_slice(&self.act[i * self.act_len..(i + 1) * self.act_len]);
            rew[b] = self.rew[i];
            done[b] = self.done[i];
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs_of(v: u8, n: usize) -> Vec<f32> {
        vec![v as f32 / 255.0; n]
    }

    #[test]
    fn u8_storage_is_lossless_for_pixel_fractions() {
        let mut r = Replay::new(4, 8, 1);
        r.push(&obs_of(200, 8), &[0.5], 1.0, &obs_of(100, 8), false);
        let mut obs = vec![0.0; 8];
        let (mut act, mut rew, mut nobs, mut done) =
            (vec![0.0; 1], vec![0.0; 1], vec![0.0; 8], vec![0.0; 1]);
        let mut rng = Rng::new(0);
        assert!(r.sample(&mut rng, 1, &mut obs, &mut act, &mut rew, &mut nobs, &mut done));
        assert_eq!(obs, obs_of(200, 8));
        assert_eq!(nobs, obs_of(100, 8));
        assert_eq!(act, vec![0.5]);
        assert_eq!((rew[0], done[0]), (1.0, 0.0));
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut r = Replay::new(2, 1, 1);
        r.push(&[0.1], &[0.0], 1.0, &[0.1], false);
        r.push(&[0.2], &[0.0], 2.0, &[0.2], false);
        assert_eq!(r.len(), 2);
        r.push(&[0.3], &[0.0], 3.0, &[0.3], true);
        assert_eq!(r.len(), 2);
        // sample many times: reward 1.0 must never appear
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let (mut o, mut a, mut rw, mut no, mut d) =
                (vec![0.0], vec![0.0], vec![0.0], vec![0.0], vec![0.0]);
            r.sample(&mut rng, 1, &mut o, &mut a, &mut rw, &mut no, &mut d);
            assert!(rw[0] > 1.5, "stale transition sampled");
        }
    }

    #[test]
    fn sample_requires_enough_data() {
        let r = Replay::new(10, 2, 1);
        let mut rng = Rng::new(2);
        let (mut o, mut a, mut rw, mut no, mut d) =
            (vec![0.0; 8], vec![0.0; 4], vec![0.0; 4], vec![0.0; 8], vec![0.0; 4]);
        assert!(!r.sample(&mut rng, 4, &mut o, &mut a, &mut rw, &mut no, &mut d));
    }

    #[test]
    fn batch_layout_is_row_major() {
        let mut r = Replay::new(4, 2, 1);
        r.push(&[0.0, 0.0], &[1.0], 0.0, &[0.0; 2], false);
        r.push(&[0.0, 0.0], &[1.0], 0.0, &[0.0; 2], false);
        let mut rng = Rng::new(3);
        let (mut o, mut a, mut rw, mut no, mut d) =
            (vec![9.0; 4], vec![9.0; 2], vec![9.0; 2], vec![9.0; 4], vec![9.0; 2]);
        assert!(r.sample(&mut rng, 2, &mut o, &mut a, &mut rw, &mut no, &mut d));
        assert_eq!(a, vec![1.0, 1.0]);
        assert_eq!(o, vec![0.0; 4]);
    }
}

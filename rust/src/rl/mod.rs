//! RL substrate: episodic [`stats`] (Best/Mean/Final-100, Tables 2-4),
//! off-policy [`replay`], on-policy [`rollout`] with GAE(λ), the generic
//! artifact-driven [`trainer`], and the pure-Rust [`native`] PPO engine
//! shared by the offline baseline and the fleet learning loop
//! (`learn::`, DESIGN.md §8).

pub mod native;
pub mod replay;
pub mod rollout;
pub mod stats;
pub mod trainer;

pub use native::{NativeConfig, NativeCore};
pub use replay::Replay;
pub use rollout::Rollout;
pub use stats::EpisodeStats;
pub use trainer::{NativeTrainer, TrainConfig, TrainReport, Trainer};

//! RL substrate: episodic [`stats`] (Best/Mean/Final-100, Tables 2-4),
//! off-policy [`replay`], on-policy [`rollout`] with GAE(λ), and the
//! generic artifact-driven [`trainer`].

pub mod replay;
pub mod rollout;
pub mod stats;
pub mod trainer;

pub use replay::Replay;
pub use rollout::Rollout;
pub use stats::EpisodeStats;
pub use trainer::{TrainConfig, TrainReport, Trainer};

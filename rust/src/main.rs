//! `miniconv` — CLI launcher for the split-policy serving stack.
//!
//! Subcommands:
//!   info                     manifest/artifact summary
//!   serve                    run the coordinator (Ctrl-C to stop)
//!   fleet                    drive a client fleet against a server
//!   sharded                  run N shards behind the consistent-hash gateway
//!   train                    train one (task, encoder) run
//!   exp <experiment>         regenerate a paper table/figure
//!   shader                   emit the GLSL shader sources for an encoder

use std::time::Duration;

use anyhow::Result;

use miniconv::coordinator::{
    merged_latencies, run_fleet, serve, Backend, BatchPolicy, ClientConfig, Route, ServerConfig,
    SimSpec,
};
use miniconv::fleet::{launch_local, FleetConfig};
use miniconv::experiments as exp;
use miniconv::experiments::learning::LearningScale;
use miniconv::rl::Trainer;
use miniconv::runtime::{default_artifact_dir, Runtime};
use miniconv::util::argparse::Parser;
use miniconv::util::tables::Table;

fn main() {
    init_logging();
    let args: Vec<String> = std::env::args().collect();
    let cmd = args.get(1).cloned().unwrap_or_default();
    let rest: Vec<String> = std::iter::once(format!("miniconv {cmd}"))
        .chain(args.iter().skip(2).cloned())
        .collect();
    let result = match cmd.as_str() {
        "info" => cmd_info(rest),
        "serve" => cmd_serve(rest),
        "fleet" => cmd_fleet(rest),
        "sharded" => cmd_sharded(rest),
        "train" => cmd_train(rest),
        "exp" => cmd_exp(rest),
        "shader" => cmd_shader(rest),
        _ => {
            eprintln!(
                "usage: miniconv <info|serve|fleet|sharded|train|exp|shader> [options]\n\
                 run `miniconv <cmd> --help` for details"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn init_logging() {
    struct Stderr;
    impl log::Log for Stderr {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::Level::Info
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level().as_str().to_lowercase(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: Stderr = Stderr;
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(log::LevelFilter::Info);
}

fn runtime() -> Result<Runtime> {
    Runtime::new(&default_artifact_dir())
}

fn cmd_info(argv: Vec<String>) -> Result<()> {
    let _ = Parser::new("print manifest / artifact summary").parse_from(argv);
    let rt = runtime()?;
    let m = &rt.manifest;
    println!("artifact dir : {}", m.dir.display());
    println!("serve X      : {} (obs {}x{}x{})", m.serve_x, m.obs_channels, m.serve_x, m.serve_x);
    println!("tiny X       : {}", m.tiny_x);
    println!("artifacts    : {}", m.artifacts.len());
    println!("param files  : {}", m.params.len());
    println!("trainstates  : {}", m.trainstates.len());
    let mut t = Table::new("encoders", &["name", "kind", "shader", "feat (serve)", "params"]);
    for (name, (serve, _)) in &m.encoders {
        t.row(&[
            name.clone(),
            serve.kind.clone(),
            serve.shader_deployable.to_string(),
            format!("{:?}", serve.feat_shape),
            serve.param_count().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let a = Parser::new("run the split-policy coordinator")
        .opt("addr", "127.0.0.1:7700", "bind address")
        .opt("arch", "miniconv4", "split-route encoder")
        .opt("max-batch", "32", "dynamic batch cap")
        .opt("max-wait-ms", "3", "batching wait budget (ms)")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let handle = serve(ServerConfig {
        addr: a.str("addr"),
        arch: a.str("arch"),
        policy: BatchPolicy {
            max_batch: a.usize("max-batch"),
            max_wait: Duration::from_millis(a.u64("max-wait-ms")),
        },
        ..ServerConfig::default()
    })?;
    println!("coordinator listening on {} (Ctrl-C to stop)", handle.addr);
    loop {
        std::thread::sleep(Duration::from_secs(5));
        let m = handle.metrics.snapshot();
        println!(
            "split: {} reqs (mean batch {:.1}, p95 {:.1}ms) | server-only: {} reqs (p95 {:.1}ms) | dropped {}",
            m.split.requests,
            m.split.mean_batch(),
            m.split.service.quantile_ns(0.95) / 1e6,
            m.full.requests,
            m.full.service.quantile_ns(0.95) / 1e6,
            m.dropped
        );
    }
}

fn cmd_fleet(argv: Vec<String>) -> Result<()> {
    let a = Parser::new("drive a client fleet against a coordinator")
        .opt("addr", "127.0.0.1:7700", "server address")
        .opt("n", "4", "number of clients")
        .opt("mode", "split", "split | server-only")
        .opt("decisions", "100", "decisions per client")
        .opt("rate", "0", "fixed decision rate Hz (0 = closed loop)")
        .opt("bw", "0", "uplink shaping, Mb/s (0 = unshaped)")
        .opt("device", "none", "device sim for encode time (pi-zero-2w|pi-4b|jetson-nano|none)")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let addr: std::net::SocketAddr = a.str("addr").parse()?;
    let mode = match a.str("mode").as_str() {
        "split" => Route::Split,
        "server-only" | "full" => Route::Full,
        other => anyhow::bail!("bad mode {other}"),
    };
    let rate = a.f64("rate");
    let bw = a.f64("bw");
    let cfg = ClientConfig {
        mode,
        decisions: a.usize("decisions"),
        rate_hz: (rate > 0.0).then_some(rate),
        shape_bps: (bw > 0.0).then_some(bw * 1e6),
        device: match a.str("device").as_str() {
            "none" => None,
            name => Some(miniconv::device::by_name(name)?),
        },
        ..ClientConfig::default()
    };
    let reports = run_fleet(addr, a.usize("n"), &cfg)?;
    let mut all = merged_latencies(&reports);
    let mut t = Table::new(
        "fleet results",
        &["clients", "decisions", "median (ms)", "p95 (ms)", "throughput (dec/s)"],
    );
    let total: usize = reports.iter().map(|r| r.decisions).sum();
    let hz: f64 = reports.iter().map(|r| r.achieved_hz()).sum();
    t.row(&[
        reports.len().to_string(),
        total.to_string(),
        format!("{:.1}", all.median() * 1e3),
        format!("{:.1}", all.p95() * 1e3),
        format!("{hz:.1}"),
    ]);
    t.print();
    Ok(())
}

fn cmd_sharded(argv: Vec<String>) -> Result<()> {
    let a = Parser::new("run a sharded serving fleet behind the consistent-hash gateway")
        .opt("shards", "4", "coordinator shards")
        .opt("clients", "8", "simulated clients driven through the gateway")
        .opt("decisions", "50", "decisions per client")
        .opt("backend", "auto", "pjrt | sim | auto (pjrt when artifacts exist)")
        .opt("mode", "server-only", "client route: server-only | split (split needs artifacts)")
        .opt("codec", "flat", "split-route feature codec: flat | delta")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let have_artifacts = default_artifact_dir().join("manifest.json").exists();
    let backend = match a.str("backend").as_str() {
        "pjrt" => Backend::Pjrt,
        "sim" => Backend::Sim(SimSpec::default()),
        "auto" => {
            if have_artifacts {
                Backend::Pjrt
            } else {
                Backend::Sim(SimSpec::default())
            }
        }
        other => anyhow::bail!("bad backend {other} (pjrt|sim|auto)"),
    };
    let sim = matches!(backend, Backend::Sim(_));
    let mode = match a.str("mode").as_str() {
        "server-only" | "full" => Route::Full,
        "split" => Route::Split,
        other => anyhow::bail!("bad mode {other} (server-only|split)"),
    };
    let codec = miniconv::codec::CodecId::parse(&a.str("codec"))?;
    anyhow::ensure!(
        mode == Route::Full || !sim,
        "split mode needs AOT artifacts (the sim backend serves raw frames only)"
    );
    let fleet = launch_local(FleetConfig {
        shards: a.usize("shards"),
        server: ServerConfig { backend, ..ServerConfig::default() },
        ..FleetConfig::default()
    })?;
    println!(
        "gateway on {} fronting {} shards ({}, {} route, {} codec)",
        fleet.addr(),
        fleet.n_shards(),
        if sim { "sim backend" } else { "pjrt backend" },
        mode.name(),
        codec.name()
    );
    let cfg = ClientConfig {
        mode,
        decisions: a.usize("decisions"),
        obs_x: if sim { Some(24) } else { None },
        codec,
        ..ClientConfig::default()
    };
    let t0 = std::time::Instant::now();
    let reports = run_fleet(fleet.addr(), a.usize("clients"), &cfg)?;
    let elapsed = t0.elapsed().as_secs_f64();
    let mut lat = merged_latencies(&reports);
    println!(
        "{} decisions in {elapsed:.2}s (median {:.1} ms, p95 {:.1} ms)",
        reports.iter().map(|r| r.decisions).sum::<usize>(),
        lat.median() * 1e3,
        lat.p95() * 1e3
    );
    let bytes: u64 = reports.iter().map(|r| r.bytes_sent).sum();
    let frames: usize = reports.iter().map(|r| r.decisions + r.errors).sum();
    println!(
        "wire: {bytes} B sent ({:.0} B/frame); codec: {} keyframes, {} deltas, {} re-keys",
        bytes as f64 / frames.max(1) as f64,
        reports.iter().map(|r| r.keyframes).sum::<u64>(),
        reports.iter().map(|r| r.deltas).sum::<u64>(),
        reports.iter().map(|r| r.need_keyframes).sum::<u64>(),
    );
    fleet.snapshot().table(elapsed).print();
    fleet.shutdown();
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let a = Parser::new("train one (task, encoder) run via the AOT artifacts")
        .opt("run", "pendulum_miniconv4", "trainstate name (task_arch)")
        .opt("scale", "smoke", "smoke | tiny | paper")
        .opt("seed", "0", "rng seed")
        .opt("eval-episodes", "2", "deterministic eval episodes after training")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let rt = runtime()?;
    let run = a.str("run");
    let spec = rt
        .manifest
        .trainstates
        .get(&run)
        .ok_or_else(|| anyhow::anyhow!("unknown run {run}"))?;
    let scale = LearningScale::parse(&a.str("scale"))?;
    let cfg = scale.config(&spec.task, spec.episodes, a.u64("seed"));
    println!("training {run}: {} episodes ({:?} scale)", cfg.episodes, scale);
    let mut trainer = Trainer::new(&rt, &run, cfg)?;
    trainer.train()?;
    let s = &trainer.report.stats;
    let mut t = Table::new("result", &["best", "final", "mean", "episodes", "env steps", "updates"]);
    t.row(&[
        format!("{:.0}", s.best()),
        format!("{:.0}", s.final_100()),
        format!("{:.0}", s.mean()),
        s.episodes().to_string(),
        trainer.report.env_steps.to_string(),
        trainer.report.updates.to_string(),
    ]);
    t.print();
    let eval_eps = a.usize("eval-episodes");
    if eval_eps > 0 {
        println!("eval ({} episodes, deterministic): {:.1}", eval_eps, trainer.evaluate(eval_eps)?);
    }
    Ok(())
}

fn cmd_exp(argv: Vec<String>) -> Result<()> {
    let which = argv.get(1).cloned().unwrap_or_default();
    let rest: Vec<String> = std::iter::once(format!("miniconv exp {which}"))
        .chain(argv.iter().skip(2).cloned())
        .collect();
    match which.as_str() {
        "learning" => {
            let a = Parser::new("Tables 2-4: learning stats per encoder")
                .opt("task", "pendulum", "pendulum | hopper | walker")
                .opt("scale", "smoke", "smoke | tiny | paper")
                .opt("seed", "0", "seed")
                .parse_from(rest)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let rt = runtime()?;
            let scale = LearningScale::parse(&a.str("scale"))?;
            let (t, _) = exp::learning_table(
                &rt,
                &a.str("task"),
                &["miniconv4", "miniconv16", "fullcnn"],
                scale,
                a.u64("seed"),
            )?;
            t.print();
        }
        "table1" => {
            let rt = runtime()?;
            exp::table1_algorithms(&rt).print();
        }
        "fig2" => {
            let a = Parser::new("Figure 2: frame time vs input size")
                .opt("reps", "100", "inferences per point")
                .parse_from(rest)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            exp::fig2_framesize(
                &miniconv::device::all_devices(),
                &[100, 200, 300, 400, 500, 750, 1000, 1500, 2000, 3000],
                a.usize("reps"),
            )
            .print();
        }
        "fig3" => {
            let a = Parser::new("Figure 3: sustained inference")
                .opt("frames", "5000", "consecutive frames")
                .parse_from(rest)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let (_, t) = exp::fig3_sustained(a.usize("frames"));
            t.print();
        }
        "fig4" => {
            let a = Parser::new("Figure 4: resource usage")
                .opt("frames", "5000", "consecutive frames")
                .parse_from(rest)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let (_, t) = exp::fig4_resources(a.usize("frames"));
            t.print();
        }
        "fig5" => {
            let a = Parser::new("Figure 5: decision-latency breakdown")
                .opt("bw", "10", "bandwidth Mb/s")
                .parse_from(rest)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            exp::fig5_breakdown(400, a.f64("bw") * 1e6, &exp::ServerCostModel::default()).print();
        }
        "table5" => {
            let a = Parser::new("Table 5: decision latency under shaping (sim, X=400)")
                .opt("decisions", "1000", "decisions per setting")
                .parse_from(rest)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            exp::table5_latency_sim(
                &[10.0, 25.0, 50.0, 100.0],
                a.usize("decisions"),
                &exp::ServerCostModel::default(),
            )
            .print();
        }
        "table6" => {
            let (t, _, _) = exp::table6_scalability_sim(10.0, 0.1);
            t.print();
        }
        "breakeven" => {
            let mut t = Table::new(
                "break-even bandwidth B = 32X²(1 - K/(4·2²ⁿ))/j",
                &["X", "K", "n", "j (ms)", "break-even (Mb/s)"],
            );
            let j = exp::serving::device_j(400, 200);
            for (x, k) in [(400usize, 4usize), (400, 16), (84, 4), (84, 16)] {
                let b = miniconv::analysis::breakeven_bandwidth_bps(x, 3, k, j);
                t.row(&[
                    x.to_string(),
                    k.to_string(),
                    "3".into(),
                    format!("{:.0}", j * 1e3),
                    format!("{:.1}", b / 1e6),
                ]);
            }
            t.print();
        }
        other => anyhow::bail!(
            "unknown experiment {other:?} (learning|table1|fig2|fig3|fig4|fig5|table5|table6|breakeven)"
        ),
    }
    Ok(())
}

fn cmd_shader(argv: Vec<String>) -> Result<()> {
    let a = Parser::new("emit GLSL fragment shaders for a MiniConv encoder")
        .opt("arch", "miniconv4", "miniconv4 | miniconv16")
        .opt("x", "84", "input size")
        .opt("out", "", "output directory (default: print to stdout)")
        .parse_from(argv)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let rt = runtime()?;
    let arch = a.str("arch");
    let (serve_meta, _) = rt
        .manifest
        .encoders
        .get(&arch)
        .ok_or_else(|| anyhow::anyhow!("unknown arch {arch}"))?;
    let ir = miniconv::shader::EncoderIr::from_meta(&arch, rt.manifest.obs_channels, serve_meta);
    let plan = miniconv::shader::plan(&ir, a.usize("x"))?;
    let shaders = miniconv::shader::gen_all(&plan);
    println!(
        "// {} @ X={}: {} passes, {} texture samples/frame, peak {} textures",
        arch,
        a.usize("x"),
        plan.passes.len(),
        plan.total_samples(),
        plan.peak_textures()
    );
    let out = a.str("out");
    if out.is_empty() {
        for s in &shaders {
            println!("// ---- {} ----\n{}", s.name, s.fragment);
        }
    } else {
        std::fs::create_dir_all(&out)?;
        std::fs::write(format!("{out}/vertex.glsl"), miniconv::shader::VERTEX_SHADER)?;
        for s in &shaders {
            std::fs::write(format!("{out}/{}.frag", s.name), &s.fragment)?;
        }
        println!("wrote {} shaders to {out}/", shaders.len() + 1);
    }
    Ok(())
}

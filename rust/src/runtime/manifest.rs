//! Typed view over `artifacts/manifest.json` (produced by python/compile/aot.py).
//!
//! The manifest is the only contract between the build-time Python layer and
//! the runtime Rust layer: artifact names, input/output signatures, encoder
//! architecture metadata (for the shader planner), initial parameter files,
//! and complete train-state descriptions for the generic trainer.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str().unwrap_or_default().to_string(),
            dtype: DType::parse(j.req("dtype")?.as_str().unwrap_or_default())?,
            shape: j
                .req("shape")?
                .as_usize_vec()
                .ok_or_else(|| anyhow!("bad shape"))?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub tags: BTreeMap<String, String>,
}

impl ArtifactSpec {
    /// The batch size tag (present on serving artifacts).
    pub fn batch(&self) -> Option<usize> {
        self.tags.get("batch").and_then(|s| s.parse().ok())
    }
}

#[derive(Debug, Clone)]
pub struct ConvLayerMeta {
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub same: bool,
}

#[derive(Debug, Clone)]
pub struct ParamLayout {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct EncoderMeta {
    pub kind: String,
    pub shader_deployable: bool,
    pub layers: Vec<ConvLayerMeta>,
    pub dense: Option<usize>,
    pub n_stride2: usize,
    pub param_layout: Vec<ParamLayout>,
    pub feat_shape: [usize; 3],
}

impl EncoderMeta {
    fn parse(j: &Json) -> Result<Self> {
        let layers = j
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow!("layers not an array"))?
            .iter()
            .map(|l| {
                Ok(ConvLayerMeta {
                    cout: l.req("cout")?.as_usize().ok_or_else(|| anyhow!("cout"))?,
                    k: l.req("k")?.as_usize().ok_or_else(|| anyhow!("k"))?,
                    stride: l.req("stride")?.as_usize().ok_or_else(|| anyhow!("stride"))?,
                    same: l.req("padding")?.as_str() == Some("same"),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let param_layout = j
            .req("param_layout")?
            .as_arr()
            .ok_or_else(|| anyhow!("param_layout"))?
            .iter()
            .map(|p| {
                Ok(ParamLayout {
                    name: p.req("name")?.as_str().unwrap_or_default().to_string(),
                    shape: p
                        .req("shape")?
                        .as_usize_vec()
                        .ok_or_else(|| anyhow!("shape"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let fs = j
            .req("feat_shape")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("feat_shape"))?;
        anyhow::ensure!(fs.len() == 3, "feat_shape must be [c,h,w]");
        Ok(EncoderMeta {
            kind: j.req("kind")?.as_str().unwrap_or_default().to_string(),
            shader_deployable: j.req("shader_deployable")?.as_bool().unwrap_or(false),
            layers,
            dense: j.get("dense").and_then(|d| d.as_usize()),
            n_stride2: j.req("n_stride2")?.as_usize().unwrap_or(0),
            param_layout,
            feat_shape: [fs[0], fs[1], fs[2]],
        })
    }

    pub fn param_count(&self) -> usize {
        self.param_layout
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }
}

#[derive(Debug, Clone)]
pub struct StateTensor {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    /// parameter file to initialise from (absent => zero/scalar init)
    pub file: Option<String>,
}

#[derive(Debug, Clone)]
pub struct TrainStateSpec {
    pub name: String,
    pub task: String,
    pub algo: String,
    pub encoder: String,
    pub x: usize,
    pub batch: usize,
    pub action_dim: usize,
    pub max_action: f64,
    pub gamma: f64,
    pub episodes: usize,
    pub state: Vec<StateTensor>,
    pub batch_inputs: Vec<String>,
    pub metrics: Vec<String>,
    pub artifacts: BTreeMap<String, String>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub serve_x: usize,
    pub tiny_x: usize,
    pub obs_channels: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub params: BTreeMap<String, (String, usize)>, // name -> (file, len)
    pub encoders: BTreeMap<String, (EncoderMeta, EncoderMeta)>, // (serve, tiny)
    pub trainstates: BTreeMap<String, TrainStateSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first?)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;

        let mut artifacts = BTreeMap::new();
        for a in j.req("artifacts")?.as_arr().unwrap_or(&[]) {
            let name = a.req("name")?.as_str().unwrap_or_default().to_string();
            let tags = a
                .get("tags")
                .and_then(|t| t.as_obj())
                .map(|kv| {
                    kv.iter()
                        .map(|(k, v)| {
                            let vs = match v {
                                Json::Str(s) => s.clone(),
                                other => other.to_string(),
                            };
                            (k.clone(), vs)
                        })
                        .collect()
                })
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    file: a.req("file")?.as_str().unwrap_or_default().to_string(),
                    inputs: a
                        .req("inputs")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::parse)
                        .collect::<Result<_>>()?,
                    outputs: a
                        .req("outputs")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::parse)
                        .collect::<Result<_>>()?,
                    tags,
                },
            );
        }

        let mut params = BTreeMap::new();
        for p in j.req("params")?.as_arr().unwrap_or(&[]) {
            params.insert(
                p.req("name")?.as_str().unwrap_or_default().to_string(),
                (
                    p.req("file")?.as_str().unwrap_or_default().to_string(),
                    p.req("len")?.as_usize().unwrap_or(0),
                ),
            );
        }

        let mut encoders = BTreeMap::new();
        if let Some(encs) = j.get("encoders").and_then(|e| e.as_obj()) {
            for (name, meta) in encs {
                encoders.insert(
                    name.clone(),
                    (
                        EncoderMeta::parse(meta.req("serve")?)?,
                        EncoderMeta::parse(meta.req("tiny")?)?,
                    ),
                );
            }
        }

        let mut trainstates = BTreeMap::new();
        for t in j.req("trainstates")?.as_arr().unwrap_or(&[]) {
            let name = t.req("name")?.as_str().unwrap_or_default().to_string();
            let state = t
                .req("state")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|s| {
                    Ok(StateTensor {
                        name: s.req("name")?.as_str().unwrap_or_default().to_string(),
                        dtype: DType::parse(s.req("dtype")?.as_str().unwrap_or_default())?,
                        shape: s
                            .req("shape")?
                            .as_usize_vec()
                            .ok_or_else(|| anyhow!("shape"))?,
                        file: s.get("file").and_then(|f| f.as_str()).map(String::from),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            trainstates.insert(
                name.clone(),
                TrainStateSpec {
                    name,
                    task: t.req("task")?.as_str().unwrap_or_default().to_string(),
                    algo: t.req("algo")?.as_str().unwrap_or_default().to_string(),
                    encoder: t.req("encoder")?.as_str().unwrap_or_default().to_string(),
                    x: t.req("x")?.as_usize().unwrap_or(0),
                    batch: t.req("batch")?.as_usize().unwrap_or(0),
                    action_dim: t.req("action_dim")?.as_usize().unwrap_or(0),
                    max_action: t.req("max_action")?.as_f64().unwrap_or(1.0),
                    gamma: t.req("gamma")?.as_f64().unwrap_or(0.99),
                    episodes: t.req("episodes")?.as_usize().unwrap_or(0),
                    state,
                    batch_inputs: t
                        .req("batch_inputs")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|v| v.as_str().map(String::from))
                        .collect(),
                    metrics: t
                        .req("metrics")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|v| v.as_str().map(String::from))
                        .collect(),
                    artifacts: t
                        .req("artifacts")?
                        .as_obj()
                        .unwrap_or(&[])
                        .iter()
                        .map(|(k, v)| (k.clone(), v.as_str().unwrap_or_default().to_string()))
                        .collect(),
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            serve_x: j.req("serve_x")?.as_usize().unwrap_or(84),
            tiny_x: j.req("tiny_x")?.as_usize().unwrap_or(36),
            obs_channels: j.req("obs_channels")?.as_usize().unwrap_or(9),
            artifacts,
            params,
            encoders,
            trainstates,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Load an initial-parameter vector by manifest name.
    pub fn load_params(&self, name: &str) -> Result<Vec<f32>> {
        let (file, len) = self
            .params
            .get(name)
            .ok_or_else(|| anyhow!("params {name:?} not in manifest"))?;
        let data = crate::util::read_f32_bin(&self.dir.join(file))?;
        anyhow::ensure!(
            data.len() == *len,
            "params {name}: file has {} floats, manifest says {len}",
            data.len()
        );
        Ok(data)
    }

    /// Serving artifact lookup helpers. `arch` is miniconv4|miniconv16.
    pub fn serve_encoder(&self, arch: &str) -> String {
        format!("enc_{arch}_x{}_b1", self.serve_x)
    }

    pub fn serve_head(&self, arch: &str, batch: usize) -> String {
        format!("head_{arch}_x{}_b{batch}", self.serve_x)
    }

    pub fn serve_full(&self, batch: usize) -> String {
        format!("full_fullcnn_x{}_b{batch}", self.serve_x)
    }

    /// The batch ladder available for a head/full family (ascending).
    pub fn batch_ladder(&self, prefix: &str) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.name.starts_with(prefix))
            .filter_map(|a| a.batch())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "version": 1, "seed": 0, "serve_x": 84, "tiny_x": 36, "obs_channels": 9,
      "encoders": {
        "miniconv4": {
          "serve": {"kind": "miniconv", "shader_deployable": true,
            "layers": [{"cout": 4, "k": 3, "stride": 2, "padding": "same"}],
            "dense": null, "n_stride2": 3,
            "param_layout": [{"name": "conv0.w", "shape": [4, 9, 3, 3]},
                              {"name": "conv0.b", "shape": [4]}],
            "feat_shape": [4, 11, 11]},
          "tiny": {"kind": "miniconv", "shader_deployable": true,
            "layers": [], "dense": null, "n_stride2": 3,
            "param_layout": [], "feat_shape": [4, 5, 5]}
        }
      },
      "artifacts": [
        {"name": "head_miniconv4_x84_b4", "file": "h.hlo.txt",
         "inputs": [{"name": "params", "dtype": "f32", "shape": [100]},
                     {"name": "feat", "dtype": "f32", "shape": [4, 4, 11, 11]}],
         "outputs": [{"name": "act", "dtype": "f32", "shape": [4, 1]}],
         "tags": {"kind": "head", "batch": 4}}
      ],
      "params": [{"name": "p", "file": "p.bin", "len": 3}],
      "trainstates": [
        {"name": "pendulum_miniconv4", "task": "pendulum", "algo": "ddpg",
         "encoder": "miniconv4", "x": 36, "batch": 64, "action_dim": 1,
         "max_action": 2.0, "gamma": 0.99, "episodes": 1000,
         "state": [{"name": "actor", "dtype": "f32", "shape": [10], "file": "a.bin"},
                    {"name": "step", "dtype": "i32", "shape": []}],
         "batch_inputs": ["obs", "act"],
         "metrics": ["critic_loss"],
         "artifacts": {"update": "u", "act": "a"}}
      ]
    }"#;

    fn write_mini() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mc_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), MINI).unwrap();
        crate::util::write_f32_bin(&dir.join("p.bin"), &[1.0, 2.0, 3.0]).unwrap();
        dir
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::load(&write_mini()).unwrap();
        assert_eq!(m.serve_x, 84);
        let a = m.artifact("head_miniconv4_x84_b4").unwrap();
        assert_eq!(a.inputs[1].shape, vec![4, 4, 11, 11]);
        assert_eq!(a.batch(), Some(4));
        assert_eq!(a.inputs[1].elems(), 4 * 4 * 11 * 11);
        let (serve, _tiny) = &m.encoders["miniconv4"];
        assert!(serve.shader_deployable);
        assert_eq!(serve.feat_shape, [4, 11, 11]);
        assert_eq!(serve.param_count(), 4 * 9 * 3 * 3 + 4);
        let ts = &m.trainstates["pendulum_miniconv4"];
        assert_eq!(ts.algo, "ddpg");
        assert_eq!(ts.state[1].dtype, DType::I32);
        assert!(ts.state[1].file.is_none());
    }

    #[test]
    fn loads_param_bins_with_length_check() {
        let m = Manifest::load(&write_mini()).unwrap();
        assert_eq!(m.load_params("p").unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(m.load_params("nope").is_err());
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::load(&write_mini()).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn serve_name_helpers() {
        let m = Manifest::load(&write_mini()).unwrap();
        assert_eq!(m.serve_encoder("miniconv4"), "enc_miniconv4_x84_b1");
        assert_eq!(m.serve_head("miniconv4", 8), "head_miniconv4_x84_b8");
        assert_eq!(m.serve_full(32), "full_fullcnn_x84_b32");
        assert_eq!(m.batch_ladder("head_miniconv4"), vec![4]);
    }
}

//! Runtime layer: the bridge from AOT artifacts (HLO text + parameter bins,
//! produced once by `make artifacts`) to live PJRT executables.
//!
//! * [`manifest`] — typed view over `artifacts/manifest.json`.
//! * [`executor`] — PJRT client wrapper, executable cache, host/device values.
//!
//! Python never runs at serving time; after `make artifacts` the Rust binary
//! is self-contained.

pub mod executor;
pub mod manifest;

pub use executor::{DeviceTensor, Exe, Runtime, Value};
pub use manifest::{ArtifactSpec, DType, EncoderMeta, Manifest, TensorSpec, TrainStateSpec};

use std::path::PathBuf;

/// Default artifact directory: `$MINICONV_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("MINICONV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

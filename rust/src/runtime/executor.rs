//! PJRT execution: load HLO-text artifacts, compile once per variant, run.
//!
//! Follows the reference wiring in /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` -> `compile` ->
//! `execute`. All artifacts are lowered with `return_tuple=True`, so every
//! execution output is a single tuple literal that we decompose per the
//! manifest's output specs.
//!
//! Threading: the xla crate's client is `Rc`-based (not `Send`), so a
//! `Runtime` is confined to the thread that created it. The coordinator
//! gives each execution context (server batcher, device fleet, trainer)
//! its own `Runtime`; cross-thread work arrives via channels.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, Manifest};

/// A host-side tensor value crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Value {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Value::F32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_i32(v: i32) -> Value {
        Value::I32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros_f32(shape: &[usize]) -> Value {
        Value::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32 { shape, .. } | Value::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::F32 { .. } => DType::F32,
            Value::I32 { .. } => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => bail!("value is not f32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => bail!("value is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32 { data, .. } => Ok(data),
            _ => bail!("value is not i32"),
        }
    }

    /// Scalar f32 convenience (metrics).
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        anyhow::ensure!(d.len() == 1, "not a scalar: {:?}", self.shape());
        Ok(d[0])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32 { shape, data } => {
                if shape.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data).reshape(&dims).map_err(wrap_xla)?
                }
            }
            Value::I32 { shape, data } => {
                if shape.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data).reshape(&dims).map_err(wrap_xla)?
                }
            }
        };
        Ok(lit)
    }
}

fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// A device-resident tensor (e.g. model parameters staged once and reused
/// across requests — the serving hot path never re-uploads params).
pub struct DeviceTensor {
    pub(crate) buf: xla::PjRtBuffer,
    shape: Vec<usize>,
}

impl DeviceTensor {
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
}

/// A compiled artifact bound to its manifest signature.
pub struct Exe {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

impl Exe {
    fn check_inputs(&self, inputs: &[&Value]) -> Result<()> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: got {} inputs, artifact takes {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        for (v, spec) in inputs.iter().zip(&self.spec.inputs) {
            anyhow::ensure!(
                v.shape() == spec.shape.as_slice() && v.dtype() == spec.dtype,
                "{}: input {:?} expects {:?} {:?}, got {:?} {:?}",
                self.spec.name,
                spec.name,
                spec.dtype,
                spec.shape,
                v.dtype(),
                v.shape()
            );
        }
        Ok(())
    }

    fn decode_outputs(&self, bufs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Value>> {
        let first = bufs
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("{}: no output buffer", self.spec.name))?;
        let tuple = first.to_literal_sync().map_err(wrap_xla)?;
        let parts = tuple.to_tuple().map_err(wrap_xla)?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "{}: {} outputs returned, manifest says {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| {
                let v = match spec.dtype {
                    DType::F32 => Value::F32 {
                        shape: spec.shape.clone(),
                        data: lit.to_vec::<f32>().map_err(wrap_xla)?,
                    },
                    DType::I32 => Value::I32 {
                        shape: spec.shape.clone(),
                        data: lit.to_vec::<i32>().map_err(wrap_xla)?,
                    },
                };
                anyhow::ensure!(
                    v.shape().iter().product::<usize>()
                        == match &v {
                            Value::F32 { data, .. } => data.len(),
                            Value::I32 { data, .. } => data.len(),
                        },
                    "{}: output {} element count mismatch",
                    self.spec.name,
                    spec.name
                );
                Ok(v)
            })
            .collect()
    }

    /// Execute with host values (validates against the manifest signature).
    pub fn run(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        self.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let out = self.exe.execute::<xla::Literal>(&literals).map_err(wrap_xla)?;
        self.decode_outputs(out)
    }

    /// Execute with device-resident buffers (hot path: params staged once).
    pub fn run_device(&self, inputs: &[&DeviceTensor]) -> Result<Vec<Value>> {
        let bufs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|t| &t.buf).collect();
        let out = self.exe.execute_b(&bufs).map_err(wrap_xla)?;
        self.decode_outputs(out)
    }

    /// Execute with device-resident buffers, decoding outputs into
    /// caller-preallocated `Value` storage. `outs` is sized and shaped on
    /// first use; afterwards each output's backing vector keeps its
    /// capacity, so the runtime side of the serving hot path stops
    /// re-allocating output values per batch. (The PJRT boundary itself —
    /// literal decode inside the xla bindings — still allocates; that cost
    /// is outside this crate.)
    pub fn run_device_into(&self, inputs: &[&DeviceTensor], outs: &mut Vec<Value>) -> Result<()> {
        let bufs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|t| &t.buf).collect();
        let raw = self.exe.execute_b(&bufs).map_err(wrap_xla)?;
        self.decode_outputs_into(raw, outs)
    }

    fn decode_outputs_into(
        &self,
        bufs: Vec<Vec<xla::PjRtBuffer>>,
        outs: &mut Vec<Value>,
    ) -> Result<()> {
        let first = bufs
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("{}: no output buffer", self.spec.name))?;
        let tuple = first.to_literal_sync().map_err(wrap_xla)?;
        let parts = tuple.to_tuple().map_err(wrap_xla)?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "{}: {} outputs returned, manifest says {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        // size the storage once; shapes are stable per executable after that
        while outs.len() < parts.len() {
            outs.push(Value::F32 { shape: Vec::new(), data: Vec::new() });
        }
        outs.truncate(parts.len());
        for ((lit, spec), out) in parts.into_iter().zip(&self.spec.outputs).zip(outs.iter_mut()) {
            let elems: usize = spec.shape.iter().product();
            match spec.dtype {
                DType::F32 => {
                    let v = lit.to_vec::<f32>().map_err(wrap_xla)?;
                    anyhow::ensure!(
                        v.len() == elems,
                        "{}: output {} element count mismatch",
                        self.spec.name,
                        spec.name
                    );
                    match out {
                        Value::F32 { shape, data } => {
                            shape.clear();
                            shape.extend_from_slice(&spec.shape);
                            data.clear();
                            data.extend_from_slice(&v);
                        }
                        other => *other = Value::F32 { shape: spec.shape.clone(), data: v },
                    }
                }
                DType::I32 => {
                    let v = lit.to_vec::<i32>().map_err(wrap_xla)?;
                    anyhow::ensure!(
                        v.len() == elems,
                        "{}: output {} element count mismatch",
                        self.spec.name,
                        spec.name
                    );
                    match out {
                        Value::I32 { shape, data } => {
                            shape.clear();
                            shape.extend_from_slice(&spec.shape);
                            data.clear();
                            data.extend_from_slice(&v);
                        }
                        other => *other = Value::I32 { shape: spec.shape.clone(), data: v },
                    }
                }
            }
        }
        Ok(())
    }
}

/// Thread-confined runtime: PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Exe>>>,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// `None` when no artifact manifest exists at `artifact_dir` — the
    /// skip-cleanly path benches and artifact-gated tests share (they all
    /// run artifact-free in CI). Artifacts that exist but fail to load are
    /// a real error and panic loudly rather than masquerading as absent.
    pub fn try_new(artifact_dir: &Path) -> Option<Runtime> {
        if !artifact_dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::new(artifact_dir).expect("artifacts present but failed to load"))
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<Exe>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(wrap_xla)
            .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap_xla)?;
        let exe = Rc::new(Exe { exe, spec });
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Stage a raw f32 host slice onto the device without building a
    /// `Value` first — the serving hot path stages the arena's pooled
    /// batch matrix directly.
    pub fn to_device_f32(&self, shape: &[usize], data: &[f32]) -> Result<DeviceTensor> {
        anyhow::ensure!(
            shape.iter().product::<usize>() == data.len(),
            "to_device_f32: shape {:?} / data len {} mismatch",
            shape,
            data.len()
        );
        let buf = self
            .client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(wrap_xla)?;
        Ok(DeviceTensor { buf, shape: shape.to_vec() })
    }

    /// Stage a host value onto the device (used for long-lived params).
    pub fn to_device(&self, v: &Value) -> Result<DeviceTensor> {
        let buf = match v {
            Value::F32 { shape, data } => self
                .client
                .buffer_from_host_buffer(data, shape, None)
                .map_err(wrap_xla)?,
            Value::I32 { shape, data } => self
                .client
                .buffer_from_host_buffer(data, shape, None)
                .map_err(wrap_xla)?,
        };
        Ok(DeviceTensor { buf, shape: v.shape().to_vec() })
    }

    /// Number of artifacts compiled so far (for tests / perf logs).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

//! The shard-side online learning loop (DESIGN.md §8): one [`Learner`]
//! per shard executor turns decoded experience frames into actions,
//! PPO segment updates, and policy publications.
//!
//! Call-order contract (what makes an ideal-link fleet run bit-identical
//! to the offline `rl::NativeTrainer` at the same seed): per frame —
//! complete the pending transition, then on a full segment bootstrap
//! with `value(obs)` *before* updating, run the PPO epochs, and only
//! then `act(obs)` for the new decision. `act` and `run_ppo_epochs` are
//! the only rng consumers, in exactly the offline order.

use anyhow::Result;

use crate::rl::native::{NativeConfig, NativeCore};

use super::buffer::{ExperienceBuffer, FrameDisposition, PendingStep};

/// Loop knobs layered over the core hyperparameters.
#[derive(Debug, Clone)]
pub struct LearnerConfig {
    pub core: NativeConfig,
    /// PPO segment length (per client track)
    pub rollout_steps: usize,
    pub ppo_epochs: usize,
    pub gae_lambda: f64,
    /// publish the policy every n segment updates (0 = never)
    pub publish_every: usize,
}

impl Default for LearnerConfig {
    fn default() -> Self {
        LearnerConfig {
            core: NativeConfig::default(),
            rollout_steps: 256,
            ppo_epochs: 10,
            gae_lambda: 0.95,
            publish_every: 1,
        }
    }
}

/// What a frame produced: the action to send back, the policy version it
/// was computed under, and (optionally) parameters to publish.
#[derive(Debug)]
pub struct LearnStep {
    pub action: Vec<f32>,
    pub acting_version: u64,
    /// a PPO segment update ran on this frame
    pub updated: bool,
    /// parameters due for publication (gateway assigns the version)
    pub publish: Option<Vec<f32>>,
}

#[derive(Debug)]
pub struct Learner {
    pub core: NativeCore,
    pub buf: ExperienceBuffer,
    cfg: LearnerConfig,
    /// version of the policy currently acting (0 until first adoption)
    pub acting_version: u64,
    /// segment updates run
    pub updates: u64,
    /// parameter vectors handed out for publication
    pub published: u64,
    /// adoptions applied, in order (strictly increasing versions)
    pub adopted_versions: Vec<u64>,
    since_publish: usize,
}

impl Learner {
    pub fn new(cfg: LearnerConfig) -> Learner {
        let buf = ExperienceBuffer::new(cfg.rollout_steps, cfg.core.obs_len, cfg.core.act_len);
        Learner {
            core: NativeCore::new(cfg.core.clone()),
            buf,
            cfg,
            acting_version: 0,
            updates: 0,
            published: 0,
            adopted_versions: Vec::new(),
            since_publish: 0,
        }
    }

    /// Handle one decoded experience frame from `client`: `obs` is the
    /// dequantised feature vector at (ep, step); the reward fields
    /// describe the previous action when `has_reward`.
    #[allow(clippy::too_many_arguments)]
    pub fn on_frame(
        &mut self,
        client: u32,
        obs: &[f32],
        ep: u32,
        step: u32,
        has_reward: bool,
        reward: f32,
        done: bool,
        terminated: bool,
    ) -> Result<LearnStep> {
        let disp = self.buf.on_frame(client, ep, step, has_reward, reward, done, terminated);
        if disp == FrameDisposition::Duplicate {
            let acting = self.acting_version;
            let p = self.buf.pending_mut(client).expect("duplicate implies pending");
            if p.version == acting {
                // retransmit: answer with the stored decision so the
                // client can never apply an action the rollout disagrees
                // with (exactly-once act() per (ep, step))
                return Ok(LearnStep {
                    action: p.act.clone(),
                    acting_version: p.version,
                    updated: false,
                    publish: None,
                });
            }
            // the pending decision predates an adopted policy (it was
            // stale-rejected downstream): re-decide under the new policy
            // and overwrite the slot — nothing was pushed yet
            let (a, logp, v) = self.core.act(obs);
            let p = self.buf.pending_mut(client).expect("still pending");
            p.obs.clear();
            p.obs.extend_from_slice(obs);
            p.act.clone_from(&a);
            p.logp = logp;
            p.value = v;
            p.version = acting;
            return Ok(LearnStep {
                action: a,
                acting_version: acting,
                updated: false,
                publish: None,
            });
        }

        let mut updated = false;
        let mut publish = None;
        if disp == (FrameDisposition::Completed { full: true }) {
            // bootstrap with pre-update parameters, then learn
            let last_v = self.core.value(obs);
            let ro = self.buf.rollout_mut(client).expect("full implies rollout");
            let (adv, ret) = ro.gae(self.cfg.core.gamma, self.cfg.gae_lambda, last_v);
            self.core.run_ppo_epochs(ro, &adv, &ret, self.cfg.ppo_epochs)?;
            ro.clear();
            self.updates += 1;
            self.since_publish += 1;
            updated = true;
            if self.cfg.publish_every > 0 && self.since_publish >= self.cfg.publish_every {
                self.since_publish = 0;
                self.published += 1;
                publish = Some(self.core.params().to_vec());
            }
        }
        let (a, logp, v) = self.core.act(obs);
        self.buf.set_pending(
            client,
            PendingStep {
                obs: obs.to_vec(),
                act: a.clone(),
                logp,
                value: v,
                ep,
                step,
                version: self.acting_version,
            },
        );
        Ok(LearnStep { action: a, acting_version: self.acting_version, updated, publish })
    }

    /// Adopt a fanned-out policy version. Older or already-adopted
    /// versions are ignored, so adoption is exactly-once per version and
    /// `adopted_versions` is strictly increasing by construction.
    pub fn adopt(&mut self, version: u64, params: &[f32]) -> Result<bool> {
        if version <= self.acting_version {
            return Ok(false);
        }
        self.core.set_params(params)?;
        self.acting_version = version;
        self.adopted_versions.push(version);
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn learner() -> Learner {
        Learner::new(LearnerConfig {
            core: NativeConfig { hidden: 8, minibatch: 4, seed: 5, ..NativeConfig::default() },
            rollout_steps: 8,
            ppo_epochs: 2,
            gae_lambda: 0.95,
            publish_every: 1,
        })
    }

    fn obs(i: u32) -> Vec<f32> {
        vec![0.1 + i as f32 * 1e-3, 0.5, 0.9 - i as f32 * 1e-3]
    }

    #[test]
    fn stream_trains_and_publishes_on_segment_boundary() {
        let mut l = learner();
        let s0 = l.on_frame(1, &obs(0), 0, 0, false, 0.0, false, false).unwrap();
        assert!(!s0.updated);
        assert_eq!(s0.acting_version, 0);
        let mut updates = 0;
        for i in 1..=9u32 {
            let s = l.on_frame(1, &obs(i), 0, i, true, -1.0, false, false).unwrap();
            if s.updated {
                updates += 1;
                assert!(s.publish.is_some(), "publish_every=1 publishes on update");
            }
        }
        // 9 completions over an 8-step segment: exactly one update
        assert_eq!(updates, 1);
        assert_eq!(l.updates, 1);
        assert_eq!(l.published, 1);
        assert_eq!(l.buf.completed, 9);
    }

    #[test]
    fn duplicate_frame_replays_the_stored_action() {
        let mut l = learner();
        let s0 = l.on_frame(1, &obs(0), 0, 0, false, 0.0, false, false).unwrap();
        let dup = l.on_frame(1, &obs(0), 0, 0, false, 0.0, false, false).unwrap();
        assert_eq!(dup.action, s0.action);
        assert_eq!(l.buf.duplicates, 1);
    }

    #[test]
    fn duplicate_after_adoption_redecides_under_new_policy() {
        let mut l = learner();
        let s0 = l.on_frame(1, &obs(0), 0, 0, false, 0.0, false, false).unwrap();
        let fresh = NativeCore::new(NativeConfig {
            hidden: 8,
            minibatch: 4,
            seed: 99,
            ..NativeConfig::default()
        });
        assert!(l.adopt(3, &fresh.params().to_vec()).unwrap());
        let dup = l.on_frame(1, &obs(0), 0, 0, false, 0.0, false, false).unwrap();
        assert_eq!(dup.acting_version, 3);
        assert_ne!(dup.action, s0.action);
        // and the pending slot now agrees with what the client applies
        assert_eq!(l.buf.pending(1).unwrap().act, dup.action);
    }

    #[test]
    fn adoption_is_monotonic_exactly_once() {
        let mut l = learner();
        let p = l.core.params().to_vec();
        assert!(l.adopt(2, &p).unwrap());
        assert!(!l.adopt(2, &p).unwrap());
        assert!(!l.adopt(1, &p).unwrap());
        assert!(l.adopt(5, &p).unwrap());
        assert_eq!(l.adopted_versions, vec![2, 5]);
        // stale adoptions skip the size check; fresh ones enforce it
        assert!(!l.adopt(5, &p[1..]).unwrap());
        assert!(l.adopt(6, &p[1..]).is_err());
    }
}

//! Versioned policy snapshots with a seqlock-style swap (DESIGN.md §8).
//!
//! One writer publishes flat parameter vectors; many readers grab the
//! latest snapshot without blocking the writer. Versions are a global
//! monotonic counter starting at 0 (= "no policy published yet"); the
//! staleness bound in the serving path compares a response's acting
//! version against [`PolicyStore::version`].
//!
//! The classic seqlock reads unsynchronised data and retries on a torn
//! sequence; safe Rust can't express the torn read, so the swap keeps
//! the seqlock *shape* — an atomic version word plus double-buffered
//! slots, readers validating the version after the copy — with each
//! slot behind an `RwLock` that is only ever write-held for the slot
//! *not* being read at the current version.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Immutable published policy: version + flat parameter vector
/// (layout `rl::native::NativeCore::params`).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySnapshot {
    pub version: u64,
    pub params: Vec<f32>,
}

#[derive(Debug)]
pub struct PolicyStore {
    version: AtomicU64,
    slots: [RwLock<Arc<PolicySnapshot>>; 2],
    /// serialises concurrent publishers (threaded server executors)
    writer: Mutex<()>,
}

impl Default for PolicyStore {
    fn default() -> Self {
        Self::new()
    }
}

impl PolicyStore {
    pub fn new() -> PolicyStore {
        let empty = Arc::new(PolicySnapshot { version: 0, params: Vec::new() });
        PolicyStore {
            version: AtomicU64::new(0),
            slots: [RwLock::new(empty.clone()), RwLock::new(empty)],
            writer: Mutex::new(()),
        }
    }

    /// Latest published version (0 = nothing published).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Publish a new parameter vector; returns its assigned version.
    pub fn publish(&self, params: &[f32]) -> u64 {
        let _guard = self.writer.lock().unwrap();
        let v = self.version.load(Ordering::Relaxed);
        let next = v + 1;
        let snap = Arc::new(PolicySnapshot { version: next, params: params.to_vec() });
        // write the inactive slot, then flip the version to it
        *self.slots[(next & 1) as usize].write().unwrap() = snap;
        self.version.store(next, Ordering::Release);
        next
    }

    /// Latest snapshot; retries if a publish overtakes the slot mid-read
    /// (the returned version always equals a version-word value observed
    /// by this thread, so per-reader views are monotonic).
    pub fn snapshot(&self) -> Arc<PolicySnapshot> {
        loop {
            let v = self.version.load(Ordering::Acquire);
            let snap = self.slots[(v & 1) as usize].read().unwrap().clone();
            if snap.version == v {
                return snap;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn starts_empty_at_version_zero() {
        let store = PolicyStore::new();
        assert_eq!(store.version(), 0);
        let s = store.snapshot();
        assert_eq!(s.version, 0);
        assert!(s.params.is_empty());
    }

    #[test]
    fn publish_is_monotonic_and_snapshot_sees_latest() {
        let store = PolicyStore::new();
        assert_eq!(store.publish(&[1.0]), 1);
        assert_eq!(store.publish(&[2.0]), 2);
        assert_eq!(store.version(), 2);
        let s = store.snapshot();
        assert_eq!(s.version, 2);
        assert_eq!(s.params, vec![2.0]);
    }

    #[test]
    fn concurrent_readers_never_see_torn_or_regressing_snapshots() {
        let store = Arc::new(PolicyStore::new());
        let stop = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let store = store.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut last = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let s = store.snapshot();
                    // params are version-stamped: a torn read shows up
                    // as a value disagreeing with the snapshot version
                    assert!(s.params.iter().all(|&p| p == s.version as f32), "torn");
                    assert!(s.version >= last, "version regressed");
                    last = s.version;
                }
            }));
        }
        for v in 1..=500u64 {
            let params = vec![v as f32; 64];
            assert_eq!(store.publish(&params), v);
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(store.version(), 500);
    }
}

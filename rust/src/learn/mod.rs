//! Fleet-scale online learning (DESIGN.md §8): train through the serving
//! stack instead of beside it. Split clients stream codec-compressed
//! features plus rewards over experience frames (`net::framing`), shard
//! executors feed per-client rollout tracks in an [`ExperienceBuffer`]
//! and run PPO segment updates on the shared `rl::native` engine
//! ([`Learner`]), and a versioned [`PolicyStore`] fans policy snapshots
//! out through the gateway with a staleness bound (`max_lag`).

pub mod buffer;
#[path = "loop.rs"]
pub mod online;
pub mod policy_store;

pub use buffer::{ExperienceBuffer, FrameDisposition, PendingStep};
pub use online::{LearnStep, Learner, LearnerConfig};
pub use policy_store::{PolicySnapshot, PolicyStore};

//! Per-shard experience ingestion (DESIGN.md §8): each client streaming
//! experience frames gets its own pending decision + rollout track, so
//! GAE chains never cross client trajectories, and the (episode, step)
//! sequence discipline makes reward completion exactly-once under
//! retransmits, reconnects, and mid-episode failover.
//!
//! Protocol recap: frame (ep, step) carries the observation *at* that
//! step plus (when flagged) the reward/done of the *previous* action.
//! The buffer completes the pending transition only when the frame is
//! the pending step's direct successor — same episode next step, or
//! step 0 of the next episode. Anything else (failover onto a shard
//! that never saw the pending step, a stream restarting after a crash)
//! drops the pending decision and cuts the GAE chain at the last pushed
//! transition instead of corrupting it with a cross-gap bootstrap.

use std::collections::BTreeMap;

use crate::rl::Rollout;

/// A decision handed out but not yet completed by its reward frame.
#[derive(Debug, Clone)]
pub struct PendingStep {
    pub obs: Vec<f32>,
    pub act: Vec<f32>,
    pub logp: f32,
    pub value: f32,
    pub ep: u32,
    pub step: u32,
    /// policy version the action was computed under
    pub version: u64,
}

/// What an incoming experience frame meant for the client's track.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameDisposition {
    /// same (ep, step) as the live pending decision: a retransmit
    Duplicate,
    /// reward consumed, pending pushed; `full` = segment ready to train
    Completed { full: bool },
    /// no (or mismatched) pending — fresh decision point
    Fresh,
}

#[derive(Debug, Default)]
struct ClientTrack {
    pending: Option<PendingStep>,
    rollout: Option<Rollout>,
}

/// All learning state a shard keeps per connected client.
#[derive(Debug)]
pub struct ExperienceBuffer {
    rollout_steps: usize,
    obs_len: usize,
    act_len: usize,
    tracks: BTreeMap<u32, ClientTrack>,
    /// transitions completed into rollouts
    pub completed: u64,
    /// reward-bearing frames that could not complete a pending decision
    pub dropped_incomplete: u64,
    /// GAE chains cut after a dropped pending decision
    pub chain_cuts: u64,
    /// retransmitted decision frames answered from the pending slot
    pub duplicates: u64,
}

impl ExperienceBuffer {
    pub fn new(rollout_steps: usize, obs_len: usize, act_len: usize) -> ExperienceBuffer {
        ExperienceBuffer {
            rollout_steps,
            obs_len,
            act_len,
            tracks: BTreeMap::new(),
            completed: 0,
            dropped_incomplete: 0,
            chain_cuts: 0,
            duplicates: 0,
        }
    }

    /// Classify frame (ep, step) against the client's pending decision,
    /// consuming the carried reward when it is the direct successor.
    #[allow(clippy::too_many_arguments)]
    pub fn on_frame(
        &mut self,
        client: u32,
        ep: u32,
        step: u32,
        has_reward: bool,
        reward: f32,
        done: bool,
        terminated: bool,
    ) -> FrameDisposition {
        let track = self.tracks.entry(client).or_default();
        let Some(p) = track.pending.as_ref() else {
            if has_reward {
                self.dropped_incomplete += 1;
            }
            return FrameDisposition::Fresh;
        };
        if (ep, step) == (p.ep, p.step) {
            self.duplicates += 1;
            return FrameDisposition::Duplicate;
        }
        let successor = (ep == p.ep && step == p.step + 1) || (ep == p.ep + 1 && step == 0);
        if has_reward && successor {
            let p = track.pending.take().unwrap();
            let ro = track.rollout.get_or_insert_with(|| {
                Rollout::new(self.rollout_steps, self.obs_len, self.act_len)
            });
            ro.push(&p.obs, &p.act, p.logp, p.value, reward, done, terminated);
            self.completed += 1;
            return FrameDisposition::Completed { full: ro.full() };
        }
        // out-of-sequence frame: the pending decision's reward is lost.
        // Drop it and cut the GAE chain so the gap never bootstraps.
        track.pending = None;
        self.dropped_incomplete += 1;
        if let Some(ro) = track.rollout.as_mut() {
            if !ro.is_empty() && *ro.done.last().unwrap() == 0.0 {
                *ro.done.last_mut().unwrap() = 1.0;
                self.chain_cuts += 1;
            }
        }
        FrameDisposition::Fresh
    }

    pub fn set_pending(&mut self, client: u32, pending: PendingStep) {
        self.tracks.entry(client).or_default().pending = Some(pending);
    }

    pub fn pending(&self, client: u32) -> Option<&PendingStep> {
        self.tracks.get(&client).and_then(|t| t.pending.as_ref())
    }

    pub fn pending_mut(&mut self, client: u32) -> Option<&mut PendingStep> {
        self.tracks.get_mut(&client).and_then(|t| t.pending.as_mut())
    }

    /// The client's rollout segment (created lazily on first completion).
    pub fn rollout_mut(&mut self, client: u32) -> Option<&mut Rollout> {
        self.tracks.get_mut(&client).and_then(|t| t.rollout.as_mut())
    }

    /// Forget a client entirely (disconnect / session eviction).
    pub fn drop_client(&mut self, client: u32) {
        self.tracks.remove(&client);
    }

    /// Hand a client's live track (pending decision + partial rollout) to
    /// a peer buffer — the planned-migration path (DESIGN.md §10): at a
    /// quiescent point the new shard continues the trajectory exactly
    /// where the old one answered last, so a clean scale-down handoff
    /// completes every transition exactly once instead of dropping the
    /// pending step at the seam. Returns false when the client has no
    /// track to move.
    pub fn transfer_client_to(&mut self, client: u32, dst: &mut ExperienceBuffer) -> bool {
        match self.tracks.remove(&client) {
            Some(track) => {
                dst.tracks.insert(client, track);
                true
            }
            None => false,
        }
    }

    pub fn n_clients(&self) -> usize {
        self.tracks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buf() -> ExperienceBuffer {
        ExperienceBuffer::new(4, 2, 1)
    }

    fn pend(ep: u32, step: u32) -> PendingStep {
        PendingStep {
            obs: vec![0.1, 0.2],
            act: vec![0.5],
            logp: -1.0,
            value: 0.3,
            ep,
            step,
            version: 7,
        }
    }

    #[test]
    fn first_frame_is_fresh_and_drops_nothing() {
        let mut b = buf();
        assert_eq!(b.on_frame(1, 0, 0, false, 0.0, false, false), FrameDisposition::Fresh);
        assert_eq!(b.dropped_incomplete, 0);
    }

    #[test]
    fn successor_frame_completes_within_episode_and_across_episodes() {
        let mut b = buf();
        b.set_pending(1, pend(0, 3));
        assert_eq!(
            b.on_frame(1, 0, 4, true, -1.5, false, false),
            FrameDisposition::Completed { full: false }
        );
        assert_eq!(b.completed, 1);
        // episode boundary: step 0 of the next episode completes too
        b.set_pending(1, pend(0, 199));
        assert_eq!(
            b.on_frame(1, 1, 0, true, -2.0, true, false),
            FrameDisposition::Completed { full: false }
        );
        let ro = b.rollout_mut(1).unwrap();
        assert_eq!(ro.len(), 2);
        assert_eq!(ro.rew, vec![-1.5, -2.0]);
        assert_eq!(ro.done, vec![0.0, 1.0]);
    }

    #[test]
    fn duplicate_frame_is_flagged_not_double_pushed() {
        let mut b = buf();
        b.set_pending(1, pend(2, 5));
        assert_eq!(b.on_frame(1, 2, 5, false, 0.0, false, false), FrameDisposition::Duplicate);
        assert_eq!(
            b.on_frame(1, 2, 6, true, -1.0, false, false),
            FrameDisposition::Completed { full: false }
        );
        // a late retransmit of the *completed* frame no longer matches a
        // pending decision; its stale reward is dropped, never re-pushed
        assert_eq!(b.on_frame(1, 2, 6, true, -1.0, false, false), FrameDisposition::Fresh);
        assert_eq!(b.completed, 1);
        assert_eq!(b.duplicates, 1);
        assert_eq!(b.dropped_incomplete, 1);
    }

    #[test]
    fn gap_drops_pending_and_cuts_chain() {
        let mut b = buf();
        b.set_pending(1, pend(0, 0));
        b.on_frame(1, 0, 1, true, -1.0, false, false);
        b.set_pending(1, pend(0, 1));
        // client skipped ahead (e.g. served elsewhere): gap
        assert_eq!(b.on_frame(1, 0, 7, true, -9.0, false, false), FrameDisposition::Fresh);
        assert_eq!(b.dropped_incomplete, 1);
        assert_eq!(b.chain_cuts, 1);
        let ro = b.rollout_mut(1).unwrap();
        assert_eq!(ro.len(), 1);
        assert_eq!(ro.done, vec![1.0]); // chain cut at the last push
        assert_eq!(ro.terminated, vec![0.0]); // ...but not terminated
    }

    #[test]
    fn tracks_are_per_client() {
        let mut b = buf();
        b.set_pending(1, pend(0, 0));
        b.set_pending(2, pend(0, 0));
        b.on_frame(1, 0, 1, true, -1.0, false, false);
        assert!(b.pending(1).is_none());
        assert!(b.pending(2).is_some());
        assert_eq!(b.n_clients(), 2);
        b.drop_client(2);
        assert_eq!(b.n_clients(), 1);
        assert!(b.pending(2).is_none());
    }

    #[test]
    fn transferred_track_completes_on_the_destination_buffer() {
        let mut a = buf();
        let mut b = buf();
        // one completed transition and a live pending decision on `a`
        a.set_pending(1, pend(0, 0));
        a.on_frame(1, 0, 1, true, -1.0, false, false);
        a.set_pending(1, pend(0, 1));
        assert!(a.transfer_client_to(1, &mut b));
        assert_eq!(a.n_clients(), 0);
        assert!(b.pending(1).is_some());
        // the successor frame lands on `b` and completes the migrated
        // pending step — nothing dropped, no chain cut, on either side
        assert_eq!(
            b.on_frame(1, 0, 2, true, -2.0, false, false),
            FrameDisposition::Completed { full: false }
        );
        assert_eq!(b.completed, 1);
        assert_eq!(a.dropped_incomplete + b.dropped_incomplete, 0);
        assert_eq!(a.chain_cuts + b.chain_cuts, 0);
        let ro = b.rollout_mut(1).unwrap();
        assert_eq!(ro.rew, vec![-1.0, -2.0]);
        // no track, nothing to move
        assert!(!a.transfer_client_to(9, &mut b));
    }

    #[test]
    fn full_segment_is_reported() {
        let mut b = buf();
        for i in 0..4u32 {
            b.set_pending(1, pend(0, i));
            let full = matches!(
                b.on_frame(1, 0, i + 1, true, -1.0, false, false),
                FrameDisposition::Completed { full: true }
            );
            assert_eq!(full, i == 3, "step {i}");
        }
    }
}

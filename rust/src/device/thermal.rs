//! First-order thermal RC model: the die heats with dissipated power and
//! cools toward ambient with time constant `tau`. Drives the throttling
//! behaviour in the sustained-load experiments (paper Fig. 3/4).
//!
//! [`ThermalModel`] is pure over `dt`; [`ClockedThermal`] closes it over
//! an instant stream from the clock seam (`sim::Clock`), so the simnet's
//! chaos scenarios integrate the identical RC dynamics in virtual time.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct ThermalModel {
    /// ambient temperature, °C
    pub ambient: f64,
    /// steady-state °C above ambient per watt
    pub c_per_watt: f64,
    /// time constant, seconds
    pub tau: f64,
    /// throttle trip point, °C
    pub throttle_temp: f64,
    /// hysteresis: resume full clock below this, °C
    pub resume_temp: f64,
    temp: f64,
    throttled: bool,
}

impl ThermalModel {
    pub fn new(ambient: f64, c_per_watt: f64, tau: f64, throttle: f64, resume: f64) -> Self {
        ThermalModel {
            ambient,
            c_per_watt,
            tau,
            throttle_temp: throttle,
            resume_temp: resume,
            temp: ambient,
            throttled: false,
        }
    }

    pub fn temp(&self) -> f64 {
        self.temp
    }

    pub fn reset(&mut self) {
        self.temp = self.ambient;
        self.throttled = false;
    }

    /// Integrate over `dt` seconds at dissipated power `watts`.
    pub fn step(&mut self, watts: f64, dt: f64) {
        let target = self.ambient + self.c_per_watt * watts;
        let a = (-dt / self.tau).exp();
        self.temp = target + (self.temp - target) * a;
        if self.temp >= self.throttle_temp {
            self.throttled = true;
        } else if self.temp <= self.resume_temp {
            self.throttled = false;
        }
    }

    /// Clock multiplier the governor should apply (1.0 or the throttled
    /// fraction); hysteresis between trip and resume points.
    pub fn throttled(&self) -> bool {
        self.throttled
    }

    /// Steady-state temperature at a given power (for calibration tests).
    pub fn steady_state(&self, watts: f64) -> f64 {
        self.ambient + self.c_per_watt * watts
    }
}

/// Clock-driven wrapper: integrates the RC model across the gaps between
/// observation instants. The caller reports the power that was dissipated
/// *since the previous update* — a shard executor calls
/// `update(idle_watts, batch_start)` then `update(active_watts, batch_end)`
/// to alternate idle/active stretches. Instants come from the clock seam,
/// so wall-clock governors and virtual-time scenarios share this code.
#[derive(Debug, Clone)]
pub struct ClockedThermal {
    model: ThermalModel,
    last: Instant,
}

impl ClockedThermal {
    pub fn new(model: ThermalModel, now: Instant) -> ClockedThermal {
        ClockedThermal { model, last: now }
    }

    /// Integrate `watts` over the time since the last update. Stale or
    /// tied instants integrate zero time (never panic, never cool
    /// backwards).
    pub fn update(&mut self, watts: f64, now: Instant) {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        if dt > 0.0 {
            self.model.step(watts, dt);
        }
        self.last = self.last.max(now);
    }

    pub fn model(&self) -> &ThermalModel {
        &self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ThermalModel {
        ThermalModel::new(25.0, 10.0, 60.0, 70.0, 60.0)
    }

    #[test]
    fn heats_toward_steady_state() {
        let mut m = model();
        for _ in 0..600 {
            m.step(3.0, 1.0); // 3W for 10 minutes
        }
        assert!((m.temp() - 55.0).abs() < 0.5, "{}", m.temp());
        assert!(!m.throttled());
    }

    #[test]
    fn exponential_approach_halfway_at_tau_ln2() {
        let mut m = model();
        let t_half = 60.0 * std::f64::consts::LN_2;
        m.step(3.0, t_half);
        // halfway between 25 and 55
        assert!((m.temp() - 40.0).abs() < 0.5, "{}", m.temp());
    }

    #[test]
    fn throttles_above_trip_with_hysteresis() {
        let mut m = model();
        for _ in 0..2000 {
            m.step(6.0, 1.0); // steady 85C > 70C trip
            if m.throttled() {
                break;
            }
        }
        assert!(m.throttled());
        // cool: stays throttled until below resume point
        while m.temp() > 61.0 {
            m.step(0.0, 1.0);
            if m.temp() > m.resume_temp {
                assert!(m.throttled());
            }
        }
        m.step(0.0, 30.0);
        assert!(!m.throttled());
    }

    #[test]
    fn cools_to_ambient() {
        let mut m = model();
        m.step(10.0, 300.0);
        m.step(0.0, 3000.0);
        assert!((m.temp() - 25.0).abs() < 0.1);
    }

    #[test]
    fn clocked_wrapper_matches_manual_stepping() {
        use std::time::Duration;
        let t0 = Instant::now();
        let mut manual = model();
        let mut clocked = ClockedThermal::new(model(), t0);
        // alternate idle/active stretches over explicit instants
        let schedule = [(3.0, 10.0), (0.5, 2.0), (6.0, 30.0), (0.0, 120.0)];
        let mut at = t0;
        for (watts, secs) in schedule {
            manual.step(watts, secs);
            at += Duration::from_secs_f64(secs);
            clocked.update(watts, at);
        }
        assert!((manual.temp() - clocked.model().temp()).abs() < 1e-9);
        assert_eq!(manual.throttled(), clocked.model().throttled());
    }

    #[test]
    fn clocked_wrapper_ignores_stale_instants() {
        let t0 = Instant::now();
        let mut c = ClockedThermal::new(model(), t0);
        c.update(6.0, t0 + std::time::Duration::from_secs(100));
        let temp = c.model().temp();
        // an instant from the past must not integrate negative time
        c.update(6.0, t0);
        assert_eq!(c.model().temp(), temp);
    }

    #[test]
    fn clocked_wrapper_under_virtual_instants_throttles_and_recovers() {
        // virtual instants are just base + offset: drive a full
        // heat-throttle-cool cycle with zero real waiting
        use std::time::Duration;
        let base = Instant::now();
        let mut c = ClockedThermal::new(model(), base);
        c.update(8.0, base + Duration::from_secs(600)); // 105C target
        assert!(c.model().throttled(), "sustained 8W must trip 70C");
        c.update(0.0, base + Duration::from_secs(1200));
        assert!(!c.model().throttled(), "10 min idle must recover");
    }
}

//! First-order thermal RC model: the die heats with dissipated power and
//! cools toward ambient with time constant `tau`. Drives the throttling
//! behaviour in the sustained-load experiments (paper Fig. 3/4).

#[derive(Debug, Clone)]
pub struct ThermalModel {
    /// ambient temperature, °C
    pub ambient: f64,
    /// steady-state °C above ambient per watt
    pub c_per_watt: f64,
    /// time constant, seconds
    pub tau: f64,
    /// throttle trip point, °C
    pub throttle_temp: f64,
    /// hysteresis: resume full clock below this, °C
    pub resume_temp: f64,
    temp: f64,
    throttled: bool,
}

impl ThermalModel {
    pub fn new(ambient: f64, c_per_watt: f64, tau: f64, throttle: f64, resume: f64) -> Self {
        ThermalModel {
            ambient,
            c_per_watt,
            tau,
            throttle_temp: throttle,
            resume_temp: resume,
            temp: ambient,
            throttled: false,
        }
    }

    pub fn temp(&self) -> f64 {
        self.temp
    }

    pub fn reset(&mut self) {
        self.temp = self.ambient;
        self.throttled = false;
    }

    /// Integrate over `dt` seconds at dissipated power `watts`.
    pub fn step(&mut self, watts: f64, dt: f64) {
        let target = self.ambient + self.c_per_watt * watts;
        let a = (-dt / self.tau).exp();
        self.temp = target + (self.temp - target) * a;
        if self.temp >= self.throttle_temp {
            self.throttled = true;
        } else if self.temp <= self.resume_temp {
            self.throttled = false;
        }
    }

    /// Clock multiplier the governor should apply (1.0 or the throttled
    /// fraction); hysteresis between trip and resume points.
    pub fn throttled(&self) -> bool {
        self.throttled
    }

    /// Steady-state temperature at a given power (for calibration tests).
    pub fn steady_state(&self, watts: f64) -> f64 {
        self.ambient + self.c_per_watt * watts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ThermalModel {
        ThermalModel::new(25.0, 10.0, 60.0, 70.0, 60.0)
    }

    #[test]
    fn heats_toward_steady_state() {
        let mut m = model();
        for _ in 0..600 {
            m.step(3.0, 1.0); // 3W for 10 minutes
        }
        assert!((m.temp() - 55.0).abs() < 0.5, "{}", m.temp());
        assert!(!m.throttled());
    }

    #[test]
    fn exponential_approach_halfway_at_tau_ln2() {
        let mut m = model();
        let t_half = 60.0 * std::f64::consts::LN_2;
        m.step(3.0, t_half);
        // halfway between 25 and 55
        assert!((m.temp() - 40.0).abs() < 0.5, "{}", m.temp());
    }

    #[test]
    fn throttles_above_trip_with_hysteresis() {
        let mut m = model();
        for _ in 0..2000 {
            m.step(6.0, 1.0); // steady 85C > 70C trip
            if m.throttled() {
                break;
            }
        }
        assert!(m.throttled());
        // cool: stays throttled until below resume point
        while m.temp() > 61.0 {
            m.step(0.0, 1.0);
            if m.temp() > m.resume_temp {
                assert!(m.throttled());
            }
        }
        m.step(0.0, 30.0);
        assert!(!m.throttled());
    }

    #[test]
    fn cools_to_ambient() {
        let mut m = model();
        m.step(10.0, 300.0);
        m.step(0.0, 3000.0);
        assert!((m.temp() - 25.0).abs() < 0.1);
    }
}

//! Simulated edge devices (Jetson Nano, Pi 4B, Pi Zero 2 W): per-frame
//! execution model over the shader plan, thermal RC dynamics, DVFS
//! throttling, power caps, and RAM accounting. Substitutes for the paper's
//! physical testbed (DESIGN.md §2); calibration anchors in [`presets`].

pub mod model;
pub mod presets;
pub mod thermal;

pub use model::{Device, DeviceSpec, ExecPath, FrameCost, FrameStats};
pub use presets::{all as all_devices, by_name, jetson_nano, pi_4b, pi_zero_2w};
pub use thermal::{ClockedThermal, ThermalModel};

//! Edge-device execution model: per-frame inference time, power, thermal
//! state, and RAM, for the GPU (OpenGL shader) and CPU (PyTorch) paths.
//!
//! Substitution note (DESIGN.md §2): this model stands in for the physical
//! Jetson Nano / Pi 4B / Pi Zero 2 W testbed. It is calibrated so the
//! paper's *shape* claims hold: the Pi Zero 2 W crosses 0.2 s/frame (5 fps)
//! near X=500; the Jetson is far faster across the range but throttles
//! under sustained load, with the 5 W cap lowering the plateau; the CPU
//! path is slower and jitterier than GL on the Pi Zero.
//!
//! The GPU cost driver is the shader plan itself: time ≈ upload +
//! Σ_passes (overhead + pixels·samples / sample_rate) — i.e. exactly the
//! quantity the pass planner computes, so planner improvements show up in
//! the simulated devices.

use crate::shader::PassPlan;
use crate::util::rng::Rng;

use super::thermal::ThermalModel;

/// Which execution path runs the encoder on-device (paper Q7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPath {
    /// OpenGL fragment shaders
    Gpu,
    /// CPU PyTorch-style inference
    Cpu,
}

/// Static description of a device model.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// CPU core count — sizes the compiled interpreter's worker pool when
    /// the software path stands in for this device
    pub cpu_cores: usize,
    /// GL texture-sample throughput at full clock, samples/s
    pub gpu_samples_per_sec: f64,
    /// fixed cost per shader pass (draw call, FBO bind), s
    pub pass_overhead: f64,
    /// host->GPU upload bandwidth, bytes/s
    pub upload_bytes_per_sec: f64,
    /// fixed per-frame cost (readback, sync), s
    pub frame_overhead: f64,
    /// effective CPU conv throughput (PyTorch path), MAC/s
    pub cpu_macs_per_sec: f64,
    /// relative jitter of the CPU path (python allocator, GC, scheduling)
    pub cpu_jitter: f64,
    /// relative jitter of the GL path
    pub gpu_jitter: f64,
    /// clock multiplier when thermally throttled
    pub throttle_frac: f64,
    /// idle power, W
    pub idle_watts: f64,
    /// peak dynamic power at full utilisation, W
    pub dyn_watts: f64,
    /// optional firmware power cap, W (Jetson 5W mode)
    pub power_cap: Option<f64>,
    pub thermal: ThermalModel,
    /// total RAM, MB
    pub ram_total_mb: f64,
    /// OS + runtime baseline, MB
    pub ram_baseline_mb: f64,
    /// extra RSS of the CPU-path framework (PyTorch), MB
    pub cpu_framework_mb: f64,
}

/// Workload cost of one frame, derived from the shader plan.
#[derive(Debug, Clone, Copy)]
pub struct FrameCost {
    pub samples: u64,
    pub macs: u64,
    pub upload_bytes: u64,
    pub n_passes: usize,
    pub texture_bytes: u64,
}

impl FrameCost {
    /// Cost of executing `plan` on one X·X RGBA frame.
    pub fn from_plan(plan: &PassPlan) -> FrameCost {
        let samples = plan.total_samples();
        FrameCost {
            samples,
            // one texture sample feeds a mat4·vec4 = 16 MACs
            macs: samples * 16,
            upload_bytes: (plan.input_x * plan.input_x * 4) as u64,
            n_passes: plan.passes.len(),
            texture_bytes: plan.bytes_written(),
        }
    }
}

/// Telemetry for one executed frame.
#[derive(Debug, Clone, Copy)]
pub struct FrameStats {
    /// wall-clock duration of this frame, s
    pub duration: f64,
    /// die temperature at frame end, °C
    pub temp: f64,
    /// average power over the frame, W
    pub watts: f64,
    /// RSS in MB
    pub ram_mb: f64,
    /// effective clock fraction applied (1.0 = full)
    pub clock_frac: f64,
    /// simulated time at frame end, s
    pub t_end: f64,
}

/// A live device: spec + mutable thermal/clock state + virtual clock.
pub struct Device {
    pub spec: DeviceSpec,
    rng: Rng,
    now: f64,
}

impl Device {
    pub fn new(spec: DeviceSpec, seed: u64) -> Device {
        Device { spec, rng: Rng::new(seed), now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn temp(&self) -> f64 {
        self.spec.thermal.temp()
    }

    pub fn reset(&mut self) {
        self.spec.thermal.reset();
        self.now = 0.0;
    }

    /// The governor's clock fraction given thermal state and power cap.
    fn clock_frac(&self) -> f64 {
        let mut f: f64 = 1.0;
        if self.spec.thermal.throttled() {
            f = f.min(self.spec.throttle_frac);
        }
        if let Some(cap) = self.spec.power_cap {
            // dynamic power ~ frac^2 (v·f scaling): fit under the cap
            let budget = (cap - self.spec.idle_watts).max(0.05);
            let frac = (budget / self.spec.dyn_watts).sqrt().min(1.0);
            f = f.min(frac);
        }
        f
    }

    /// Execute one encoder frame; advances device time and thermal state.
    pub fn encode_frame(&mut self, cost: &FrameCost, path: ExecPath) -> FrameStats {
        let clock = self.clock_frac();
        let (mut duration, util, jitter, ram) = match path {
            ExecPath::Gpu => {
                let compute = cost.samples as f64 / (self.spec.gpu_samples_per_sec * clock);
                let upload = cost.upload_bytes as f64 / self.spec.upload_bytes_per_sec;
                let overhead =
                    self.spec.frame_overhead + cost.n_passes as f64 * self.spec.pass_overhead;
                let ram = self.spec.ram_baseline_mb
                    + (cost.texture_bytes + cost.upload_bytes) as f64 / 1e6;
                (compute + upload + overhead, 0.95, self.spec.gpu_jitter, ram)
            }
            ExecPath::Cpu => {
                let compute = cost.macs as f64 / (self.spec.cpu_macs_per_sec * clock);
                let ram = self.spec.ram_baseline_mb
                    + self.spec.cpu_framework_mb
                    + 2.0 * (cost.upload_bytes as f64) / 1e6;
                (compute + self.spec.frame_overhead, 1.0, self.spec.cpu_jitter, ram)
            }
        };
        // multiplicative jitter + occasional scheduling spike (CPU path)
        let mut noise = 1.0 + jitter * self.rng.normal();
        if path == ExecPath::Cpu && self.rng.uniform() < 0.02 {
            noise += 0.6 * self.rng.uniform(); // GC / scheduler spike
        }
        duration *= noise.max(0.5);

        // power: idle + dynamic·util·clock²
        let watts = self.spec.idle_watts + self.spec.dyn_watts * util * clock * clock;
        self.spec.thermal.step(watts, duration);
        self.now += duration;

        FrameStats {
            duration,
            temp: self.spec.thermal.temp(),
            watts,
            ram_mb: ram,
            clock_frac: clock,
            t_end: self.now,
        }
    }

    /// Let the device idle (cool) for `dt` seconds.
    pub fn idle(&mut self, dt: f64) {
        self.spec.thermal.step(self.spec.idle_watts, dt);
        self.now += dt;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shader::ir::{EncoderIr, Op};
    use crate::shader::plan;

    fn mini_ir() -> EncoderIr {
        EncoderIr {
            name: "m".into(),
            input_channels: 9,
            ops: (0..3)
                .flat_map(|_| {
                    vec![Op::Conv { cout: 4, k: 3, stride: 2, same: true }, Op::Relu]
                })
                .collect(),
        }
    }

    fn toy_spec() -> DeviceSpec {
        DeviceSpec {
            name: "toy",
            cpu_cores: 4,
            gpu_samples_per_sec: 10e6,
            pass_overhead: 1e-4,
            upload_bytes_per_sec: 100e6,
            frame_overhead: 1e-3,
            cpu_macs_per_sec: 50e6,
            cpu_jitter: 0.08,
            gpu_jitter: 0.02,
            throttle_frac: 0.5,
            idle_watts: 0.4,
            dyn_watts: 2.0,
            power_cap: None,
            thermal: ThermalModel::new(25.0, 12.0, 60.0, 75.0, 65.0),
            ram_total_mb: 512.0,
            ram_baseline_mb: 80.0,
            cpu_framework_mb: 180.0,
        }
    }

    #[test]
    fn frame_cost_from_plan() {
        let p = plan(&mini_ir(), 84).unwrap();
        let c = FrameCost::from_plan(&p);
        assert_eq!(c.samples, p.total_samples());
        assert_eq!(c.macs, c.samples * 16);
        assert_eq!(c.upload_bytes, 84 * 84 * 4);
        assert_eq!(c.n_passes, 3);
    }

    #[test]
    fn gpu_time_scales_with_input_size() {
        let mut d = Device::new(toy_spec(), 1);
        let c100 = FrameCost::from_plan(&plan(&mini_ir(), 100).unwrap());
        let c400 = FrameCost::from_plan(&plan(&mini_ir(), 400).unwrap());
        let mut t100 = 0.0;
        let mut t400 = 0.0;
        for _ in 0..50 {
            t100 += d.encode_frame(&c100, ExecPath::Gpu).duration;
            t400 += d.encode_frame(&c400, ExecPath::Gpu).duration;
        }
        // 16x pixels => roughly an order of magnitude slower
        assert!(t400 / t100 > 6.0, "ratio {}", t400 / t100);
    }

    #[test]
    fn cpu_path_slower_and_jitterier_than_gpu() {
        let mut d = Device::new(toy_spec(), 2);
        let c = FrameCost::from_plan(&plan(&mini_ir(), 400).unwrap());
        let mut gpu = crate::util::stats::Running::new();
        let mut cpu = crate::util::stats::Running::new();
        for _ in 0..300 {
            gpu.push(d.encode_frame(&c, ExecPath::Gpu).duration);
            cpu.push(d.encode_frame(&c, ExecPath::Cpu).duration);
        }
        assert!(cpu.mean() > 1.5 * gpu.mean(), "cpu {} vs gpu {}", cpu.mean(), gpu.mean());
        assert!(
            cpu.std() / cpu.mean() > gpu.std() / gpu.mean(),
            "cpu cv {} vs gpu cv {}",
            cpu.std() / cpu.mean(),
            gpu.std() / gpu.mean()
        );
        // CPU path carries the framework RSS
        let ram_cpu = d.encode_frame(&c, ExecPath::Cpu).ram_mb;
        let ram_gpu = d.encode_frame(&c, ExecPath::Gpu).ram_mb;
        assert!(ram_cpu > ram_gpu + 100.0);
    }

    #[test]
    fn sustained_load_throttles_and_slows() {
        let mut spec = toy_spec();
        spec.dyn_watts = 6.0; // hot part
        let mut d = Device::new(spec, 3);
        let c = FrameCost::from_plan(&plan(&mini_ir(), 800).unwrap());
        let first = d.encode_frame(&c, ExecPath::Gpu);
        let mut last = first;
        for _ in 0..4000 {
            last = d.encode_frame(&c, ExecPath::Gpu);
            if last.clock_frac < 1.0 {
                break;
            }
        }
        assert!(last.clock_frac < 1.0, "never throttled (T={})", d.temp());
        assert!(last.duration > 1.5 * first.duration);
    }

    #[test]
    fn power_cap_limits_clock_and_power() {
        let mut spec = toy_spec();
        spec.power_cap = Some(1.4); // 0.4 idle + 1.0 budget of 2.0 => frac ~0.707
        let mut d = Device::new(spec, 4);
        let c = FrameCost::from_plan(&plan(&mini_ir(), 400).unwrap());
        let s = d.encode_frame(&c, ExecPath::Gpu);
        assert!((s.clock_frac - 0.7071).abs() < 0.01, "{}", s.clock_frac);
        assert!(s.watts <= 1.45);
    }

    #[test]
    fn idle_cools() {
        let mut spec = toy_spec();
        spec.dyn_watts = 6.0;
        let mut d = Device::new(spec, 5);
        let c = FrameCost::from_plan(&plan(&mini_ir(), 800).unwrap());
        for _ in 0..500 {
            d.encode_frame(&c, ExecPath::Gpu);
        }
        let hot = d.temp();
        d.idle(600.0);
        assert!(d.temp() < hot - 10.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let c = FrameCost::from_plan(&plan(&mini_ir(), 200).unwrap());
        let mut a = Device::new(toy_spec(), 7);
        let mut b = Device::new(toy_spec(), 7);
        for _ in 0..20 {
            assert_eq!(
                a.encode_frame(&c, ExecPath::Gpu).duration,
                b.encode_frame(&c, ExecPath::Gpu).duration
            );
        }
    }
}

//! Calibrated device models for the paper's three-board testbed.
//!
//! Calibration targets (the paper's quantitative anchors, §4.2):
//!   * Pi Zero 2 W, MiniConv-4 @ X=400 on GL: j ≈ 0.1 s/frame, giving the
//!     paper's ≈50.4 Mb/s break-even bandwidth;
//!   * Pi Zero 2 W needs X ≲ 500 for ~5 fps;
//!   * Jetson Nano is substantially faster across the range (Fig. 2c) but
//!     shows a marked per-frame time increase after an initial period of
//!     sustained 3000² inference; the 5 W power mode changes that behaviour
//!     (slower from the start, thermally stable) — Fig. 3a / 4b;
//!   * CPU (PyTorch) execution on the Pi Zero is slower and less stable
//!     than GL (Fig. 3b), and costs the framework's RSS (512 MB budget).

use super::model::DeviceSpec;
use super::thermal::ThermalModel;

/// Raspberry Pi Zero 2 W (quad-A53, VideoCore IV GL ES).
pub fn pi_zero_2w() -> DeviceSpec {
    DeviceSpec {
        name: "pi-zero-2w",
        cpu_cores: 4, // quad-A53
        gpu_samples_per_sec: 12.0e6,
        pass_overhead: 0.3e-3,
        upload_bytes_per_sec: 250e6,
        frame_overhead: 1.5e-3,
        cpu_macs_per_sec: 80e6,
        cpu_jitter: 0.10,
        gpu_jitter: 0.025,
        throttle_frac: 0.6,
        idle_watts: 0.6,
        dyn_watts: 1.6,
        power_cap: None,
        thermal: ThermalModel::new(25.0, 18.0, 120.0, 80.0, 70.0),
        ram_total_mb: 512.0,
        ram_baseline_mb: 118.0,
        cpu_framework_mb: 185.0,
    }
}

/// Raspberry Pi 4B (quad-A72, VideoCore VI).
pub fn pi_4b() -> DeviceSpec {
    DeviceSpec {
        name: "pi-4b",
        cpu_cores: 4, // quad-A72
        gpu_samples_per_sec: 55.0e6,
        pass_overhead: 0.2e-3,
        upload_bytes_per_sec: 800e6,
        frame_overhead: 1.0e-3,
        cpu_macs_per_sec: 450e6,
        cpu_jitter: 0.07,
        gpu_jitter: 0.02,
        throttle_frac: 0.7,
        idle_watts: 2.4,
        dyn_watts: 3.4,
        power_cap: None,
        thermal: ThermalModel::new(25.0, 8.0, 90.0, 80.0, 72.0),
        ram_total_mb: 2048.0,
        ram_baseline_mb: 280.0,
        cpu_framework_mb: 210.0,
    }
}

/// NVIDIA Jetson Nano (128-core Maxwell). `power_cap_watts` = Some(5.0)
/// models the 5 W nvpmodel mode; None is the unconstrained (MAXN) mode.
pub fn jetson_nano(power_cap_watts: Option<f64>) -> DeviceSpec {
    DeviceSpec {
        name: "jetson-nano",
        cpu_cores: 4, // quad-A57
        gpu_samples_per_sec: 300.0e6,
        pass_overhead: 0.15e-3,
        upload_bytes_per_sec: 2.0e9,
        frame_overhead: 0.8e-3,
        cpu_macs_per_sec: 1.5e9,
        cpu_jitter: 0.05,
        gpu_jitter: 0.02,
        throttle_frac: 0.55,
        idle_watts: 1.5,
        dyn_watts: 8.0,
        power_cap: power_cap_watts,
        thermal: ThermalModel::new(25.0, 6.0, 90.0, 70.0, 64.0),
        ram_total_mb: 4096.0,
        ram_baseline_mb: 620.0,
        cpu_framework_mb: 480.0,
    }
}

/// All Figure-2 devices in paper order.
pub fn all() -> Vec<DeviceSpec> {
    vec![pi_zero_2w(), pi_4b(), jetson_nano(None)]
}

pub fn by_name(name: &str) -> anyhow::Result<DeviceSpec> {
    match name {
        "pi-zero-2w" => Ok(pi_zero_2w()),
        "pi-4b" => Ok(pi_4b()),
        "jetson-nano" => Ok(jetson_nano(None)),
        "jetson-nano-5w" => Ok(jetson_nano(Some(5.0))),
        other => anyhow::bail!("unknown device {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::model::{Device, ExecPath, FrameCost};
    use crate::shader::ir::{EncoderIr, Op};
    use crate::shader::plan;

    fn miniconv4_cost(x: usize) -> FrameCost {
        let ir = EncoderIr {
            name: "m".into(),
            input_channels: 9,
            ops: (0..3)
                .flat_map(|_| {
                    vec![Op::Conv { cout: 4, k: 3, stride: 2, same: true }, Op::Relu]
                })
                .collect(),
        };
        FrameCost::from_plan(&plan(&ir, x).unwrap())
    }

    fn mean_frame(spec: DeviceSpec, x: usize, path: ExecPath, n: usize) -> f64 {
        let mut d = Device::new(spec, 42);
        let c = miniconv4_cost(x);
        (0..n).map(|_| d.encode_frame(&c, path).duration).sum::<f64>() / n as f64
    }

    #[test]
    fn pizero_j_near_100ms_at_x400() {
        // the paper's break-even anchor: j ~ 0.1s at X=400 (K=4, n=3)
        let j = mean_frame(pi_zero_2w(), 400, ExecPath::Gpu, 100);
        assert!((0.08..0.13).contains(&j), "j={j}");
    }

    #[test]
    fn pizero_5fps_bound_near_x500() {
        let t450 = mean_frame(pi_zero_2w(), 450, ExecPath::Gpu, 50);
        let t650 = mean_frame(pi_zero_2w(), 650, ExecPath::Gpu, 50);
        assert!(t450 < 0.2, "t450={t450}");
        assert!(t650 > 0.2, "t650={t650}");
    }

    #[test]
    fn device_ordering_matches_fig2() {
        // jetson << pi4 << pi zero at every size
        for x in [100usize, 400, 1000] {
            let z = mean_frame(pi_zero_2w(), x, ExecPath::Gpu, 30);
            let p4 = mean_frame(pi_4b(), x, ExecPath::Gpu, 30);
            let j = mean_frame(jetson_nano(None), x, ExecPath::Gpu, 30);
            assert!(j < p4 && p4 < z, "x={x}: jetson {j}, pi4 {p4}, zero {z}");
        }
    }

    #[test]
    fn jetson_throttles_under_sustained_3000sq() {
        let mut d = Device::new(jetson_nano(None), 1);
        let c = miniconv4_cost(3000);
        let first = d.encode_frame(&c, ExecPath::Gpu).duration;
        let mut throttled_at = None;
        for i in 0..5000 {
            let s = d.encode_frame(&c, ExecPath::Gpu);
            if s.clock_frac < 1.0 {
                throttled_at = Some((i, s.duration));
                break;
            }
        }
        let (i, dur) = throttled_at.expect("jetson never throttled in 5000 frames");
        assert!(i > 50, "throttled immediately (frame {i})");
        assert!(dur > 1.4 * first, "throttle not visible in frame time");
    }

    #[test]
    fn jetson_5w_cap_is_slower_but_stable() {
        let mut capped = Device::new(jetson_nano(Some(5.0)), 2);
        let mut free = Device::new(jetson_nano(None), 2);
        let c = miniconv4_cost(3000);
        let t_capped_first = capped.encode_frame(&c, ExecPath::Gpu).duration;
        let t_free_first = free.encode_frame(&c, ExecPath::Gpu).duration;
        assert!(
            t_capped_first > 1.3 * t_free_first,
            "cap not slower from the start: {t_capped_first} vs {t_free_first}"
        );
        // capped mode never trips thermal throttle over the full run
        for _ in 0..5000 {
            let s = capped.encode_frame(&c, ExecPath::Gpu);
            assert!(s.watts <= 5.05, "cap exceeded: {}", s.watts);
            assert!(!capped.spec.thermal.throttled(), "capped run throttled");
        }
    }

    #[test]
    fn pizero_cpu_ram_fits_in_512_but_tight() {
        let mut d = Device::new(pi_zero_2w(), 3);
        let c = miniconv4_cost(400);
        let gpu = d.encode_frame(&c, ExecPath::Gpu);
        let cpu = d.encode_frame(&c, ExecPath::Cpu);
        assert!(gpu.ram_mb < cpu.ram_mb);
        assert!(cpu.ram_mb < 512.0, "cpu path OOM: {}", cpu.ram_mb);
        assert!(cpu.ram_mb > 250.0, "cpu framework RSS unrealistically low");
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["pi-zero-2w", "pi-4b", "jetson-nano", "jetson-nano-5w"] {
            assert!(by_name(n).is_ok());
        }
        assert!(by_name("gpu9000").is_err());
        assert_eq!(all().len(), 3);
    }
}

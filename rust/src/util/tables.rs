//! Markdown table / CSV emitters used by every bench harness to print the
//! paper's tables and figure series in a uniform, diffable format.

#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print the markdown form to stdout (bench harness convention).
    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }
}

/// Format a float with a sensible number of digits for table cells.
pub fn fmt_f(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 100.0 {
        format!("{x:.0}")
    } else if x.abs() >= 1.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

/// Format nanoseconds as a human latency (ms with 1 decimal unless tiny).
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e6 {
        format!("{:.1}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.1}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_layout() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a | bb |"));
        assert!(md.contains("| 1 | 2  |"));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn row_width_checked() {
        Table::new("", &["a", "b"]).row(&["1".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["x"]);
        t.row(&["a,b\"c".into()]);
        assert_eq!(t.to_csv(), "x\n\"a,b\"\"c\"\n");
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_f(0.0), "0");
        assert_eq!(fmt_f(1234.56), "1235");
        assert_eq!(fmt_f(12.34), "12.3");
        assert_eq!(fmt_f(0.1234), "0.123");
        assert_eq!(fmt_ns(2_500_000.0), "2.5ms");
        assert_eq!(fmt_ns(2500.0), "2.5us");
        assert_eq!(fmt_ns(250.0), "250ns");
    }
}

//! Property-testing mini-framework (the proptest crate is unavailable
//! offline — DESIGN.md §1). Provides seeded random case generation with
//! greedy input shrinking for integer-vector-shaped cases.
//!
//! Usage:
//! ```ignore
//! check(200, |g| {
//!     let n = g.usize(1, 64);
//!     let xs = g.vec_f64(n, -10.0, 10.0);
//!     prop_assert(invariant(&xs), format!("failed for {xs:?}"));
//! });
//! ```

use super::rng::Rng;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// trace of drawn scalars, used for reporting
    pub trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), trace: Vec::new() }
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.below(hi - lo + 1);
        self.trace.push(format!("usize({v})"));
        v
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let v = lo + self.rng.next_u64() % (hi - lo + 1);
        self.trace.push(format!("u64({v})"));
        v
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range(lo, hi);
        self.trace.push(format!("f64({v:.4})"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.uniform() < 0.5;
        self.trace.push(format!("bool({v})"));
        v
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        let i = self.rng.below(items.len());
        self.trace.push(format!("choice(#{i})"));
        &items[i]
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.range(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| lo + self.rng.below(hi - lo + 1)).collect()
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Outcome of one property case.
pub struct PropFailure {
    pub seed: u64,
    pub case: usize,
    pub message: String,
    pub trace: Vec<String>,
}

/// Run `cases` random cases of `prop`. Panics with a reproducible seed on
/// the first failure. The property signals failure via `Err(message)`.
pub fn check<F>(cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    check_seeded(0xC0FFEE, cases, prop)
}

pub fn check_seeded<F>(seed: u64, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let mut meta = Rng::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut g = Gen::new(case_seed);
        if let Err(message) = prop(&mut g) {
            panic!(
                "property failed (case {case}/{cases}, reproduce with seed {case_seed:#x}):\n  \
                 {message}\n  draws: [{}]",
                g.trace.join(", ")
            );
        }
    }
}

/// Assert helper for use inside properties.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two floats are within tolerance.
pub fn prop_close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check(50, |g| {
            counter.set(counter.get() + 1);
            let n = g.usize(0, 10);
            prop_assert(n <= 10, "bound")
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(100, |g| {
            let n = g.usize(0, 100);
            prop_assert(n < 95, format!("n={n}"))
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let out = std::cell::RefCell::new(Vec::new());
            check_seeded(seed, 5, |g| {
                out.borrow_mut().push(g.u64(0, 1000));
                Ok(())
            });
            out.into_inner()
        };
        assert_eq!(collect(1), collect(1));
        assert_ne!(collect(1), collect(2));
    }

    #[test]
    fn prop_close_tolerance() {
        assert!(prop_close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(prop_close(1.0, 1.1, 1e-6).is_err());
    }
}

//! Streaming statistics: running mean/std, exact quantiles over bounded
//! samples, and an HDR-style latency histogram for the serving metrics
//! (p50/p95/p99 decision latency, Table 5 / Table 6).

/// Running mean / variance (Welford). O(1) memory.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact-quantile reservoir for moderate sample counts (we keep every
/// sample; experiments record at most a few hundred thousand points).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Samples { xs: Vec::new(), sorted: true }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Quantile by linear interpolation; q in `[0,1]`.
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!(!self.xs.is_empty(), "quantile of empty sample set");
        self.ensure_sorted();
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Log-bucketed latency histogram: thread-cheap recording with bounded
/// memory, ~2% relative error per bucket. Units are nanoseconds.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    /// `buckets[i]` counts values in `[lo_i, lo_i * GROWTH)`
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
}

const HIST_BUCKETS: usize = 640;
const HIST_MIN_NS: f64 = 100.0; // 100ns floor
const HIST_GROWTH: f64 = 1.04;

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist { buckets: vec![0; HIST_BUCKETS], count: 0, sum: 0.0 }
    }

    fn index(ns: f64) -> usize {
        if ns <= HIST_MIN_NS {
            return 0;
        }
        let i = (ns / HIST_MIN_NS).ln() / HIST_GROWTH.ln();
        (i as usize).min(HIST_BUCKETS - 1)
    }

    fn bucket_value(i: usize) -> f64 {
        HIST_MIN_NS * HIST_GROWTH.powi(i as i32) * (1.0 + HIST_GROWTH) / 2.0
    }

    pub fn record_ns(&mut self, ns: f64) {
        self.buckets[Self::index(ns)] += 1;
        self.count += 1;
        self.sum += ns;
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as f64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        // Degenerate q must never poison the result: ±inf and any finite
        // value outside [0,1] clamp to the endpoints, NaN reads as the
        // median. The return value is always a finite bucket midpoint.
        let q = if q.is_nan() { 0.5 } else { q.clamp(0.0, 1.0) };
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::bucket_value(i);
            }
        }
        Self::bucket_value(HIST_BUCKETS - 1)
    }

    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// The histogram of only the events recorded since `prev` was cloned
    /// off this recorder: saturating bucket-wise subtraction, so quantiles
    /// of the result describe the observation *window* rather than the
    /// process lifetime. `count` is recomputed from the subtracted buckets
    /// (and `sum` floored at zero), so a `prev` that is not actually an
    /// earlier snapshot of `self` still yields a self-consistent — if
    /// meaningless — histogram instead of underflowing.
    pub fn delta(&self, prev: &LatencyHist) -> LatencyHist {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .zip(&prev.buckets)
            .map(|(a, b)| a.saturating_sub(*b))
            .collect();
        let count = buckets.iter().sum();
        LatencyHist { buckets, count, sum: (self.sum - prev.sum).max(0.0) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((r.var() - var).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 10.0);
        assert_eq!(r.count(), 5);
    }

    #[test]
    fn quantiles_exact_on_small_sets() {
        let mut s = Samples::new();
        for x in [5.0, 1.0, 3.0, 2.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(1.0), 5.0);
        assert!((s.quantile(0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn p95_of_uniform_sequence() {
        let mut s = Samples::new();
        for i in 0..1000 {
            s.push(i as f64);
        }
        assert!((s.p95() - 949.05).abs() < 0.5);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn quantile_empty_panics() {
        Samples::new().quantile(0.5);
    }

    #[test]
    fn hist_quantile_relative_error_bounded() {
        let mut h = LatencyHist::new();
        // fill with a known distribution: 1..=10ms uniformly
        for i in 1..=10_000u64 {
            h.record_ns((i as f64) * 1_000.0); // 1us .. 10ms
        }
        let p50 = h.quantile_ns(0.5);
        let expect = 5_000_000.0 * 0.001; // 5000us -> ns = 5_000_000
        let got = p50;
        let rel = (got - 5_000_000.0f64).abs() / 5_000_000.0;
        assert!(rel < 0.05, "p50={got} rel={rel} (expect near {expect})");
        assert_eq!(h.count(), 10_000);
    }

    #[test]
    fn hist_merge() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        a.record_ns(1e6);
        b.record_ns(2e6);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn hist_mean() {
        let mut h = LatencyHist::new();
        h.record_ns(1000.0);
        h.record_ns(3000.0);
        assert!((h.mean_ns() - 2000.0).abs() < 1e-9);
    }

    /// `delta` must describe only the window between two snapshots: a slow
    /// event before the snapshot cannot leak into the window's quantiles.
    #[test]
    fn hist_delta_isolates_the_observation_window() {
        let mut h = LatencyHist::new();
        h.record_ns(500e6); // historical 500 ms outlier
        let snap = h.clone();
        for _ in 0..100 {
            h.record_ns(1e6); // the window: all 1 ms
        }
        let w = h.delta(&snap);
        assert_eq!(w.count(), 100);
        let p95 = w.quantile_ns(0.95);
        assert!(p95 < 2e6, "window p95 {p95} still sees the pre-window outlier");
        // the lifetime histogram, by contrast, keeps the outlier at p100
        assert!(h.quantile_ns(1.0) > 400e6);
        // delta against an equal snapshot is empty
        let empty = h.delta(&h);
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.quantile_ns(0.95), 0.0);
    }

    /// A `prev` that is not an earlier snapshot must saturate, not
    /// underflow: counts recompute from the subtracted buckets.
    #[test]
    fn hist_delta_saturates_on_non_prefix_prev() {
        let mut a = LatencyHist::new();
        a.record_ns(1e6);
        let mut b = LatencyHist::new();
        b.record_ns(1e6);
        b.record_ns(1e6);
        b.record_ns(9e6);
        let d = a.delta(&b);
        assert_eq!(d.count(), 0);
        assert_eq!(d.mean_ns(), 0.0);
        assert!(d.sum >= 0.0);
    }

    /// Property: quantile_ns stays finite and within the bucket range for
    /// every q, including NaN, ±inf, and values far outside [0,1].
    #[test]
    fn hist_quantile_finite_for_degenerate_q() {
        use crate::util::proptest::{check, prop_assert};
        check(300, |g| {
            let mut h = LatencyHist::new();
            for _ in 0..g.usize(1, 50) {
                h.record_ns(g.f64(0.0, 1e9));
            }
            let q = match g.usize(0, 5) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => f64::NEG_INFINITY,
                3 => g.f64(-100.0, 0.0),
                4 => g.f64(1.0, 100.0),
                _ => g.f64(0.0, 1.0),
            };
            let v = h.quantile_ns(q);
            prop_assert(v.is_finite(), format!("quantile_ns({q}) = {v} not finite"))?;
            prop_assert(v >= 0.0, format!("quantile_ns({q}) = {v} negative"))?;
            prop_assert(
                v <= LatencyHist::bucket_value(HIST_BUCKETS - 1),
                format!("quantile_ns({q}) = {v} above the top bucket"),
            )?;
            // clamping puts every out-of-range q at an endpoint
            if q > 1.0 {
                prop_assert(v == h.quantile_ns(1.0), "q>1 must clamp to q=1")?;
            }
            if q < 0.0 {
                prop_assert(v == h.quantile_ns(0.0), "q<0 must clamp to q=0")?;
            }
            Ok(())
        });
    }
}

//! Deterministic PRNG (xoshiro256**) + distributions.
//!
//! The `rand` crate is unavailable offline; experiments need reproducible
//! seeds anyway (the paper reports single fixed-seed runs), so we implement
//! a small, well-known generator with exactly the distributions the stack
//! uses: uniform, standard normal (Box–Muller), and integer ranges.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed, as recommended by the xoshiro authors
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free mapping is fine for our n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.normal_f32();
        }
    }

    /// Fisher–Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            idx.swap(i, j);
        }
        idx
    }

    /// Derive an independent stream (for per-thread/per-client rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(10);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}

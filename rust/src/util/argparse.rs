//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text. Each binary declares its options up front so
//! `--help` is accurate.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    pub program: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
    specs: Vec<OptSpec>,
}

#[derive(Debug)]
pub enum ArgError {
    Unknown(String),
    MissingValue(String),
    Invalid(String, String),
    Help,
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgError::Unknown(name) => write!(f, "unknown option --{name}"),
            ArgError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            ArgError::Invalid(name, value) => write!(f, "invalid value for --{name}: {value}"),
            ArgError::Help => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for ArgError {}

pub struct Parser {
    about: &'static str,
    specs: Vec<OptSpec>,
}

impl Parser {
    pub fn new(about: &'static str) -> Self {
        Parser { about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self, program: &str) -> String {
        let mut s = format!("{}\n\nUsage: {} [options]\n\nOptions:\n", self.about, program);
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <v>", spec.name)
            };
            let def = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:<26} {}{def}\n", spec.help));
        }
        s.push_str("  --help                   show this help\n");
        s
    }

    /// Parse from an iterator (first element = program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, it: I) -> Result<Args, ArgError> {
        let mut it = it.into_iter();
        let program = it.next().unwrap_or_else(|| "prog".into());
        let mut args = Args {
            program,
            specs: self.specs.clone(),
            ..Default::default()
        };
        let known = |n: &str| self.specs.iter().find(|s| s.name == n);
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(ArgError::Help);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = known(&name).ok_or_else(|| ArgError::Unknown(name.clone()))?;
                if spec.is_flag {
                    args.flags.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| ArgError::MissingValue(name.clone()))?,
                    };
                    args.values.insert(name, v);
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse std::env::args(); print usage and exit on --help or error.
    pub fn parse(&self) -> Args {
        match self.parse_from(std::env::args()) {
            Ok(a) => a,
            Err(ArgError::Help) => {
                let prog = std::env::args().next().unwrap_or_else(|| "prog".into());
                println!("{}", self.usage(&prog));
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("error: {e}");
                let prog = std::env::args().next().unwrap_or_else(|| "prog".into());
                eprintln!("{}", self.usage(&prog));
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    fn default_for(&self, name: &str) -> Option<&'static str> {
        self.specs.iter().find(|s| s.name == name).and_then(|s| s.default)
    }

    pub fn get(&self, name: &str) -> Option<String> {
        self.values
            .get(name)
            .cloned()
            .or_else(|| self.default_for(name).map(|s| s.to_string()))
    }

    pub fn str(&self, name: &str) -> String {
        self.get(name)
            .unwrap_or_else(|| panic!("missing required option --{name}"))
    }

    pub fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<T, ArgError> {
        let v = self
            .get(name)
            .ok_or_else(|| ArgError::MissingValue(name.into()))?;
        v.parse::<T>()
            .map_err(|_| ArgError::Invalid(name.into(), v))
    }

    pub fn usize(&self, name: &str) -> usize {
        self.parse_as(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.parse_as(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.parse_as(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parser() -> Parser {
        Parser::new("test")
            .opt("task", "pendulum", "task name")
            .opt("episodes", "10", "episode count")
            .opt_req("addr", "server address")
            .flag("verbose", "chatty")
    }

    fn parse(words: &[&str]) -> Result<Args, ArgError> {
        let mut v = vec!["prog".to_string()];
        v.extend(words.iter().map(|s| s.to_string()));
        parser().parse_from(v)
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["--addr", "x"]).unwrap();
        assert_eq!(a.str("task"), "pendulum");
        assert_eq!(a.usize("episodes"), 10);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn overrides_and_flags() {
        let a = parse(&["--task", "walker", "--verbose", "--episodes=25", "--addr", "y"]).unwrap();
        assert_eq!(a.str("task"), "walker");
        assert_eq!(a.usize("episodes"), 25);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn positional_collected() {
        let a = parse(&["--addr", "x", "pos1", "pos2"]).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn unknown_rejected() {
        assert!(matches!(parse(&["--nope"]), Err(ArgError::Unknown(_))));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(parse(&["--task"]), Err(ArgError::MissingValue(_))));
    }

    #[test]
    fn help_flag() {
        assert!(matches!(parse(&["--help"]), Err(ArgError::Help)));
    }

    #[test]
    fn bad_number_reports() {
        let a = parse(&["--episodes", "abc", "--addr", "x"]).unwrap();
        assert!(a.parse_as::<usize>("episodes").is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = parser().usage("prog");
        assert!(u.contains("--task"));
        assert!(u.contains("default: pendulum"));
    }
}

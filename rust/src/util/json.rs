//! Minimal JSON parser + writer.
//!
//! serde is not available in the offline build environment (DESIGN.md §1),
//! so the artifact manifest and config files are handled by this hand-rolled
//! implementation. It supports the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, bools, null) and preserves object key
//! order via an association list, which keeps manifest round-trips stable.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing key {key:?}"),
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Array of usize, or None if any element is not a non-negative number.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer ---------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(d + 1));
                        v.write(out, Some(d + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let (Some(d), false) = (indent, a.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(d));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(d + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(d + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let (Some(d), false) = (indent, o.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(d));
                }
                out.push('}');
            }
        }
    }

    // ---- builders -------------------------------------------------------

    pub fn obj(kv: Vec<(&str, Json)>) -> Json {
        Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number {s:?}")))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: only BMP escapes appear in our
                            // manifests; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

/// Convenience: parse a JSON object into a string->Json map (drops order).
pub fn to_map(j: &Json) -> BTreeMap<String, Json> {
    match j {
        Json::Obj(kv) => kv.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"enc_k4","shape":[1,4,11,11],"ok":true,"x":-3.5}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(j.as_usize_vec(), Some(vec![1, 2, 3]));
        assert_eq!(Json::parse("[1, -2]").unwrap().as_usize_vec(), None);
    }

    #[test]
    fn preserves_key_order() {
        let j = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn req_reports_key() {
        let j = Json::parse("{}").unwrap();
        let e = j.req("missing").unwrap_err();
        assert!(e.to_string().contains("missing"));
    }
}

//! Cross-cutting substrates: JSON, CLI parsing, RNG, statistics, simulated
//! time, table emitters, and a property-testing mini-framework.
//!
//! These exist because the offline build environment provides no serde /
//! clap / rand / criterion / proptest (DESIGN.md §1); each is small, tested,
//! and purpose-built for this stack.

pub mod alloc_counter;
pub mod argparse;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod signal;
pub mod simclock;
pub mod stats;
pub mod tables;

/// Read a little-endian f32 binary file (the aot.py parameter format).
pub fn read_f32_bin(path: &std::path::Path) -> anyhow::Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "{}: length {} not a multiple of 4",
        path.display(),
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a little-endian f32 binary file.
pub fn write_f32_bin(path: &std::path::Path, data: &[f32]) -> anyhow::Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bin_roundtrip() {
        let dir = std::env::temp_dir().join("miniconv_util_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let data = vec![1.0f32, -2.5, 0.0, f32::MAX];
        write_f32_bin(&p, &data).unwrap();
        assert_eq!(read_f32_bin(&p).unwrap(), data);
    }

    #[test]
    fn f32_bin_rejects_bad_length() {
        let dir = std::env::temp_dir().join("miniconv_util_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [0u8; 5]).unwrap();
        assert!(read_f32_bin(&p).is_err());
    }
}

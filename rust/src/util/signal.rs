//! Edge-triggered readiness signal: an epoch counter under a condvar.
//!
//! The fleet's e2e tests used to discover state changes (a shard marked
//! Down, a drain completing, a rejection counted) by polling shared state
//! in a `sleep` loop — the classic source of timing flake. A [`Signal`] is
//! notified by whoever mutates the state; waiters re-evaluate a predicate
//! only when something actually changed (or on timeout), so convergence is
//! observed the instant it happens with no sleep granularity in the path.
//!
//! Locking contract: `notify()` only locks the signal's own epoch mutex,
//! and `wait_until` never holds that mutex while running the predicate —
//! so predicates may freely lock foreign state (a topology, a stats map)
//! without lock-ordering hazards.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A monotone epoch counter + condvar. Cheap to share behind an `Arc`.
#[derive(Debug, Default)]
pub struct Signal {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl Signal {
    pub fn new() -> Signal {
        Signal::default()
    }

    /// Announce that observable state changed. Call *after* releasing any
    /// state locks the change touched.
    pub fn notify(&self) {
        *self.epoch.lock().unwrap() += 1;
        self.cv.notify_all();
    }

    /// Current epoch (mostly useful for tests and diagnostics).
    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap()
    }

    /// Block until `pred()` holds or `timeout` elapses. The predicate is
    /// re-evaluated after every notification (and once at the deadline);
    /// returns the predicate's final verdict.
    pub fn wait_until<F: FnMut() -> bool>(&self, timeout: Duration, mut pred: F) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            let seen = *self.epoch.lock().unwrap();
            if pred() {
                return true;
            }
            let mut g = self.epoch.lock().unwrap();
            loop {
                if *g != seen {
                    break; // something changed while the predicate ran
                }
                let now = Instant::now();
                if now >= deadline {
                    drop(g);
                    return pred();
                }
                let (ng, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
                g = ng;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn immediate_predicate_returns_without_waiting() {
        let s = Signal::new();
        let t0 = Instant::now();
        assert!(s.wait_until(Duration::from_secs(5), || true));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn timeout_returns_false_when_predicate_never_holds() {
        let s = Signal::new();
        assert!(!s.wait_until(Duration::from_millis(30), || false));
    }

    #[test]
    fn waiter_wakes_on_notify() {
        let s = Arc::new(Signal::new());
        let v = Arc::new(AtomicU64::new(0));
        let (ts, tv) = (s.clone(), v.clone());
        let t = std::thread::spawn(move || {
            tv.store(7, Ordering::SeqCst);
            ts.notify();
        });
        assert!(s.wait_until(Duration::from_secs(5), || v.load(Ordering::SeqCst) == 7));
        t.join().unwrap();
    }

    #[test]
    fn epoch_counts_notifications() {
        let s = Signal::new();
        assert_eq!(s.epoch(), 0);
        s.notify();
        s.notify();
        assert_eq!(s.epoch(), 2);
    }

    #[test]
    fn change_during_predicate_is_not_missed() {
        // pred false -> state changes + notify before the waiter re-locks:
        // the epoch comparison must catch it rather than sleeping the full
        // timeout. We can't force the interleaving, but we can at least
        // assert the waiter converges fast with a racing notifier.
        let s = Arc::new(Signal::new());
        let v = Arc::new(AtomicU64::new(0));
        let (ts, tv) = (s.clone(), v.clone());
        let t = std::thread::spawn(move || {
            for i in 1..=100u64 {
                tv.store(i, Ordering::SeqCst);
                ts.notify();
            }
        });
        assert!(s.wait_until(Duration::from_secs(5), || v.load(Ordering::SeqCst) >= 100));
        t.join().unwrap();
    }
}

//! Counting allocator shared by the zero-allocation gates
//! (`rust/tests/compiled_alloc.rs`, `benches/micro_hotpath.rs`).
//!
//! Each gate binary declares its own `#[global_allocator] static G:
//! CountingAlloc = CountingAlloc;` and reads [`CountingAlloc::count`]
//! around the measured window. All allocating entry points are counted —
//! including `alloc_zeroed`, which `vec![0; n]` reaches without going
//! through `alloc` — so a hot path cannot escape the gate via the zeroed
//! fast path. Deallocations are deliberately not counted: the gates care
//! about heap traffic initiated per frame, and frees of warmup buffers
//! would only add noise.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

pub struct CountingAlloc;

impl CountingAlloc {
    /// Total allocations observed so far (monotonic).
    pub fn count() -> u64 {
        ALLOCS.load(Ordering::SeqCst)
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
}

//! Simulated time. The device simulators (thermal models, DVFS) and the
//! deterministic network model advance a virtual clock instead of sleeping,
//! which makes the 5,000-frame sustained-load experiments (Fig. 3/4)
//! reproducible and fast regardless of host speed.
//!
//! Two sim-time types coexist deliberately: this module's [`SimClock`] is
//! the *plain f64-seconds counter* the device/experiment layer advances
//! by hand, while `crate::sim::clock::SimClock` is the *`Instant`-minting
//! shared clock* behind the `sim::Clock` seam (injectable wherever
//! production code expects wall-clock instants). New time-seam work
//! should use `sim::clock`; the [`EventQueue`] here is shared by both
//! (re-exported from `sim::clock`).

/// A monotonically-advancing virtual clock, in seconds.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0, "time cannot go backwards (dt={dt})");
        self.now += dt;
    }

    pub fn advance_to(&mut self, t: f64) {
        assert!(t >= self.now, "advance_to into the past ({t} < {})", self.now);
        self.now = t;
    }
}

/// A simple event queue over simulated time, used by the sim-time network
/// link to model in-flight packets.
#[derive(Debug)]
pub struct EventQueue<T> {
    // (time, seq, payload); seq breaks ties FIFO
    heap: std::collections::BinaryHeap<Entry<T>>,
    seq: u64,
}

#[derive(Debug)]
struct Entry<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap: reverse on (time, seq)
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then(other.seq.cmp(&self.seq))
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: std::collections::BinaryHeap::new(), seq: 0 }
    }

    pub fn push(&mut self, time: f64, payload: T) {
        self.heap.push(Entry { time, seq: self.seq, payload });
        self.seq += 1;
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Time and payload of the next event without popping it.
    pub fn peek(&self) -> Option<(f64, &T)> {
        self.heap.peek().map(|e| (e.time, &e.payload))
    }

    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances() {
        let mut c = SimClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
        c.advance_to(3.0);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn clock_rejects_negative() {
        SimClock::new().advance(-1.0);
    }

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop().unwrap().1, "a");
        assert_eq!(q.pop().unwrap().1, "b");
        assert_eq!(q.pop().unwrap().1, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn events_fifo_on_tie() {
        let mut q = EventQueue::new();
        q.push(1.0, 1);
        q.push(1.0, 2);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert!(q.peek_time().is_none());
        q.push(5.0, ());
        assert_eq!(q.peek_time(), Some(5.0));
        assert_eq!(q.len(), 1);
    }
}

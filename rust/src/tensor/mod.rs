//! Minimal CHW tensor types shared by the environments (frames), the shader
//! interpreter (textures), and validation code (reference convolution).
//!
//! This is intentionally not a general ndarray: fixed layouts (CHW for
//! float planes, HWC-interleaved u8 for rendered frames) keep the hot-path
//! conversions explicit and allocation-free where it matters.

/// A C,H,W float32 tensor (channel-major planes).
#[derive(Debug, Clone, PartialEq)]
pub struct Chw {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Chw {
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        Chw { c, h, w, data: vec![0.0; c * h * w] }
    }

    pub fn from_vec(c: usize, h: usize, w: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), c * h * w, "shape/data mismatch");
        Chw { c, h, w, data }
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        debug_assert!(c < self.c && y < self.h && x < self.w);
        self.data[(c * self.h + y) * self.w + x]
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: f32) {
        debug_assert!(c < self.c && y < self.h && x < self.w);
        self.data[(c * self.h + y) * self.w + x] = v;
    }

    /// Zero-padded read (used by 'same' convolution).
    #[inline]
    pub fn at_padded(&self, c: usize, y: isize, x: isize) -> f32 {
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0.0
        } else {
            self.at(c, y as usize, x as usize)
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn max_abs_diff(&self, other: &Chw) -> f32 {
        assert_eq!((self.c, self.h, self.w), (other.c, other.h, other.w));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// An H,W,RGB interleaved u8 frame as produced by the rasterizer (and, in
/// the paper, by the environment's renderer / device camera).
#[derive(Debug, Clone, PartialEq)]
pub struct FrameRgb {
    pub h: usize,
    pub w: usize,
    pub data: Vec<u8>, // h*w*3
}

impl FrameRgb {
    pub fn new(h: usize, w: usize) -> Self {
        FrameRgb { h, w, data: vec![0; h * w * 3] }
    }

    #[inline]
    pub fn put(&mut self, y: usize, x: usize, rgb: [u8; 3]) {
        let i = (y * self.w + x) * 3;
        self.data[i..i + 3].copy_from_slice(&rgb);
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize) -> [u8; 3] {
        let i = (y * self.w + x) * 3;
        [self.data[i], self.data[i + 1], self.data[i + 2]]
    }

    pub fn fill(&mut self, rgb: [u8; 3]) {
        for px in self.data.chunks_exact_mut(3) {
            px.copy_from_slice(&rgb);
        }
    }

    /// Crop a square region (paper: 100x100 render -> 84x84 crop).
    pub fn crop(&self, top: usize, left: usize, size: usize) -> FrameRgb {
        assert!(top + size <= self.h && left + size <= self.w, "crop out of bounds");
        let mut out = FrameRgb::new(size, size);
        for y in 0..size {
            let src = ((top + y) * self.w + left) * 3;
            let dst = y * size * 3;
            out.data[dst..dst + size * 3].copy_from_slice(&self.data[src..src + size * 3]);
        }
        out
    }

    /// Append an opaque alpha channel: RGBA bytes (the paper's OpenGL
    /// upload boundary; also the server-only wire format).
    pub fn to_rgba_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.h * self.w * 4);
        for px in self.data.chunks_exact(3) {
            out.extend_from_slice(px);
            out.push(255);
        }
        out
    }

    /// Normalised float planes: u8 HWC -> f32 CHW in `[0,1]` (SB3
    /// normalize_images + VecTransposeImage).
    pub fn to_chw_norm(&self) -> Chw {
        let mut out = Chw::zeros(3, self.h, self.w);
        for y in 0..self.h {
            for x in 0..self.w {
                let [r, g, b] = self.get(y, x);
                out.set(0, y, x, r as f32 / 255.0);
                out.set(1, y, x, g as f32 / 255.0);
                out.set(2, y, x, b as f32 / 255.0);
            }
        }
        out
    }
}

/// Reference valid/same convolution on Chw tensors — the oracle the shader
/// interpreter is validated against (mirrors python kernels/ref.py).
pub fn conv2d_ref(
    x: &Chw,
    w: &[f32], // [cout, cin, k, k]
    b: &[f32],
    cout: usize,
    k: usize,
    stride: usize,
    same: bool,
) -> Chw {
    let cin = x.c;
    assert_eq!(w.len(), cout * cin * k * k, "weight size");
    assert_eq!(b.len(), cout, "bias size");
    let (ho, wo, pad) = if same {
        let ho = x.h.div_ceil(stride);
        let wo = x.w.div_ceil(stride);
        let pad_h = ((ho - 1) * stride + k).saturating_sub(x.h);
        (ho, wo, (pad_h / 2) as isize)
    } else {
        ((x.h - k) / stride + 1, (x.w - k) / stride + 1, 0)
    };
    let mut out = Chw::zeros(cout, ho, wo);
    for oc in 0..cout {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = b[oc];
                for ic in 0..cin {
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (oy * stride + ky) as isize - pad;
                            let ix = (ox * stride + kx) as isize - pad;
                            let xv = x.at_padded(ic, iy, ix);
                            let wv = w[((oc * cin + ic) * k + ky) * k + kx];
                            acc += xv * wv;
                        }
                    }
                }
                out.set(oc, oy, ox, acc);
            }
        }
    }
    out
}

/// ReLU in place.
pub fn relu(x: &mut Chw) {
    for v in x.data.iter_mut() {
        *v = v.max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chw_indexing() {
        let mut t = Chw::zeros(2, 3, 4);
        t.set(1, 2, 3, 7.0);
        assert_eq!(t.at(1, 2, 3), 7.0);
        assert_eq!(t.at(0, 0, 0), 0.0);
        assert_eq!(t.data[23], 7.0); // last element
    }

    #[test]
    fn padded_reads() {
        let t = Chw::from_vec(1, 1, 1, vec![5.0]);
        assert_eq!(t.at_padded(0, 0, 0), 5.0);
        assert_eq!(t.at_padded(0, -1, 0), 0.0);
        assert_eq!(t.at_padded(0, 0, 1), 0.0);
    }

    #[test]
    fn frame_crop() {
        let mut f = FrameRgb::new(4, 4);
        f.put(1, 1, [9, 9, 9]);
        let c = f.crop(1, 1, 2);
        assert_eq!(c.get(0, 0), [9, 9, 9]);
        assert_eq!(c.get(1, 1), [0, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn crop_bounds_checked() {
        FrameRgb::new(4, 4).crop(3, 3, 2);
    }

    #[test]
    fn rgba_has_opaque_alpha() {
        let mut f = FrameRgb::new(1, 2);
        f.put(0, 0, [1, 2, 3]);
        f.put(0, 1, [4, 5, 6]);
        assert_eq!(f.to_rgba_bytes(), vec![1, 2, 3, 255, 4, 5, 6, 255]);
    }

    #[test]
    fn chw_normalisation() {
        let mut f = FrameRgb::new(1, 1);
        f.put(0, 0, [255, 0, 51]);
        let t = f.to_chw_norm();
        assert!((t.at(0, 0, 0) - 1.0).abs() < 1e-6);
        assert_eq!(t.at(1, 0, 0), 0.0);
        assert!((t.at(2, 0, 0) - 0.2).abs() < 1e-6);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 identity conv reproduces the input
        let x = Chw::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let out = conv2d_ref(&x, &[1.0], &[0.0], 1, 1, 1, false);
        assert_eq!(out.data, x.data);
    }

    #[test]
    fn conv_same_stride2_shape() {
        let x = Chw::zeros(9, 17, 17);
        let w = vec![0.0; 4 * 9 * 9];
        let out = conv2d_ref(&x, &w, &[0.0; 4], 4, 3, 2, true);
        assert_eq!((out.c, out.h, out.w), (4, 9, 9)); // ceil(17/2)
    }

    #[test]
    fn conv_valid_matches_hand_calc() {
        // x = [[1,2],[3,4]], k = [[1,0],[0,1]] valid stride 1 => [1+4] = [5]
        let x = Chw::from_vec(1, 2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let out = conv2d_ref(&x, &w, &[0.5], 1, 2, 1, false);
        assert_eq!(out.data, vec![5.5]);
    }

    #[test]
    fn relu_inplace() {
        let mut t = Chw::from_vec(1, 1, 3, vec![-1.0, 0.0, 2.0]);
        relu(&mut t);
        assert_eq!(t.data, vec![0.0, 0.0, 2.0]);
    }
}

//! Decision-latency decomposition (paper Fig. 5): the components of one
//! decision for the server-only vs split-policy pipelines, over a link
//! model + device encode time + server compute times.

use crate::net::shaped::LinkModel;

use super::breakeven::{feature_bits, raw_bits};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineKind {
    ServerOnly,
    Split,
}

/// Per-component times (seconds) of one decision.
#[derive(Debug, Clone, Copy)]
pub struct DecisionBreakdown {
    pub kind: PipelineKind,
    /// on-device encode (split only; 0 for server-only)
    pub device_encode: f64,
    /// observation/feature upload
    pub uplink: f64,
    /// server-side model execution
    pub server_compute: f64,
    /// action download
    pub downlink: f64,
}

impl DecisionBreakdown {
    pub fn total(&self) -> f64 {
        self.device_encode + self.uplink + self.server_compute + self.downlink
    }

    /// Server-only pipeline: full RGBA frame up, full policy on server.
    pub fn server_only(
        link: &LinkModel,
        x: usize,
        server_full_compute: f64,
        action_bytes: usize,
    ) -> DecisionBreakdown {
        DecisionBreakdown {
            kind: PipelineKind::ServerOnly,
            device_encode: 0.0,
            uplink: link.transfer_time((raw_bits(x) / 8.0) as usize),
            server_compute: server_full_compute,
            downlink: link.transfer_time(action_bytes),
        }
    }

    /// Split pipeline: device encodes (time j), uint8 features up, head-only
    /// compute on server.
    #[allow(clippy::too_many_arguments)]
    pub fn split(
        link: &LinkModel,
        x: usize,
        n: u32,
        k: usize,
        j: f64,
        server_head_compute: f64,
        action_bytes: usize,
    ) -> DecisionBreakdown {
        DecisionBreakdown {
            kind: PipelineKind::Split,
            device_encode: j,
            uplink: link.transfer_time((feature_bits(x, n, k) / 8.0) as usize),
            server_compute: server_head_compute,
            downlink: link.transfer_time(action_bytes),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link(mbps: f64) -> LinkModel {
        LinkModel::new(mbps * 1e6, 0.005)
    }

    #[test]
    fn total_is_sum() {
        let b = DecisionBreakdown {
            kind: PipelineKind::Split,
            device_encode: 0.1,
            uplink: 0.02,
            server_compute: 0.005,
            downlink: 0.003,
        };
        assert!((b.total() - 0.128).abs() < 1e-12);
    }

    #[test]
    fn paper_shape_low_bandwidth_split_wins() {
        // X=400, n=3, K=4, j=0.1, server full 35ms / head 3ms (GPU server)
        let l = link(10.0);
        let so = DecisionBreakdown::server_only(&l, 400, 0.035, 16);
        let sp = DecisionBreakdown::split(&l, 400, 3, 4, 0.1, 0.003, 16);
        assert!(sp.total() < so.total());
        // server-only is dominated by the uplink at 10 Mb/s
        assert!(so.uplink > 0.8 * so.total());
        // paper's magnitudes: ~540ms vs ~145ms
        assert!((0.45..0.65).contains(&so.total()), "{}", so.total());
        assert!((0.11..0.18).contains(&sp.total()), "{}", sp.total());
    }

    #[test]
    fn paper_shape_high_bandwidth_server_only_wins() {
        let l = link(100.0);
        let so = DecisionBreakdown::server_only(&l, 400, 0.035, 16);
        let sp = DecisionBreakdown::split(&l, 400, 3, 4, 0.1, 0.003, 16);
        assert!(so.total() < sp.total());
        // split is dominated by on-device compute now
        assert!(sp.device_encode > 0.6 * sp.total());
    }

    #[test]
    fn crossover_near_50mbps() {
        let diff_at = |mbps: f64| {
            let l = link(mbps);
            let so = DecisionBreakdown::server_only(&l, 400, 0.035, 16);
            let sp = DecisionBreakdown::split(&l, 400, 3, 4, 0.1, 0.003, 16);
            so.total() - sp.total()
        };
        assert!(diff_at(35.0) > 0.0);
        assert!(diff_at(75.0) < 0.0);
    }
}

//! The paper's break-even bandwidth model (§4.2).
//!
//! With link bandwidth B (bits/s), input side X, n stride-two layers (so the
//! feature map is (X/2ⁿ)², uint8), K transmitted channels, and on-device
//! encode time j, split-policy beats server-only when
//!
//! ```text
//! B < 32·X²·(1 − K/(4·2^{2n})) / j
//! ```
//!
//! Derivation: raw RGBA is 4X² bytes = 32X² bits; features are K(X/2ⁿ)²
//! bytes = 8K X²/4ⁿ bits; split wins when the transmission-time saving
//! exceeds the extra on-device compute j.

/// Break-even bandwidth in bits/s. Above this, server-only is faster.
pub fn breakeven_bandwidth_bps(x: usize, n: u32, k: usize, j: f64) -> f64 {
    assert!(j > 0.0, "on-device time must be positive");
    let x2 = (x * x) as f64;
    32.0 * x2 * (1.0 - k as f64 / (4.0 * 4f64.powi(n as i32))) / j
}

/// Break-even bandwidth for **measured** per-frame payloads: split wins
/// while the per-frame transmission saving `8·(raw_bytes − feat_bytes)/B`
/// exceeds the on-device encode time `j`. This is the general form the
/// closed-form model above specialises (raw = 4X², feat = K(X/2ⁿ)²) —
/// feed it the achieved bytes/frame of an adaptive codec instead of the
/// flat u8 assumption.
pub fn breakeven_bandwidth_bps_bytes(raw_bytes: f64, feat_bytes: f64, j: f64) -> f64 {
    assert!(j > 0.0, "on-device time must be positive");
    8.0 * (raw_bytes - feat_bytes) / j
}

/// Compression-ratio-aware break-even: the flat feature payload shrinks
/// by `ratio` (achieved flat-bytes / wire-bytes; 1.0 reproduces the
/// paper's uncompressed model, the regression test pins the equivalence).
/// A codec that halves the payload (`ratio = 2.0`) raises the break-even
/// bandwidth — split stays the right choice on faster links.
pub fn breakeven_bandwidth_bps_compressed(x: usize, n: u32, k: usize, j: f64, ratio: f64) -> f64 {
    assert!(ratio > 0.0, "compression ratio must be positive");
    let x2 = (x * x) as f64;
    let raw_bytes = 4.0 * x2;
    let feat_bytes = k as f64 * x2 / 4f64.powi(n as i32) / ratio;
    breakeven_bandwidth_bps_bytes(raw_bytes, feat_bytes, j)
}

/// Does split-policy yield lower decision latency at bandwidth `b_bps`?
pub fn split_wins(b_bps: f64, x: usize, n: u32, k: usize, j: f64) -> bool {
    b_bps < breakeven_bandwidth_bps(x, n, k, j)
}

/// [`split_wins`] over measured per-frame payload sizes.
pub fn split_wins_bytes(b_bps: f64, raw_bytes: f64, feat_bytes: f64, j: f64) -> bool {
    b_bps < breakeven_bandwidth_bps_bytes(raw_bytes, feat_bytes, j)
}

/// Raw-observation bits per frame (uncompressed RGBA, the paper's model).
pub fn raw_bits(x: usize) -> f64 {
    32.0 * (x * x) as f64
}

/// Transmitted-feature bits per frame (uint8 features).
pub fn feature_bits(x: usize, n: u32, k: usize) -> f64 {
    let s = (x as f64 / 2f64.powi(n as i32)).ceil();
    8.0 * k as f64 * s * s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_50_4_mbps() {
        // §4.2: X=400, n=3, j≈0.1s, K=4 -> ≈ 50.4 Mb/s
        let b = breakeven_bandwidth_bps(400, 3, 4, 0.1);
        assert!((b / 1e6 - 50.4).abs() < 0.1, "{} Mb/s", b / 1e6);
    }

    #[test]
    fn split_wins_below_crossover_only() {
        assert!(split_wins(10e6, 400, 3, 4, 0.1));
        assert!(split_wins(25e6, 400, 3, 4, 0.1));
        assert!(!split_wins(100e6, 400, 3, 4, 0.1));
    }

    #[test]
    fn faster_device_raises_breakeven() {
        let slow = breakeven_bandwidth_bps(400, 3, 4, 0.2);
        let fast = breakeven_bandwidth_bps(400, 3, 4, 0.05);
        assert!(fast > slow * 3.9);
    }

    #[test]
    fn bigger_features_lower_breakeven() {
        let k4 = breakeven_bandwidth_bps(400, 3, 4, 0.1);
        let k16 = breakeven_bandwidth_bps(400, 3, 16, 0.1);
        assert!(k16 < k4);
        // K = 4·4^n would mean no compression at all: break-even hits zero
        let none = breakeven_bandwidth_bps(400, 3, 256, 0.1);
        assert!(none.abs() < 1e-6);
    }

    #[test]
    fn bits_model() {
        assert_eq!(raw_bits(400), 32.0 * 160_000.0);
        // X=400, n=3 -> 50x50 features
        assert_eq!(feature_bits(400, 3, 4), 8.0 * 4.0 * 2500.0);
        // compression ratio 4X^2 / K(X/8)^2 = 256/K/... = 64 for K=4
        let ratio = raw_bits(400) / feature_bits(400, 3, 4);
        assert!((ratio - 64.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_j_rejected() {
        breakeven_bandwidth_bps(400, 3, 4, 0.0);
    }

    /// Regression pin: the bytes-parameterised model at ratio 1.0 IS the
    /// paper's closed form, across the whole (X, n, K, j) grid the repo
    /// uses.
    #[test]
    fn ratio_one_reproduces_the_closed_form() {
        for x in [84usize, 400] {
            for n in [2u32, 3] {
                for k in [4usize, 16] {
                    for j in [0.01f64, 0.1, 0.2] {
                        let old = breakeven_bandwidth_bps(x, n, k, j);
                        let new = breakeven_bandwidth_bps_compressed(x, n, k, j, 1.0);
                        assert!(
                            (old - new).abs() <= old.abs() * 1e-12,
                            "X={x} n={n} K={k} j={j}: {old} vs {new}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn compression_raises_the_breakeven() {
        let flat = breakeven_bandwidth_bps_compressed(400, 3, 4, 0.1, 1.0);
        let halved = breakeven_bandwidth_bps_compressed(400, 3, 4, 0.1, 2.0);
        assert!(halved > flat, "{halved} <= {flat}");
        // at infinite compression the feature payload vanishes: the bound
        // is pure raw transmission vs on-device time
        let limit = breakeven_bandwidth_bps_bytes(4.0 * 400.0 * 400.0, 0.0, 0.1);
        assert!(halved < limit);
        let nearly_free = breakeven_bandwidth_bps_compressed(400, 3, 4, 0.1, 1e9);
        assert!((nearly_free - limit).abs() < limit * 1e-6);
    }

    #[test]
    fn bytes_model_matches_measured_payloads() {
        // achieved 2.3x compression on a 4×50×50 feature frame, X=400
        let raw = 4.0 * 400.0 * 400.0;
        let feat_flat = 4.0 * 50.0 * 50.0;
        let feat = feat_flat / 2.3;
        let b = breakeven_bandwidth_bps_bytes(raw, feat, 0.1);
        assert!(b > breakeven_bandwidth_bps(400, 3, 4, 0.1));
        assert!(split_wins_bytes(b - 1.0, raw, feat, 0.1));
        assert!(!split_wins_bytes(b + 1.0, raw, feat, 0.1));
    }
}

//! Analytic models from the paper's §4.2: the communication/computation
//! break-even bandwidth and the decision-latency decomposition (Fig. 5).

pub mod breakeven;
pub mod latency;

pub use breakeven::{
    breakeven_bandwidth_bps, breakeven_bandwidth_bps_bytes, breakeven_bandwidth_bps_compressed,
    split_wins, split_wins_bytes,
};
pub use latency::{DecisionBreakdown, PipelineKind};

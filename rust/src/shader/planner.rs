//! Pass planner: map an [`EncoderIr`] onto OpenGL fragment-shader passes
//! under the embedded-GL constraints the paper documents for the
//! Pi Zero 2 W deployment (§3):
//!
//!   * each pass writes one RGBA texture => 4 output channels per pass;
//!   * a fragment shader samples from at most **8 bound textures**;
//!   * each shader invocation has a **64-texture-sample budget**.
//!
//! Channels are packed 4-per-texture (RGBA). A conv layer with `cout`
//! output channels over `cin` input channels becomes
//! `ceil(cout/4)` passes, each binding `ceil(cin/4)` textures and
//! performing `k^2 * ceil(cin/4)` samples per output pixel.

use super::ir::{EncoderIr, Op};

pub const CHANNELS_PER_TEXTURE: usize = 4;
pub const MAX_BOUND_TEXTURES: usize = 8;
pub const MAX_SAMPLES_PER_PASS: usize = 64;

#[derive(Debug, PartialEq)]
pub enum PlanError {
    TooManyTextures { layer: usize, textures: usize, limit: usize },
    SampleBudget { layer: usize, samples: usize, budget: usize },
    Unsupported { layer: usize, what: String },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::TooManyTextures { layer, textures, limit } => {
                write!(f, "layer {layer}: pass needs {textures} bound textures, limit is {limit}")
            }
            PlanError::SampleBudget { layer, samples, budget } => {
                write!(f, "layer {layer}: pass needs {samples} texture samples, budget is {budget}")
            }
            PlanError::Unsupported { layer, what } => {
                write!(f, "layer {layer}: unsupported op for shader deployment: {what}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

/// A logical texture: 4 packed channels of one layer's activation map.
#[derive(Debug, Clone, PartialEq)]
pub struct Texture {
    pub id: usize,
    /// layer the texture belongs to (0 = network input)
    pub layer: usize,
    /// channel block index within the layer (channels block*4 .. block*4+4)
    pub block: usize,
    pub h: usize,
    pub w: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PassKind {
    Conv { k: usize, stride: usize, same: bool, relu: bool },
    MaxPool { k: usize, stride: usize },
}

/// One fragment-shader pass: reads `in_textures`, writes `out_texture`.
#[derive(Debug, Clone)]
pub struct Pass {
    pub layer: usize,
    /// output channel block (out channels block*4 .. block*4+4)
    pub out_block: usize,
    pub kind: PassKind,
    pub in_textures: Vec<usize>,
    pub out_texture: usize,
    /// texture samples per output pixel
    pub samples: usize,
    /// output resolution
    pub out_h: usize,
    pub out_w: usize,
}

#[derive(Debug, Clone)]
pub struct PassPlan {
    pub input_x: usize,
    pub textures: Vec<Texture>,
    pub passes: Vec<Pass>,
    /// ids of the network-input textures (layer 0)
    pub input_textures: Vec<usize>,
    /// ids of the final-layer textures (the transmitted feature blocks)
    pub output_textures: Vec<usize>,
}

impl PassPlan {
    /// Total texture samples for one frame = Σ passes (out_h*out_w*samples).
    /// This is the planner-level cost the device model consumes (Fig. 2).
    pub fn total_samples(&self) -> u64 {
        self.passes
            .iter()
            .map(|p| (p.out_h * p.out_w * p.samples) as u64)
            .sum()
    }

    /// Total bytes written to textures per frame (RGBA8 assumption).
    pub fn bytes_written(&self) -> u64 {
        self.passes
            .iter()
            .map(|p| (p.out_h * p.out_w * CHANNELS_PER_TEXTURE) as u64)
            .sum()
    }

    /// Peak number of live textures (resident texture memory pressure).
    pub fn peak_textures(&self) -> usize {
        // textures of two consecutive layers are live at once
        let mut per_layer = std::collections::BTreeMap::new();
        for t in &self.textures {
            *per_layer.entry(t.layer).or_insert(0usize) += 1;
        }
        let counts: Vec<usize> = per_layer.values().copied().collect();
        counts
            .windows(2)
            .map(|w| w[0] + w[1])
            .max()
            .unwrap_or_else(|| counts.first().copied().unwrap_or(0))
    }
}

/// Plan the shader passes for `ir` at input resolution `x`, enforcing the
/// embedded-GL constraints.
pub fn plan(ir: &EncoderIr, x: usize) -> Result<PassPlan, PlanError> {
    let mut textures = Vec::new();
    let mut passes = Vec::new();

    let blocks = |c: usize| c.div_ceil(CHANNELS_PER_TEXTURE);

    // layer-0 textures: the packed input frame
    let mut cur: Vec<usize> = (0..blocks(ir.input_channels))
        .map(|b| {
            let id = textures.len();
            textures.push(Texture { id, layer: 0, block: b, h: x, w: x });
            id
        })
        .collect();
    let input_textures = cur.clone();
    let mut cur_h = x;
    let mut cur_w = x;
    let mut layer_idx = 0usize;
    let mut pending_relu = false;

    // Look ahead: ReLU fuses into the preceding conv's pass.
    let mut ops = ir.ops.iter().peekable();
    while let Some(op) = ops.next() {
        match op {
            Op::Relu => {
                // standalone ReLU (not fused): only legal right after conv,
                // which we fuse eagerly below, so a bare Relu here is a
                // leading ReLU — unsupported.
                if !pending_relu {
                    return Err(PlanError::Unsupported {
                        layer: layer_idx,
                        what: "ReLU without preceding conv".into(),
                    });
                }
                pending_relu = false;
            }
            Op::Conv { cout, k, stride, same } => {
                layer_idx += 1;
                let relu = matches!(ops.peek(), Some(Op::Relu));
                pending_relu = relu;
                let in_blocks = cur.len();
                if in_blocks > MAX_BOUND_TEXTURES {
                    return Err(PlanError::TooManyTextures {
                        layer: layer_idx,
                        textures: in_blocks,
                        limit: MAX_BOUND_TEXTURES,
                    });
                }
                let samples = k * k * in_blocks;
                if samples > MAX_SAMPLES_PER_PASS {
                    return Err(PlanError::SampleBudget {
                        layer: layer_idx,
                        samples,
                        budget: MAX_SAMPLES_PER_PASS,
                    });
                }
                let (oh, ow) = if *same {
                    (cur_h.div_ceil(*stride), cur_w.div_ceil(*stride))
                } else {
                    ((cur_h - k) / stride + 1, (cur_w - k) / stride + 1)
                };
                let mut next = Vec::new();
                for ob in 0..blocks(*cout) {
                    let out_id = textures.len();
                    textures.push(Texture { id: out_id, layer: layer_idx, block: ob, h: oh, w: ow });
                    passes.push(Pass {
                        layer: layer_idx,
                        out_block: ob,
                        kind: PassKind::Conv { k: *k, stride: *stride, same: *same, relu },
                        in_textures: cur.clone(),
                        out_texture: out_id,
                        samples,
                        out_h: oh,
                        out_w: ow,
                    });
                    next.push(out_id);
                }
                cur = next;
                cur_h = oh;
                cur_w = ow;
            }
            Op::MaxPool { k, stride } => {
                layer_idx += 1;
                let samples = k * k; // pooling reads one texture
                if samples > MAX_SAMPLES_PER_PASS {
                    return Err(PlanError::SampleBudget {
                        layer: layer_idx,
                        samples,
                        budget: MAX_SAMPLES_PER_PASS,
                    });
                }
                let oh = (cur_h - k) / stride + 1;
                let ow = (cur_w - k) / stride + 1;
                let mut next = Vec::new();
                for (ob, &tex) in cur.iter().enumerate() {
                    let out_id = textures.len();
                    textures.push(Texture { id: out_id, layer: layer_idx, block: ob, h: oh, w: ow });
                    passes.push(Pass {
                        layer: layer_idx,
                        out_block: ob,
                        kind: PassKind::MaxPool { k: *k, stride: *stride },
                        in_textures: vec![tex],
                        out_texture: out_id,
                        samples,
                        out_h: oh,
                        out_w: ow,
                    });
                    next.push(out_id);
                }
                cur = next;
                cur_h = oh;
                cur_w = ow;
            }
        }
    }

    Ok(PassPlan {
        input_x: x,
        output_textures: cur,
        textures,
        passes,
        input_textures,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shader::ir::{EncoderIr, Op};

    fn miniconv(k_out: usize) -> EncoderIr {
        EncoderIr {
            name: format!("miniconv{k_out}"),
            input_channels: 9,
            ops: (0..3)
                .flat_map(|_| {
                    vec![
                        Op::Conv { cout: k_out, k: 3, stride: 2, same: true },
                        Op::Relu,
                    ]
                })
                .collect(),
        }
    }

    #[test]
    fn miniconv4_plan_structure() {
        let p = plan(&miniconv(4), 84).unwrap();
        // layer 1: 1 pass (4 out ch), layers 2-3: 1 pass each
        assert_eq!(p.passes.len(), 3);
        // layer 1 binds ceil(9/4)=3 textures, 27 samples
        assert_eq!(p.passes[0].in_textures.len(), 3);
        assert_eq!(p.passes[0].samples, 27);
        // later layers bind 1 texture, 9 samples
        assert_eq!(p.passes[1].samples, 9);
        // output: one 4-channel block at 11x11
        assert_eq!(p.output_textures.len(), 1);
        let out = &p.textures[p.output_textures[0]];
        assert_eq!((out.h, out.w), (11, 11));
        // relu fused on every pass
        for pass in &p.passes {
            assert!(matches!(pass.kind, PassKind::Conv { relu: true, .. }));
        }
    }

    #[test]
    fn miniconv16_pass_counts() {
        let p = plan(&miniconv(16), 84).unwrap();
        // layer1: 4 passes; layers 2,3: 4 passes each (16 out = 4 blocks)
        assert_eq!(p.passes.len(), 12);
        // layer 2 binds 4 input textures (16 in ch), 36 samples <= 64
        let l2 = p.passes.iter().find(|q| q.layer == 2).unwrap();
        assert_eq!(l2.in_textures.len(), 4);
        assert_eq!(l2.samples, 36);
    }

    #[test]
    fn naturecnn_first_layer_rejected() {
        // 8x8 conv over 9 channels: 64 * 3 = 192 samples > 64 budget
        let ir = EncoderIr {
            name: "fullcnn".into(),
            input_channels: 9,
            ops: vec![Op::Conv { cout: 32, k: 8, stride: 4, same: false }],
        };
        match plan(&ir, 84) {
            Err(PlanError::SampleBudget { samples, .. }) => assert_eq!(samples, 192),
            other => panic!("expected SampleBudget, got {other:?}"),
        }
    }

    #[test]
    fn texture_limit_enforced() {
        // 64 input channels = 16 textures > 8
        let ir = EncoderIr {
            name: "wide".into(),
            input_channels: 64,
            ops: vec![Op::Conv { cout: 4, k: 1, stride: 1, same: true }],
        };
        assert!(matches!(
            plan(&ir, 32),
            Err(PlanError::TooManyTextures { textures: 16, .. })
        ));
    }

    #[test]
    fn cost_model_scales_quadratically() {
        let p100 = plan(&miniconv(4), 100).unwrap();
        let p200 = plan(&miniconv(4), 200).unwrap();
        let r = p200.total_samples() as f64 / p100.total_samples() as f64;
        assert!((r - 4.0).abs() < 0.2, "expected ~4x, got {r}");
    }

    #[test]
    fn total_samples_hand_check() {
        // miniconv4 @ 84: L1 42*42*27 + L2 21*21*9 + L3 11*11*9
        let p = plan(&miniconv(4), 84).unwrap();
        let expect = 42 * 42 * 27 + 21 * 21 * 9 + 11 * 11 * 9;
        assert_eq!(p.total_samples(), expect as u64);
    }

    #[test]
    fn maxpool_plans_per_block() {
        let ir = EncoderIr {
            name: "p".into(),
            input_channels: 8,
            ops: vec![Op::MaxPool { k: 2, stride: 2 }],
        };
        let p = plan(&ir, 16).unwrap();
        assert_eq!(p.passes.len(), 2); // 8 channels = 2 blocks
        assert!(matches!(p.passes[0].kind, PassKind::MaxPool { .. }));
        assert_eq!(p.passes[0].samples, 4);
    }

    #[test]
    fn leading_relu_unsupported() {
        let ir = EncoderIr { name: "r".into(), input_channels: 4, ops: vec![Op::Relu] };
        assert!(matches!(plan(&ir, 8), Err(PlanError::Unsupported { .. })));
    }

    #[test]
    fn peak_textures_counts_live_layers() {
        let p = plan(&miniconv(16), 84).unwrap();
        // consecutive 16-channel layers: 4 + 4 textures live together = 8
        assert_eq!(p.peak_textures(), 8);
    }
}

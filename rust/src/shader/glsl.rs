//! GLSL ES 1.00 fragment-shader code generation.
//!
//! Emits one fragment shader per planned pass, in the dialect supported by
//! the embedded GPUs the paper targets (VideoCore IV/VI, Maxwell): no
//! dynamic loops over samplers, explicit unrolled taps, `mat4` weight
//! uniforms (4 input channels -> 4 output channels per matrix), and
//! border-zero sampling implemented via a coverage test (matching the
//! zero-padding of 'same' convolution).
//!
//! The generated source is both an artifact users can ship (see
//! examples/shader_export.rs) and the program text our software
//! interpreter executes structurally.

use super::planner::{Pass, PassKind, PassPlan, Texture};

/// A generated shader program for one pass.
#[derive(Debug, Clone)]
pub struct ShaderSource {
    pub name: String,
    pub fragment: String,
    /// uniform names for the weight matrices, tap-major
    pub n_weight_mats: usize,
    pub n_samplers: usize,
}

/// The standard fullscreen-quad vertex shader shared by every pass.
pub const VERTEX_SHADER: &str = "\
attribute vec2 a_pos;
varying vec2 v_uv;
void main() {
    v_uv = a_pos * 0.5 + 0.5;
    gl_Position = vec4(a_pos, 0.0, 1.0);
}
";

/// Generate the fragment shader for one pass of the plan.
pub fn gen_pass(plan: &PassPlan, pass: &Pass, textures: &[Texture]) -> ShaderSource {
    match pass.kind {
        PassKind::Conv { k, stride, same, relu } => {
            gen_conv(plan, pass, textures, k, stride, same, relu)
        }
        PassKind::MaxPool { k, stride } => gen_pool(pass, textures, k, stride),
    }
}

fn header(n_samplers: usize) -> String {
    let mut s = String::from("precision highp float;\nvarying vec2 v_uv;\n");
    for i in 0..n_samplers {
        s.push_str(&format!("uniform sampler2D u_tex{i};\n"));
    }
    s
}

#[allow(clippy::too_many_arguments)]
fn gen_conv(
    _plan: &PassPlan,
    pass: &Pass,
    textures: &[Texture],
    k: usize,
    stride: usize,
    same: bool,
    relu: bool,
) -> ShaderSource {
    let n_in = pass.in_textures.len();
    let n_mats = k * k * n_in;
    let in_h = textures[pass.in_textures[0]].h;
    let in_w = textures[pass.in_textures[0]].w;
    // 'same' zero padding offset (matches kernels/conv.py)
    let pad = if same {
        (((pass.out_h - 1) * stride + k).saturating_sub(in_h) / 2) as i64
    } else {
        0
    };

    let mut f = header(n_in);
    f.push_str(&format!("uniform mat4 u_w[{n_mats}];\nuniform vec4 u_bias;\n"));
    f.push_str(&format!(
        "const vec2 IN_SIZE = vec2({in_w}.0, {in_h}.0);\nconst vec2 OUT_SIZE = vec2({}.0, {}.0);\n",
        pass.out_w, pass.out_h
    ));
    f.push_str(
        "vec4 fetch(sampler2D t, vec2 px) {\n\
         \x20   // border-zero: outside the texture reads as 0 (zero padding)\n\
         \x20   if (px.x < 0.0 || px.y < 0.0 || px.x >= IN_SIZE.x || px.y >= IN_SIZE.y)\n\
         \x20       return vec4(0.0);\n\
         \x20   return texture2D(t, (px + 0.5) / IN_SIZE);\n\
         }\n",
    );
    f.push_str("void main() {\n");
    f.push_str("    vec2 opx = floor(v_uv * OUT_SIZE);\n");
    f.push_str(&format!(
        "    vec2 ipx = opx * {stride}.0 - {pad}.0;\n"
    ));
    f.push_str("    vec4 acc = u_bias;\n");
    // fully unrolled taps: the paper's static sampling pattern
    let mut m = 0;
    for ky in 0..k {
        for kx in 0..k {
            for t in 0..n_in {
                f.push_str(&format!(
                    "    acc += u_w[{m}] * fetch(u_tex{t}, ipx + vec2({kx}.0, {ky}.0));\n"
                ));
                m += 1;
            }
        }
    }
    if relu {
        f.push_str("    acc = max(acc, vec4(0.0));\n");
    }
    f.push_str("    gl_FragColor = acc;\n}\n");

    ShaderSource {
        name: format!("conv_l{}_b{}", pass.layer, pass.out_block),
        fragment: f,
        n_weight_mats: n_mats,
        n_samplers: n_in,
    }
}

fn gen_pool(pass: &Pass, textures: &[Texture], k: usize, stride: usize) -> ShaderSource {
    let in_h = textures[pass.in_textures[0]].h;
    let in_w = textures[pass.in_textures[0]].w;
    let mut f = header(1);
    f.push_str(&format!(
        "const vec2 IN_SIZE = vec2({in_w}.0, {in_h}.0);\nconst vec2 OUT_SIZE = vec2({}.0, {}.0);\n",
        pass.out_w, pass.out_h
    ));
    f.push_str("void main() {\n");
    f.push_str("    vec2 opx = floor(v_uv * OUT_SIZE);\n");
    f.push_str(&format!("    vec2 ipx = opx * {stride}.0;\n"));
    f.push_str("    vec4 acc = vec4(-1.0e30);\n");
    for ky in 0..k {
        for kx in 0..k {
            f.push_str(&format!(
                "    acc = max(acc, texture2D(u_tex0, (ipx + vec2({kx}.5, {ky}.5)) / IN_SIZE));\n"
            ));
        }
    }
    f.push_str("    gl_FragColor = acc;\n}\n");
    ShaderSource {
        name: format!("pool_l{}_b{}", pass.layer, pass.out_block),
        fragment: f,
        n_weight_mats: 0,
        n_samplers: 1,
    }
}

/// Generate all shaders for a plan (pass order).
pub fn gen_all(plan: &PassPlan) -> Vec<ShaderSource> {
    plan.passes
        .iter()
        .map(|p| gen_pass(plan, p, &plan.textures))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shader::ir::{EncoderIr, Op};
    use crate::shader::planner::plan;

    fn mini() -> PassPlan {
        let ir = EncoderIr {
            name: "m".into(),
            input_channels: 9,
            ops: vec![
                Op::Conv { cout: 4, k: 3, stride: 2, same: true },
                Op::Relu,
            ],
        };
        plan(&ir, 84).unwrap()
    }

    #[test]
    fn conv_shader_structure() {
        let p = mini();
        let s = gen_pass(&p, &p.passes[0], &p.textures);
        // 3 input textures, 3x3 taps -> 27 weight matrices and 27 fetches
        assert_eq!(s.n_weight_mats, 27);
        assert_eq!(s.n_samplers, 3);
        assert_eq!(s.fragment.matches("fetch(u_tex").count(), 27);
        assert!(s.fragment.contains("uniform mat4 u_w[27];"));
        assert!(s.fragment.contains("uniform sampler2D u_tex2;"));
        assert!(!s.fragment.contains("u_tex3"));
        // relu fused
        assert!(s.fragment.contains("max(acc, vec4(0.0))"));
        // GLSL ES 1.00: no for-loops over samplers, no #version 300
        assert!(!s.fragment.contains("for ("));
        assert!(!s.fragment.contains("#version"));
    }

    #[test]
    fn sample_count_matches_planner_budget() {
        // the emitted fetch count must equal the planner's per-pixel samples
        let p = mini();
        let s = gen_pass(&p, &p.passes[0], &p.textures);
        assert_eq!(
            s.fragment.matches("fetch(u_tex").count(),
            p.passes[0].samples
        );
    }

    #[test]
    fn pool_shader_structure() {
        let ir = EncoderIr {
            name: "p".into(),
            input_channels: 4,
            ops: vec![Op::MaxPool { k: 2, stride: 2 }],
        };
        let p = plan(&ir, 8).unwrap();
        let s = gen_pass(&p, &p.passes[0], &p.textures);
        assert_eq!(s.fragment.matches("texture2D(u_tex0").count(), 4);
        assert!(s.fragment.contains("max(acc,"));
    }

    #[test]
    fn vertex_shader_is_fullscreen_quad() {
        assert!(VERTEX_SHADER.contains("gl_Position"));
        assert!(VERTEX_SHADER.contains("v_uv"));
    }

    #[test]
    fn gen_all_covers_every_pass() {
        let p = mini();
        assert_eq!(gen_all(&p).len(), p.passes.len());
    }

    #[test]
    fn border_zero_documented_in_source() {
        let p = mini();
        let s = gen_pass(&p, &p.passes[0], &p.textures);
        assert!(s.fragment.contains("border-zero"));
    }
}

//! Precompiled, zero-allocation execution of a [`PassPlan`] — the
//! interpreter hot path the serving fleet and sustained-load benches run
//! per frame.
//!
//! [`ShaderPipeline`](super::interp::ShaderPipeline) re-derives every
//! pass's tap-major weight matrices on every frame and allocates fresh
//! texture buffers per pass. [`CompiledPipeline`] splits that work into a
//! one-time *compile* step and a steady-state *execute* step:
//!
//!   * all per-pass mat4 blocks, biases and padding are precomputed at
//!     build time;
//!   * every texture in the plan gets a preallocated scratch buffer that
//!     is overwritten in place each frame — with `run_into` and a single
//!     execution thread, steady-state frames perform **zero heap
//!     allocations**;
//!   * each conv/pool pass is split into an *interior* region where every
//!     tap is in bounds (tight row-major accumulate over `[f32; 4]` lanes,
//!     no border checks) and a thin *border* region that keeps the legacy
//!     zero-pad semantics;
//!   * in `Rgba8` mode, texture reads go through a per-layer 256-entry
//!     dequantisation LUT and the store fuses ReLU + quantisation (the
//!     clamp's lower bound *is* the ReLU) with a precomputed scale
//!     reciprocal;
//!   * passes of the same layer are independent (disjoint output
//!     textures), so `run` can fan them out across a small
//!     `std::thread::scope` pool sized by the device model's CPU cores.
//!
//! Float mode is bit-exact against the legacy interpreter (same tap
//! order, same accumulate expression); the legacy path is kept as the
//! oracle in tests.

use anyhow::{anyhow, Result};

use super::interp::{conv_index_checked, tap_major_mats, ShaderPipeline, TextureFormat};
use super::ir::ConvWeights;
use super::planner::{PassKind, PassPlan, CHANNELS_PER_TEXTURE};
use crate::tensor::Chw;

/// Zero LUT used as the placeholder in fixed-size fetch arrays.
static ZERO_LUT: [f32; 256] = [0.0; 256];

/// One preallocated texture buffer of the scratch arena.
///
/// Arena lifetime rules: a buffer is written exactly once per frame (by
/// its producing pass or the input upload) and read only by later passes,
/// so buffers never need clearing between frames — every pixel of a live
/// texture is overwritten before it is read.
enum ScratchData {
    Float(Vec<[f32; 4]>),
    Rgba8(Vec<[u8; 4]>),
}

struct TexBuf {
    h: usize,
    w: usize,
    data: ScratchData,
}

/// Per-layer tables for the `Rgba8` texture format.
struct Rgba8Tables {
    /// `dequant[layer][byte]` = byte/255 * scale\[layer\] — bit-identical
    /// to the legacy fetch arithmetic.
    dequant: Vec<[f32; 256]>,
    /// 1/scale per layer, fused into the quantising store.
    inv_scale: Vec<f32>,
}

enum CompiledKind {
    Conv {
        k: usize,
        stride: usize,
        /// zero-padding on each side (derived from the input height, same
        /// formula as the legacy interpreter)
        pad: usize,
        relu: bool,
        /// tap-major (ky, kx, in_block) mat4 blocks, precomputed once
        mats: Vec<[[f32; 4]; 4]>,
        bias: [f32; 4],
    },
    MaxPool {
        k: usize,
        stride: usize,
    },
}

struct CompiledPass {
    layer: usize,
    /// layer the input textures belong to (all inputs of a pass share it)
    in_layer: usize,
    in_slots: Vec<usize>,
    out_slot: usize,
    out_h: usize,
    out_w: usize,
    kind: CompiledKind,
    /// interior region `[oy0, oy1) x [ox0, ox1)` where every tap of every
    /// output pixel lands in bounds; empty when `oy0 >= oy1 || ox0 >= ox1`
    oy0: usize,
    oy1: usize,
    ox0: usize,
    ox1: usize,
}

/// A maximal run of same-layer passes: mutually independent (disjoint
/// output textures) and therefore parallelisable.
struct Group {
    start: usize,
    end: usize,
    /// all inputs of the group live in slots `< split`, all outputs in
    /// slots `>= split` — the arena is split at this point so workers get
    /// shared reads and exclusive writes
    split: usize,
}

/// Compiled form of a shader pipeline: one-time compilation, reusable
/// scratch arena, allocation-free steady-state execution.
pub struct CompiledPipeline {
    plan: PassPlan,
    format: TextureFormat,
    passes: Vec<CompiledPass>,
    groups: Vec<Group>,
    scratch: Vec<TexBuf>,
    rgba8: Option<Rgba8Tables>,
    /// (slot, layer) of each output texture block
    outputs: Vec<(usize, usize)>,
    out_h: usize,
    out_w: usize,
    threads: usize,
}

// ---------------------------------------------------------------------------
// texture readers (monomorphised per storage format — no enum dispatch in
// the inner loops)

trait TexRead: Copy + Sync {
    fn h(&self) -> usize;
    fn w(&self) -> usize;
    /// In-bounds read. Callers must guarantee `y < h && x < w`; the
    /// interior loops do so by construction (checked by debug_assert).
    fn at(&self, y: usize, x: usize) -> [f32; 4];
    /// Border-zero read, matching the generated shader's coverage test.
    #[inline]
    fn fetch(&self, y: isize, x: isize) -> [f32; 4] {
        if y < 0 || x < 0 || y >= self.h() as isize || x >= self.w() as isize {
            [0.0; 4]
        } else {
            self.at(y as usize, x as usize)
        }
    }
}

#[derive(Clone, Copy)]
struct FloatTex<'a> {
    data: &'a [[f32; 4]],
    h: usize,
    w: usize,
}

impl TexRead for FloatTex<'_> {
    #[inline]
    fn h(&self) -> usize {
        self.h
    }
    #[inline]
    fn w(&self) -> usize {
        self.w
    }
    #[inline]
    fn at(&self, y: usize, x: usize) -> [f32; 4] {
        debug_assert!(y < self.h && x < self.w);
        unsafe { *self.data.get_unchecked(y * self.w + x) }
    }
}

#[derive(Clone, Copy)]
struct LutTex<'a> {
    data: &'a [[u8; 4]],
    lut: &'a [f32; 256],
    h: usize,
    w: usize,
}

impl TexRead for LutTex<'_> {
    #[inline]
    fn h(&self) -> usize {
        self.h
    }
    #[inline]
    fn w(&self) -> usize {
        self.w
    }
    #[inline]
    fn at(&self, y: usize, x: usize) -> [f32; 4] {
        debug_assert!(y < self.h && x < self.w);
        let px = unsafe { *self.data.get_unchecked(y * self.w + x) };
        [
            self.lut[px[0] as usize],
            self.lut[px[1] as usize],
            self.lut[px[2] as usize],
            self.lut[px[3] as usize],
        ]
    }
}

// ---------------------------------------------------------------------------
// pass kernels

/// ReLU fused with the quantising store: the clamp's lower bound is the
/// ReLU, the precomputed reciprocal replaces the per-pixel division.
#[inline]
fn quantize_px(v: [f32; 4], inv_scale: f32) -> [u8; 4] {
    let q = |x: f32| ((x * inv_scale).clamp(0.0, 1.0) * 255.0).round() as u8;
    [q(v[0]), q(v[1]), q(v[2]), q(v[3])]
}

/// One output pixel of a conv pass with border-zero fetches (legacy
/// semantics; used only for the thin border region).
#[inline]
fn conv_px_border<T: TexRead>(
    ins: &[T],
    mats: &[[[f32; 4]; 4]],
    bias: [f32; 4],
    k: usize,
    iy0: isize,
    ix0: isize,
    relu: bool,
) -> [f32; 4] {
    let mut acc = bias;
    let mut m = 0;
    for ky in 0..k {
        for kx in 0..k {
            for tex in ins {
                let px = tex.fetch(iy0 + ky as isize, ix0 + kx as isize);
                let w = &mats[m];
                for o in 0..4 {
                    acc[o] += w[o][0] * px[0]
                        + w[o][1] * px[1]
                        + w[o][2] * px[2]
                        + w[o][3] * px[3];
                }
                m += 1;
            }
        }
    }
    if relu {
        for a in acc.iter_mut() {
            *a = a.max(0.0);
        }
    }
    acc
}

/// Run one conv pass: interior without bounds checks, border with the
/// legacy zero-pad fetch. `store` receives (pixel index, value).
#[allow(clippy::too_many_arguments)]
fn run_conv<T: TexRead>(
    ins: &[T],
    mats: &[[[f32; 4]; 4]],
    bias: [f32; 4],
    k: usize,
    stride: usize,
    pad: usize,
    relu: bool,
    out_h: usize,
    out_w: usize,
    (oy0, oy1, ox0, ox1): (usize, usize, usize, usize),
    mut store: impl FnMut(usize, [f32; 4]),
) {
    let interior = oy0 < oy1 && ox0 < ox1;
    // top and bottom border rows (plus everything if there is no interior)
    let (top_end, bot_start) = if interior { (oy0, oy1) } else { (out_h, out_h) };
    for oy in (0..top_end).chain(bot_start..out_h) {
        let iy0 = (oy * stride) as isize - pad as isize;
        for ox in 0..out_w {
            let ix0 = (ox * stride) as isize - pad as isize;
            store(oy * out_w + ox, conv_px_border(ins, mats, bias, k, iy0, ix0, relu));
        }
    }
    if !interior {
        return;
    }
    // left/right border columns of the interior rows
    for oy in oy0..oy1 {
        let iy0 = (oy * stride) as isize - pad as isize;
        for ox in (0..ox0).chain(ox1..out_w) {
            let ix0 = (ox * stride) as isize - pad as isize;
            store(oy * out_w + ox, conv_px_border(ins, mats, bias, k, iy0, ix0, relu));
        }
    }
    // interior: every tap in bounds — same accumulate expression and tap
    // order as the legacy interpreter, so Float mode stays bit-exact
    for oy in oy0..oy1 {
        let iy0 = oy * stride - pad;
        for ox in ox0..ox1 {
            let ix0 = ox * stride - pad;
            let mut acc = bias;
            let mut m = 0;
            for ky in 0..k {
                let row = iy0 + ky;
                for kx in 0..k {
                    let col = ix0 + kx;
                    for tex in ins {
                        let px = tex.at(row, col);
                        let w = &mats[m];
                        for o in 0..4 {
                            acc[o] += w[o][0] * px[0]
                                + w[o][1] * px[1]
                                + w[o][2] * px[2]
                                + w[o][3] * px[3];
                        }
                        m += 1;
                    }
                }
            }
            if relu {
                for a in acc.iter_mut() {
                    *a = a.max(0.0);
                }
            }
            store(oy * out_w + ox, acc);
        }
    }
}

/// One output pixel of a max-pool pass with border-zero fetches.
#[inline]
fn pool_px_border<T: TexRead>(tex: &T, k: usize, iy0: usize, ix0: usize) -> [f32; 4] {
    let mut acc = [f32::NEG_INFINITY; 4];
    for ky in 0..k {
        for kx in 0..k {
            let px = tex.fetch((iy0 + ky) as isize, (ix0 + kx) as isize);
            for o in 0..4 {
                acc[o] = acc[o].max(px[o]);
            }
        }
    }
    acc
}

fn run_pool<T: TexRead>(
    tex: &T,
    k: usize,
    stride: usize,
    out_h: usize,
    out_w: usize,
    (oy0, oy1, ox0, ox1): (usize, usize, usize, usize),
    mut store: impl FnMut(usize, [f32; 4]),
) {
    let interior = oy0 < oy1 && ox0 < ox1;
    let (top_end, bot_start) = if interior { (oy0, oy1) } else { (out_h, out_h) };
    for oy in (0..top_end).chain(bot_start..out_h) {
        for ox in 0..out_w {
            store(oy * out_w + ox, pool_px_border(tex, k, oy * stride, ox * stride));
        }
    }
    if !interior {
        return;
    }
    for oy in oy0..oy1 {
        for ox in (0..ox0).chain(ox1..out_w) {
            store(oy * out_w + ox, pool_px_border(tex, k, oy * stride, ox * stride));
        }
    }
    for oy in oy0..oy1 {
        let iy0 = oy * stride;
        for ox in ox0..ox1 {
            let ix0 = ox * stride;
            let mut acc = [f32::NEG_INFINITY; 4];
            for ky in 0..k {
                let row = iy0 + ky;
                for kx in 0..k {
                    let px = tex.at(row, ix0 + kx);
                    for o in 0..4 {
                        acc[o] = acc[o].max(px[o]);
                    }
                }
            }
            store(oy * out_w + ox, acc);
        }
    }
}

// ---------------------------------------------------------------------------
// pass dispatch

/// Execute one compiled pass: `head` is the arena below the group's split
/// point (all inputs), `out` the pass's own output buffer.
fn exec_pass(pass: &CompiledPass, head: &[TexBuf], out: &mut TexBuf, rgba8: Option<&Rgba8Tables>) {
    let interior = (pass.oy0, pass.oy1, pass.ox0, pass.ox1);
    match (&pass.kind, rgba8) {
        (CompiledKind::Conv { k, stride, pad, relu, mats, bias }, None) => {
            let empty: &[[f32; 4]] = &[];
            let mut ins = [FloatTex { data: empty, h: 0, w: 0 }; 8];
            for (i, &slot) in pass.in_slots.iter().enumerate() {
                let t = &head[slot];
                let ScratchData::Float(v) = &t.data else { unreachable!("format mismatch") };
                ins[i] = FloatTex { data: v, h: t.h, w: t.w };
            }
            let ScratchData::Float(dst) = &mut out.data else { unreachable!() };
            run_conv(
                &ins[..pass.in_slots.len()],
                mats,
                *bias,
                *k,
                *stride,
                *pad,
                *relu,
                pass.out_h,
                pass.out_w,
                interior,
                |i, v| dst[i] = v,
            );
        }
        (CompiledKind::Conv { k, stride, pad, relu, mats, bias }, Some(tab)) => {
            let empty: &[[u8; 4]] = &[];
            let mut ins = [LutTex { data: empty, lut: &ZERO_LUT, h: 0, w: 0 }; 8];
            let lut = &tab.dequant[pass.in_layer];
            for (i, &slot) in pass.in_slots.iter().enumerate() {
                let t = &head[slot];
                let ScratchData::Rgba8(v) = &t.data else { unreachable!("format mismatch") };
                ins[i] = LutTex { data: v, lut, h: t.h, w: t.w };
            }
            let inv = tab.inv_scale[pass.layer];
            let ScratchData::Rgba8(dst) = &mut out.data else { unreachable!() };
            run_conv(
                &ins[..pass.in_slots.len()],
                mats,
                *bias,
                *k,
                *stride,
                *pad,
                *relu,
                pass.out_h,
                pass.out_w,
                interior,
                |i, v| dst[i] = quantize_px(v, inv),
            );
        }
        (CompiledKind::MaxPool { k, stride }, None) => {
            let t = &head[pass.in_slots[0]];
            let ScratchData::Float(v) = &t.data else { unreachable!() };
            let tex = FloatTex { data: v, h: t.h, w: t.w };
            let ScratchData::Float(dst) = &mut out.data else { unreachable!() };
            run_pool(&tex, *k, *stride, pass.out_h, pass.out_w, interior, |i, v| dst[i] = v);
        }
        (CompiledKind::MaxPool { k, stride }, Some(tab)) => {
            let t = &head[pass.in_slots[0]];
            let ScratchData::Rgba8(v) = &t.data else { unreachable!() };
            let tex = LutTex { data: v, lut: &tab.dequant[pass.in_layer], h: t.h, w: t.w };
            let inv = tab.inv_scale[pass.layer];
            let ScratchData::Rgba8(dst) = &mut out.data else { unreachable!() };
            run_pool(&tex, *k, *stride, pass.out_h, pass.out_w, interior, |i, v| {
                dst[i] = quantize_px(v, inv)
            });
        }
    }
}

// ---------------------------------------------------------------------------
// compilation

/// Interior bounds along one axis: smallest/one-past-largest output
/// coordinate whose taps `[o*stride - pad, o*stride - pad + k)` all land in
/// `[0, in_dim)`.
fn interior_axis(
    out_dim: usize,
    in_dim: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> (usize, usize) {
    let lo = pad.div_ceil(stride);
    if in_dim + pad < k {
        return (0, 0); // kernel larger than padded input: all border
    }
    let hi = ((in_dim + pad - k) / stride + 1).min(out_dim);
    if lo >= hi {
        (0, 0)
    } else {
        (lo, hi)
    }
}

impl CompiledPipeline {
    /// Compile `plan` + `weights` for steady-state execution. Mirrors the
    /// validation of [`ShaderPipeline::new`].
    pub fn new(plan: PassPlan, weights: Vec<ConvWeights>, format: TextureFormat) -> Result<Self> {
        let conv_index = conv_index_checked(&plan, &weights)?;

        let n_layers = plan.passes.iter().map(|p| p.layer).max().unwrap_or(0) + 1;
        let rgba8 = match &format {
            TextureFormat::Float => None,
            TextureFormat::Rgba8 { scales } => {
                anyhow::ensure!(
                    scales.len() >= n_layers,
                    "{} scales for {} layers",
                    scales.len(),
                    n_layers
                );
                let dequant = scales
                    .iter()
                    .map(|&s| {
                        let mut lut = [0.0f32; 256];
                        for (b, v) in lut.iter_mut().enumerate() {
                            *v = b as f32 / 255.0 * s;
                        }
                        lut
                    })
                    .collect();
                let inv_scale = scales.iter().map(|&s| 1.0 / s).collect();
                Some(Rgba8Tables { dequant, inv_scale })
            }
        };

        // scratch arena: one buffer per plan texture, preallocated
        let scratch: Vec<TexBuf> = plan
            .textures
            .iter()
            .map(|t| TexBuf {
                h: t.h,
                w: t.w,
                data: match &format {
                    TextureFormat::Float => ScratchData::Float(vec![[0.0; 4]; t.h * t.w]),
                    TextureFormat::Rgba8 { .. } => ScratchData::Rgba8(vec![[0; 4]; t.h * t.w]),
                },
            })
            .collect();

        // compile each pass
        let mut passes = Vec::with_capacity(plan.passes.len());
        for pass in &plan.passes {
            let in_tex = &plan.textures[pass.in_textures[0]];
            let (in_h, in_w, in_layer) = (in_tex.h, in_tex.w, in_tex.layer);
            let (kind, pad, k, stride) = match pass.kind {
                PassKind::Conv { k, stride, same, relu } => {
                    let pad = if same {
                        ((pass.out_h - 1) * stride + k).saturating_sub(in_h) / 2
                    } else {
                        0
                    };
                    let w = &weights[conv_index[&pass.layer]];
                    let (mats, bias) = tap_major_mats(w, pass.out_block, pass.in_textures.len(), k);
                    (CompiledKind::Conv { k, stride, pad, relu, mats, bias }, pad, k, stride)
                }
                PassKind::MaxPool { k, stride } => {
                    (CompiledKind::MaxPool { k, stride }, 0, k, stride)
                }
            };
            let (oy0, oy1) = interior_axis(pass.out_h, in_h, k, stride, pad);
            let (ox0, ox1) = interior_axis(pass.out_w, in_w, k, stride, pad);
            passes.push(CompiledPass {
                layer: pass.layer,
                in_layer,
                in_slots: pass.in_textures.clone(),
                out_slot: pass.out_texture,
                out_h: pass.out_h,
                out_w: pass.out_w,
                kind,
                oy0,
                oy1,
                ox0,
                ox1,
            });
        }

        // group consecutive same-layer passes; verify the arena split
        // invariant (inputs strictly below every output of the group)
        let mut groups: Vec<Group> = Vec::new();
        for (i, p) in passes.iter().enumerate() {
            match groups.last_mut() {
                Some(g) if passes[g.start].layer == p.layer => g.end = i + 1,
                _ => groups.push(Group { start: i, end: i + 1, split: 0 }),
            }
        }
        for g in &mut groups {
            let grp = &passes[g.start..g.end];
            let split = grp.iter().map(|p| p.out_slot).min().unwrap();
            for (j, p) in grp.iter().enumerate() {
                anyhow::ensure!(
                    p.in_slots.iter().all(|&s| s < split),
                    "pass plan is not layer-ordered: input slot >= output slot"
                );
                // the planner allocates a layer's output textures in pass
                // order, so slot `split + j` belongs to pass j — the
                // allocation-free parallel dispatch depends on it
                anyhow::ensure!(
                    p.out_slot == split + j,
                    "pass plan output slots are not consecutive within a layer"
                );
            }
            g.split = split;
        }

        let outputs: Vec<(usize, usize)> = plan
            .output_textures
            .iter()
            .map(|&t| (t, plan.textures[t].layer))
            .collect();
        let (out_h, out_w) = {
            let t = &plan.textures[outputs
                .first()
                .ok_or_else(|| anyhow!("plan has no output textures"))?
                .0];
            (t.h, t.w)
        };

        Ok(CompiledPipeline {
            plan,
            format,
            passes,
            groups,
            scratch,
            rgba8,
            outputs,
            out_h,
            out_w,
            threads: 1,
        })
    }

    /// Compile an existing legacy pipeline (the oracle) without consuming it.
    pub fn from_legacy(pipe: &ShaderPipeline) -> Result<Self> {
        CompiledPipeline::new(pipe.plan.clone(), pipe.weights().to_vec(), pipe.format.clone())
    }

    /// Worker budget for independent same-layer passes. 1 (the default)
    /// keeps execution on the calling thread — the zero-allocation path.
    pub fn set_threads(&mut self, n: usize) {
        self.threads = n.max(1);
    }

    pub fn plan(&self) -> &PassPlan {
        &self.plan
    }

    pub fn format(&self) -> &TextureFormat {
        &self.format
    }

    /// Output feature-map shape (C, H, W); C is block-padded to 4.
    pub fn out_shape(&self) -> (usize, usize, usize) {
        (self.outputs.len() * CHANNELS_PER_TEXTURE, self.out_h, self.out_w)
    }

    /// Upload the input frame into the layer-0 scratch textures in place.
    fn upload(&mut self, input: &Chw) {
        let inv0 = self.rgba8.as_ref().map(|t| t.inv_scale[0]);
        for (b, &slot) in self.plan.input_textures.iter().enumerate() {
            let buf = &mut self.scratch[slot];
            let (h, w) = (buf.h, buf.w);
            match (&mut buf.data, inv0) {
                (ScratchData::Float(vals), _) => {
                    for y in 0..h {
                        for x in 0..w {
                            let mut px = [0.0f32; 4];
                            for (ch, v) in px.iter_mut().enumerate() {
                                let c = b * CHANNELS_PER_TEXTURE + ch;
                                if c < input.c {
                                    *v = input.at(c, y, x);
                                }
                            }
                            vals[y * w + x] = px;
                        }
                    }
                }
                (ScratchData::Rgba8(vals), Some(inv)) => {
                    for y in 0..h {
                        for x in 0..w {
                            let mut px = [0.0f32; 4];
                            for (ch, v) in px.iter_mut().enumerate() {
                                let c = b * CHANNELS_PER_TEXTURE + ch;
                                if c < input.c {
                                    *v = input.at(c, y, x);
                                }
                            }
                            vals[y * w + x] = quantize_px(px, inv);
                        }
                    }
                }
                (ScratchData::Rgba8(_), None) => unreachable!("rgba8 arena without tables"),
            }
        }
    }

    /// Execute all passes over the current scratch contents.
    fn exec_all(&mut self) {
        let passes = &self.passes;
        let rgba8 = self.rgba8.as_ref();
        for g in &self.groups {
            let group = &passes[g.start..g.end];
            let (head, tail) = self.scratch.split_at_mut(g.split);
            if self.threads > 1 && group.len() > 1 {
                // contiguous chunks of the group per worker: pass j writes
                // slot split+j (checked at compile time), so slicing the
                // arena tail in lockstep with the pass list hands each
                // worker exclusive &mut output buffers and shared reads
                // below the split point — no per-frame bookkeeping allocs,
                // only the scoped thread spawns themselves
                let head: &[TexBuf] = head;
                let n = self.threads.min(group.len());
                let chunk = group.len().div_ceil(n);
                let outs = &mut tail[..group.len()];
                std::thread::scope(|s| {
                    for (passes_chunk, outs_chunk) in
                        group.chunks(chunk).zip(outs.chunks_mut(chunk))
                    {
                        s.spawn(move || {
                            for (p, out) in passes_chunk.iter().zip(outs_chunk) {
                                exec_pass(p, head, out, rgba8);
                            }
                        });
                    }
                });
            } else {
                for p in group {
                    let out = &mut tail[p.out_slot - g.split];
                    exec_pass(p, head, out, rgba8);
                }
            }
        }
    }

    /// Execute the pipeline on one frame, writing the feature map into a
    /// caller-owned buffer (resized only on shape mismatch) — the
    /// zero-allocation steady-state entry point.
    pub fn run_into(&mut self, input: &Chw, out: &mut Chw) -> Result<()> {
        anyhow::ensure!(
            input.h == self.plan.input_x && input.w == self.plan.input_x,
            "input is {}x{}, plan built for {}",
            input.h,
            input.w,
            self.plan.input_x
        );
        // the legacy path fails loudly on a channel mismatch (missing input
        // textures); match it rather than silently zero-filling lanes
        anyhow::ensure!(
            input.c.div_ceil(CHANNELS_PER_TEXTURE) == self.plan.input_textures.len(),
            "input has {} channels, plan expects {} input texture blocks",
            input.c,
            self.plan.input_textures.len()
        );
        self.upload(input);
        self.exec_all();

        let (c, h, w) = self.out_shape();
        if (out.c, out.h, out.w) != (c, h, w) {
            *out = Chw::zeros(c, h, w);
        }
        for (b, &(slot, layer)) in self.outputs.iter().enumerate() {
            let buf = &self.scratch[slot];
            match &buf.data {
                ScratchData::Float(vals) => {
                    for y in 0..h {
                        for x in 0..w {
                            let px = vals[y * w + x];
                            for (o, &v) in px.iter().enumerate() {
                                out.set(b * 4 + o, y, x, v);
                            }
                        }
                    }
                }
                ScratchData::Rgba8(vals) => {
                    let lut = &self.rgba8.as_ref().expect("tables").dequant[layer];
                    for y in 0..h {
                        for x in 0..w {
                            let px = vals[y * w + x];
                            for (o, &pb) in px.iter().enumerate() {
                                out.set(b * 4 + o, y, x, lut[pb as usize]);
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Convenience wrapper allocating the output (parity with the legacy
    /// `ShaderPipeline::run` signature).
    pub fn run(&mut self, input: &Chw) -> Result<Chw> {
        let (c, h, w) = self.out_shape();
        let mut out = Chw::zeros(c, h, w);
        self.run_into(input, &mut out)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shader::ir::{unpack_conv_weights, EncoderIr, Op};
    use crate::shader::planner::plan;
    use crate::util::rng::Rng;

    fn mini_ir(k_out: usize) -> EncoderIr {
        EncoderIr {
            name: "m".into(),
            input_channels: 9,
            ops: (0..3)
                .flat_map(|_| {
                    vec![Op::Conv { cout: k_out, k: 3, stride: 2, same: true }, Op::Relu]
                })
                .collect(),
        }
    }

    fn rand_frame(c: usize, x: usize, rng: &mut Rng) -> Chw {
        let mut f = Chw::zeros(c, x, x);
        for v in f.data.iter_mut() {
            *v = (rng.uniform() * 255.0).round() as f32 / 255.0;
        }
        f
    }

    #[test]
    fn interior_axis_bounds() {
        // 84 -> 42, k3 s2 same: pad 0, last row out of bounds
        assert_eq!(interior_axis(42, 84, 3, 2, 0), (0, 41));
        // 21 -> 11, k3 s2 same: pad 1, first and last rows border
        assert_eq!(interior_axis(11, 21, 3, 2, 1), (1, 10));
        // pool 2x2 s2 on even dims: fully interior
        assert_eq!(interior_axis(2, 4, 2, 2, 0), (0, 2));
        // kernel bigger than input: all border
        assert_eq!(interior_axis(1, 2, 3, 1, 0), (0, 0));
    }

    #[test]
    fn float_bit_exact_vs_legacy() {
        let mut rng = Rng::new(7);
        for k_out in [4usize, 16] {
            let ir = mini_ir(k_out);
            let flat: Vec<f32> =
                (0..ir.param_count()).map(|_| rng.normal_f32() * 0.3).collect();
            let frame = rand_frame(9, 24, &mut rng);
            let p = plan(&ir, 24).unwrap();
            let ws = unpack_conv_weights(&ir, &flat).unwrap();
            let legacy =
                ShaderPipeline::new(p.clone(), ws.clone(), TextureFormat::Float).unwrap();
            let mut compiled =
                CompiledPipeline::new(p, ws, TextureFormat::Float).unwrap();
            let want = legacy.run(&frame).unwrap();
            let got = compiled.run(&frame).unwrap();
            assert_eq!((got.c, got.h, got.w), (want.c, want.h, want.w));
            for (a, b) in got.data.iter().zip(&want.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "K={k_out}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn scratch_reuse_is_stateless_across_frames() {
        let mut rng = Rng::new(9);
        let ir = mini_ir(4);
        let flat: Vec<f32> = (0..ir.param_count()).map(|_| rng.normal_f32() * 0.3).collect();
        let p = plan(&ir, 24).unwrap();
        let ws = unpack_conv_weights(&ir, &flat).unwrap();
        let mut pipe = CompiledPipeline::new(p.clone(), ws.clone(), TextureFormat::Float).unwrap();
        let f1 = rand_frame(9, 24, &mut rng);
        let f2 = rand_frame(9, 24, &mut rng);
        let mut out = Chw::zeros(1, 1, 1);
        pipe.run_into(&f1, &mut out).unwrap();
        pipe.run_into(&f2, &mut out).unwrap();
        // second frame through a warm arena == first frame through a cold one
        let mut fresh = CompiledPipeline::new(p, ws, TextureFormat::Float).unwrap();
        let want = fresh.run(&f2).unwrap();
        assert_eq!(out.data, want.data);
    }

    #[test]
    fn parallel_passes_match_single_thread() {
        let mut rng = Rng::new(11);
        let ir = mini_ir(16); // 4 passes per layer -> real fan-out
        let flat: Vec<f32> = (0..ir.param_count()).map(|_| rng.normal_f32() * 0.3).collect();
        let frame = rand_frame(9, 24, &mut rng);
        let p = plan(&ir, 24).unwrap();
        let ws = unpack_conv_weights(&ir, &flat).unwrap();
        let mut one = CompiledPipeline::new(p.clone(), ws.clone(), TextureFormat::Float).unwrap();
        let mut four = CompiledPipeline::new(p, ws, TextureFormat::Float).unwrap();
        four.set_threads(4);
        let a = one.run(&frame).unwrap();
        let b = four.run(&frame).unwrap();
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn rgba8_error_bounded_vs_float() {
        let mut rng = Rng::new(13);
        let ir = mini_ir(4);
        let flat: Vec<f32> = (0..ir.param_count()).map(|_| rng.normal_f32() * 0.3).collect();
        let frame = rand_frame(9, 24, &mut rng);
        let p = plan(&ir, 24).unwrap();
        let ws = unpack_conv_weights(&ir, &flat).unwrap();
        let scales = ShaderPipeline::calibrate(&p, &ws, &frame).unwrap();
        let mut q = CompiledPipeline::new(
            p.clone(),
            ws.clone(),
            TextureFormat::Rgba8 { scales: scales.clone() },
        )
        .unwrap();
        let mut f = CompiledPipeline::new(p, ws, TextureFormat::Float).unwrap();
        let got_q = q.run(&frame).unwrap();
        let got_f = f.run(&frame).unwrap();
        let tol = scales.last().unwrap() * 0.05;
        let diff = got_q.max_abs_diff(&got_f);
        assert!(diff < tol, "diff {diff} vs tol {tol}");
        assert!(diff > 0.0, "quantisation should not be bit-exact");
    }

    #[test]
    fn maxpool_compiles_and_runs() {
        let ir = EncoderIr {
            name: "p".into(),
            input_channels: 4,
            ops: vec![Op::MaxPool { k: 2, stride: 2 }],
        };
        let p = plan(&ir, 4).unwrap();
        let mut pipe = CompiledPipeline::new(p, vec![], TextureFormat::Float).unwrap();
        let mut frame = Chw::zeros(4, 4, 4);
        frame.set(0, 1, 1, 0.9);
        frame.set(0, 2, 2, 0.4);
        let out = pipe.run(&frame).unwrap();
        assert_eq!(out.at(0, 0, 0), 0.9);
        assert_eq!(out.at(0, 1, 1), 0.4);
    }

    #[test]
    fn input_size_checked() {
        let ir = mini_ir(4);
        let p = plan(&ir, 24).unwrap();
        let flat = vec![0.0; ir.param_count()];
        let ws = unpack_conv_weights(&ir, &flat).unwrap();
        let mut pipe = CompiledPipeline::new(p, ws, TextureFormat::Float).unwrap();
        assert!(pipe.run(&Chw::zeros(9, 16, 16)).is_err());
    }

    #[test]
    fn weight_count_checked() {
        let ir = mini_ir(4);
        let p = plan(&ir, 24).unwrap();
        assert!(CompiledPipeline::new(p, vec![], TextureFormat::Float).is_err());
    }
}

//! The MiniConv shader toolchain — the paper's deployment contribution.
//!
//! Pipeline: [`ir::EncoderIr`] (from the artifact manifest) →
//! [`planner::plan`] (fragment-shader passes under embedded-GL limits) →
//! [`glsl::gen_all`] (GLSL ES 1.00 sources) and/or [`interp::ShaderPipeline`]
//! (software execution, float or RGBA8-quantised textures).
//!
//! Two software execution engines exist: [`interp::ShaderPipeline`], the
//! straightforward per-pass interpreter kept as the numerical oracle, and
//! [`compiled::CompiledPipeline`], the precompiled zero-allocation hot
//! path serving and sustained-load benches run per frame (bit-exact
//! against the oracle in Float mode).
//!
//! The planner enforces the constraints the paper documents for the
//! Pi Zero 2 W: 4 output channels per pass (RGBA), ≤ 8 bound textures,
//! ≤ 64 texture samples per shader.

pub mod compiled;
pub mod glsl;
pub mod interp;
pub mod ir;
pub mod planner;

pub use compiled::CompiledPipeline;
pub use glsl::{gen_all, ShaderSource, VERTEX_SHADER};
pub use interp::{ShaderPipeline, TextureFormat};
pub use ir::{unpack_conv_weights, ConvWeights, EncoderIr, Op};
pub use planner::{plan, Pass, PassKind, PassPlan, PlanError};

use crate::runtime::{EncoderMeta, Manifest};
use anyhow::Result;

/// Build a ready-to-run shader pipeline for a manifest encoder at input
/// size `x`, loading its trained/initial conv weights from `params_name`.
pub fn pipeline_from_manifest(
    manifest: &Manifest,
    arch: &str,
    meta: &EncoderMeta,
    x: usize,
    params_name: &str,
    format: TextureFormat,
) -> Result<ShaderPipeline> {
    anyhow::ensure!(
        meta.shader_deployable,
        "{arch} is not shader-deployable (the planner would reject it)"
    );
    let ir = EncoderIr::from_meta(arch, manifest.obs_channels, meta);
    let plan = plan(&ir, x)?;
    let flat = manifest.load_params(params_name)?;
    let weights = unpack_conv_weights(&ir, &flat)?;
    ShaderPipeline::new(plan, weights, format)
}

/// Build the precompiled hot-path pipeline for a manifest encoder — same
/// inputs as [`pipeline_from_manifest`], compiled for steady-state serving.
pub fn compiled_from_manifest(
    manifest: &Manifest,
    arch: &str,
    meta: &EncoderMeta,
    x: usize,
    params_name: &str,
    format: TextureFormat,
) -> Result<CompiledPipeline> {
    anyhow::ensure!(
        meta.shader_deployable,
        "{arch} is not shader-deployable (the planner would reject it)"
    );
    let ir = EncoderIr::from_meta(arch, manifest.obs_channels, meta);
    let plan = plan(&ir, x)?;
    let flat = manifest.load_params(params_name)?;
    let weights = unpack_conv_weights(&ir, &flat)?;
    CompiledPipeline::new(plan, weights, format)
}

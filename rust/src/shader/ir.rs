//! MiniConv intermediate representation: the encoder as a sequence of ops
//! that the pass planner maps onto OpenGL fragment-shader passes.
//!
//! The IR is deliberately small — the paper's point is that *this* op set
//! (small convs, ReLU, pooling) is exactly what compiles cleanly to
//! embedded-GL fragment shaders.

use crate::runtime::EncoderMeta;

#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// 2-D convolution; `same` pads with zeros so out = ceil(in/stride).
    Conv { cout: usize, k: usize, stride: usize, same: bool },
    /// ReLU applied to the previous op's output (fused into its pass).
    Relu,
    /// Max pooling (valid padding).
    MaxPool { k: usize, stride: usize },
}

#[derive(Debug, Clone)]
pub struct EncoderIr {
    pub name: String,
    pub input_channels: usize,
    pub ops: Vec<Op>,
}

impl EncoderIr {
    /// Build the IR for a manifest encoder (conv layers each followed by
    /// ReLU, mirroring model.py's `enc_apply`).
    pub fn from_meta(name: &str, input_channels: usize, meta: &EncoderMeta) -> EncoderIr {
        let mut ops = Vec::new();
        for l in &meta.layers {
            ops.push(Op::Conv { cout: l.cout, k: l.k, stride: l.stride, same: l.same });
            ops.push(Op::Relu);
        }
        EncoderIr { name: name.to_string(), input_channels, ops }
    }

    /// Channel count after every op.
    pub fn channel_trace(&self) -> Vec<usize> {
        let mut c = self.input_channels;
        let mut out = vec![c];
        for op in &self.ops {
            if let Op::Conv { cout, .. } = op {
                c = *cout;
            }
            out.push(c);
        }
        out
    }

    /// Output (c, h, w) for a square input of side `x`.
    pub fn out_shape(&self, x: usize) -> (usize, usize, usize) {
        let mut c = self.input_channels;
        let mut h = x;
        let mut w = x;
        for op in &self.ops {
            match op {
                Op::Conv { cout, k, stride, same } => {
                    c = *cout;
                    if *same {
                        h = h.div_ceil(*stride);
                        w = w.div_ceil(*stride);
                    } else {
                        h = (h - k) / stride + 1;
                        w = (w - k) / stride + 1;
                    }
                }
                Op::MaxPool { k, stride } => {
                    h = (h - k) / stride + 1;
                    w = (w - k) / stride + 1;
                }
                Op::Relu => {}
            }
        }
        (c, h, w)
    }

    /// Number of stride-2 layers `n` in the paper's bandwidth model
    /// (transmitted feature map is (X/2^n)^2).
    pub fn n_stride2(&self) -> usize {
        self.ops
            .iter()
            .filter(|op| matches!(op, Op::Conv { stride: 2, .. } | Op::MaxPool { stride: 2, .. }))
            .count()
    }

    /// Total weight+bias parameter count of all conv layers.
    pub fn param_count(&self) -> usize {
        let mut cin = self.input_channels;
        let mut total = 0;
        for op in &self.ops {
            if let Op::Conv { cout, k, .. } = op {
                total += cout * cin * k * k + cout;
                cin = *cout;
            }
        }
        total
    }
}

/// Per-layer conv weights in OIHW layout + bias, unpacked from the flat
/// parameter vector the artifacts use (layout from the manifest).
#[derive(Debug, Clone)]
pub struct ConvWeights {
    pub cout: usize,
    pub cin: usize,
    pub k: usize,
    pub w: Vec<f32>, // cout*cin*k*k
    pub b: Vec<f32>, // cout
}

/// Split a flat encoder parameter vector into per-layer conv weights.
pub fn unpack_conv_weights(ir: &EncoderIr, flat: &[f32]) -> anyhow::Result<Vec<ConvWeights>> {
    let mut out = Vec::new();
    let mut cin = ir.input_channels;
    let mut off = 0;
    for op in &ir.ops {
        if let Op::Conv { cout, k, .. } = op {
            let nw = cout * cin * k * k;
            anyhow::ensure!(off + nw + cout <= flat.len(), "flat params too short");
            out.push(ConvWeights {
                cout: *cout,
                cin,
                k: *k,
                w: flat[off..off + nw].to_vec(),
                b: flat[off + nw..off + nw + cout].to_vec(),
            });
            off += nw + cout;
            cin = *cout;
        }
    }
    anyhow::ensure!(
        off == flat.len(),
        "flat params: {} consumed, {} provided (dense tail is not shader-deployable)",
        off,
        flat.len()
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn miniconv4() -> EncoderIr {
        EncoderIr {
            name: "miniconv4".into(),
            input_channels: 9,
            ops: vec![
                Op::Conv { cout: 4, k: 3, stride: 2, same: true },
                Op::Relu,
                Op::Conv { cout: 4, k: 3, stride: 2, same: true },
                Op::Relu,
                Op::Conv { cout: 4, k: 3, stride: 2, same: true },
                Op::Relu,
            ],
        }
    }

    #[test]
    fn out_shape_is_ceil_x_over_8() {
        let ir = miniconv4();
        assert_eq!(ir.out_shape(84), (4, 11, 11));
        assert_eq!(ir.out_shape(400), (4, 50, 50));
        assert_eq!(ir.out_shape(36), (4, 5, 5));
    }

    #[test]
    fn n_stride2() {
        assert_eq!(miniconv4().n_stride2(), 3);
    }

    #[test]
    fn param_count_matches_model() {
        // (9*4*9+4) + (4*4*9+4)*2 — see python test_enc_param_count_tiny
        assert_eq!(miniconv4().param_count(), 328 + 148 + 148);
    }

    #[test]
    fn channel_trace() {
        let tr = miniconv4().channel_trace();
        assert_eq!(tr, vec![9, 4, 4, 4, 4, 4, 4]);
    }

    #[test]
    fn unpack_weights_layout() {
        let ir = miniconv4();
        let flat: Vec<f32> = (0..ir.param_count()).map(|i| i as f32).collect();
        let ws = unpack_conv_weights(&ir, &flat).unwrap();
        assert_eq!(ws.len(), 3);
        assert_eq!(ws[0].cin, 9);
        assert_eq!(ws[0].w[0], 0.0);
        assert_eq!(ws[0].b[0], (4 * 9 * 9) as f32); // bias follows weights
        assert_eq!(ws[1].cin, 4);
        // wrong length rejected
        assert!(unpack_conv_weights(&ir, &flat[..10]).is_err());
    }

    #[test]
    fn maxpool_shape() {
        let ir = EncoderIr {
            name: "p".into(),
            input_channels: 4,
            ops: vec![Op::MaxPool { k: 2, stride: 2 }],
        };
        assert_eq!(ir.out_shape(8), (4, 4, 4));
        assert_eq!(ir.n_stride2(), 1);
    }
}

//! Software fragment-shader interpreter: executes a [`PassPlan`] the way an
//! embedded GL stack would — texture by texture, pass by pass — so the
//! deployment path can be validated numerically without a GPU.
//!
//! Two texture formats are modelled:
//!   * `Float` — RGBA32F textures (OES_texture_float), bit-exact conv math;
//!   * `Rgba8 { scales }` — the ubiquitous RGBA8 path: every pass's output
//!     is quantised to 8 bits with a per-layer scale, exactly what happens
//!     on GPUs without float render targets (e.g. the Pi Zero 2 W's
//!     VideoCore). `calibrate()` picks the scales from a sample input.
//!
//! Validation: `validate.rs` checks Float mode against the reference conv
//! stack (and hence, transitively, against the Pallas/XLA artifacts).

use anyhow::{anyhow, Result};

use super::ir::ConvWeights;
use super::planner::{Pass, PassKind, PassPlan, CHANNELS_PER_TEXTURE};
use crate::tensor::Chw;

/// Texture storage format for intermediate activations.
#[derive(Debug, Clone)]
pub enum TextureFormat {
    Float,
    /// 8-bit textures: values stored as round(clamp(v/scale,0,1)*255).
    /// One scale per *layer* (all blocks of a layer share one scale).
    Rgba8 { scales: Vec<f32> },
}

/// One RGBA texture's storage.
#[derive(Debug, Clone)]
enum TexData {
    Float(Vec<[f32; 4]>),
    Rgba8 { data: Vec<[u8; 4]>, scale: f32 },
}

struct Tex {
    h: usize,
    w: usize,
    data: TexData,
}

impl Tex {
    #[inline]
    fn fetch(&self, y: isize, x: isize) -> [f32; 4] {
        // border-zero, matching the generated shader's coverage test
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            return [0.0; 4];
        }
        let i = y as usize * self.w + x as usize;
        match &self.data {
            TexData::Float(v) => v[i],
            TexData::Rgba8 { data, scale } => {
                let px = data[i];
                [
                    px[0] as f32 / 255.0 * scale,
                    px[1] as f32 / 255.0 * scale,
                    px[2] as f32 / 255.0 * scale,
                    px[3] as f32 / 255.0 * scale,
                ]
            }
        }
    }
}

fn quantize(v: f32, scale: f32) -> u8 {
    ((v / scale).clamp(0.0, 1.0) * 255.0).round() as u8
}

fn store(h: usize, w: usize, vals: Vec<[f32; 4]>, fmt: Option<f32>) -> Tex {
    match fmt {
        None => Tex { h, w, data: TexData::Float(vals) },
        Some(scale) => Tex {
            h,
            w,
            data: TexData::Rgba8 {
                data: vals
                    .iter()
                    .map(|px| {
                        [
                            quantize(px[0], scale),
                            quantize(px[1], scale),
                            quantize(px[2], scale),
                            quantize(px[3], scale),
                        ]
                    })
                    .collect(),
                scale,
            },
        },
    }
}

/// Weights for one pass as tap-major mat4 blocks (what the GLSL uniform
/// array holds): W[tap][in_block] is a 4x4 matrix out<-in. Shared by the
/// legacy interpreter and the compiled pipeline so both paths read the
/// exact same per-tap matrices.
pub(crate) fn tap_major_mats(
    w: &ConvWeights,
    out_block: usize,
    n_in: usize,
    k: usize,
) -> (Vec<[[f32; 4]; 4]>, [f32; 4]) {
    let mut mats = Vec::with_capacity(k * k * n_in);
    for ky in 0..k {
        for kx in 0..k {
            for ib in 0..n_in {
                let mut m = [[0.0f32; 4]; 4]; // m[out][in]
                for o in 0..4 {
                    let oc = out_block * 4 + o;
                    if oc >= w.cout {
                        continue;
                    }
                    for i in 0..4 {
                        let ic = ib * 4 + i;
                        if ic >= w.cin {
                            continue;
                        }
                        m[o][i] = w.w[((oc * w.cin + ic) * k + ky) * k + kx];
                    }
                }
                mats.push(m);
            }
        }
    }
    let mut bias = [0.0f32; 4];
    for o in 0..4 {
        let oc = out_block * 4 + o;
        if oc < w.cout {
            bias[o] = w.b[oc];
        }
    }
    (mats, bias)
}

/// Sorted conv-layer ids of a plan (one weight set per entry).
pub(crate) fn conv_layers_of(plan: &PassPlan) -> Vec<usize> {
    plan.passes
        .iter()
        .filter(|p| matches!(p.kind, PassKind::Conv { .. }))
        .map(|p| p.layer)
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect()
}

/// Validate one weight set per conv layer and build the conv layer id →
/// weight index map — shared by both pipeline constructors so the oracle
/// and the compiled hot path can never drift on this rule.
pub(crate) fn conv_index_checked(
    plan: &PassPlan,
    weights: &[ConvWeights],
) -> Result<std::collections::BTreeMap<usize, usize>> {
    let conv_layers = conv_layers_of(plan);
    anyhow::ensure!(
        conv_layers.len() == weights.len(),
        "plan has {} conv layers, {} weight sets given",
        conv_layers.len(),
        weights.len()
    );
    Ok(conv_layers.iter().enumerate().map(|(i, &l)| (l, i)).collect())
}

/// The GL pipeline state for one encoder: plan + per-layer weights.
pub struct ShaderPipeline {
    pub plan: PassPlan,
    weights: Vec<ConvWeights>,
    pub format: TextureFormat,
    /// conv layer id -> index into `weights`, built once at construction so
    /// the per-pass hot path never rescans the plan.
    conv_index: std::collections::BTreeMap<usize, usize>,
}

impl ShaderPipeline {
    pub fn new(plan: PassPlan, weights: Vec<ConvWeights>, format: TextureFormat) -> Result<Self> {
        // one ConvWeights per conv layer in the plan
        let conv_index = conv_index_checked(&plan, &weights)?;
        Ok(ShaderPipeline { plan, weights, format, conv_index })
    }

    /// Per-layer conv weights (for compiling this pipeline).
    pub fn weights(&self) -> &[ConvWeights] {
        &self.weights
    }

    fn layer_scale(&self, layer: usize) -> Option<f32> {
        match &self.format {
            TextureFormat::Float => None,
            TextureFormat::Rgba8 { scales } => Some(scales[layer]),
        }
    }

    /// Upload the input frame (CHW float in `[0,1]`) as packed RGBA textures.
    /// Input quantisation is exact for u8-sourced frames (x*255 is integral),
    /// mirroring the real pipeline where the camera frame *is* an RGBA8
    /// texture.
    fn upload(&self, input: &Chw) -> Vec<Tex> {
        let n_blocks = input.c.div_ceil(CHANNELS_PER_TEXTURE);
        let scale0 = self.layer_scale(0);
        (0..n_blocks)
            .map(|b| {
                let mut vals = vec![[0.0f32; 4]; input.h * input.w];
                for ch in 0..CHANNELS_PER_TEXTURE {
                    let c = b * CHANNELS_PER_TEXTURE + ch;
                    if c >= input.c {
                        break;
                    }
                    for y in 0..input.h {
                        for x in 0..input.w {
                            vals[y * input.w + x][ch] = input.at(c, y, x);
                        }
                    }
                }
                store(input.h, input.w, vals, scale0)
            })
            .collect()
    }

    /// Weights for one pass as tap-major mat4 blocks (cached layer index,
    /// no plan rescan).
    fn pass_mats(&self, pass: &Pass, k: usize) -> (Vec<[[f32; 4]; 4]>, [f32; 4]) {
        let conv_idx = *self.conv_index.get(&pass.layer).expect("conv layer index");
        tap_major_mats(&self.weights[conv_idx], pass.out_block, pass.in_textures.len(), k)
    }

    fn run_pass(&self, pass: &Pass, textures: &[Option<Tex>]) -> Tex {
        let scale = self.layer_scale(pass.layer);
        match pass.kind {
            PassKind::Conv { k, stride, same, relu } => {
                let ins: Vec<&Tex> = pass
                    .in_textures
                    .iter()
                    .map(|&t| textures[t].as_ref().expect("input texture live"))
                    .collect();
                let (mats, bias) = self.pass_mats(pass, k);
                let in_h = ins[0].h;
                let pad = if same {
                    (((pass.out_h - 1) * stride + k).saturating_sub(in_h) / 2) as isize
                } else {
                    0
                };
                let mut vals = vec![[0.0f32; 4]; pass.out_h * pass.out_w];
                for oy in 0..pass.out_h {
                    for ox in 0..pass.out_w {
                        let mut acc = bias;
                        let mut m = 0;
                        let iy0 = (oy * stride) as isize - pad;
                        let ix0 = (ox * stride) as isize - pad;
                        for ky in 0..k {
                            for kx in 0..k {
                                for tex in &ins {
                                    let px = tex.fetch(iy0 + ky as isize, ix0 + kx as isize);
                                    let w = &mats[m];
                                    for o in 0..4 {
                                        acc[o] += w[o][0] * px[0]
                                            + w[o][1] * px[1]
                                            + w[o][2] * px[2]
                                            + w[o][3] * px[3];
                                    }
                                    m += 1;
                                }
                            }
                        }
                        if relu {
                            for a in acc.iter_mut() {
                                *a = a.max(0.0);
                            }
                        }
                        vals[oy * pass.out_w + ox] = acc;
                    }
                }
                store(pass.out_h, pass.out_w, vals, scale)
            }
            PassKind::MaxPool { k, stride } => {
                let tex = textures[pass.in_textures[0]].as_ref().expect("input");
                let mut vals = vec![[0.0f32; 4]; pass.out_h * pass.out_w];
                for oy in 0..pass.out_h {
                    for ox in 0..pass.out_w {
                        let mut acc = [f32::NEG_INFINITY; 4];
                        for ky in 0..k {
                            for kx in 0..k {
                                let px = tex.fetch(
                                    (oy * stride + ky) as isize,
                                    (ox * stride + kx) as isize,
                                );
                                for o in 0..4 {
                                    acc[o] = acc[o].max(px[o]);
                                }
                            }
                        }
                        vals[oy * pass.out_w + ox] = acc;
                    }
                }
                store(pass.out_h, pass.out_w, vals, scale)
            }
        }
    }

    /// Execute the full pipeline on one frame. Returns the feature map
    /// (C,H,W) assembled from the output textures.
    pub fn run(&self, input: &Chw) -> Result<Chw> {
        anyhow::ensure!(
            input.h == self.plan.input_x && input.w == self.plan.input_x,
            "input is {}x{}, plan built for {}",
            input.h,
            input.w,
            self.plan.input_x
        );
        let mut textures: Vec<Option<Tex>> = vec![None; self.plan.textures.len()]
            .into_iter()
            .map(|_: Option<()>| None)
            .collect();
        for (slot, tex) in self
            .plan
            .input_textures
            .iter()
            .zip(self.upload(input))
        {
            textures[*slot] = Some(tex);
        }
        for pass in &self.plan.passes {
            let out = self.run_pass(pass, &textures);
            textures[pass.out_texture] = Some(out);
        }
        // assemble output feature map
        let out_texs: Vec<&Tex> = self
            .plan
            .output_textures
            .iter()
            .map(|&t| textures[t].as_ref().ok_or_else(|| anyhow!("missing output texture")))
            .collect::<Result<_>>()?;
        let (h, w) = (out_texs[0].h, out_texs[0].w);
        let c = out_texs.len() * CHANNELS_PER_TEXTURE;
        let mut out = Chw::zeros(c, h, w);
        for (b, tex) in out_texs.iter().enumerate() {
            for y in 0..h {
                for x in 0..w {
                    let px = tex.fetch(y as isize, x as isize);
                    for o in 0..4 {
                        out.set(b * 4 + o, y, x, px[o]);
                    }
                }
            }
        }
        Ok(out)
    }

    /// Choose per-layer RGBA8 scales from a calibration frame: run in float
    /// mode and take each layer's max activation (headroom x1.05).
    pub fn calibrate(plan: &PassPlan, weights: &[ConvWeights], frame: &Chw) -> Result<Vec<f32>> {
        let float_pipe =
            ShaderPipeline::new(plan.clone(), weights.to_vec(), TextureFormat::Float)?;
        let n_layers = plan.passes.iter().map(|p| p.layer).max().unwrap_or(0) + 1;
        let mut scales = vec![1.0f32; n_layers];

        // run and track per-layer maxima
        let mut textures: Vec<Option<Tex>> = (0..plan.textures.len()).map(|_| None).collect();
        for (slot, tex) in plan.input_textures.iter().zip(float_pipe.upload(frame)) {
            textures[*slot] = Some(tex);
        }
        for pass in &plan.passes {
            let out = float_pipe.run_pass(pass, &textures);
            if let TexData::Float(vals) = &out.data {
                let mx = vals
                    .iter()
                    .flat_map(|p| p.iter())
                    .fold(0.0f32, |a, &b| a.max(b));
                scales[pass.layer] = scales[pass.layer].max(mx * 1.05);
            }
            textures[pass.out_texture] = Some(out);
        }
        Ok(scales)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shader::ir::{unpack_conv_weights, EncoderIr, Op};
    use crate::shader::planner::plan;
    use crate::tensor::{conv2d_ref, relu as relu_ref};
    use crate::util::rng::Rng;

    fn mini_ir(k_out: usize) -> EncoderIr {
        EncoderIr {
            name: "m".into(),
            input_channels: 9,
            ops: (0..3)
                .flat_map(|_| {
                    vec![Op::Conv { cout: k_out, k: 3, stride: 2, same: true }, Op::Relu]
                })
                .collect(),
        }
    }

    fn rand_params(ir: &EncoderIr, rng: &mut Rng) -> Vec<f32> {
        (0..ir.param_count()).map(|_| rng.normal_f32() * 0.3).collect()
    }

    fn rand_frame(c: usize, x: usize, rng: &mut Rng) -> Chw {
        // u8-quantised values in `[0,1]`, like a real rendered frame
        let mut f = Chw::zeros(c, x, x);
        for v in f.data.iter_mut() {
            *v = (rng.uniform() * 255.0).round() as f32 / 255.0;
        }
        f
    }

    /// Reference: run the conv stack with the plain Chw conv.
    fn reference(ir: &EncoderIr, flat: &[f32], frame: &Chw) -> Chw {
        let ws = unpack_conv_weights(ir, flat).unwrap();
        let mut x = frame.clone();
        for w in &ws {
            let mut out = conv2d_ref(&x, &w.w, &w.b, w.cout, w.k, 2, true);
            relu_ref(&mut out);
            x = out;
        }
        x
    }

    #[test]
    fn float_mode_matches_reference_conv() {
        let mut rng = Rng::new(1);
        for k_out in [4usize, 16] {
            let ir = mini_ir(k_out);
            let flat = rand_params(&ir, &mut rng);
            let frame = rand_frame(9, 24, &mut rng);
            let p = plan(&ir, 24).unwrap();
            let ws = unpack_conv_weights(&ir, &flat).unwrap();
            let pipe = ShaderPipeline::new(p, ws, TextureFormat::Float).unwrap();
            let got = pipe.run(&frame).unwrap();
            let want = reference(&ir, &flat, &frame);
            // interpreter output is channel-padded to blocks of 4
            assert!(got.c >= want.c);
            let mut max_diff = 0.0f32;
            for c in 0..want.c {
                for y in 0..want.h {
                    for x in 0..want.w {
                        max_diff = max_diff.max((got.at(c, y, x) - want.at(c, y, x)).abs());
                    }
                }
            }
            assert!(max_diff < 1e-4, "K={k_out}: max diff {max_diff}");
            // padding channels are exactly zero
            for c in want.c..got.c {
                for y in 0..got.h {
                    for x in 0..got.w {
                        assert_eq!(got.at(c, y, x), 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn rgba8_mode_quantisation_error_bounded() {
        let mut rng = Rng::new(2);
        let ir = mini_ir(4);
        let flat = rand_params(&ir, &mut rng);
        let frame = rand_frame(9, 24, &mut rng);
        let p = plan(&ir, 24).unwrap();
        let ws = unpack_conv_weights(&ir, &flat).unwrap();

        let scales = ShaderPipeline::calibrate(&p, &ws, &frame).unwrap();
        assert!(scales.iter().all(|&s| s >= 1.0));

        let pipe8 = ShaderPipeline::new(
            p.clone(),
            ws.clone(),
            TextureFormat::Rgba8 { scales: scales.clone() },
        )
        .unwrap();
        let pipef = ShaderPipeline::new(p, ws, TextureFormat::Float).unwrap();
        let got8 = pipe8.run(&frame).unwrap();
        let gotf = pipef.run(&frame).unwrap();
        // 3 layers of 8-bit quantisation: error stays well under 5% of scale
        let tol = scales.last().unwrap() * 0.05;
        let diff = got8.max_abs_diff(&gotf);
        assert!(diff < tol, "diff {diff} vs tol {tol}");
        // but it is *not* bit-exact (quantisation is real)
        assert!(diff > 0.0);
    }

    #[test]
    fn input_size_checked() {
        let ir = mini_ir(4);
        let flat = vec![0.0; ir.param_count()];
        let p = plan(&ir, 24).unwrap();
        let ws = unpack_conv_weights(&ir, &flat).unwrap();
        let pipe = ShaderPipeline::new(p, ws, TextureFormat::Float).unwrap();
        assert!(pipe.run(&Chw::zeros(9, 16, 16)).is_err());
    }

    #[test]
    fn weight_count_checked() {
        let ir = mini_ir(4);
        let p = plan(&ir, 24).unwrap();
        assert!(ShaderPipeline::new(p, vec![], TextureFormat::Float).is_err());
    }

    #[test]
    fn maxpool_pass_executes() {
        let ir = EncoderIr {
            name: "p".into(),
            input_channels: 4,
            ops: vec![Op::MaxPool { k: 2, stride: 2 }],
        };
        let p = plan(&ir, 4).unwrap();
        let pipe = ShaderPipeline::new(p, vec![], TextureFormat::Float).unwrap();
        let mut frame = Chw::zeros(4, 4, 4);
        frame.set(0, 1, 1, 0.9);
        frame.set(0, 2, 2, 0.4);
        let out = pipe.run(&frame).unwrap();
        assert_eq!(out.at(0, 0, 0), 0.9);
        assert_eq!(out.at(0, 1, 1), 0.4);
    }
}

//! On-device execution experiments: Figure 2 (per-frame time vs input
//! size), Figure 3 (sustained 5,000-frame runs), Figure 4 (resource
//! traces). All run on the calibrated device simulators over the real
//! MiniConv-4 shader plan (DESIGN.md §2 substitution).

use crate::device::{Device, DeviceSpec, ExecPath, FrameCost};
use crate::shader::ir::{EncoderIr, Op};
use crate::shader::plan;
use crate::telemetry::Recorder;
use crate::util::stats::Running;
use crate::util::tables::Table;

/// The deployed encoder: MiniConv-4 (3x 3x3-s2 conv+ReLU over 9 channels).
pub fn miniconv4_ir() -> EncoderIr {
    EncoderIr {
        name: "miniconv4".into(),
        input_channels: 9,
        ops: (0..3)
            .flat_map(|_| vec![Op::Conv { cout: 4, k: 3, stride: 2, same: true }, Op::Relu])
            .collect(),
    }
}

/// The wide variant: MiniConv-16 (4 passes per layer — the multi-pass
/// layer shape the parallel hot path fans out over).
pub fn miniconv16_ir() -> EncoderIr {
    EncoderIr {
        name: "miniconv16".into(),
        input_channels: 9,
        ops: (0..3)
            .flat_map(|_| vec![Op::Conv { cout: 16, k: 3, stride: 2, same: true }, Op::Relu])
            .collect(),
    }
}

pub fn frame_cost(x: usize) -> FrameCost {
    FrameCost::from_plan(&plan(&miniconv4_ir(), x).expect("miniconv4 plan"))
}

/// Figure 2: per-frame processing time (mean ± std of `reps` consecutive
/// inferences) across devices as input size varies.
pub fn fig2_framesize(devices: &[DeviceSpec], sizes: &[usize], reps: usize) -> Table {
    let mut t = Table::new(
        "Figure 2 — per-frame processing time vs input size (mean±sd of consecutive inferences)",
        &["device", "X", "mean (ms)", "sd (ms)", "fps"],
    );
    for spec in devices {
        for &x in sizes {
            let cost = frame_cost(x);
            let mut d = Device::new(spec.clone(), 42);
            let mut stats = Running::new();
            for _ in 0..reps {
                stats.push(d.encode_frame(&cost, ExecPath::Gpu).duration);
            }
            t.row(&[
                spec.name.to_string(),
                x.to_string(),
                format!("{:.1}", stats.mean() * 1e3),
                format!("{:.2}", stats.std() * 1e3),
                format!("{:.1}", 1.0 / stats.mean()),
            ]);
        }
    }
    t
}

/// One sustained run's trace + summary.
pub struct SustainedTrace {
    pub label: String,
    pub recorder: Recorder,
    pub head_mean_ms: f64,
    pub tail_mean_ms: f64,
}

/// Run `frames` consecutive inferences and record per-frame telemetry.
pub fn sustained_run(
    label: &str,
    spec: DeviceSpec,
    x: usize,
    frames: usize,
    path: ExecPath,
    seed: u64,
) -> SustainedTrace {
    let cost = frame_cost(x);
    let mut d = Device::new(spec, seed);
    let mut rec = Recorder::new();
    for i in 0..frames {
        let s = d.encode_frame(&cost, path);
        rec.record(
            i as f64,
            &[
                ("frame_ms", s.duration * 1e3),
                ("temp_c", s.temp),
                ("watts", s.watts),
                ("ram_mb", s.ram_mb),
                ("clock", s.clock_frac),
            ],
        );
    }
    let head = rec.head_mean("frame_ms", 200).unwrap_or(0.0);
    let tail = rec.tail_mean("frame_ms", 200).unwrap_or(0.0);
    SustainedTrace {
        label: label.to_string(),
        recorder: rec,
        head_mean_ms: head,
        tail_mean_ms: tail,
    }
}

/// Figure 3: sustained inference over `frames` consecutive frames.
/// (a) Jetson at 3000², power caps; (b) Pi Zero 2 W at 400², GL vs CPU.
pub fn fig3_sustained(frames: usize) -> (Vec<SustainedTrace>, Table) {
    let traces = vec![
        sustained_run(
            "jetson-nano (no limit, 3000²)",
            crate::device::jetson_nano(None),
            3000,
            frames,
            ExecPath::Gpu,
            1,
        ),
        sustained_run(
            "jetson-nano (5W cap, 3000²)",
            crate::device::jetson_nano(Some(5.0)),
            3000,
            frames,
            ExecPath::Gpu,
            1,
        ),
        sustained_run(
            "pi-zero-2w GPU/OpenGL (400²)",
            crate::device::pi_zero_2w(),
            400,
            frames,
            ExecPath::Gpu,
            2,
        ),
        sustained_run(
            "pi-zero-2w CPU/PyTorch (400²)",
            crate::device::pi_zero_2w(),
            400,
            frames,
            ExecPath::Cpu,
            2,
        ),
    ];
    let mut t = Table::new(
        "Figure 3 — sustained inference (first-200 vs last-200 frame mean)",
        &["condition", "head mean (ms)", "tail mean (ms)", "drift", "frame-time trace"],
    );
    for tr in &traces {
        t.row(&[
            tr.label.clone(),
            format!("{:.1}", tr.head_mean_ms),
            format!("{:.1}", tr.tail_mean_ms),
            format!("{:.2}x", tr.tail_mean_ms / tr.head_mean_ms.max(1e-9)),
            tr.recorder.sparkline("frame_ms", 40),
        ]);
    }
    (traces, t)
}

/// Figure 4: resource usage during sustained inference — Pi Zero temp/RAM
/// (CPU vs GPU), Jetson power/memory (5W vs none, 3000²).
pub fn fig4_resources(frames: usize) -> (Vec<SustainedTrace>, Table) {
    let traces = vec![
        sustained_run("pi-zero-2w GPU", crate::device::pi_zero_2w(), 400, frames, ExecPath::Gpu, 3),
        sustained_run("pi-zero-2w CPU", crate::device::pi_zero_2w(), 400, frames, ExecPath::Cpu, 3),
        sustained_run("jetson (no limit)", crate::device::jetson_nano(None), 3000, frames, ExecPath::Gpu, 4),
        sustained_run("jetson (5W)", crate::device::jetson_nano(Some(5.0)), 3000, frames, ExecPath::Gpu, 4),
    ];
    let mut t = Table::new(
        "Figure 4 — resource usage during sustained inference",
        &["condition", "final temp (°C)", "mean W", "RAM (MB)", "temp trace"],
    );
    for tr in &traces {
        let temp = tr.recorder.tail_mean("temp_c", 50).unwrap_or(0.0);
        let watts = tr.recorder.tail_mean("watts", frames).unwrap_or(0.0);
        let ram = tr.recorder.tail_mean("ram_mb", 50).unwrap_or(0.0);
        t.row(&[
            tr.label.clone(),
            format!("{temp:.1}"),
            format!("{watts:.2}"),
            format!("{ram:.0}"),
            tr.recorder.sparkline("temp_c", 40),
        ]);
    }
    (traces, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{all_devices, pi_zero_2w};

    #[test]
    fn fig2_has_row_per_device_size() {
        let t = fig2_framesize(&all_devices(), &[100, 200], 10);
        assert_eq!(t.n_rows(), 6);
    }

    #[test]
    fn sustained_trace_records_all_series() {
        let tr = sustained_run("x", pi_zero_2w(), 200, 50, ExecPath::Gpu, 0);
        assert_eq!(tr.recorder.len(), 50);
        for k in ["frame_ms", "temp_c", "watts", "ram_mb", "clock"] {
            assert!(tr.recorder.get(k).is_some(), "{k} missing");
        }
        assert!(tr.head_mean_ms > 0.0);
    }

    #[test]
    fn fig3_shapes_hold_at_reduced_length() {
        let (traces, t) = fig3_sustained(1200);
        assert_eq!(t.n_rows(), 4);
        // jetson uncapped drifts up; capped starts slower
        let jet_free = &traces[0];
        let jet_cap = &traces[1];
        assert!(jet_cap.head_mean_ms > 1.2 * jet_free.head_mean_ms);
        // pi zero: cpu slower than gpu
        let gpu = &traces[2];
        let cpu = &traces[3];
        assert!(cpu.head_mean_ms > 1.5 * gpu.head_mean_ms);
    }
}

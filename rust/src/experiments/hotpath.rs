//! Hot-path harness: legacy interpreter vs compiled pipeline, artifact-free.
//!
//! Builds the MiniConv encoder plan with synthetic deterministic weights,
//! runs both engines on the same frames, and reports frames/sec and
//! ns/pass per (format, engine, threads) cell plus the single-thread
//! speedups the perf trajectory is tracked by. `benches/micro_hotpath.rs`
//! wraps this into a before/after table and emits `BENCH_hotpath.json`.

use std::time::Instant;

use anyhow::Result;

use crate::shader::{plan, CompiledPipeline, EncoderIr, ShaderPipeline, TextureFormat};
use crate::shader::{unpack_conv_weights, ConvWeights, PassPlan};
use crate::tensor::Chw;
use crate::util::rng::Rng;

/// One measured cell of the hot-path matrix.
#[derive(Debug, Clone)]
pub struct HotpathRow {
    /// "float" | "rgba8"
    pub format: String,
    /// "legacy" | "compiled"
    pub engine: String,
    pub threads: usize,
    pub frames_per_sec: f64,
    pub ns_per_pass: f64,
}

#[derive(Debug, Clone)]
pub struct HotpathReport {
    pub arch: String,
    pub input_x: usize,
    pub iters: usize,
    pub n_passes: usize,
    pub rows: Vec<HotpathRow>,
    /// compiled/legacy single-thread frames-per-sec ratios
    pub speedup_float_1t: f64,
    pub speedup_rgba8_1t: f64,
    /// heap allocations per steady-state compiled frame (threads = 1),
    /// measured by the bench binary's counting allocator; None when the
    /// harness runs without one
    pub allocs_per_frame: Option<u64>,
}

/// Deterministic synthetic weights (same distribution the parity tests use).
pub fn synthetic_weights(ir: &EncoderIr, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..ir.param_count()).map(|_| rng.normal_f32() * 0.3).collect()
}

/// Deterministic u8-quantised frame in `[0,1]`, like a rendered camera frame.
pub fn synthetic_frame(c: usize, x: usize, seed: u64) -> Chw {
    let mut rng = Rng::new(seed);
    let mut f = Chw::zeros(c, x, x);
    for v in f.data.iter_mut() {
        *v = (rng.uniform() * 255.0).round() as f32 / 255.0;
    }
    f
}

fn time_frames<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    for _ in 0..(iters / 10).max(1) {
        f(); // warmup
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

#[allow(clippy::too_many_arguments)]
fn push_rows(
    rows: &mut Vec<HotpathRow>,
    format: &str,
    plan: &PassPlan,
    weights: &[ConvWeights],
    tex_format: &TextureFormat,
    frame: &Chw,
    iters: usize,
    threads: &[usize],
) -> Result<()> {
    let n_passes = plan.passes.len();
    let legacy = ShaderPipeline::new(plan.clone(), weights.to_vec(), tex_format.clone())?;
    let per = time_frames(iters, || {
        std::hint::black_box(legacy.run(frame).unwrap());
    });
    rows.push(HotpathRow {
        format: format.into(),
        engine: "legacy".into(),
        threads: 1,
        frames_per_sec: 1.0 / per,
        ns_per_pass: per * 1e9 / n_passes as f64,
    });
    for &t in threads {
        let mut compiled =
            CompiledPipeline::new(plan.clone(), weights.to_vec(), tex_format.clone())?;
        compiled.set_threads(t);
        let mut out = Chw::zeros(1, 1, 1);
        let per = time_frames(iters, || {
            compiled.run_into(frame, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        rows.push(HotpathRow {
            format: format.into(),
            engine: "compiled".into(),
            threads: t,
            frames_per_sec: 1.0 / per,
            ns_per_pass: per * 1e9 / n_passes as f64,
        });
    }
    Ok(())
}

fn speedup(rows: &[HotpathRow], format: &str) -> f64 {
    let fps = |engine: &str| {
        rows.iter()
            .find(|r| r.format == format && r.engine == engine && r.threads == 1)
            .map(|r| r.frames_per_sec)
            .unwrap_or(0.0)
    };
    let legacy = fps("legacy");
    if legacy > 0.0 {
        fps("compiled") / legacy
    } else {
        0.0
    }
}

/// Run the full matrix for one encoder IR at input size `x`: Float and
/// Rgba8 (scales calibrated on the bench frame), legacy vs compiled at
/// each thread count in `threads`.
pub fn run_hotpath(
    ir: &EncoderIr,
    x: usize,
    iters: usize,
    threads: &[usize],
) -> Result<HotpathReport> {
    let p = plan(ir, x).map_err(|e| anyhow::anyhow!("plan: {e}"))?;
    let flat = synthetic_weights(ir, 1);
    let weights = unpack_conv_weights(ir, &flat)?;
    let frame = synthetic_frame(ir.input_channels, x, 2);
    let scales = ShaderPipeline::calibrate(&p, &weights, &frame)?;

    let mut rows = Vec::new();
    push_rows(&mut rows, "float", &p, &weights, &TextureFormat::Float, &frame, iters, threads)?;
    push_rows(
        &mut rows,
        "rgba8",
        &p,
        &weights,
        &TextureFormat::Rgba8 { scales },
        &frame,
        iters,
        threads,
    )?;

    let speedup_float_1t = speedup(&rows, "float");
    let speedup_rgba8_1t = speedup(&rows, "rgba8");
    Ok(HotpathReport {
        arch: ir.name.clone(),
        input_x: x,
        iters,
        n_passes: p.passes.len(),
        rows,
        speedup_float_1t,
        speedup_rgba8_1t,
        allocs_per_frame: None,
    })
}

impl HotpathReport {
    /// Machine-readable record for `BENCH_hotpath.json` (no serde offline —
    /// hand-rolled, stable field order).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"bench\": \"micro_hotpath\",\n");
        s.push_str(&format!("  \"arch\": \"{}\",\n", self.arch));
        s.push_str(&format!("  \"input_x\": {},\n", self.input_x));
        s.push_str(&format!("  \"iters\": {},\n", self.iters));
        s.push_str(&format!("  \"n_passes\": {},\n", self.n_passes));
        s.push_str(&format!("  \"speedup_float_1t\": {:.3},\n", self.speedup_float_1t));
        s.push_str(&format!("  \"speedup_rgba8_1t\": {:.3},\n", self.speedup_rgba8_1t));
        match self.allocs_per_frame {
            Some(n) => s.push_str(&format!("  \"steady_state_allocs_per_frame\": {n},\n")),
            None => s.push_str("  \"steady_state_allocs_per_frame\": null,\n"),
        }
        s.push_str("  \"results\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"format\": \"{}\", \"engine\": \"{}\", \"threads\": {}, \
                 \"frames_per_sec\": {:.1}, \"ns_per_pass\": {:.0}}}{}\n",
                r.format,
                r.engine,
                r.threads,
                r.frames_per_sec,
                r.ns_per_pass,
                if i + 1 == self.rows.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::execution::miniconv4_ir;

    #[test]
    fn harness_measures_all_cells() {
        // tiny input + few iters: shape check, not a perf assertion
        let rep = run_hotpath(&miniconv4_ir(), 24, 3, &[1, 2]).unwrap();
        assert_eq!(rep.rows.len(), 2 * 3); // 2 formats x (legacy + 2 compiled)
        assert!(rep.rows.iter().all(|r| r.frames_per_sec > 0.0));
        assert!(rep.speedup_float_1t > 0.0);
        let json = rep.to_json();
        assert!(json.contains("\"speedup_float_1t\""));
        assert!(json.contains("\"engine\": \"compiled\""));
        assert!(json.contains("\"steady_state_allocs_per_frame\": null"));
    }

    #[test]
    fn synthetic_inputs_deterministic() {
        let ir = miniconv4_ir();
        assert_eq!(synthetic_weights(&ir, 5), synthetic_weights(&ir, 5));
        assert_eq!(synthetic_frame(9, 8, 5).data, synthetic_frame(9, 8, 5).data);
    }
}

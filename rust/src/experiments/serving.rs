//! Serving experiments: Figure 5 (decision-latency breakdown), Table 5
//! (end-to-end decision latency under bandwidth shaping) and Table 6
//! (server scalability), in two modes:
//!
//!   * **sim** — paper-scale (X=400) over the analytic link model, the
//!     Pi Zero 2 W device simulator, and a GPU-server cost model calibrated
//!     to the paper's residuals (see [`ServerCostModel`]); deterministic.
//!   * **real** — task-scale (X=84) over the actual coordinator, loopback
//!     TCP, and PJRT executables (driven from benches/examples).

use crate::analysis::latency::DecisionBreakdown;
use crate::device::{Device, ExecPath};
use crate::net::shaped::LinkModel;
use crate::util::rng::Rng;
use crate::util::simclock::EventQueue;
use crate::util::stats::Samples;
use crate::util::tables::Table;

use super::execution::frame_cost;

/// Server-side compute model for the paper's GPU server. Calibrated from
/// the paper's Table 5 residuals: at 100 Mb/s server-only = 90 ms with a
/// 51.2 ms uplink, leaving ~38 ms of RTT+compute; the split pipeline's
/// non-device residual is ~36 ms — i.e. a ~30 ms network/framework floor
/// plus single-digit-ms model times.
#[derive(Debug, Clone, Copy)]
pub struct ServerCostModel {
    /// one-way link latency (includes framework overhead), s
    pub one_way_latency: f64,
    /// Full-CNN policy execution per request, s
    pub full_compute: f64,
    /// head-only execution per request, s
    pub head_compute: f64,
    pub action_bytes: usize,
}

impl Default for ServerCostModel {
    fn default() -> Self {
        ServerCostModel {
            one_way_latency: 0.015,
            full_compute: 0.008,
            head_compute: 0.005,
            action_bytes: 64,
        }
    }
}

/// Median on-device encode time at size `x` on the Pi Zero 2 W (GL path).
pub fn device_j(x: usize, reps: usize) -> f64 {
    let mut d = Device::new(crate::device::pi_zero_2w(), 7);
    let cost = frame_cost(x);
    let mut s = Samples::new();
    for _ in 0..reps {
        s.push(d.encode_frame(&cost, ExecPath::Gpu).duration);
    }
    s.median()
}

/// Figure 5: component breakdown of one decision for both pipelines.
pub fn fig5_breakdown(x: usize, bandwidth_bps: f64, model: &ServerCostModel) -> Table {
    let link = LinkModel::new(bandwidth_bps, model.one_way_latency);
    let j = device_j(x, 200);
    let so = DecisionBreakdown::server_only(&link, x, model.full_compute, model.action_bytes);
    let sp = DecisionBreakdown::split(&link, x, 3, 4, j, model.head_compute, model.action_bytes);
    let mut t = Table::new(
        &format!(
            "Figure 5 — decision-latency components (X={x}, {:.0} Mb/s)",
            bandwidth_bps / 1e6
        ),
        &["component", "server-only (ms)", "split-policy (ms)"],
    );
    let ms = |v: f64| format!("{:.1}", v * 1e3);
    t.row(&["on-device encode".into(), ms(so.device_encode), ms(sp.device_encode)]);
    t.row(&["observation/feature uplink".into(), ms(so.uplink), ms(sp.uplink)]);
    t.row(&["server compute".into(), ms(so.server_compute), ms(sp.server_compute)]);
    t.row(&["action downlink".into(), ms(so.downlink), ms(sp.downlink)]);
    t.row(&["TOTAL".into(), ms(so.total()), ms(sp.total())]);
    t
}

/// Table 5 (sim mode): median end-to-end decision latency under bandwidth
/// shaping at paper scale (X=400, n=3, K=4, Pi Zero 2 W device).
pub fn table5_latency_sim(
    bandwidths_mbps: &[f64],
    decisions: usize,
    model: &ServerCostModel,
) -> Table {
    let x = 400;
    let cost = frame_cost(x);
    let mut t = Table::new(
        "Table 5 — end-to-end decision latency under bandwidth shaping (median, X=400)",
        &["bandwidth", "server-only (ms)", "split-policy (ms)", "winner"],
    );
    for &mbps in bandwidths_mbps {
        let link = LinkModel::new(mbps * 1e6, model.one_way_latency);
        let mut so = Samples::new();
        let mut sp = Samples::new();
        // fresh devices per condition; per-decision j varies with jitter
        let mut dev = Device::new(crate::device::pi_zero_2w(), 11);
        for _ in 0..decisions {
            so.push(
                DecisionBreakdown::server_only(&link, x, model.full_compute, model.action_bytes)
                    .total(),
            );
            let j = dev.encode_frame(&cost, ExecPath::Gpu).duration;
            sp.push(
                DecisionBreakdown::split(&link, x, 3, 4, j, model.head_compute, model.action_bytes)
                    .total(),
            );
        }
        let (mso, msp) = (so.median() * 1e3, sp.median() * 1e3);
        t.row(&[
            format!("{mbps:.0} Mb/s"),
            format!("{mso:.0}"),
            format!("{msp:.0}"),
            (if msp < mso { "split" } else { "server-only" }).into(),
        ]);
    }
    t
}

/// Discrete-event simulation of the multi-client server (Table 6): `n`
/// clients at `rate_hz`, batched service with per-batch fixed cost +
/// per-item cost. Returns the p95 decision latency in seconds.
///
/// Service-cost calibration mirrors the paper's GPU server: full-CNN
/// requests cost ~7 ms/item after a 2 ms batch overhead (≈ 12 clients at
/// 10 Hz under 100 ms p95); head-only requests cost ~2.2 ms/item (≈ 36).
pub fn simulate_scalability(
    n_clients: usize,
    rate_hz: f64,
    duration_s: f64,
    batch_overhead: f64,
    per_item: f64,
    uplink_per_req: f64,
    max_batch: usize,
    seed: u64,
) -> f64 {
    #[derive(Debug)]
    enum Ev {
        Arrival { client: usize },
        ServerDone,
    }
    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut rng = Rng::new(seed);
    // staggered client phases
    for c in 0..n_clients {
        q.push(rng.uniform() / rate_hz, Ev::Arrival { client: c });
    }
    let mut waiting: Vec<(f64, usize)> = Vec::new(); // (arrival time, client)
    let mut busy_until = 0.0f64;
    let mut server_busy = false;
    let mut latencies = Samples::new();

    while let Some((t, ev)) = q.pop() {
        if t > duration_s {
            break;
        }
        match ev {
            Ev::Arrival { client } => {
                waiting.push((t + uplink_per_req, client));
                q.push(t + 1.0 / rate_hz, Ev::Arrival { client });
                if !server_busy {
                    server_busy = true;
                    q.push(t.max(busy_until), Ev::ServerDone);
                }
            }
            Ev::ServerDone => {
                // take a batch of everything whose uplink has landed
                let mut ready: Vec<(f64, usize)> = Vec::new();
                waiting.retain(|&(arr, c)| {
                    if arr <= t && ready.len() < max_batch {
                        ready.push((arr, c));
                        false
                    } else {
                        true
                    }
                });
                if ready.is_empty() {
                    if waiting.is_empty() {
                        server_busy = false;
                    } else {
                        // wait for the next uplink to land
                        let next = waiting.iter().map(|&(a, _)| a).fold(f64::MAX, f64::min);
                        q.push(next.max(t), Ev::ServerDone);
                    }
                    continue;
                }
                let service = batch_overhead + per_item * ready.len() as f64;
                let done = t + service;
                busy_until = done;
                for (arr, _) in &ready {
                    // decision latency: request issued (arr - uplink) -> done
                    latencies.push(done - (arr - uplink_per_req));
                }
                q.push(done, Ev::ServerDone);
            }
        }
    }
    if latencies.is_empty() {
        0.0
    } else {
        latencies.p95()
    }
}

/// Table 6 (sim mode): maximum concurrent clients at `rate_hz` under a p95
/// decision-latency budget.
pub fn table6_scalability_sim(rate_hz: f64, p95_budget_s: f64) -> (Table, usize, usize) {
    let find_max = |batch_overhead: f64, per_item: f64, uplink: f64| -> usize {
        let mut best = 0;
        for n in 1..200 {
            let p95 = simulate_scalability(n, rate_hz, 30.0, batch_overhead, per_item, uplink, 32, 5);
            if p95 <= p95_budget_s && p95 > 0.0 {
                best = n;
            } else if n > best + 4 {
                break;
            }
        }
        best
    };
    // server-only: full-CNN per item; split: head-only per item.
    let server_only = find_max(0.002, 0.0075, 0.013);
    let split = find_max(0.002, 0.0026, 0.002);
    let mut t = Table::new(
        "Table 6 — server scalability at a fixed decision rate",
        &["constraint", "server-only", "split-policy"],
    );
    t.row(&[
        format!("{rate_hz:.0}Hz per client, p95 < {:.0}ms", p95_budget_s * 1e3),
        format!("{server_only} clients"),
        format!("{split} clients"),
    ]);
    (t, server_only, split)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::breakeven::{feature_bits, raw_bits};

    #[test]
    fn device_j_near_paper_anchor() {
        let j = device_j(400, 100);
        assert!((0.08..0.13).contains(&j), "j={j}");
    }

    #[test]
    fn table5_sim_matches_paper_shape() {
        let t = table5_latency_sim(&[10.0, 25.0, 50.0, 100.0], 100, &ServerCostModel::default());
        let csv = t.to_csv();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        // 10 & 25 Mb/s -> split wins; 100 -> server-only wins
        assert!(rows[0].ends_with("split"), "{}", rows[0]);
        assert!(rows[1].ends_with("split"), "{}", rows[1]);
        assert!(rows[3].ends_with("server-only"), "{}", rows[3]);
        // magnitudes: server-only @10 in the 500s of ms; split ~140
        let so10: f64 = rows[0].split(',').nth(1).unwrap().parse().unwrap();
        let sp10: f64 = rows[0].split(',').nth(2).unwrap().parse().unwrap();
        assert!((450.0..650.0).contains(&so10), "{so10}");
        assert!((100.0..200.0).contains(&sp10), "{sp10}");
    }

    #[test]
    fn scalability_sim_split_serves_about_3x() {
        let (_t, so, sp) = table6_scalability_sim(10.0, 0.1);
        assert!((8..=18).contains(&so), "server-only {so}");
        assert!((25..=50).contains(&sp), "split {sp}");
        let ratio = sp as f64 / so as f64;
        assert!((2.0..4.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn p95_grows_with_load() {
        let light = simulate_scalability(2, 10.0, 20.0, 0.002, 0.007, 0.013, 32, 1);
        let heavy = simulate_scalability(40, 10.0, 20.0, 0.002, 0.007, 0.013, 32, 1);
        assert!(heavy > 2.0 * light, "light {light} heavy {heavy}");
    }

    #[test]
    fn fig5_total_row_consistent() {
        let t = fig5_breakdown(400, 10e6, &ServerCostModel::default());
        let csv = t.to_csv();
        let rows: Vec<Vec<f64>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').skip(1).map(|v| v.parse().unwrap()).collect())
            .collect();
        for col in 0..2 {
            let sum: f64 = rows[..4].iter().map(|r| r[col]).sum();
            assert!((sum - rows[4][col]).abs() < 0.2, "col {col}: {sum} vs {}", rows[4][col]);
        }
    }

    #[test]
    fn bits_helpers_consistent_with_wire() {
        assert_eq!(raw_bits(84) as usize, 84 * 84 * 32);
        assert_eq!(feature_bits(84, 3, 4) as usize, 4 * 11 * 11 * 8);
    }
}

//! Serving experiments: Figure 5 (decision-latency breakdown), Table 5
//! (end-to-end decision latency under bandwidth shaping) and Table 6
//! (server scalability), in two modes:
//!
//!   * **sim** — paper-scale (X=400) over the analytic link model, the
//!     Pi Zero 2 W device simulator, and a GPU-server cost model calibrated
//!     to the paper's residuals (see [`ServerCostModel`]); deterministic.
//!   * **real** — task-scale (X=84) over the actual coordinator, loopback
//!     TCP, and PJRT executables (driven from benches/examples).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::analysis::latency::DecisionBreakdown;
use crate::coordinator::batcher::{BatchCollector, BatchPolicy, Item};
use crate::coordinator::{BatchArena, Route, SessionManager};
use crate::device::{Device, ExecPath};
use crate::net::framing::{
    dequantize_features, dequantize_features_into, encode_response_into, quantize_features, Msg,
    Payload, Response,
};
use crate::net::shaped::LinkModel;
use crate::net::tcp::{write_frame, write_msg};
use crate::util::rng::Rng;
use crate::util::simclock::EventQueue;
use crate::util::stats::Samples;
use crate::util::tables::Table;

use super::execution::frame_cost;

/// Server-side compute model for the paper's GPU server. Calibrated from
/// the paper's Table 5 residuals: at 100 Mb/s server-only = 90 ms with a
/// 51.2 ms uplink, leaving ~38 ms of RTT+compute; the split pipeline's
/// non-device residual is ~36 ms — i.e. a ~30 ms network/framework floor
/// plus single-digit-ms model times.
#[derive(Debug, Clone, Copy)]
pub struct ServerCostModel {
    /// one-way link latency (includes framework overhead), s
    pub one_way_latency: f64,
    /// Full-CNN policy execution per request, s
    pub full_compute: f64,
    /// head-only execution per request, s
    pub head_compute: f64,
    pub action_bytes: usize,
}

impl Default for ServerCostModel {
    fn default() -> Self {
        ServerCostModel {
            one_way_latency: 0.015,
            full_compute: 0.008,
            head_compute: 0.005,
            action_bytes: 64,
        }
    }
}

/// Median on-device encode time at size `x` on the Pi Zero 2 W (GL path).
pub fn device_j(x: usize, reps: usize) -> f64 {
    let mut d = Device::new(crate::device::pi_zero_2w(), 7);
    let cost = frame_cost(x);
    let mut s = Samples::new();
    for _ in 0..reps {
        s.push(d.encode_frame(&cost, ExecPath::Gpu).duration);
    }
    s.median()
}

/// Figure 5: component breakdown of one decision for both pipelines.
pub fn fig5_breakdown(x: usize, bandwidth_bps: f64, model: &ServerCostModel) -> Table {
    let link = LinkModel::new(bandwidth_bps, model.one_way_latency);
    let j = device_j(x, 200);
    let so = DecisionBreakdown::server_only(&link, x, model.full_compute, model.action_bytes);
    let sp = DecisionBreakdown::split(&link, x, 3, 4, j, model.head_compute, model.action_bytes);
    let mut t = Table::new(
        &format!(
            "Figure 5 — decision-latency components (X={x}, {:.0} Mb/s)",
            bandwidth_bps / 1e6
        ),
        &["component", "server-only (ms)", "split-policy (ms)"],
    );
    let ms = |v: f64| format!("{:.1}", v * 1e3);
    t.row(&["on-device encode".into(), ms(so.device_encode), ms(sp.device_encode)]);
    t.row(&["observation/feature uplink".into(), ms(so.uplink), ms(sp.uplink)]);
    t.row(&["server compute".into(), ms(so.server_compute), ms(sp.server_compute)]);
    t.row(&["action downlink".into(), ms(so.downlink), ms(sp.downlink)]);
    t.row(&["TOTAL".into(), ms(so.total()), ms(sp.total())]);
    t
}

/// Table 5 (sim mode): median end-to-end decision latency under bandwidth
/// shaping at paper scale (X=400, n=3, K=4, Pi Zero 2 W device).
pub fn table5_latency_sim(
    bandwidths_mbps: &[f64],
    decisions: usize,
    model: &ServerCostModel,
) -> Table {
    let x = 400;
    let cost = frame_cost(x);
    let mut t = Table::new(
        "Table 5 — end-to-end decision latency under bandwidth shaping (median, X=400)",
        &["bandwidth", "server-only (ms)", "split-policy (ms)", "winner"],
    );
    for &mbps in bandwidths_mbps {
        let link = LinkModel::new(mbps * 1e6, model.one_way_latency);
        let mut so = Samples::new();
        let mut sp = Samples::new();
        // fresh devices per condition; per-decision j varies with jitter
        let mut dev = Device::new(crate::device::pi_zero_2w(), 11);
        for _ in 0..decisions {
            so.push(
                DecisionBreakdown::server_only(&link, x, model.full_compute, model.action_bytes)
                    .total(),
            );
            let j = dev.encode_frame(&cost, ExecPath::Gpu).duration;
            sp.push(
                DecisionBreakdown::split(&link, x, 3, 4, j, model.head_compute, model.action_bytes)
                    .total(),
            );
        }
        let (mso, msp) = (so.median() * 1e3, sp.median() * 1e3);
        t.row(&[
            format!("{mbps:.0} Mb/s"),
            format!("{mso:.0}"),
            format!("{msp:.0}"),
            (if msp < mso { "split" } else { "server-only" }).into(),
        ]);
    }
    t
}

/// Discrete-event simulation of the multi-client server (Table 6): `n`
/// clients at `rate_hz`, batched service with per-batch fixed cost +
/// per-item cost. Returns the p95 decision latency in seconds.
///
/// Service-cost calibration mirrors the paper's GPU server: full-CNN
/// requests cost ~7 ms/item after a 2 ms batch overhead (≈ 12 clients at
/// 10 Hz under 100 ms p95); head-only requests cost ~2.2 ms/item (≈ 36).
pub fn simulate_scalability(
    n_clients: usize,
    rate_hz: f64,
    duration_s: f64,
    batch_overhead: f64,
    per_item: f64,
    uplink_per_req: f64,
    max_batch: usize,
    seed: u64,
) -> f64 {
    #[derive(Debug)]
    enum Ev {
        Arrival { client: usize },
        ServerDone,
    }
    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut rng = Rng::new(seed);
    // staggered client phases
    for c in 0..n_clients {
        q.push(rng.uniform() / rate_hz, Ev::Arrival { client: c });
    }
    let mut waiting: Vec<(f64, usize)> = Vec::new(); // (arrival time, client)
    let mut busy_until = 0.0f64;
    let mut server_busy = false;
    let mut latencies = Samples::new();

    while let Some((t, ev)) = q.pop() {
        if t > duration_s {
            break;
        }
        match ev {
            Ev::Arrival { client } => {
                waiting.push((t + uplink_per_req, client));
                q.push(t + 1.0 / rate_hz, Ev::Arrival { client });
                if !server_busy {
                    server_busy = true;
                    q.push(t.max(busy_until), Ev::ServerDone);
                }
            }
            Ev::ServerDone => {
                // take a batch of everything whose uplink has landed
                let mut ready: Vec<(f64, usize)> = Vec::new();
                waiting.retain(|&(arr, c)| {
                    if arr <= t && ready.len() < max_batch {
                        ready.push((arr, c));
                        false
                    } else {
                        true
                    }
                });
                if ready.is_empty() {
                    if waiting.is_empty() {
                        server_busy = false;
                    } else {
                        // wait for the next uplink to land
                        let next = waiting.iter().map(|&(a, _)| a).fold(f64::MAX, f64::min);
                        q.push(next.max(t), Ev::ServerDone);
                    }
                    continue;
                }
                let service = batch_overhead + per_item * ready.len() as f64;
                let done = t + service;
                busy_until = done;
                for (arr, _) in &ready {
                    // decision latency: request issued (arr - uplink) -> done
                    latencies.push(done - (arr - uplink_per_req));
                }
                q.push(done, Ev::ServerDone);
            }
        }
    }
    if latencies.is_empty() {
        0.0
    } else {
        latencies.p95()
    }
}

/// Table 6 (sim mode): maximum concurrent clients at `rate_hz` under a p95
/// decision-latency budget.
pub fn table6_scalability_sim(rate_hz: f64, p95_budget_s: f64) -> (Table, usize, usize) {
    let find_max = |batch_overhead: f64, per_item: f64, uplink: f64| -> usize {
        let mut best = 0;
        for n in 1..200 {
            let p95 = simulate_scalability(n, rate_hz, 30.0, batch_overhead, per_item, uplink, 32, 5);
            if p95 <= p95_budget_s && p95 > 0.0 {
                best = n;
            } else if n > best + 4 {
                break;
            }
        }
        best
    };
    // server-only: full-CNN per item; split: head-only per item.
    let server_only = find_max(0.002, 0.0075, 0.013);
    let split = find_max(0.002, 0.0026, 0.002);
    let mut t = Table::new(
        "Table 6 — server scalability at a fixed decision rate",
        &["constraint", "server-only", "split-policy"],
    );
    t.row(&[
        format!("{rate_hz:.0}Hz per client, p95 < {:.0}ms", p95_budget_s * 1e3),
        format!("{server_only} clients"),
        format!("{split} clients"),
    ]);
    (t, server_only, split)
}

// ---------------------------------------------------------------------------
// Serve hot path (real mode, artifact-free): the coordinator's
// ingest→batch→policy→reply pipeline, legacy per-request engine vs the
// pooled BatchArena engine. `benches/serve_hotpath.rs` wraps this into the
// before/after matrix and emits `BENCH_serve.json`; the legacy engine is
// kept as the bit-exact oracle (identical reply bytes for identical
// inputs), enforced by `rust/tests/serve_pack_props.rs`.
// ---------------------------------------------------------------------------

/// Which implementation of the pipeline machinery runs a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeEngine {
    /// seed-coordinator behaviour: fresh zeroed batch matrix per batch,
    /// per-request `dequantize_features` / `ingest_rgba` vectors, HashMap
    /// action scatter, per-reply `Msg::Response` encode allocation
    Legacy,
    /// the BatchArena path: fused dequantise/ingest pack into pooled rows,
    /// flat action buffer, pooled reply frame
    Pooled,
}

impl ServeEngine {
    pub fn name(self) -> &'static str {
        match self {
            ServeEngine::Legacy => "legacy",
            ServeEngine::Pooled => "pooled",
        }
    }
}

/// One request as the harness fleet replays it. The payload is borrowed
/// from the per-client pool, so the measured loop owns no request
/// allocations (on the wire path the reader thread owns the decode; that
/// cost is identical for both engines and outside this harness).
#[derive(Debug)]
pub struct BenchRequest<'a> {
    pub client: u32,
    pub id: u64,
    pub payload: &'a Payload,
}

/// The stand-in policy head shared by both engines: strided sums over the
/// batch row — deterministic, O(feat_dim/stride) per action, cheap enough
/// that the measured difference is the pipeline machinery itself.
const HEAD_STRIDE: usize = 97;

fn head_into(row: &[f32], out: &mut [f32]) {
    for (a, o) in out.iter_mut().enumerate() {
        let mut sum = 0.0f32;
        let mut k = a;
        while k < row.len() {
            sum += row[k];
            k += HEAD_STRIDE;
        }
        *o = sum;
    }
}

/// Mutable pipeline state shared by both engines (sessions evolve
/// identically because both engines ingest through the same manager
/// semantics). Replies are written into `sink`, standing in for the
/// per-connection sockets, and retained per round so engines can be
/// compared bit-for-bit.
pub struct ServeHarness {
    pub sessions: SessionManager,
    pub arena: BatchArena,
    pub action_dim: usize,
    pub sink: Vec<u8>,
}

impl ServeHarness {
    pub fn new(action_dim: usize) -> Self {
        ServeHarness {
            sessions: SessionManager::new(),
            arena: BatchArena::new(),
            action_dim,
            sink: Vec::new(),
        }
    }
}

/// One legacy batch, mirroring the seed coordinator's request path.
pub fn run_batch_legacy(
    h: &mut ServeHarness,
    items: &[Item<BenchRequest<'_>>],
    feat_dim: usize,
) -> Result<()> {
    let n = items.len();
    // fresh zeroed batch matrix every batch
    let mut data = vec![0.0f32; n * feat_dim];
    for (i, item) in items.iter().enumerate() {
        let dst = &mut data[i * feat_dim..(i + 1) * feat_dim];
        match item.work.payload {
            Payload::RawRgba { x, data: rgba } => {
                let obs = h.sessions.ingest_rgba(item.work.client, *x as usize, rgba)?;
                anyhow::ensure!(obs.len() == feat_dim, "obs len {} != {feat_dim}", obs.len());
                dst.copy_from_slice(&obs);
            }
            Payload::Features { scale, data: q, .. } => {
                anyhow::ensure!(q.len() == feat_dim, "feat len {} != {feat_dim}", q.len());
                // the per-request dequantised vector the tentpole removes
                let f = dequantize_features(*scale, q);
                dst.copy_from_slice(&f);
            }
            Payload::FeaturesV2(_) => {
                anyhow::bail!("codec frames are decoded by the coordinator, not this bench")
            }
        }
    }
    // per-item action vectors scattered through a HashMap (the seed Sim
    // backend's shape)
    let mut actions: HashMap<usize, Vec<f32>> = HashMap::new();
    for i in 0..n {
        let mut a = vec![0.0f32; h.action_dim];
        head_into(&data[i * feat_dim..(i + 1) * feat_dim], &mut a);
        actions.insert(i, a);
    }
    for (i, item) in items.iter().enumerate() {
        let action = actions.remove(&i).unwrap_or_else(|| vec![0.0; h.action_dim]);
        let resp = Msg::Response(Response { client: item.work.client, id: item.work.id, action });
        write_msg(&mut h.sink, &resp)?;
    }
    Ok(())
}

/// One pooled batch: the BatchArena path, as `coordinator::server` runs it.
pub fn run_batch_pooled(
    h: &mut ServeHarness,
    items: &[Item<BenchRequest<'_>>],
    feat_dim: usize,
) -> Result<()> {
    let n = items.len();
    h.arena.begin(n, n, feat_dim);
    for (i, item) in items.iter().enumerate() {
        let row = h.arena.row_mut(i);
        match item.work.payload {
            Payload::RawRgba { x, data: rgba } => {
                h.sessions.ingest_rgba_into(item.work.client, *x as usize, rgba, row)?;
            }
            Payload::Features { scale, data: q, .. } => {
                anyhow::ensure!(q.len() == feat_dim, "feat len {} != {feat_dim}", q.len());
                dequantize_features_into(*scale, q, row);
            }
            Payload::FeaturesV2(_) => {
                anyhow::bail!("codec frames are decoded by the coordinator, not this bench")
            }
        }
    }
    h.arena.begin_actions(n, h.action_dim);
    for i in 0..n {
        let (row, act) = h.arena.row_and_action(i, h.action_dim);
        head_into(row, act);
    }
    for (i, item) in items.iter().enumerate() {
        let a0 = i * h.action_dim;
        encode_response_into(
            item.work.client,
            item.work.id,
            &h.arena.actions[a0..a0 + h.action_dim],
            &mut h.arena.frame,
        );
        write_frame(&mut h.sink, &h.arena.frame)?;
    }
    Ok(())
}

/// Deterministic per-client request payloads for one route. Returns the
/// payload pool and the route's feature dimension (batch-row width).
pub fn bench_payloads(
    route: Route,
    clients: usize,
    x: usize,
    feat: (u16, u16, u16),
    seed: u64,
) -> (Vec<(u32, Payload)>, usize) {
    let mut rng = Rng::new(seed);
    let mut payloads = Vec::with_capacity(clients);
    let feat_dim = match route {
        Route::Full => 9 * x * x,
        Route::Split => feat.0 as usize * feat.1 as usize * feat.2 as usize,
    };
    for c in 0..clients {
        let payload = match route {
            Route::Full => {
                let data: Vec<u8> =
                    (0..4 * x * x).map(|_| (rng.uniform() * 255.0) as u8).collect();
                Payload::RawRgba { x: x as u16, data }
            }
            Route::Split => {
                let f: Vec<f32> =
                    (0..feat_dim).map(|_| (rng.uniform() * 3.0) as f32).collect();
                let (scale, data) = quantize_features(&f);
                Payload::Features { c: feat.0, h: feat.1, w: feat.2, scale, data }
            }
        };
        payloads.push((c as u32, payload));
    }
    (payloads, feat_dim)
}

/// Replays rounds of one request per client through the batcher and one
/// engine. All state (collector, drained-batch storage, harness arena)
/// persists across rounds, so pooled steady-state rounds are
/// allocation-free — the property `rust/tests/serve_alloc.rs` gates.
pub struct ServeDriver<'a> {
    pub harness: ServeHarness,
    collector: BatchCollector<BenchRequest<'a>>,
    batch: Vec<Item<BenchRequest<'a>>>,
    payloads: &'a [(u32, Payload)],
    feat_dim: usize,
    next_id: u64,
}

impl<'a> ServeDriver<'a> {
    pub fn new(
        payloads: &'a [(u32, Payload)],
        max_batch: usize,
        feat_dim: usize,
        action_dim: usize,
    ) -> Self {
        ServeDriver {
            harness: ServeHarness::new(action_dim),
            collector: BatchCollector::new(
                BatchPolicy { max_batch, max_wait: Duration::ZERO },
                payloads.len().max(1) * 2,
            ),
            batch: Vec::new(),
            payloads,
            feat_dim,
            next_id: 0,
        }
    }

    /// One round: enqueue one request per client, then drain every ready
    /// batch through `engine`. Reply bytes of the whole round are left in
    /// `harness.sink`.
    pub fn round(&mut self, engine: ServeEngine) -> Result<()> {
        self.harness.sink.clear();
        let now = Instant::now();
        let payloads = self.payloads;
        for (client, payload) in payloads {
            self.next_id += 1;
            let work = BenchRequest { client: *client, id: self.next_id, payload };
            anyhow::ensure!(
                self.collector.push(Route::of(payload), work, now).is_none(),
                "bench collector saturated"
            );
        }
        while let Some(route) = self.collector.ready(now) {
            self.collector.take_into(route, &mut self.batch);
            match engine {
                ServeEngine::Legacy => {
                    run_batch_legacy(&mut self.harness, &self.batch, self.feat_dim)?
                }
                ServeEngine::Pooled => {
                    run_batch_pooled(&mut self.harness, &self.batch, self.feat_dim)?
                }
            }
        }
        Ok(())
    }

    /// Timed rounds (with warmup): seconds per round.
    pub fn rounds(&mut self, engine: ServeEngine, iters: usize) -> Result<f64> {
        for _ in 0..(iters / 10).max(1) {
            self.round(engine)?;
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            self.round(engine)?;
        }
        Ok(t0.elapsed().as_secs_f64() / iters as f64)
    }

    pub fn sink(&self) -> &[u8] {
        &self.harness.sink
    }

    pub fn requests_per_round(&self) -> usize {
        self.payloads.len()
    }
}

/// One measured cell of the serve hot-path matrix.
#[derive(Debug, Clone)]
pub struct ServeHotpathCell {
    /// "server-only" | "split"
    pub route: &'static str,
    /// "legacy" | "pooled"
    pub engine: &'static str,
    pub clients: usize,
    pub max_batch: usize,
    pub requests_per_sec: f64,
    pub ns_per_request: f64,
}

#[derive(Debug, Clone)]
pub struct ServeHotpathReport {
    pub iters: usize,
    pub action_dim: usize,
    /// raw-observation side length (server-only route)
    pub raw_x: usize,
    /// split feature dims (c, h, w)
    pub feat: (u16, u16, u16),
    pub max_batch: usize,
    pub cells: Vec<ServeHotpathCell>,
    /// pooled/legacy requests-per-sec ratios at clients == max_batch
    pub speedup_full_b: f64,
    pub speedup_split_b: f64,
    /// heap allocations per steady-state pooled request, measured by the
    /// bench binary's counting allocator; None when the harness runs
    /// without one
    pub allocs_per_request: Option<u64>,
}

fn cell_rps(cells: &[ServeHotpathCell], route: &str, engine: &str, clients: usize) -> f64 {
    cells
        .iter()
        .find(|c| c.route == route && c.engine == engine && c.clients == clients)
        .map(|c| c.requests_per_sec)
        .unwrap_or(0.0)
}

/// Run the full serve hot-path matrix: every (route, engine, clients)
/// cell, fresh pipeline state per cell so session stacks are comparable.
pub fn run_serve_hotpath(
    clients_matrix: &[usize],
    max_batch: usize,
    iters: usize,
) -> Result<ServeHotpathReport> {
    let action_dim = 4;
    let raw_x = 84;
    let feat = (4u16, 11u16, 11u16);
    let mut cells = Vec::new();
    for route in [Route::Full, Route::Split] {
        for &clients in clients_matrix {
            let (payloads, feat_dim) = bench_payloads(route, clients, raw_x, feat, 0xBA7C4);
            for engine in [ServeEngine::Legacy, ServeEngine::Pooled] {
                let mut driver = ServeDriver::new(&payloads, max_batch, feat_dim, action_dim);
                let per_round = driver.rounds(engine, iters)?;
                let per_req = per_round / clients.max(1) as f64;
                cells.push(ServeHotpathCell {
                    route: route.name(),
                    engine: engine.name(),
                    clients,
                    max_batch,
                    requests_per_sec: 1.0 / per_req,
                    ns_per_request: per_req * 1e9,
                });
            }
        }
    }
    let speedup = |route: &str| {
        let legacy = cell_rps(&cells, route, "legacy", max_batch);
        if legacy > 0.0 {
            cell_rps(&cells, route, "pooled", max_batch) / legacy
        } else {
            0.0
        }
    };
    Ok(ServeHotpathReport {
        iters,
        action_dim,
        raw_x,
        feat,
        max_batch,
        speedup_full_b: speedup("server-only"),
        speedup_split_b: speedup("split"),
        cells,
        allocs_per_request: None,
    })
}

impl ServeHotpathReport {
    /// Machine-readable record for `BENCH_serve.json` (no serde offline —
    /// hand-rolled, stable field order; see DESIGN.md §5 for semantics).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"bench\": \"serve_hotpath\",\n");
        s.push_str(&format!("  \"iters\": {},\n", self.iters));
        s.push_str(&format!("  \"action_dim\": {},\n", self.action_dim));
        s.push_str(&format!("  \"raw_x\": {},\n", self.raw_x));
        s.push_str(&format!(
            "  \"feat_dims\": [{}, {}, {}],\n",
            self.feat.0, self.feat.1, self.feat.2
        ));
        s.push_str(&format!("  \"max_batch\": {},\n", self.max_batch));
        s.push_str(&format!(
            "  \"speedup_full_at_max_batch\": {:.3},\n",
            self.speedup_full_b
        ));
        s.push_str(&format!(
            "  \"speedup_split_at_max_batch\": {:.3},\n",
            self.speedup_split_b
        ));
        match self.allocs_per_request {
            Some(n) => s.push_str(&format!("  \"steady_state_allocs_per_request\": {n},\n")),
            None => s.push_str("  \"steady_state_allocs_per_request\": null,\n"),
        }
        s.push_str("  \"gates\": {\n");
        s.push_str("    \"min_speedup_full_at_max_batch\": 2.0,\n");
        s.push_str("    \"max_steady_state_allocs_per_request\": 0,\n");
        s.push_str(&format!(
            "    \"speedup_pass\": {},\n",
            self.speedup_full_b >= 2.0
        ));
        match self.allocs_per_request {
            Some(n) => s.push_str(&format!("    \"alloc_pass\": {}\n", n == 0)),
            None => s.push_str("    \"alloc_pass\": null\n"),
        }
        s.push_str("  },\n");
        s.push_str("  \"results\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"route\": \"{}\", \"engine\": \"{}\", \"clients\": {}, \
                 \"max_batch\": {}, \"requests_per_sec\": {:.1}, \"ns_per_request\": {:.0}}}{}\n",
                c.route,
                c.engine,
                c.clients,
                c.max_batch,
                c.requests_per_sec,
                c.ns_per_request,
                if i + 1 == self.cells.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::breakeven::{feature_bits, raw_bits};

    #[test]
    fn device_j_near_paper_anchor() {
        let j = device_j(400, 100);
        assert!((0.08..0.13).contains(&j), "j={j}");
    }

    #[test]
    fn table5_sim_matches_paper_shape() {
        let t = table5_latency_sim(&[10.0, 25.0, 50.0, 100.0], 100, &ServerCostModel::default());
        let csv = t.to_csv();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        // 10 & 25 Mb/s -> split wins; 100 -> server-only wins
        assert!(rows[0].ends_with("split"), "{}", rows[0]);
        assert!(rows[1].ends_with("split"), "{}", rows[1]);
        assert!(rows[3].ends_with("server-only"), "{}", rows[3]);
        // magnitudes: server-only @10 in the 500s of ms; split ~140
        let so10: f64 = rows[0].split(',').nth(1).unwrap().parse().unwrap();
        let sp10: f64 = rows[0].split(',').nth(2).unwrap().parse().unwrap();
        assert!((450.0..650.0).contains(&so10), "{so10}");
        assert!((100.0..200.0).contains(&sp10), "{sp10}");
    }

    #[test]
    fn scalability_sim_split_serves_about_3x() {
        let (_t, so, sp) = table6_scalability_sim(10.0, 0.1);
        assert!((8..=18).contains(&so), "server-only {so}");
        assert!((25..=50).contains(&sp), "split {sp}");
        let ratio = sp as f64 / so as f64;
        assert!((2.0..4.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn p95_grows_with_load() {
        let light = simulate_scalability(2, 10.0, 20.0, 0.002, 0.007, 0.013, 32, 1);
        let heavy = simulate_scalability(40, 10.0, 20.0, 0.002, 0.007, 0.013, 32, 1);
        assert!(heavy > 2.0 * light, "light {light} heavy {heavy}");
    }

    #[test]
    fn fig5_total_row_consistent() {
        let t = fig5_breakdown(400, 10e6, &ServerCostModel::default());
        let csv = t.to_csv();
        let rows: Vec<Vec<f64>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').skip(1).map(|v| v.parse().unwrap()).collect())
            .collect();
        for col in 0..2 {
            let sum: f64 = rows[..4].iter().map(|r| r[col]).sum();
            assert!((sum - rows[4][col]).abs() < 0.2, "col {col}: {sum} vs {}", rows[4][col]);
        }
    }

    #[test]
    fn bits_helpers_consistent_with_wire() {
        assert_eq!(raw_bits(84) as usize, 84 * 84 * 32);
        assert_eq!(feature_bits(84, 3, 4) as usize, 4 * 11 * 11 * 8);
    }

    #[test]
    fn serve_engines_are_bit_exact_on_both_routes() {
        // small geometry so the test is quick; 3 rounds exercise the
        // evolving per-client frame stacks on the raw route
        for route in [Route::Full, Route::Split] {
            let (payloads, feat_dim) = bench_payloads(route, 5, 8, (4, 3, 3), 42);
            let mut legacy = ServeDriver::new(&payloads, 2, feat_dim, 4);
            let mut pooled = ServeDriver::new(&payloads, 2, feat_dim, 4);
            for round in 0..3 {
                legacy.round(ServeEngine::Legacy).unwrap();
                pooled.round(ServeEngine::Pooled).unwrap();
                assert!(!legacy.sink().is_empty());
                assert_eq!(
                    legacy.sink(),
                    pooled.sink(),
                    "reply bytes diverged on {} round {round}",
                    route.name()
                );
            }
        }
    }

    #[test]
    fn serve_hotpath_report_covers_matrix_and_emits_gates() {
        let rep = run_serve_hotpath(&[1, 2], 2, 3).unwrap();
        // 2 routes x 2 clients x 2 engines
        assert_eq!(rep.cells.len(), 8);
        assert!(rep.cells.iter().all(|c| c.requests_per_sec > 0.0));
        assert!(rep.speedup_full_b > 0.0);
        assert!(rep.speedup_split_b > 0.0);
        let json = rep.to_json();
        assert!(json.contains("\"speedup_full_at_max_batch\""));
        assert!(json.contains("\"min_speedup_full_at_max_batch\": 2.0"));
        assert!(json.contains("\"steady_state_allocs_per_request\": null"));
        assert!(json.contains("\"alloc_pass\": null"));
        assert!(json.contains("\"engine\": \"pooled\""));
    }

    #[test]
    fn bench_payloads_are_deterministic_and_sized() {
        let (a, da) = bench_payloads(Route::Split, 3, 84, (4, 11, 11), 9);
        let (b, db) = bench_payloads(Route::Split, 3, 84, (4, 11, 11), 9);
        assert_eq!(da, 4 * 11 * 11);
        assert_eq!(da, db);
        assert_eq!(a, b);
        let (r, dr) = bench_payloads(Route::Full, 2, 16, (4, 11, 11), 9);
        assert_eq!(dr, 9 * 16 * 16);
        for (_, p) in &r {
            assert_eq!(p.wire_bytes(), 4 * 16 * 16);
        }
    }
}

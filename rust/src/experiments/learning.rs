//! Learning experiments: Table 1 (algorithm per task) and Tables 2–4
//! (episodic-return statistics per encoder), driven through the generic
//! trainer over the AOT artifacts.
//!
//! Scale note (DESIGN.md §2): paper-scale is 1,000–2,000 episodes of pixel
//! RL — far beyond this CPU testbed for a default run. `LearningScale`
//! selects the budget; Smoke/Tiny preserve the within-task comparison
//! machinery (same encoders, same pipeline) at reduced episode counts and
//! are what CI exercises. Paper scale is available behind the same flag.

use anyhow::Result;

use crate::rl::{TrainConfig, Trainer};
use crate::runtime::Runtime;
use crate::util::tables::Table;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LearningScale {
    /// a handful of episodes — pipeline proof, minutes of CPU
    Smoke,
    /// enough to see a learning trend on pendulum
    Tiny,
    /// the paper's episode budgets (Tables 2-4) — hours/days of CPU
    Paper,
}

impl LearningScale {
    pub fn parse(s: &str) -> Result<LearningScale> {
        match s {
            "smoke" => Ok(LearningScale::Smoke),
            "tiny" => Ok(LearningScale::Tiny),
            "paper" => Ok(LearningScale::Paper),
            other => anyhow::bail!("unknown scale {other:?} (smoke|tiny|paper)"),
        }
    }

    pub fn episodes(&self, task: &str, paper_episodes: usize) -> usize {
        match self {
            LearningScale::Smoke => 3,
            LearningScale::Tiny => {
                if task == "pendulum" {
                    40
                } else {
                    20
                }
            }
            LearningScale::Paper => paper_episodes,
        }
    }

    pub fn config(&self, task: &str, paper_episodes: usize, seed: u64) -> TrainConfig {
        let episodes = self.episodes(task, paper_episodes);
        match self {
            LearningScale::Smoke => TrainConfig {
                episodes,
                warmup_steps: 100,
                train_freq: 16,
                rollout_steps: 64,
                ppo_epochs: 2,
                seed,
                log_every: 1,
                ..TrainConfig::default()
            },
            LearningScale::Tiny => TrainConfig {
                episodes,
                warmup_steps: 400,
                train_freq: 4,
                rollout_steps: 256,
                ppo_epochs: 6,
                seed,
                log_every: 5,
                ..TrainConfig::default()
            },
            LearningScale::Paper => TrainConfig {
                episodes,
                warmup_steps: 1000,
                train_freq: 2,
                rollout_steps: 2048,
                ppo_epochs: 10,
                replay_capacity: 50_000,
                seed,
                log_every: 10,
                ..TrainConfig::default()
            },
        }
    }
}

/// Table 1: algorithm used for each visual control task.
pub fn table1_algorithms(rt: &Runtime) -> Table {
    let mut t = Table::new(
        "Table 1 — algorithms used for each visual control task",
        &["task", "algorithm", "action dim", "episodes (paper)", "artifacts present"],
    );
    let mut seen = std::collections::BTreeSet::new();
    for ts in rt.manifest.trainstates.values() {
        if !seen.insert(ts.task.clone()) {
            continue;
        }
        let present = ts
            .artifacts
            .values()
            .all(|a| rt.manifest.artifact(a).is_ok());
        t.row(&[
            ts.task.clone(),
            ts.algo.to_uppercase(),
            ts.action_dim.to_string(),
            ts.episodes.to_string(),
            present.to_string(),
        ]);
    }
    t
}

/// One row of a learning table (Tables 2–4).
pub struct LearningRow {
    pub arch: String,
    pub best: f64,
    pub final_100: f64,
    pub mean: f64,
    pub episodes: usize,
    pub updates: usize,
}

/// Train every encoder variant on `task` at the given scale and emit the
/// paper's Best/Final/Mean table (single fixed-seed run, as in the paper).
pub fn learning_table(
    rt: &Runtime,
    task: &str,
    archs: &[&str],
    scale: LearningScale,
    seed: u64,
) -> Result<(Table, Vec<LearningRow>)> {
    let mut rows = Vec::new();
    for arch in archs {
        let run = format!("{task}_{arch}");
        let spec = rt
            .manifest
            .trainstates
            .get(&run)
            .ok_or_else(|| anyhow::anyhow!("no trainstate {run}"))?;
        let cfg = scale.config(task, spec.episodes, seed);
        let mut trainer = Trainer::new(rt, &run, cfg)?;
        trainer.train()?;
        rows.push(LearningRow {
            arch: arch.to_string(),
            best: trainer.report.stats.best(),
            final_100: trainer.report.stats.final_100(),
            mean: trainer.report.stats.mean(),
            episodes: trainer.report.stats.episodes(),
            updates: trainer.report.updates,
        });
    }
    let algo = rt.manifest.trainstates[&format!("{task}_{}", archs[0])]
        .algo
        .to_uppercase();
    let mut t = Table::new(
        &format!("{task} ({algo}): episodic return statistics (single fixed-seed run)"),
        &["architecture", "best", "final", "mean", "episodes", "updates"],
    );
    for r in &rows {
        t.row(&[
            pretty_arch(&r.arch),
            format!("{:.0}", r.best),
            format!("{:.0}", r.final_100),
            format!("{:.0}", r.mean),
            r.episodes.to_string(),
            r.updates.to_string(),
        ]);
    }
    Ok((t, rows))
}

fn pretty_arch(a: &str) -> String {
    match a {
        "miniconv4" => "MiniConv encoder (K=4)".into(),
        "miniconv16" => "MiniConv encoder (K=16)".into(),
        "fullcnn" => "Full-CNN".into(),
        other => other.into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parsing() {
        assert_eq!(LearningScale::parse("tiny").unwrap(), LearningScale::Tiny);
        assert!(LearningScale::parse("huge").is_err());
    }

    #[test]
    fn episode_budgets() {
        assert_eq!(LearningScale::Smoke.episodes("pendulum", 1000), 3);
        assert_eq!(LearningScale::Tiny.episodes("pendulum", 1000), 40);
        assert_eq!(LearningScale::Paper.episodes("walker", 2000), 2000);
    }

    #[test]
    fn pretty_arch_names_match_paper() {
        assert_eq!(pretty_arch("miniconv4"), "MiniConv encoder (K=4)");
        assert_eq!(pretty_arch("fullcnn"), "Full-CNN");
    }
}

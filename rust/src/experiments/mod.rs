//! Experiment harnesses: one function per paper table/figure, shared by the
//! CLI (`miniconv exp …`) and the bench binaries (`cargo bench`). Each
//! returns printable tables (and CSV recorders for the figure traces), so
//! results are diffable against EXPERIMENTS.md.

pub mod execution;
pub mod hotpath;
pub mod learning;
pub mod serving;

pub use execution::{fig2_framesize, fig3_sustained, fig4_resources, SustainedTrace};
pub use hotpath::{run_hotpath, HotpathReport, HotpathRow};
pub use learning::{learning_table, table1_algorithms, LearningScale};
pub use serving::{
    bench_payloads, fig5_breakdown, run_serve_hotpath, table5_latency_sim, table6_scalability_sim,
    ServeDriver, ServeEngine, ServeHotpathCell, ServeHotpathReport, ServerCostModel,
};

//! Adaptive feature-frame codec (DESIGN.md §7): temporal [`delta`] coding
//! against the previous frame, [`pack`]ed with per-block significance
//! masks + zigzag/varint entropy coding, under a closed-loop [`rate`]
//! controller that picks the quantisation level and keyframe cadence from
//! the observed link.
//!
//! The codec is negotiated per session in the `Hello` handshake
//! (`net::framing`): a split client requests a codec id, the server ack
//! echoes the one it accepts, and every feature frame then travels as a
//! versioned `Msg::Request` with `Payload::FeaturesV2` carrying
//! `(codec, flags, qmax, seq)` alongside the quantised payload. Raw-route
//! clients and flat-codec clients are untouched — they keep the v1 wire
//! format byte for byte.
//!
//! Correctness contract: the codec is **lossless over the quantised
//! domain**. Quantising at ceiling `qmax` and shipping the frame through
//! encoder → wire → decoder reconstructs the exact quantised bytes at
//! every quantisation level, and at `qmax = 255` both the quantise and
//! dequantise steps are bit-identical to the flat v1 path
//! (`net::framing::{quantize_features_into, dequantize_features_into}`) —
//! the oracle `rust/tests/codec_props.rs` pins.

pub mod delta;
pub mod pack;
pub mod rate;

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

pub use delta::{Decoder, Encoder, FLAG_KEYFRAME, FLAG_RAW};
pub use pack::BLOCK;
pub use rate::{RateConfig, RateController};

/// Wire id of the flat v1 format (per-frame u8 quantisation, no state).
pub const CODEC_FLAT: u8 = 0;
/// Wire id of the delta + entropy-packed format.
pub const CODEC_DELTA: u8 = 1;

/// Which feature-frame codec a session speaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecId {
    /// flat per-frame u8 quantisation (the paper's wire format)
    Flat,
    /// temporal delta + entropy packing with closed-loop rate control
    Delta,
}

impl CodecId {
    pub fn wire_id(self) -> u8 {
        match self {
            CodecId::Flat => CODEC_FLAT,
            CodecId::Delta => CODEC_DELTA,
        }
    }

    pub fn from_wire(id: u8) -> Option<CodecId> {
        match id {
            CODEC_FLAT => Some(CodecId::Flat),
            CODEC_DELTA => Some(CodecId::Delta),
            _ => None,
        }
    }

    /// Parse a CLI spelling (`flat` | `delta`).
    pub fn parse(s: &str) -> Result<CodecId> {
        match s {
            "flat" => Ok(CodecId::Flat),
            "delta" => Ok(CodecId::Delta),
            other => anyhow::bail!("unknown codec {other:?} (flat|delta)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CodecId::Flat => "flat",
            CodecId::Delta => "delta",
        }
    }
}

/// Quantise a float feature map (post-ReLU, >= 0) into `[0, qmax]` with
/// its max as scale, writing into a caller-owned buffer. At `qmax = 255`
/// this is bit-identical to `net::framing::quantize_features_into` (same
/// expression, same reciprocal).
pub fn quantize_into(feat: &[f32], qmax: u8, out: &mut Vec<u8>) -> f32 {
    let scale = feat.iter().fold(0.0f32, |a, &b| a.max(b)).max(1e-6);
    let inv = qmax as f32 / scale;
    out.clear();
    out.reserve(feat.len());
    out.extend(feat.iter().map(|&v| (v * inv).clamp(0.0, qmax as f32).round() as u8));
    scale
}

/// Dequantise a `[0, qmax]` frame directly into a batch-matrix row via a
/// per-scale LUT — the codec counterpart of the fused
/// `net::framing::dequantize_features_into` path, bit-identical to it at
/// `qmax = 255`.
pub fn dequantize_into(scale: f32, qmax: u8, data: &[u8], out: &mut [f32]) {
    assert_eq!(data.len(), out.len(), "dequantize into a slice of the wrong length");
    let mut lut = [0.0f32; 256];
    for (b, v) in lut.iter_mut().enumerate().take(qmax as usize + 1) {
        *v = b as f32 / qmax as f32 * scale;
    }
    for (o, &b) in out.iter_mut().zip(data.iter()) {
        *o = lut[b as usize];
    }
}

/// Per-client decoder state held by a serving executor (or a sim shard):
/// one [`Decoder`] per session, reset on every session (re)connect so a
/// new incarnation can never delta against a stale base. A `BTreeMap`
/// keeps iteration order deterministic under the simnet.
#[derive(Debug, Default)]
pub struct Decoders {
    streams: BTreeMap<u32, Decoder>,
    /// consecutive rejects per session, reset by any accepted frame — the
    /// quarantine signal of `net::limits` (DESIGN.md §9): a healthy delta
    /// client takes at most one reject per chain break before its
    /// recovery keyframe lands, while a session feeding garbage climbs
    /// without bound
    consecutive: BTreeMap<u32, u32>,
    /// frames rejected across all sessions (chain breaks, corrupt payloads)
    pub rejects: u64,
    /// frames decoded across all sessions
    pub accepted: u64,
}

impl Decoders {
    pub fn new() -> Decoders {
        Decoders::default()
    }

    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// Session (re)connect: drop the cached previous-frame state so the
    /// next frame from this client must be a keyframe.
    pub fn invalidate(&mut self, client: u32) {
        if let Some(d) = self.streams.get_mut(&client) {
            d.reset();
        }
    }

    /// Session gone: free its stream state entirely.
    pub fn disconnect(&mut self, client: u32) {
        self.streams.remove(&client);
        self.consecutive.remove(&client);
    }

    /// Consecutive rejected frames from this session since its last
    /// accepted one. Executors compare this against
    /// `LimitsConfig::max_codec_rejects` to quarantine codec abusers
    /// without touching any other session's stream.
    pub fn consecutive_rejects(&self, client: u32) -> u32 {
        self.consecutive.get(&client).copied().unwrap_or(0)
    }

    /// The most recently reconstructed quantised frame for a session
    /// (None before its first accepted frame).
    pub fn frame(&self, client: u32) -> Option<&[u8]> {
        self.streams
            .get(&client)
            .filter(|d| d.primed())
            .map(|d| d.frame())
    }

    /// Decode one `FeaturesV2` frame straight into a batch-matrix row
    /// (`row.len()` must equal `c·h·w`): reconstruct the quantised frame
    /// through the client's [`Decoder`], then dequantise via the fused LUT
    /// path. On `Err` the row is untouched or partially stale — callers
    /// reply `need_keyframe` and zero the slot.
    pub fn decode_into(
        &mut self,
        client: u32,
        f: &crate::net::framing::FeatureFrame,
        row: &mut [f32],
    ) -> Result<()> {
        ensure!(f.codec == CODEC_DELTA, "unsupported codec id {}", f.codec);
        ensure!(f.qmax > 0, "qmax must be positive");
        let n = f.c as usize * f.h as usize * f.w as usize;
        ensure!(row.len() == n, "feat len {n} != row {}", row.len());
        let dec = self.streams.entry(client).or_default();
        let r = dec.apply(f.flags, f.qmax, f.seq, n, &f.data);
        match r {
            Ok(()) => {
                self.accepted += 1;
                self.consecutive.remove(&client);
                dequantize_into(f.scale, f.qmax, dec.frame(), row);
                Ok(())
            }
            Err(e) => {
                self.rejects += 1;
                *self.consecutive.entry(client).or_insert(0) += 1;
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::framing::FeatureFrame;

    #[test]
    fn codec_id_roundtrips_wire_and_cli() {
        for c in [CodecId::Flat, CodecId::Delta] {
            assert_eq!(CodecId::from_wire(c.wire_id()), Some(c));
            assert_eq!(CodecId::parse(c.name()).unwrap(), c);
        }
        assert_eq!(CodecId::from_wire(9), None);
        assert!(CodecId::parse("zstd").is_err());
    }

    #[test]
    fn quantize_at_255_matches_the_flat_path_bit_for_bit() {
        let feat: Vec<f32> = (0..300).map(|i| ((i as f32 * 0.37) % 5.0).max(0.0)).collect();
        let (scale_flat, q_flat) = crate::net::framing::quantize_features(&feat);
        let mut q = Vec::new();
        let scale = quantize_into(&feat, 255, &mut q);
        assert_eq!(scale.to_bits(), scale_flat.to_bits());
        assert_eq!(q, q_flat);
        let mut a = vec![f32::NAN; feat.len()];
        let mut b = vec![f32::NAN; feat.len()];
        dequantize_into(scale, 255, &q, &mut a);
        crate::net::framing::dequantize_features_into(scale_flat, &q_flat, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn coarser_levels_bound_the_error_by_half_a_step() {
        let feat: Vec<f32> = (0..128).map(|i| (i as f32 * 0.11) % 2.0).collect();
        for qmax in [255u8, 127, 63, 31] {
            let mut q = Vec::new();
            let scale = quantize_into(&feat, qmax, &mut q);
            assert!(q.iter().all(|&b| b <= qmax));
            let mut back = vec![0.0f32; feat.len()];
            dequantize_into(scale, qmax, &q, &mut back);
            let step = scale / qmax as f32;
            for (a, b) in feat.iter().zip(&back) {
                assert!((a - b).abs() <= step * 0.5 + scale * 1e-6, "qmax {qmax}: {a} vs {b}");
            }
        }
    }

    fn frame_of(enc: &mut Encoder, qbuf: &[u8], qmax: u8, scale: f32) -> FeatureFrame {
        let mut data = Vec::new();
        let (flags, seq) = enc.encode_into(qbuf, &mut data);
        FeatureFrame {
            c: 1,
            h: 1,
            w: qbuf.len() as u16,
            codec: CODEC_DELTA,
            flags,
            qmax,
            seq,
            scale,
            data,
        }
    }

    #[test]
    fn decoders_invalidate_forces_a_keyframe_per_incarnation() {
        let mut enc = Encoder::new();
        let mut decs = Decoders::new();
        let mut row = vec![0.0f32; 64];
        let q0 = vec![4u8; 64];
        let f0 = frame_of(&mut enc, &q0, 255, 1.0);
        decs.decode_into(7, &f0, &mut row).unwrap();
        // reconnect: cached base dropped, the in-flight delta is rejected
        decs.invalidate(7);
        let mut q1 = q0.clone();
        q1[63] = 5;
        let f1 = frame_of(&mut enc, &q1, 255, 1.0);
        assert_eq!(f1.flags, 0, "expected a delta frame");
        assert!(decs.decode_into(7, &f1, &mut row).is_err());
        assert_eq!(decs.rejects, 1);
        // the client keyframes and the stream recovers
        enc.force_keyframe();
        let f2 = frame_of(&mut enc, &[1; 64], 255, 2.0);
        decs.decode_into(7, &f2, &mut row).unwrap();
        assert_eq!(decs.accepted, 2);
        assert_eq!(decs.n_streams(), 1);
        decs.disconnect(7);
        assert_eq!(decs.n_streams(), 0);
    }

    #[test]
    fn consecutive_rejects_climb_for_garbage_and_reset_on_recovery() {
        let mut decs = Decoders::new();
        let mut row = vec![0.0f32; 8];
        // garbage payloads that pass frame validation but fail the codec
        let junk = FeatureFrame {
            c: 1,
            h: 1,
            w: 8,
            codec: CODEC_DELTA,
            flags: 0, // a delta with no primed base can never decode
            qmax: 255,
            seq: 3,
            scale: 1.0,
            data: vec![0xFF; 8],
        };
        for i in 1..=5u32 {
            assert!(decs.decode_into(66, &junk, &mut row).is_err());
            assert_eq!(decs.consecutive_rejects(66), i);
        }
        // an unrelated healthy session is unaffected
        assert_eq!(decs.consecutive_rejects(7), 0);
        let mut enc = Encoder::new();
        let good = frame_of(&mut enc, &[3u8; 8], 255, 1.0);
        decs.decode_into(7, &good, &mut row).unwrap();
        assert_eq!(decs.consecutive_rejects(7), 0);
        assert_eq!(decs.consecutive_rejects(66), 5);
        // recovery (a keyframe that decodes) resets the abuser's count
        let mut enc2 = Encoder::new();
        let kf = frame_of(&mut enc2, &[1u8; 8], 255, 1.0);
        decs.decode_into(66, &kf, &mut row).unwrap();
        assert_eq!(decs.consecutive_rejects(66), 0);
        // disconnect drops the bookkeeping entirely
        decs.disconnect(66);
        assert_eq!(decs.consecutive_rejects(66), 0);
    }

    #[test]
    fn decode_rejects_wrong_codec_and_geometry() {
        let mut decs = Decoders::new();
        let mut row = vec![0.0f32; 4];
        let bad = FeatureFrame {
            c: 1,
            h: 2,
            w: 2,
            codec: CODEC_FLAT,
            flags: FLAG_KEYFRAME | FLAG_RAW,
            qmax: 255,
            seq: 1,
            scale: 1.0,
            data: vec![0; 4],
        };
        assert!(decs.decode_into(1, &bad, &mut row).is_err());
        let mut short_row = vec![0.0f32; 3];
        let ok = FeatureFrame { codec: CODEC_DELTA, ..bad };
        assert!(decs.decode_into(1, &ok, &mut short_row).is_err());
        assert!(decs.decode_into(1, &ok, &mut row).is_ok());
    }
}

//! Temporal delta coding of quantised feature frames.
//!
//! The encoder keeps the previous frame it put on the wire and emits one
//! of three frame kinds (wire `flags`, see DESIGN.md §7):
//!
//! * **packed keyframe** (`FLAG_KEYFRAME`) — residuals against the
//!   all-zeros frame (post-ReLU features are sparse, so this usually
//!   beats the flat bytes);
//! * **raw keyframe** (`FLAG_KEYFRAME | FLAG_RAW`) — the quantised bytes
//!   verbatim, chosen whenever packing would not help (dense frames);
//! * **delta** (no flags) — residuals against the previous frame.
//!
//! The encoder always picks the smaller representation, so the wire
//! payload never exceeds the flat `n`-byte frame. Keyframes are
//! self-contained: the decoder accepts one at any sequence number and
//! resets its chain state. Deltas require the decoder to hold the exact
//! previous frame (`seq` must advance by one); anything else — a restart,
//! a reconnect, a lost frame, a corrupt payload — is a rejection, after
//! which the decoder stays poisoned until the next keyframe. Chain-state
//! recovery is the rate controller's job ([`super::rate`]): it forces a
//! keyframe on every loss signal.

use anyhow::{ensure, Result};

use super::pack::{pack_residuals_into, unpack_residuals_into};

/// Wire flag: this frame is self-contained (no reference required).
pub const FLAG_KEYFRAME: u8 = 1;
/// Wire flag: the payload is the quantised frame verbatim, not packed.
pub const FLAG_RAW: u8 = 2;

/// Delta encoder for one feature stream (one client session).
#[derive(Debug, Default)]
pub struct Encoder {
    /// the quantised frame most recently put on the wire
    prev: Vec<u8>,
    /// all-zeros reference for packed keyframes (kept sized to the frame)
    zeros: Vec<u8>,
    /// packed-keyframe scratch for the packed-vs-raw size choice
    packed: Vec<u8>,
    seq: u32,
    /// false until a keyframe has been emitted (and after `force_keyframe`)
    primed: bool,
    /// keyframes emitted (raw + packed)
    pub keyframes: u64,
    /// delta frames emitted
    pub deltas: u64,
}

impl Encoder {
    pub fn new() -> Encoder {
        Encoder::default()
    }

    /// The next frame will be a keyframe (reconnect, server rejection, or
    /// the rate controller's periodic refresh).
    pub fn force_keyframe(&mut self) {
        self.primed = false;
    }

    /// Encode the quantised frame `cur` into `out` (cleared first; its
    /// capacity is pooled across frames — zero steady-state allocations
    /// once the stream's buffers are warm). Returns the wire
    /// `(flags, seq)` for the frame header. The payload is never longer
    /// than `cur` itself.
    pub fn encode_into(&mut self, cur: &[u8], out: &mut Vec<u8>) -> (u8, u32) {
        out.clear();
        let n = cur.len();
        let seq = self.seq.wrapping_add(1);
        let key = !self.primed || self.prev.len() != n;
        let flags = if key {
            if self.zeros.len() != n {
                self.zeros.clear();
                self.zeros.resize(n, 0);
            }
            self.packed.clear();
            pack_residuals_into(cur, &self.zeros, &mut self.packed);
            if self.packed.len() < n {
                out.extend_from_slice(&self.packed);
                FLAG_KEYFRAME
            } else {
                out.extend_from_slice(cur);
                FLAG_KEYFRAME | FLAG_RAW
            }
        } else {
            pack_residuals_into(cur, &self.prev, out);
            if out.len() < n {
                0
            } else {
                // the delta grew past the flat frame: a raw keyframe is no
                // larger and restarts the chain for free
                out.clear();
                out.extend_from_slice(cur);
                FLAG_KEYFRAME | FLAG_RAW
            }
        };
        if flags & FLAG_KEYFRAME != 0 {
            self.keyframes += 1;
        } else {
            self.deltas += 1;
        }
        self.prev.clear();
        self.prev.extend_from_slice(cur);
        self.primed = true;
        self.seq = seq;
        (flags, seq)
    }
}

/// Delta decoder for one feature stream. Holds the reconstructed previous
/// frame; [`Decoder::apply`] advances it by one wire frame.
#[derive(Debug, Default)]
pub struct Decoder {
    prev: Vec<u8>,
    seq: u32,
    /// false until a keyframe has been applied (and after any error)
    primed: bool,
    /// frames accepted
    pub accepted: u64,
    /// frames rejected (chain break, geometry change, corrupt payload)
    pub rejected: u64,
}

impl Decoder {
    pub fn new() -> Decoder {
        Decoder::default()
    }

    /// Drop the cached reference frame: the stream's next frame must be a
    /// keyframe. Called on every session (re)connect so a new incarnation
    /// can never delta against a stale base.
    pub fn reset(&mut self) {
        self.primed = false;
    }

    /// True once a frame has been applied since the last reset/error.
    pub fn primed(&self) -> bool {
        self.primed
    }

    /// The most recently reconstructed quantised frame.
    pub fn frame(&self) -> &[u8] {
        &self.prev
    }

    /// Apply one wire frame of `n = c·h·w` values. On success
    /// [`Decoder::frame`] holds the reconstructed quantised frame
    /// (bit-identical to what the encoder consumed). Any error poisons the
    /// chain state — a later delta cannot silently decode against a
    /// half-applied base — until a keyframe re-primes it.
    pub fn apply(&mut self, flags: u8, qmax: u8, seq: u32, n: usize, data: &[u8]) -> Result<()> {
        let r = self.apply_inner(flags, qmax, seq, n, data);
        match r {
            Ok(()) => self.accepted += 1,
            Err(_) => {
                self.primed = false;
                self.rejected += 1;
            }
        }
        r
    }

    fn apply_inner(&mut self, flags: u8, qmax: u8, seq: u32, n: usize, data: &[u8]) -> Result<()> {
        if flags & FLAG_KEYFRAME != 0 {
            if flags & FLAG_RAW != 0 {
                ensure!(data.len() == n, "raw keyframe is {} bytes, frame is {n}", data.len());
                ensure!(
                    data.iter().all(|&b| b <= qmax),
                    "raw keyframe value above qmax {qmax}"
                );
                self.prev.clear();
                self.prev.extend_from_slice(data);
            } else {
                self.prev.clear();
                self.prev.resize(n, 0);
                unpack_residuals_into(data, &mut self.prev, qmax)?;
            }
        } else {
            ensure!(self.primed, "delta frame without a decoded base");
            ensure!(
                self.prev.len() == n,
                "delta geometry changed ({} != {n})",
                self.prev.len()
            );
            ensure!(
                seq == self.seq.wrapping_add(1),
                "delta chain break (got seq {seq}, base is {})",
                self.seq
            );
            unpack_residuals_into(data, &mut self.prev, qmax)?;
        }
        self.seq = seq;
        self.primed = true;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive a frame sequence through encoder + decoder, asserting
    /// bit-exact reconstruction after every frame. Returns total payload
    /// bytes.
    fn pump(frames: &[Vec<u8>]) -> usize {
        let mut enc = Encoder::new();
        let mut dec = Decoder::new();
        let mut wire = Vec::new();
        let mut total = 0;
        for f in frames {
            let (flags, seq) = enc.encode_into(f, &mut wire);
            assert!(wire.len() <= f.len(), "payload exceeded the flat frame");
            dec.apply(flags, 255, seq, f.len(), &wire).expect("apply");
            assert_eq!(dec.frame(), &f[..], "reconstruction diverged");
            total += wire.len();
        }
        total
    }

    #[test]
    fn first_frame_is_a_keyframe_then_deltas_flow() {
        let mut enc = Encoder::new();
        let mut wire = Vec::new();
        let (flags, seq) = enc.encode_into(&[1, 2, 3], &mut wire);
        assert_ne!(flags & FLAG_KEYFRAME, 0);
        assert_eq!(seq, 1);
        let (flags, seq) = enc.encode_into(&[1, 2, 4], &mut wire);
        assert_eq!(flags, 0, "second frame should be a delta");
        assert_eq!(seq, 2);
        assert_eq!(enc.keyframes, 1);
        assert_eq!(enc.deltas, 1);
    }

    #[test]
    fn constant_stream_collapses() {
        let frames: Vec<Vec<u8>> = (0..10).map(|_| vec![40u8; 256]).collect();
        let total = pump(&frames);
        // keyframe ≤ 256, then 9 mask-only deltas of 2 bytes each
        // (256 values = 16 blocks = 2 mask bytes)
        assert!(total <= 256 + 9 * 2, "constant stream cost {total} bytes");
    }

    #[test]
    fn slowly_varying_stream_beats_flat() {
        let n = 256;
        let frames: Vec<Vec<u8>> = (0..12)
            .map(|t| {
                (0..n)
                    .map(|i| if i / 8 == t { 100 + t as u8 } else { 3 })
                    .collect()
            })
            .collect();
        let total = pump(&frames);
        assert!(total < 12 * n / 2, "slowly varying stream cost {total} of {}", 12 * n);
    }

    #[test]
    fn dense_random_frames_fall_back_to_raw_keyframes() {
        // frames with no temporal structure: every payload must still be
        // bounded by the flat size
        let mut rng = crate::util::rng::Rng::new(9);
        let frames: Vec<Vec<u8>> = (0..6)
            .map(|_| (0..300).map(|_| rng.below(256) as u8).collect())
            .collect();
        let total = pump(&frames);
        assert!(total <= 6 * 300);
    }

    #[test]
    fn forced_keyframe_restarts_the_chain() {
        let mut enc = Encoder::new();
        let mut wire = Vec::new();
        enc.encode_into(&[9; 64], &mut wire);
        enc.force_keyframe();
        let (flags, _) = enc.encode_into(&[9; 64], &mut wire);
        assert_ne!(flags & FLAG_KEYFRAME, 0);
    }

    #[test]
    fn decoder_rejects_delta_after_reset_until_a_keyframe() {
        let mut enc = Encoder::new();
        let mut dec = Decoder::new();
        let mut wire = Vec::new();
        let f0 = vec![5u8; 64];
        let (flags, seq) = enc.encode_into(&f0, &mut wire);
        dec.apply(flags, 255, seq, 64, &wire).unwrap();
        dec.reset();
        let mut f1 = f0.clone();
        f1[0] = 6;
        let (flags, seq) = enc.encode_into(&f1, &mut wire);
        assert_eq!(flags, 0);
        assert!(dec.apply(flags, 255, seq, 64, &wire).is_err());
        assert_eq!(dec.rejected, 1);
        // keyframe recovers
        enc.force_keyframe();
        let mut f2 = f1.clone();
        f2[1] = 7;
        let (flags, seq) = enc.encode_into(&f2, &mut wire);
        dec.apply(flags, 255, seq, 64, &wire).unwrap();
        assert_eq!(dec.frame(), &f2[..]);
    }

    #[test]
    fn skipped_frame_breaks_the_chain() {
        let mut enc = Encoder::new();
        let mut dec = Decoder::new();
        let mut wire = Vec::new();
        let f1 = vec![1u8; 64];
        let (flags, seq) = enc.encode_into(&f1, &mut wire);
        dec.apply(flags, 255, seq, 64, &wire).unwrap();
        // frame 2 is lost in transit
        let mut f2 = f1.clone();
        f2[0] = 2;
        let mut lost = Vec::new();
        enc.encode_into(&f2, &mut lost);
        // frame 3 arrives: a genuine delta whose seq jumped by two
        let mut f3 = f2.clone();
        f3[1] = 3;
        let (flags, seq) = enc.encode_into(&f3, &mut wire);
        assert_eq!(flags, 0, "sparse change must encode as a delta");
        assert!(dec.apply(flags, 255, seq, 64, &wire).is_err());
        assert!(!dec.primed());
    }

    #[test]
    fn corrupt_payload_poisons_the_chain() {
        let mut enc = Encoder::new();
        let mut dec = Decoder::new();
        let mut wire = Vec::new();
        let (flags, seq) = enc.encode_into(&[10; 64], &mut wire);
        dec.apply(flags, 255, seq, 64, &wire).unwrap();
        let mut f1 = vec![10u8; 64];
        f1[5] = 12;
        let (flags, seq) = enc.encode_into(&f1, &mut wire);
        assert_eq!(flags, 0);
        let cut = &wire[..wire.len() - 1];
        assert!(dec.apply(flags, 255, seq, 64, cut).is_err());
        // the chain is poisoned: even the true payload is now refused
        assert!(dec.apply(flags, 255, seq, 64, &wire).is_err());
    }

    #[test]
    fn geometry_change_forces_a_keyframe() {
        let mut enc = Encoder::new();
        let mut wire = Vec::new();
        enc.encode_into(&[1; 64], &mut wire);
        let (flags, _) = enc.encode_into(&[1; 32], &mut wire);
        assert_ne!(flags & FLAG_KEYFRAME, 0, "length change must re-key");
    }
}

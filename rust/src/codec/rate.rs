//! Closed-loop rate control for the feature codec.
//!
//! The controller owns two per-session decisions the encoder consults
//! before every frame:
//!
//! * **quantisation level** — a ceiling `qmax` from a configurable ladder
//!   (finest → coarsest). Each server ack feeds one link-time sample
//!   (end-to-end latency minus the server-reported queue wait, i.e. the
//!   part the link is responsible for) into an EWMA; when the EWMA sits
//!   above the latency target the controller steps coarser, when it sits
//!   comfortably below it steps finer. A hold-down of `hold` acks between
//!   moves plus the `low_water`/`high_water` hysteresis gap keeps it from
//!   oscillating.
//! * **keyframe vs delta** — deltas by default; a keyframe is forced by
//!   any loss signal ([`RateController::on_loss`]: reconnect, an explicit
//!   server rejection, or a `need_keyframe` ack) and by the periodic
//!   refresh every `keyframe_interval` frames, which bounds how long a
//!   silent desync can live.
//!
//! State machine (DESIGN.md §7): `Keyframe → Delta` on every sent
//! keyframe; `Delta → Keyframe` on loss or refresh. The quantisation
//! level moves independently of the keyframe axis.
//!
//! All arithmetic is plain `f64` over caller-provided samples — no clock
//! reads — so the controller is bit-deterministic under the simnet.

/// Tuning for [`RateController`].
#[derive(Debug, Clone)]
pub struct RateConfig {
    /// per-decision link-time budget the controller steers toward, seconds
    pub target_latency: f64,
    /// quantisation ceilings, finest first (values quantise into [0, qmax])
    pub ladder: Vec<u8>,
    /// EWMA smoothing factor for link-time samples, in (0, 1]
    pub alpha: f64,
    /// step coarser when `ewma > target_latency * high_water`
    pub high_water: f64,
    /// step finer when `ewma < target_latency * low_water`
    pub low_water: f64,
    /// minimum acks between quantisation moves (adaptation hold-down)
    pub hold: u32,
    /// force a keyframe every this many frames (0 = only on loss)
    pub keyframe_interval: u32,
}

impl Default for RateConfig {
    fn default() -> Self {
        RateConfig {
            target_latency: 0.05,
            ladder: vec![255, 127, 63, 31],
            alpha: 0.3,
            high_water: 1.0,
            low_water: 0.5,
            hold: 4,
            keyframe_interval: 64,
        }
    }
}

/// Per-session adaptive controller; see the module docs.
#[derive(Debug)]
pub struct RateController {
    cfg: RateConfig,
    /// index into `cfg.ladder` (0 = finest)
    level: usize,
    ewma: Option<f64>,
    ewma_bps: Option<f64>,
    acks_since_move: u32,
    frames_since_key: u32,
    force_key: bool,
    /// quantisation steps taken toward coarser levels
    pub coarser_steps: u64,
    /// quantisation steps taken back toward finer levels
    pub finer_steps: u64,
    /// loss signals received (each forces the next frame to be a keyframe)
    pub losses: u64,
}

impl RateController {
    pub fn new(cfg: RateConfig) -> RateController {
        assert!(!cfg.ladder.is_empty(), "rate ladder must not be empty");
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0, "alpha must be in (0, 1]");
        RateController {
            cfg,
            level: 0,
            ewma: None,
            ewma_bps: None,
            acks_since_move: 0,
            frames_since_key: 0,
            force_key: true,
            coarser_steps: 0,
            finer_steps: 0,
            losses: 0,
        }
    }

    /// The current quantisation ceiling.
    pub fn qmax(&self) -> u8 {
        self.cfg.ladder[self.level]
    }

    /// Current ladder position (0 = finest).
    pub fn level(&self) -> usize {
        self.level
    }

    /// Smoothed link-time estimate, seconds (None before the first ack).
    pub fn ewma_latency(&self) -> Option<f64> {
        self.ewma
    }

    /// Smoothed goodput estimate, bits/s (None before the first ack).
    pub fn estimated_bps(&self) -> Option<f64> {
        self.ewma_bps
    }

    /// A loss signal: reconnect, an explicit server rejection, or a
    /// `need_keyframe` ack. The next frame will be a keyframe.
    pub fn on_loss(&mut self) {
        self.force_key = true;
        self.losses += 1;
    }

    /// Feed one server ack: `wire_bytes` were acknowledged after
    /// `latency_s` end to end, of which `queue_wait_s` was spent queued at
    /// the server (not the link's fault, so it is subtracted).
    pub fn on_ack(&mut self, wire_bytes: usize, latency_s: f64, queue_wait_s: f64) {
        let link = (latency_s - queue_wait_s).max(1e-6);
        let a = self.cfg.alpha;
        self.ewma = Some(match self.ewma {
            None => link,
            Some(e) => e + a * (link - e),
        });
        let bps = wire_bytes as f64 * 8.0 / link;
        self.ewma_bps = Some(match self.ewma_bps {
            None => bps,
            Some(e) => e + a * (bps - e),
        });
        self.acks_since_move += 1;
        if self.acks_since_move < self.cfg.hold {
            return;
        }
        let e = self.ewma.unwrap();
        if e > self.cfg.target_latency * self.cfg.high_water {
            if self.level + 1 < self.cfg.ladder.len() {
                self.level += 1;
                self.coarser_steps += 1;
                self.acks_since_move = 0;
            }
        } else if e < self.cfg.target_latency * self.cfg.low_water && self.level > 0 {
            self.level -= 1;
            self.finer_steps += 1;
            self.acks_since_move = 0;
        }
    }

    /// Must the next frame be a keyframe (forced or periodic refresh)?
    pub fn keyframe_due(&self) -> bool {
        self.force_key
            || (self.cfg.keyframe_interval > 0
                && self.frames_since_key >= self.cfg.keyframe_interval)
    }

    /// Note a sent frame so the forced-keyframe latch and the periodic
    /// refresh counter advance. `keyframe` is what actually went on the
    /// wire (the encoder may upgrade a delta to a keyframe on its own).
    pub fn frame_sent(&mut self, keyframe: bool) {
        if keyframe {
            self.force_key = false;
            self.frames_since_key = 0;
        } else {
            self.frames_since_key += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> RateController {
        RateController::new(RateConfig {
            target_latency: 0.01,
            hold: 2,
            ..RateConfig::default()
        })
    }

    #[test]
    fn starts_finest_and_keyframe_forced() {
        let c = ctl();
        assert_eq!(c.qmax(), 255);
        assert!(c.keyframe_due());
    }

    #[test]
    fn sustained_congestion_walks_to_the_coarse_floor() {
        let mut c = ctl();
        for _ in 0..40 {
            c.on_ack(400, 0.05, 0.0); // 5x over target
        }
        assert_eq!(c.level(), 3, "should sit at the coarsest rung");
        assert_eq!(c.qmax(), 31);
        assert!(c.coarser_steps >= 3);
        // and a relieved link walks it back to the finest
        for _ in 0..40 {
            c.on_ack(400, 0.001, 0.0); // 10x under target
        }
        assert_eq!(c.level(), 0);
        assert!(c.finer_steps >= 3);
    }

    #[test]
    fn hysteresis_band_holds_the_level() {
        let mut c = ctl();
        // between low (0.005) and high (0.01): no movement ever
        for _ in 0..100 {
            c.on_ack(400, 0.007, 0.0);
        }
        assert_eq!(c.level(), 0);
        assert_eq!(c.coarser_steps + c.finer_steps, 0);
    }

    #[test]
    fn queue_wait_is_not_the_links_fault() {
        let mut c = ctl();
        // 50 ms end to end, but 45 ms of it queued at the server
        for _ in 0..40 {
            c.on_ack(400, 0.05, 0.045);
        }
        assert_eq!(c.level(), 0, "server queueing must not coarsen the codec");
    }

    #[test]
    fn loss_forces_exactly_one_keyframe() {
        let mut c = ctl();
        c.frame_sent(true);
        assert!(!c.keyframe_due());
        c.on_loss();
        assert!(c.keyframe_due());
        c.frame_sent(true);
        assert!(!c.keyframe_due());
        assert_eq!(c.losses, 1);
    }

    #[test]
    fn periodic_refresh_fires_on_the_interval() {
        let mut c = RateController::new(RateConfig {
            keyframe_interval: 3,
            ..RateConfig::default()
        });
        c.frame_sent(true);
        for _ in 0..3 {
            assert!(!c.keyframe_due());
            c.frame_sent(false);
        }
        assert!(c.keyframe_due(), "4th frame is the refresh");
        // interval 0 disables the refresh entirely
        let mut c = RateController::new(RateConfig {
            keyframe_interval: 0,
            ..RateConfig::default()
        });
        c.frame_sent(true);
        for _ in 0..500 {
            c.frame_sent(false);
        }
        assert!(!c.keyframe_due());
    }

    #[test]
    fn goodput_estimate_tracks_the_samples() {
        let mut c = ctl();
        c.on_ack(1250, 0.01, 0.0); // 1250 B in 10 ms = 1 Mb/s
        let bps = c.estimated_bps().unwrap();
        assert!((bps - 1e6).abs() < 1.0, "{bps}");
        assert!((c.ewma_latency().unwrap() - 0.01).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "ladder")]
    fn empty_ladder_is_rejected() {
        RateController::new(RateConfig { ladder: vec![], ..RateConfig::default() });
    }
}

//! Entropy packing for quantised feature residuals: per-block significance
//! masks + zigzag/varint coding, all into pooled buffers.
//!
//! The packed form of a residual vector `cur - prev` (both quantised u8
//! frames of the same length `n`) is
//!
//! ```text
//! [mask: ceil(ceil(n/BLOCK)/8) bytes][varints of every significant block]
//! ```
//!
//! where block `b` covers values `[b·BLOCK, (b+1)·BLOCK)` and is
//! *significant* (mask bit set, LSB-first) iff any residual in it is
//! nonzero. Insignificant blocks cost one mask bit and nothing else — the
//! skip path that makes constant and slowly-varying frames collapse to a
//! few bytes. Significant blocks carry every residual in order, each
//! zigzag-mapped to an unsigned value and LEB128-varint coded (residuals
//! live in [-255, 255], so a varint is at most two bytes).
//!
//! The format is canonical: unused bits of the final mask byte must be
//! zero and the payload must end exactly at the last varint, so corrupt or
//! truncated payloads are rejected, never half-applied silently (the
//! caller additionally poisons its chain state on any error; see
//! [`super::delta::Decoder`]).

use anyhow::{ensure, Result};

/// Values per significance block. 16 keeps the mask overhead at `n/128`
/// bytes while skipping most of a static background; raster changes
/// cluster along a handful of rows, so small blocks keep a moving
/// sprite's cost proportional to the pixels it actually touched.
pub const BLOCK: usize = 16;

/// Map a signed residual to an unsigned code (0, -1, 1, -2, 2 → 0, 1, 2,
/// 3, 4): small magnitudes of either sign get short varints.
#[inline]
pub fn zigzag(d: i32) -> u32 {
    ((d << 1) ^ (d >> 31)) as u32
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(z: u32) -> i32 {
    ((z >> 1) as i32) ^ -((z & 1) as i32)
}

/// Append one LEB128 varint (7 value bits per byte, high bit = continue).
#[inline]
pub fn put_varint(out: &mut Vec<u8>, mut v: u32) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// Read one LEB128 varint at `*pos`, advancing it. Rejects truncation and
/// varints longer than the 5 bytes a u32 can need.
#[inline]
pub fn get_varint(data: &[u8], pos: &mut usize) -> Result<u32> {
    let mut v: u32 = 0;
    let mut shift = 0u32;
    loop {
        ensure!(*pos < data.len(), "truncated varint");
        ensure!(shift <= 28, "varint overflows u32");
        let b = data[*pos];
        *pos += 1;
        // the 5th byte contributes only 4 bits; silently dropping the rest
        // would let two distinct byte streams decode to the same value,
        // breaking the canonical-form contract
        ensure!(shift < 28 || b & 0x7F <= 0x0F, "varint overflows u32");
        v |= ((b & 0x7F) as u32) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Pack the residuals `cur - prev` (equal-length quantised frames),
/// appending the mask + varint stream to `out` (the caller clears; the
/// buffer's capacity is pooled across frames).
pub fn pack_residuals_into(cur: &[u8], prev: &[u8], out: &mut Vec<u8>) {
    assert_eq!(cur.len(), prev.len(), "residual frames must have equal length");
    let n = cur.len();
    let n_blocks = n.div_ceil(BLOCK);
    let mask_bytes = n_blocks.div_ceil(8);
    let mask_start = out.len();
    out.resize(mask_start + mask_bytes, 0);
    for b in 0..n_blocks {
        let lo = b * BLOCK;
        let hi = (lo + BLOCK).min(n);
        if cur[lo..hi] == prev[lo..hi] {
            continue;
        }
        out[mask_start + b / 8] |= 1 << (b % 8);
        for i in lo..hi {
            put_varint(out, zigzag(cur[i] as i32 - prev[i] as i32));
        }
    }
}

/// Apply a packed residual stream onto `base` in place (`base` holds the
/// reference frame and ends up holding the reconstructed one). Every
/// reconstructed value must stay in `[0, qmax]` — anything else means the
/// stream was built against a different base (or corrupted) and the whole
/// frame is rejected. On `Err`, `base` may be partially updated; the
/// caller must treat its chain state as poisoned.
pub fn unpack_residuals_into(data: &[u8], base: &mut [u8], qmax: u8) -> Result<()> {
    let n = base.len();
    let n_blocks = n.div_ceil(BLOCK);
    let mask_bytes = n_blocks.div_ceil(8);
    ensure!(data.len() >= mask_bytes, "truncated block mask");
    // canonical form: mask bits past the last block must be zero
    for b in n_blocks..mask_bytes * 8 {
        ensure!(data[b / 8] & (1 << (b % 8)) == 0, "nonzero padding bit in block mask");
    }
    let mut pos = mask_bytes;
    for b in 0..n_blocks {
        if data[b / 8] & (1 << (b % 8)) == 0 {
            continue;
        }
        let lo = b * BLOCK;
        let hi = (lo + BLOCK).min(n);
        for v in base[lo..hi].iter_mut() {
            let z = get_varint(data, &mut pos)?;
            let r = *v as i32 + unzigzag(z);
            ensure!(
                (0..=qmax as i32).contains(&r),
                "reconstructed value {r} outside [0, {qmax}]"
            );
            *v = r as u8;
        }
    }
    ensure!(pos == data.len(), "trailing bytes after packed residuals");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_is_a_bijection_on_residual_range() {
        for d in -255i32..=255 {
            let z = zigzag(d);
            assert!(z <= 510, "zigzag({d}) = {z}");
            assert_eq!(unzigzag(z), d);
        }
    }

    #[test]
    fn varint_roundtrips_and_is_short_for_small_values() {
        let mut buf = Vec::new();
        for v in [0u32, 1, 63, 64, 127, 128, 510, 16383, 16384, u32::MAX] {
            buf.clear();
            put_varint(&mut buf, v);
            if v < 128 {
                assert_eq!(buf.len(), 1, "{v}");
            }
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn varint_rejects_truncation_and_overflow() {
        let mut pos = 0;
        assert!(get_varint(&[], &mut pos).is_err());
        let mut pos = 0;
        assert!(get_varint(&[0x80, 0x80], &mut pos).is_err(), "unterminated");
        let mut pos = 0;
        assert!(get_varint(&[0x80, 0x80, 0x80, 0x80, 0x80, 0x01], &mut pos).is_err());
        // a 5th byte with bits beyond u32 must be rejected, not truncated:
        // [0x80,0x80,0x80,0x80,0x70] would otherwise decode to 0, aliasing
        // the canonical [0x00]
        let mut pos = 0;
        assert!(get_varint(&[0x80, 0x80, 0x80, 0x80, 0x70], &mut pos).is_err());
        // the maximal canonical u32 still decodes
        let mut buf = Vec::new();
        put_varint(&mut buf, u32::MAX);
        let mut pos = 0;
        assert_eq!(get_varint(&buf, &mut pos).unwrap(), u32::MAX);
    }

    fn roundtrip(cur: &[u8], prev: &[u8]) -> Vec<u8> {
        let mut packed = Vec::new();
        pack_residuals_into(cur, prev, &mut packed);
        let mut base = prev.to_vec();
        unpack_residuals_into(&packed, &mut base, 255).expect("unpack");
        assert_eq!(base, cur);
        packed
    }

    #[test]
    fn identical_frames_cost_only_the_mask() {
        let frame = vec![7u8; 100];
        let packed = roundtrip(&frame, &frame);
        // 7 blocks -> 1 mask byte, nothing else
        assert_eq!(packed.len(), 1);
        assert_eq!(packed[0], 0);
    }

    #[test]
    fn single_changed_value_costs_one_block() {
        let prev = vec![10u8; 100];
        let mut cur = prev.clone();
        cur[50] = 11;
        let packed = roundtrip(&cur, &prev);
        // 1 mask byte + 16 one-byte varints for the touched block
        // (value 50 falls in block 3, which is full: 100 = 6*16 + 4)
        assert_eq!(packed.len(), 1 + 16);
    }

    #[test]
    fn empty_frame_packs_to_nothing() {
        let packed = roundtrip(&[], &[]);
        assert!(packed.is_empty());
        let mut base: Vec<u8> = Vec::new();
        assert!(unpack_residuals_into(&[], &mut base, 255).is_ok());
    }

    #[test]
    fn out_of_range_reconstruction_is_rejected() {
        // residual says +2 on a base of 254 at qmax 255 — fine; at qmax 63
        // the same stream must be rejected
        let prev = vec![60u8; 8];
        let mut cur = prev.clone();
        cur[0] = 62;
        let mut packed = Vec::new();
        pack_residuals_into(&cur, &prev, &mut packed);
        let mut base = prev.clone();
        assert!(unpack_residuals_into(&packed, &mut base, 63).is_ok());
        let mut cur_high = prev.clone();
        cur_high[0] = 70; // above qmax 63
        packed.clear();
        pack_residuals_into(&cur_high, &prev, &mut packed);
        let mut base = prev.clone();
        assert!(unpack_residuals_into(&packed, &mut base, 63).is_err());
    }

    #[test]
    fn truncated_and_padded_streams_are_rejected() {
        let prev = vec![0u8; 64];
        let mut cur = prev.clone();
        cur[0] = 5;
        cur[40] = 9;
        let mut packed = Vec::new();
        pack_residuals_into(&cur, &prev, &mut packed);
        // truncate anywhere: must error, never panic
        for cut in 0..packed.len() {
            let mut base = prev.clone();
            assert!(
                unpack_residuals_into(&packed[..cut], &mut base, 255).is_err(),
                "accepted a {cut}-byte truncation of {} bytes",
                packed.len()
            );
        }
        // trailing garbage
        let mut padded = packed.clone();
        padded.push(0);
        let mut base = prev.clone();
        assert!(unpack_residuals_into(&padded, &mut base, 255).is_err());
        // nonzero padding bit in the mask (64 values -> 2 blocks, bits 2..8
        // of the single mask byte are padding)
        let mut bent = packed.clone();
        bent[0] |= 1 << 5;
        let mut base = prev.clone();
        assert!(unpack_residuals_into(&bent, &mut base, 255).is_err());
    }
}

//! The clock seam: every time-sensitive subsystem (token-bucket shaping,
//! batch deadlines, client pacing, thermal integration) reads time through
//! a [`Clock`] instead of calling `Instant::now()` directly. Production
//! code injects [`WallClock`]; the deterministic scenario runner injects a
//! [`SimClock`] whose `Instant`s are minted from a virtual offset, so the
//! exact same arithmetic (the batcher's `Instant`-typed deadlines, the
//! bucket's refill math) runs under simulated time with zero real sleeps.
//!
//! `SimClock` pairs with the virtual [`EventQueue`] (re-exported from
//! `util::simclock`): the runner pops the next event, `advance_to_secs`
//! the clock, and handles it — discrete-event simulation over the same
//! component code the threaded servers run.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub use crate::util::simclock::EventQueue;

/// A source of monotonic time plus the ability to wait.
pub trait Clock: Send + Sync {
    /// Current instant. Sim clocks mint `base + virtual_offset`, so the
    /// values are ordinary `Instant`s and all `Duration` arithmetic in
    /// downstream code works unchanged.
    fn now(&self) -> Instant;

    /// Wait for `d`. On the wall clock this is `thread::sleep`; on a sim
    /// clock the virtual time simply advances (in a single-threaded
    /// simulation the sleeper is the only runnable task).
    fn sleep(&self, d: Duration);
}

/// Real time: `Instant::now()` + `thread::sleep`.
#[derive(Debug, Clone, Copy, Default)]
pub struct WallClock;

impl Clock for WallClock {
    fn now(&self) -> Instant {
        Instant::now()
    }

    fn sleep(&self, d: Duration) {
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }
}

/// Shared virtual clock. Cloning shares the underlying time cell, so a
/// scenario runner and the components it drives all observe one timeline.
#[derive(Debug, Clone)]
pub struct SimClock {
    inner: Arc<Mutex<Duration>>,
    base: Instant,
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock { inner: Arc::new(Mutex::new(Duration::ZERO)), base: Instant::now() }
    }

    /// Seconds of virtual time since the clock was created.
    pub fn now_secs(&self) -> f64 {
        self.inner.lock().unwrap().as_secs_f64()
    }

    /// The instant a virtual timestamp (seconds since start) maps to.
    pub fn instant_at(&self, t_secs: f64) -> Instant {
        self.base + Duration::from_secs_f64(t_secs.max(0.0))
    }

    pub fn advance(&self, d: Duration) {
        *self.inner.lock().unwrap() += d;
    }

    pub fn advance_secs(&self, s: f64) {
        assert!(s >= 0.0 && s.is_finite(), "advance by {s}");
        self.advance(Duration::from_secs_f64(s));
    }

    /// Jump to an absolute virtual time (seconds since start). Never moves
    /// backwards: an event popped at a tied or stale timestamp leaves the
    /// clock where it is.
    pub fn advance_to_secs(&self, t: f64) {
        assert!(t.is_finite(), "advance_to {t}");
        let mut g = self.inner.lock().unwrap();
        let target = Duration::from_secs_f64(t.max(0.0));
        if target > *g {
            *g = target;
        }
    }

    /// A type-erased handle for injection into configs.
    pub fn handle(&self) -> ClockHandle {
        ClockHandle(Arc::new(self.clone()))
    }
}

impl Clock for SimClock {
    fn now(&self) -> Instant {
        self.base + *self.inner.lock().unwrap()
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

/// Cloneable, debuggable handle to a `dyn Clock` — the currency configs
/// carry (`ClientConfig`, `ServerConfig`, `ShapedWriter`).
#[derive(Clone)]
pub struct ClockHandle(Arc<dyn Clock>);

impl ClockHandle {
    pub fn wall() -> ClockHandle {
        ClockHandle(Arc::new(WallClock))
    }

    pub fn sim(clock: &SimClock) -> ClockHandle {
        clock.handle()
    }

    pub fn now(&self) -> Instant {
        self.0.now()
    }

    pub fn sleep(&self, d: Duration) {
        self.0.sleep(d);
    }
}

impl Default for ClockHandle {
    fn default() -> Self {
        ClockHandle::wall()
    }
}

impl std::fmt::Debug for ClockHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("ClockHandle")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_moves_forward() {
        let c = WallClock;
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn sim_clock_advances_only_virtually() {
        let c = SimClock::new();
        let t0 = c.now();
        c.advance_secs(2.5);
        assert_eq!(c.now().duration_since(t0), Duration::from_secs_f64(2.5));
        assert!((c.now_secs() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn sim_sleep_is_instant_in_real_time() {
        let c = SimClock::new();
        let real0 = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!(real0.elapsed() < Duration::from_secs(1));
        assert!((c.now_secs() - 3600.0).abs() < 1e-9);
    }

    #[test]
    fn clones_share_the_timeline() {
        let a = SimClock::new();
        let b = a.clone();
        a.advance_secs(1.0);
        assert!((b.now_secs() - 1.0).abs() < 1e-12);
        b.advance_to_secs(5.0);
        assert!((a.now_secs() - 5.0).abs() < 1e-12);
        // stale pops never rewind
        b.advance_to_secs(4.0);
        assert!((a.now_secs() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn instant_at_matches_advance() {
        let c = SimClock::new();
        let i = c.instant_at(1.25);
        c.advance_secs(1.25);
        assert_eq!(c.now(), i);
    }

    #[test]
    fn handle_is_injectable() {
        let c = SimClock::new();
        let h = c.handle();
        let t0 = h.now();
        h.sleep(Duration::from_millis(250));
        assert_eq!(h.now().duration_since(t0), Duration::from_millis(250));
        // default handle is the wall clock
        let w = ClockHandle::default();
        assert!(w.now().elapsed() < Duration::from_secs(1));
    }
}

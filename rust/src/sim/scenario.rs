//! The chaos-scenario runner: gateway + N shards + M split/server-only
//! clients composed fully in-process over [`SimNet`] lanes, advanced in
//! virtual time, emitting a canonical [`EventLog`].
//!
//! The runner is a single-threaded discrete-event simulation that reuses
//! the *real* fleet components wherever they are pure over time: the
//! consistent-hash [`Topology`] routes sessions, [`BatchCollector`] forms
//! batches from `Instant`s minted by the [`SimClock`], [`SessionManager`]
//! stacks raw frames, `net::framing` encodes every byte on the wire, and
//! [`probe_transition`] drives the same Up/Degraded/Down/Draining state
//! machine the threaded health monitor runs. Only the thread/socket shell
//! is replaced — by lanes, events, and virtual sleeps.
//!
//! Determinism contract: one seeded [`Rng`] feeds every fault decision,
//! all shared maps are `BTreeMap`s (no hash-iteration order anywhere),
//! and no wall-clock read exists on this path — two runs with the same
//! [`ScenarioConfig`] render byte-identical logs. See DESIGN.md §6.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::codec::{self, CodecId, Decoders, Encoder, RateConfig, RateController, CODEC_DELTA};
use crate::coordinator::batcher::{BatchCollector, BatchPolicy, Item};
use crate::coordinator::router::Route;
use crate::coordinator::session::SessionManager;
use crate::device::thermal::{ClockedThermal, ThermalModel};
use crate::envs::{Env, Pendulum};
use crate::fleet::aggregate::{GatewayCounters, LoadWindow};
use crate::fleet::autoscale::{AutoscaleConfig, Autoscaler, ScaleAction};
use crate::fleet::health::{probe_transition, HealthConfig, ProbeStats};
use crate::fleet::topology::{ShardId, ShardState, Topology};
use crate::learn::{Learner, LearnerConfig, PolicyStore};
use crate::net::framing::{
    ErrorMsg, ExperienceFrame, FeatureFrame, Hello, Msg, Payload, PolicySync, Request, Response,
    ResponseLearn, ResponseV2, CAP_EXPERIENCE, CAP_TRACE, ERR_EXPERIENCE_UNSUPPORTED,
    ERR_OVERLOADED, EXP_DONE, EXP_EP_START, EXP_HAS_REWARD, EXP_TERMINATED,
    RESP_FLAG_NEED_KEYFRAME, RESP_FLAG_STALE,
};
use crate::net::limits::backoff_delay;
use crate::trace::{self, StageNs, TraceCtx};
use crate::rl::native::{episode_rng, normalize_pendulum_obs};
use crate::util::rng::Rng;
use crate::util::simclock::EventQueue;
use crate::util::stats::{LatencyHist, Samples};

use super::clock::SimClock;
use super::log::EventLog;
use super::transport::{Delivery, LaneId, LinkFaults, SimNet};

/// Thermal chaos: an RC die model behind the shard executor. While the
/// model reports throttled, batch costs multiply by `throttle_factor`.
#[derive(Debug, Clone)]
pub struct ThermalSpec {
    pub model: ThermalModel,
    /// dissipation while executing a batch, watts
    pub active_watts: f64,
    /// dissipation between batches, watts
    pub idle_watts: f64,
    /// batch-cost multiplier while throttled
    pub throttle_factor: f64,
}

/// Timed chaos commands, applied at their scheduled virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultCmd {
    /// hard-kill a shard: lanes close, queued work dies with it
    CrashShard(usize),
    /// bring a crashed shard back with fresh state (listener reopens)
    RestartShard(usize),
    /// blackhole both trunk directions of a shard (links up, path gone)
    PartitionShard(usize),
    /// heal a partition
    HealShard(usize),
    /// operator drain: existing pins keep flowing, new sessions go elsewhere
    DrainShard(usize),
    /// tear the gateway→shard trunk inside the next frame's bytes
    CutShardUplinkMidFrame(usize),
    /// integrate the shard's thermal model to now and log temp/throttle
    SampleThermal(usize),
    /// elastic scale-up: a pre-provisioned spare joins the ring with
    /// fresh state and the moved keyspace migrates onto it
    AddShard(usize),
    /// elastic scale-down: the shard leaves the ring, its pinned sessions
    /// drain through the migration state machine, and it keeps answering
    /// in-flight work until every handoff completes
    RemoveShard(usize),
}

/// Online-learning mode (DESIGN.md §8): appended learning clients stream
/// pendulum experience frames through the fleet while every shard
/// executor trains a [`Learner`] in place. In gateway mode the gateway
/// owns the authoritative [`PolicyStore`], assigns versions to shard
/// publications, broadcasts adoptions down every trunk, and stale-rejects
/// actions whose version lags the latest by more than `max_lag`.
#[derive(Debug, Clone)]
pub struct LearnSpec {
    /// learning split clients, appended after raw + split clients
    pub clients: usize,
    /// episodes per learning client
    pub episodes: usize,
    /// shard-side learner configuration (engine + loop knobs)
    pub learner: LearnerConfig,
    /// staleness bound: highest tolerated `latest - acting` version lag
    pub max_lag: u64,
    /// modelled seconds per segment update (added to the batch window)
    pub update_cost: f64,
}

impl Default for LearnSpec {
    fn default() -> Self {
        LearnSpec {
            clients: 1,
            episodes: 10,
            learner: LearnerConfig::default(),
            max_lag: 4,
            update_cost: 0.002,
        }
    }
}

/// Everything a scenario is: fleet shape, link fault models, batch policy,
/// modelled costs, and the timed fault plan. Fully determines the run
/// together with `seed`.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub seed: u64,
    pub shards: usize,
    /// server-only clients (RawRgba payloads through SessionManager)
    pub raw_clients: usize,
    /// split clients (quantised Feature payloads, on-device encode time j)
    pub split_clients: usize,
    /// decisions per client
    pub decisions: usize,
    /// observation side length for raw clients (keep small: 4–8)
    pub obs_x: usize,
    /// transmitted feature block for split clients: (c, h, w)
    pub feat: (usize, usize, usize),
    /// feature-frame codec for split clients (raw clients ignore it)
    pub codec: CodecId,
    /// rate-controller tuning when `codec == Delta`
    pub rate: RateConfig,
    /// drive split-client payloads from a real pendulum raster stream
    /// (`feat` must be `(3, p, p)`): the env renders, the frame crops to
    /// p×p RGB planes, and consecutive decisions carry genuine temporal
    /// redundancy for the codec. `false` keeps the synthetic per-id fill.
    pub pendulum_stream: bool,
    /// modelled on-device encode time per split decision, seconds
    pub encode_j: f64,
    /// idle time between a response and the next decision
    pub think: f64,
    /// client response deadline before reconnect + retransmit
    pub req_timeout: f64,
    /// per-client retry/reconnect budget before giving up
    pub max_retries: u64,
    pub policy: BatchPolicy,
    pub max_depth: usize,
    /// modelled batch cost: fixed + per_item·n, seconds
    pub exec_fixed: f64,
    pub exec_per_item: f64,
    /// route through the consistent-hash gateway (false = clients dial
    /// shard 0 directly, as the break-even experiments do)
    pub gateway: bool,
    /// client → gateway (or → shard) uplink
    pub client_link: LinkFaults,
    /// gateway (or shard) → client downlink
    pub reply_link: LinkFaults,
    /// gateway ↔ shard trunk, both directions
    pub shard_link: LinkFaults,
    /// virtual-time health probing cadence (None = no prober)
    pub probe_interval: Option<f64>,
    /// thresholds for [`probe_transition`]
    pub health: HealthConfig,
    pub thermal: Option<ThermalSpec>,
    /// online-learning mode (None = pure inference fleet)
    pub learning: Option<LearnSpec>,
    /// hostile clients, appended after every healthy cohort. Even relative
    /// indices spray undecodable junk at the gateway's frame parser; odd
    /// ones stream well-formed codec frames with corrupt payloads so the
    /// shard's decoder (not the framing layer) has to refuse them.
    pub malicious_clients: usize,
    /// attack frames each malicious client sends before retiring
    pub attack_frames: u64,
    /// gap between attack frames, seconds
    pub attack_interval: f64,
    /// gateway per-connection undecodable-frame budget before quarantine
    /// (mirrors `LimitsConfig::max_decode_errors` on the threaded path)
    pub gw_error_budget: u32,
    /// per-session consecutive codec-reject budget before a shard cuts the
    /// session off (mirrors `LimitsConfig::max_codec_rejects`)
    pub codec_reject_budget: u32,
    /// admission bound on concurrently pinned gateway sessions (0 = off);
    /// hellos beyond it are shed with an explicit `ERR_OVERLOADED` frame
    /// and the client retries with jittered exponential backoff
    pub gw_max_sessions: usize,
    /// negotiate CAP_TRACE fleet-wide (DESIGN.md §12): honest inference
    /// clients append a per-decision trace trailer to every request, each
    /// hop stamps its virtual-clock instant into the same bytes, and the
    /// closed span comes back on the reply. Off by default — an untraced
    /// run's event log is byte-identical to one from before this knob
    /// existed.
    pub trace: bool,
    pub faults: Vec<(f64, FaultCmd)>,
    /// closed-loop autoscaling on a virtual-time sampling cadence
    /// (None = the topology only changes through timed faults)
    pub autoscale: Option<AutoscaleSpec>,
    /// diurnal load curve `(period_s, idle_factor)`: the think gap between
    /// decisions follows a triangle wave from `think * idle_factor` at the
    /// trough (phase 0) down to `think` at the peak (phase 0.5). Piecewise
    /// linear on purpose — no transcendentals, so the produced virtual
    /// timestamps are bit-reproducible across platforms.
    pub diurnal: Option<(f64, f64)>,
    /// livelock safety valve
    pub max_events: usize,
}

/// Closed-loop autoscaling (DESIGN.md §11): on a fixed virtual-time cadence
/// the sim feeds its queue-wait histogram and gateway admission counters
/// through a windowed [`LoadWindow`] into an [`Autoscaler`], and the
/// verdicts drive the same join/leave machinery the timed
/// `AddShard`/`RemoveShard` faults use — drain → cut-over migration,
/// exactly-once learning handoff, forced-keyframe codec re-sync.
#[derive(Debug, Clone)]
pub struct AutoscaleSpec {
    /// watermarks, confirmation streaks, cooldown, shard bounds
    pub cfg: AutoscaleConfig,
    /// virtual seconds between load samples
    pub interval: f64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            seed: 1,
            shards: 2,
            raw_clients: 4,
            split_clients: 0,
            decisions: 8,
            obs_x: 4,
            feat: (4, 3, 3),
            codec: CodecId::Flat,
            rate: RateConfig::default(),
            pendulum_stream: false,
            encode_j: 0.002,
            think: 0.0,
            req_timeout: 0.25,
            max_retries: 64,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
            max_depth: 512,
            exec_fixed: 0.0005,
            exec_per_item: 0.0002,
            gateway: true,
            client_link: LinkFaults::ideal(),
            reply_link: LinkFaults::ideal(),
            shard_link: LinkFaults::ideal(),
            probe_interval: None,
            health: HealthConfig::default(),
            thermal: None,
            learning: None,
            malicious_clients: 0,
            attack_frames: 64,
            attack_interval: 0.002,
            gw_error_budget: 8,
            codec_reject_budget: 16,
            gw_max_sessions: 0,
            trace: false,
            faults: Vec::new(),
            autoscale: None,
            diurnal: None,
            max_events: 2_000_000,
        }
    }
}

#[derive(Debug, Default)]
pub struct ClientOutcome {
    /// accepted decisions (non-empty actions)
    pub decisions: usize,
    /// explicit back-pressure rejections observed
    pub rejected: u64,
    /// duplicate/stale responses discarded by id de-duplication
    pub dup_responses: u64,
    /// hello retries + request retransmits
    pub retries: u64,
    /// connection epochs beyond the first
    pub reconnects: u64,
    pub gave_up: u64,
    /// hello acks observed per connection epoch (exactly-once invariant:
    /// every entry should be 1)
    pub hello_acks: Vec<u64>,
    /// end-to-end decision latencies, virtual seconds
    pub latencies: Samples,
    /// total request payload bytes put on the wire (retransmits included)
    pub bytes_sent: u64,
    /// request frames put on the wire (retransmits included)
    pub frames_sent: u64,
    /// codec keyframes sent (delta codec only)
    pub keyframes: u64,
    /// codec delta frames sent
    pub deltas: u64,
    /// server re-key demands observed (frames the shard could not decode)
    pub need_keyframes: u64,
    /// v2 actions whose decoded-content checksum did not echo the sent
    /// frame — the stale-base oracle; any nonzero value means a shard
    /// decoded a delta against the wrong reference
    pub payload_mismatches: u64,
    /// rate controller's final quantisation ceiling (0 = flat codec)
    pub final_qmax: u8,
    /// quantisation steps taken toward coarser levels
    pub quant_coarser: u64,
    /// quantisation steps taken back toward finer levels
    pub quant_finer: u64,
    /// completed episode returns, in order (learning clients)
    pub returns: Vec<f64>,
    /// episodes completed (learning clients)
    pub episodes: usize,
    /// actions refused at the staleness bound (gateway-enforced)
    pub stale_rejections: u64,
    /// actions applied whose version lag exceeded `max_lag` — the
    /// staleness oracle; any nonzero value means the bound leaked
    pub applied_stale: u64,
    /// highest `latest_version` stamp observed in acks
    pub latest_version_seen: u64,
    /// explicit `ERR_OVERLOADED` sheds observed (admission or rate caps)
    pub overload_rejections: u64,
    /// highest topology epoch stamped on an accepted hello ack
    pub topology_epoch: u64,
    /// closed per-decision spans, one per accepted decision
    /// ([`ScenarioConfig::trace`] mode; virtual-clock nanosecond stamps)
    pub traces: Vec<TraceCtx>,
}

#[derive(Debug, Default)]
pub struct ShardOutcome {
    pub requests: u64,
    pub batches: u64,
    pub max_batch: usize,
    /// batches fired because the route filled to max_batch
    pub size_fired: u64,
    /// batches fired on the max_wait deadline
    pub deadline_fired: u64,
    /// admissions bounced by the depth bound (explicit empty-action reply)
    pub rejected: u64,
    /// torn/undecodable frames surfaced at this shard
    pub frame_errors: u64,
    /// codec frames that reached the decoder
    pub codec_frames: u64,
    /// codec frames the decoder refused (chain break / stale base /
    /// corrupt payload) — answered with `need_keyframe`
    pub codec_rejects: u64,
    pub throttled_batches: u64,
    pub max_temp: f64,
    pub final_throttled: bool,
    /// experience frames that reached this shard (learning mode)
    pub exp_frames: u64,
    /// PPO segment updates run by the live learner incarnation
    pub updates: u64,
    /// parameter vectors handed out for publication
    pub published: u64,
    /// policy versions adopted by the live learner, in order (strictly
    /// increasing by construction)
    pub adopted_versions: Vec<u64>,
    /// reward frames dropped for want of a matching pending decision
    pub dropped_incomplete: u64,
    /// the live learner's final acting policy version
    pub final_version: u64,
    /// sessions cut off after exhausting the consecutive-reject budget
    pub quarantined_sessions: u64,
    /// frames from quarantined sessions dropped without processing
    pub quarantine_drops: u64,
}

#[derive(Debug, Default)]
pub struct GatewayOutcome {
    /// first-time session placements
    pub assignments: u64,
    /// placements that moved a session to a different shard
    pub reassigned: u64,
    /// shard-side hello acks filtered off the return path
    pub filtered_shard_acks: u64,
    pub forwarded_requests: u64,
    pub forwarded_responses: u64,
    /// hellos/requests with no routable shard
    pub no_route: u64,
    /// trunk closures observed (crash detection)
    pub crash_detected: u64,
    /// policy versions assigned by the gateway's store
    pub policy_published: u64,
    /// learn replies rejected at the staleness bound
    pub policy_stale_rejects: u64,
    /// on-demand policy resyncs pushed to lagging shards
    pub policy_resyncs: u64,
    /// hellos shed at the admission bound with `ERR_OVERLOADED`
    pub shed_hellos: u64,
    /// connections cut off after exhausting the frame-error budget
    pub quarantined_sessions: u64,
    /// frames from quarantined connections dropped unread
    pub quarantine_drops: u64,
    /// completed session handoffs (exactly one per migration entry)
    pub migrations: u64,
    /// handoffs that completed via a quiescent drain (state transferred);
    /// the remainder were forced by a crash or cut mid-migration
    pub drained_handoffs: u64,
}

/// What the closed autoscaling loop did over the run (all zero when
/// [`ScenarioConfig::autoscale`] is `None`).
#[derive(Debug, Default)]
pub struct AutoscaleOutcome {
    /// windowed load samples taken
    pub samples: u64,
    /// shard joins driven by an autoscaler verdict (not a timed fault)
    pub scale_ups: u64,
    /// shard leaves driven by an autoscaler verdict
    pub scale_downs: u64,
}

#[derive(Debug)]
pub struct ScenarioReport {
    /// the canonical event log (byte-identical across same-seed runs)
    pub log: String,
    pub clients: Vec<ClientOutcome>,
    pub shards: Vec<ShardOutcome>,
    pub gateway: GatewayOutcome,
    pub autoscale: AutoscaleOutcome,
    /// final topology state per shard (gateway mode)
    pub shard_states: Vec<ShardState>,
    /// final `Topology::drained` verdict per shard (gateway mode)
    pub drained: Vec<bool>,
    /// virtual end time, seconds
    pub elapsed: f64,
    /// events processed
    pub events: usize,
    /// fleet-wide per-stage attribution summed over every closed span
    /// (zero when [`ScenarioConfig::trace`] is off)
    pub stage_totals: StageNs,
}

impl ScenarioReport {
    pub fn completed_decisions(&self) -> usize {
        self.clients.iter().map(|c| c.decisions).sum()
    }

    pub fn total_give_ups(&self) -> u64 {
        self.clients.iter().map(|c| c.gave_up).sum()
    }

    /// Every connection epoch of every client saw exactly one hello ack.
    pub fn hello_acks_exactly_once(&self) -> bool {
        self.clients
            .iter()
            .all(|c| c.hello_acks.iter().all(|&n| n == 1))
    }

    /// Stale-rejected actions across every learning client.
    pub fn total_stale_rejections(&self) -> u64 {
        self.clients.iter().map(|c| c.stale_rejections).sum()
    }

    /// Actions applied beyond the staleness bound — must stay 0.
    pub fn total_applied_stale(&self) -> u64 {
        self.clients.iter().map(|c| c.applied_stale).sum()
    }

    /// Episodes completed across every learning client.
    pub fn total_episodes(&self) -> usize {
        self.clients.iter().map(|c| c.episodes).sum()
    }

    /// `ERR_OVERLOADED` sheds observed across every client.
    pub fn total_overload_rejections(&self) -> u64 {
        self.clients.iter().map(|c| c.overload_rejections).sum()
    }

    /// Experience transitions lost anywhere in the fleet: reward-bearing
    /// frames that found no matching pending decision. A planned
    /// scale-down must keep this at zero — the migration handoff moves
    /// the pending track instead of dropping it at the seam.
    pub fn total_dropped_transitions(&self) -> u64 {
        self.shards.iter().map(|s| s.dropped_incomplete).sum()
    }

    /// Sessions quarantined anywhere: gateway frame-error budgets plus
    /// shard codec-reject budgets.
    pub fn total_quarantined(&self) -> u64 {
        self.gateway.quarantined_sessions
            + self.shards.iter().map(|s| s.quarantined_sessions).sum::<u64>()
    }
}

// ---------------------------------------------------------------------------
// world internals
// ---------------------------------------------------------------------------

/// Who consumes deliveries on a lane.
#[derive(Debug, Clone, Copy)]
enum Owner {
    Client(usize),
    GatewayFromClient(usize),
    GatewayFromShard(usize),
    Shard(usize),
}

#[derive(Debug)]
enum Ev {
    /// client (re)connects: send hello on the current epoch
    Connect(usize),
    /// client starts its next decision
    Kick(usize),
    /// client's pending request goes on the wire (encode done)
    Send(usize),
    HelloTimeout { c: usize, epoch: u64 },
    ReqTimeout { c: usize, id: u64, epoch: u64 },
    /// batch-deadline check
    ShardWake(usize),
    /// modelled execution finished: replies (and any policy publications
    /// produced by segment updates in the batch) go on the wire — but only
    /// if the shard incarnation that formed the batch is still the one
    /// alive
    ExecDone { s: usize, incarnation: u64, replies: Vec<SimReply>, published: Vec<Vec<f32>> },
    Probe,
    /// closed-loop autoscaler takes a windowed load sample
    AutoscaleTick,
    /// index into cfg.faults
    Fault(usize),
    /// a malicious client's next hostile frame goes on the wire
    Attack(usize),
}

struct Pending {
    id: u64,
    t0: f64,
    /// payload bytes of this request's most recent transmission
    wire_bytes: usize,
    /// expected v2 action — the decoded-content checksum oracle: the shard
    /// answers codec frames with a checksum of the quantised bytes it
    /// reconstructed, so a stale-base decode is detectable end to end
    expect: Option<f32>,
}

/// Per-client state for a learning (experience-streaming) client: a live
/// pendulum whose normalised observation rides the delta codec up to the
/// shard, with the episode/step cursor and reward of the *previous*
/// transition carried on each frame.
struct LearnClientSim {
    env: Pendulum,
    env_seed: u64,
    /// current normalised observation (what the next frame will carry)
    obs: Vec<f32>,
    ep: u32,
    step: u32,
    ep_return: f64,
    /// experience flags for the next frame (EXP_* bits)
    flags: u8,
    /// reward of the transition the next frame completes
    reward: f32,
}

/// What a malicious client puts on the wire each attack tick.
#[derive(Debug, Clone, Copy, PartialEq)]
enum AttackKind {
    /// bytes that fail `Msg::decode` — burned against the gateway's
    /// per-connection frame-error budget
    JunkFrames,
    /// structurally valid `FeaturesV2` frames whose payload the shard's
    /// delta decoder must refuse — burned against its consecutive-reject
    /// budget without touching the framing layer
    CorruptCodec,
}

struct ClientSim {
    mode: Route,
    up: LaneId,
    down: LaneId,
    epoch: u64,
    next_id: u64,
    pending: Option<Pending>,
    done: usize,
    finished: bool,
    /// hostile behaviour; None = honest client
    attack: Option<AttackKind>,
    attacks_sent: u64,
    /// consecutive `ERR_OVERLOADED` sheds since the last accepted hello,
    /// driving the exponential backoff ladder
    overload_attempts: u32,
    /// per-decision pendulum feature frames (empty = synthetic fill)
    stream: Vec<Vec<f32>>,
    /// delta-codec state (encoder + rate controller); None = flat v1
    delta: Option<(Encoder, RateController)>,
    /// pooled quantisation scratch
    qbuf: Vec<u8>,
    /// online-learning state; None = pure inference client
    learn: Option<LearnClientSim>,
    out: ClientOutcome,
}

struct SimWork {
    client: u32,
    id: u64,
    payload: Payload,
    /// wire-propagated span (enqueue stamped), carried across the batch
    trace: Option<TraceCtx>,
}

/// The learning half of a shard reply: what becomes a `ResponseLearn`
/// frame (or an `ErrorMsg` when the session never negotiated experience).
#[derive(Debug)]
struct LearnReply {
    seq: u32,
    flags: u8,
    acting_version: u64,
    action: Vec<f32>,
    /// experience frame arrived on a shard with no learner configured
    unsupported: bool,
}

/// One shard reply scheduled for the end of a modelled execution window.
#[derive(Debug)]
struct SimReply {
    client: u32,
    id: u64,
    action: f32,
    /// `Some((seq, need_keyframe, queue_wait_us))` — answer as a v2
    /// response with codec feedback; `None` — plain v1 response
    v2: Option<(u32, bool, u32)>,
    /// `Some` — answer as a learn response (experience path)
    learn: Option<LearnReply>,
    /// the request's span, dequeue/pack stamped; execute/reply stamp at
    /// the modelled completion instant before the trailer goes back out
    trace: Option<TraceCtx>,
}

struct ShardSim {
    up: LaneId,
    down: LaneId,
    alive: bool,
    /// bumped on every restart: in-flight work from a dead incarnation
    /// (batches executing at crash time) must not answer after a restart
    incarnation: u64,
    collector: BatchCollector<SimWork>,
    sessions: SessionManager,
    /// per-client codec decoder state; replaced wholesale on restart so a
    /// fresh incarnation can never decode against a stale delta base
    codecs: Decoders,
    obs_scratch: Vec<f32>,
    busy_until: f64,
    thermal: Option<ClockedThermal>,
    /// online learner (experience buffer + PPO core); replaced wholesale on
    /// restart — a fresh incarnation starts from policy version 0 and is
    /// re-synced by the gateway
    learn: Option<Learner>,
    /// sessions cut off for exhausting the codec-reject budget; their
    /// frames drop unprocessed, exactly like the executor's socket
    /// shutdown, and a restart forgets them with the rest of the state
    quarantined: BTreeSet<u32>,
    out: ShardOutcome,
}

/// One session mid-handoff (DESIGN.md §10): requests keep draining
/// through `from` until its last in-flight reply lands (or it dies),
/// then the pin moves to `to` and every per-session layer is
/// re-established there under the recorded topology epoch.
#[derive(Debug, Clone, Copy)]
struct MigrationSim {
    from: usize,
    to: usize,
    epoch: u64,
}

struct GatewaySim {
    topology: Topology,
    /// live pin per session (hello-established, request-consulted)
    pins: BTreeMap<u32, usize>,
    /// outstanding forwarded-but-unanswered requests per session — the
    /// quiescence ledger the migration state machine drains against
    inflight: BTreeMap<u32, u32>,
    /// sessions mid-handoff, keyed by session id
    migrations: BTreeMap<u32, MigrationSim>,
    /// last placement per session, for the reassignment counter
    last_assign: BTreeMap<u32, usize>,
    /// versioned policy store: shard publications land here and fan back
    /// out to every live shard
    store: PolicyStore,
    /// exactly-once re-sync guard: the latest store version each lagging
    /// shard has already been sent a snapshot for
    resynced: BTreeMap<usize, u64>,
    /// undecodable frames per client connection (`net::limits` analogue:
    /// an absolute budget — honest clients never produce any)
    errors: BTreeMap<usize, u32>,
    /// connections cut off for exhausting the frame-error budget
    quarantined: BTreeSet<usize>,
    out: GatewayOutcome,
}

struct World {
    cfg: ScenarioConfig,
    clock: SimClock,
    net: SimNet,
    log: EventLog,
    events: EventQueue<Ev>,
    owners: Vec<Owner>,
    clients: Vec<ClientSim>,
    shards: Vec<ShardSim>,
    gw: GatewaySim,
    probe_stats: Vec<ProbeStats>,
    partitioned: Vec<bool>,
    auto: Option<AutoSim>,
    n_events: usize,
    /// seeded jitter source for overload backoff — the only random draw
    /// outside the transport, consumed in deterministic delivery order
    rng: Rng,
    /// cumulative per-stage attribution over every closed span, the
    /// autoscaler's `stage_window` feed
    stage_totals: StageNs,
}

/// Closed-loop autoscaling state: the policy, the windowed sampler, and the
/// cumulative queue-wait histogram it samples. The histogram records fill
/// wait *plus* executor backlog per batched item — the sim analogue of the
/// threaded metrics' queue_wait — kept separate from the protocol-visible
/// `qw_us` (which deliberately excludes backlog because it feeds the client
/// rate controllers).
struct AutoSim {
    scaler: Autoscaler,
    window: LoadWindow,
    queue: LatencyHist,
    out: AutoscaleOutcome,
}

/// Encode a message to its frame body (length prefix stripped): the byte
/// form lanes carry and `Msg::decode` accepts.
fn msg_body(m: &Msg) -> Vec<u8> {
    let framed = m.encode();
    framed[4..].to_vec()
}

/// The per-client pendulum raster stream: the shared generator
/// (`envs::pendulum_raster_stream`) under a client-mixed seed, so every
/// split client swings its own deterministic pendulum.
fn pendulum_feature_stream(seed: u64, client: u64, side: usize, frames: usize) -> Vec<Vec<f32>> {
    crate::envs::pendulum_raster_stream(
        seed ^ client.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        side,
        frames,
    )
}

/// The sim shard's action for a decoded codec frame: a checksum of the
/// reconstructed quantised bytes, folded into a value the client can
/// predict from what it sent. A stale-base decode produces different
/// bytes, a different checksum, and a `payload_mismatches` count.
fn checksum_action(frame: &[u8]) -> f32 {
    let sum: u32 = frame.iter().map(|&b| b as u32).sum();
    0.25 + (sum % 251) as f32 * 1e-3
}

/// Disjoint mutable borrows of two distinct shard slots, for the
/// migration handoff's old→new state transfer.
fn two_shards(shards: &mut [ShardSim], a: usize, b: usize) -> (&mut ShardSim, &mut ShardSim) {
    assert_ne!(a, b, "a handoff needs two distinct shards");
    if a < b {
        let (l, r) = shards.split_at_mut(b);
        (&mut l[a], &mut r[0])
    } else {
        let (l, r) = shards.split_at_mut(a);
        (&mut r[0], &mut l[b])
    }
}

/// Run one scenario to completion. See the module docs for the model.
pub fn run_scenario(cfg: &ScenarioConfig) -> Result<ScenarioReport> {
    let mut w = World::new(cfg.clone())?;
    w.prime();
    w.drive()?;
    Ok(w.finish())
}

impl World {
    fn new(cfg: ScenarioConfig) -> Result<World> {
        if cfg.shards == 0 {
            bail!("a scenario needs at least one shard");
        }
        let n_learn = cfg.learning.as_ref().map(|sp| sp.clients).unwrap_or(0);
        if cfg.raw_clients + cfg.split_clients + n_learn == 0 {
            bail!("a scenario needs at least one client");
        }
        if let Some(spec) = &cfg.learning {
            if spec.clients == 0 {
                bail!("a learning scenario needs at least one learning client");
            }
            let core = &spec.learner.core;
            if spec.learner.rollout_steps % core.minibatch != 0 {
                bail!(
                    "rollout_steps {} must be a multiple of minibatch {}",
                    spec.learner.rollout_steps,
                    core.minibatch
                );
            }
            if core.obs_len != 3 || core.act_len != 1 {
                bail!("the sim learning loop drives a pendulum: obs_len must be 3, act_len 1");
            }
        }
        if cfg.pendulum_stream && (cfg.feat.0 != 3 || cfg.feat.1 != cfg.feat.2) {
            bail!(
                "pendulum_stream ships 3 square RGB planes; feat {:?} must be (3, p, p)",
                cfg.feat
            );
        }
        if let Some(spec) = &cfg.autoscale {
            if !(spec.interval > 0.0) || !spec.interval.is_finite() {
                bail!("autoscale sampling interval must be a positive finite number of seconds");
            }
            if !cfg.gateway {
                bail!("closed-loop autoscaling needs the gateway (it drives migrations)");
            }
        }
        if let Some((period, idle_factor)) = cfg.diurnal {
            if !(period > 0.0) || !period.is_finite() || !(idle_factor >= 1.0) {
                bail!("diurnal curve needs period > 0 and idle_factor >= 1");
            }
        }
        let mut net = SimNet::new(cfg.seed);
        let mut owners = Vec::new();
        let mut topology = Topology::new(32);
        // spare capacity is provisioned up front (lanes, slots) so the
        // owner table and lane ids are identical whether or not a timed
        // AddShard (or an autoscaler verdict) ever fires: spares start dead
        // and outside the ring, and joining later is a state change, not a
        // topology-of-the-sim change — determinism never depends on the
        // fault plan's timing or on when the autoscaler chooses to act
        let provisioned = cfg
            .faults
            .iter()
            .filter_map(|(_, f)| match f {
                FaultCmd::AddShard(s) => Some(*s + 1),
                _ => None,
            })
            .max()
            .unwrap_or(0)
            .max(cfg.autoscale.as_ref().map(|a| a.cfg.max_shards).unwrap_or(0))
            .max(cfg.shards);
        let mut shards = Vec::with_capacity(provisioned);
        for s in 0..provisioned {
            let live = s < cfg.shards;
            let name = format!("shard-{s}");
            let up = net.lane("gw", &name, cfg.shard_link);
            owners.push(Owner::Shard(s));
            let down = net.lane(&name, "gw", cfg.shard_link);
            owners.push(Owner::GatewayFromShard(s));
            if live {
                topology.add_shard(
                    ShardId(s as u16),
                    format!("127.0.0.1:{}", 9000 + s).parse().unwrap(),
                );
            }
            shards.push(ShardSim {
                up,
                down,
                alive: live,
                incarnation: 0,
                collector: BatchCollector::new(cfg.policy, cfg.max_depth),
                sessions: SessionManager::new(),
                codecs: Decoders::new(),
                obs_scratch: Vec::new(),
                busy_until: 0.0,
                thermal: None,
                learn: cfg.learning.as_ref().map(|sp| Learner::new(sp.learner.clone())),
                quarantined: BTreeSet::new(),
                out: ShardOutcome::default(),
            });
        }
        let peer = if cfg.gateway { "gw".to_string() } else { "shard-0".to_string() };
        let n_honest = cfg.raw_clients + cfg.split_clients + n_learn;
        let n_clients = n_honest + cfg.malicious_clients;
        let mut clients = Vec::with_capacity(n_clients);
        for c in 0..n_clients {
            let name = format!("client-{c}");
            let up = net.lane(&name, &peer, cfg.client_link);
            owners.push(if cfg.gateway {
                Owner::GatewayFromClient(c)
            } else {
                Owner::Shard(0)
            });
            let down = net.lane(&peer, &name, cfg.reply_link);
            owners.push(Owner::Client(c));
            // client ordering: raw, then split, then learning, then
            // malicious (alternating junk-byte and corrupt-codec attackers)
            let attack = (c >= n_honest).then(|| {
                if (c - n_honest) % 2 == 0 {
                    AttackKind::JunkFrames
                } else {
                    AttackKind::CorruptCodec
                }
            });
            let learning = attack.is_none() && c >= cfg.raw_clients + cfg.split_clients;
            let split = attack.is_none() && c >= cfg.raw_clients
                || attack == Some(AttackKind::CorruptCodec);
            let stream = if cfg.pendulum_stream && split && !learning && attack.is_none() {
                pendulum_feature_stream(cfg.seed, c as u64, cfg.feat.1, cfg.decisions)
            } else {
                Vec::new()
            };
            // learning clients always ride the delta codec at full precision
            // (qmax pinned to 255): the frame must survive round-trip
            // bit-for-bit for offline/online training parity
            let delta = if learning {
                Some((Encoder::new(), RateController::new(RateConfig::default())))
            } else {
                // attackers carry no real encoder: their frames are forged
                (attack.is_none() && split && cfg.codec == CodecId::Delta)
                    .then(|| (Encoder::new(), RateController::new(cfg.rate.clone())))
            };
            let learn = learning.then(|| {
                // decorrelate env seeds across learning clients with a
                // different odd constant than `episode_rng`'s golden ratio
                // so the two mixes can't collide; learning client 0 keeps
                // the raw scenario seed, matching the offline trainer
                let l = (c - cfg.raw_clients - cfg.split_clients) as u64;
                let env_seed = cfg.seed ^ l.wrapping_mul(0xD1B5_4A32_D192_ED03);
                let mut env = Pendulum::new();
                let mut rng = episode_rng(env_seed, 0);
                env.reset(&mut rng);
                let mut obs = vec![0.0f32; 3];
                normalize_pendulum_obs(&env.state(), &mut obs);
                LearnClientSim {
                    env,
                    env_seed,
                    obs,
                    ep: 0,
                    step: 0,
                    ep_return: 0.0,
                    flags: EXP_EP_START,
                    reward: 0.0,
                }
            });
            clients.push(ClientSim {
                mode: if split { Route::Split } else { Route::Full },
                up,
                down,
                epoch: 0,
                next_id: 0,
                pending: None,
                done: 0,
                finished: false,
                attack,
                attacks_sent: 0,
                overload_attempts: 0,
                stream,
                delta,
                qbuf: Vec::new(),
                learn,
                out: ClientOutcome { hello_acks: vec![0], ..ClientOutcome::default() },
            });
        }
        // a constant-mixed fork of the scenario seed: the backoff jitter
        // stream is independent of the transport's, so enabling admission
        // control never perturbs link-level draws
        let rng = Rng::new(cfg.seed ^ 0xB0FF_5E77_ED0C_4A11);
        // Autoscaler::new asserts its watermark bands are non-empty; a sim
        // config that violates them should fail loudly at construction too
        let auto = cfg.autoscale.as_ref().map(|spec| AutoSim {
            scaler: Autoscaler::new(spec.cfg.clone()),
            window: LoadWindow::new(),
            queue: LatencyHist::default(),
            out: AutoscaleOutcome::default(),
        });
        Ok(World {
            cfg,
            clock: SimClock::new(),
            net,
            log: EventLog::new(),
            events: EventQueue::new(),
            owners,
            clients,
            shards,
            gw: GatewaySim {
                topology,
                pins: BTreeMap::new(),
                inflight: BTreeMap::new(),
                migrations: BTreeMap::new(),
                last_assign: BTreeMap::new(),
                store: PolicyStore::new(),
                resynced: BTreeMap::new(),
                errors: BTreeMap::new(),
                quarantined: BTreeSet::new(),
                out: GatewayOutcome::default(),
            },
            probe_stats: vec![ProbeStats::default(); provisioned],
            partitioned: vec![false; provisioned],
            auto,
            n_events: 0,
            rng,
            stage_totals: StageNs::default(),
        })
    }

    fn prime(&mut self) {
        if let Some(spec) = &self.cfg.thermal {
            let t0 = self.clock.instant_at(0.0);
            for sh in &mut self.shards {
                sh.thermal = Some(ClockedThermal::new(spec.model.clone(), t0));
            }
        }
        for c in 0..self.clients.len() {
            self.events.push(1e-4 * (c + 1) as f64, Ev::Connect(c));
        }
        for (k, (t, _)) in self.cfg.faults.iter().enumerate() {
            self.events.push(*t, Ev::Fault(k));
        }
        if let Some(p) = self.cfg.probe_interval {
            self.events.push(p, Ev::Probe);
        }
        if let Some(spec) = &self.cfg.autoscale {
            self.events.push(spec.interval, Ev::AutoscaleTick);
        }
    }

    fn drive(&mut self) -> Result<()> {
        loop {
            let net_t = self.net.peek_time();
            let ev_t = self.events.peek_time();
            let from_net = match (net_t, ev_t) {
                (None, None) => break,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (Some(a), Some(b)) => a <= b,
            };
            self.n_events += 1;
            if self.n_events > self.cfg.max_events {
                bail!("scenario exceeded {} events — livelock?", self.cfg.max_events);
            }
            if from_net {
                let (t, lane, d) = self.net.pop().unwrap();
                self.clock.advance_to_secs(t);
                self.on_delivery(t, lane, d);
            } else {
                let (t, ev) = self.events.pop().unwrap();
                self.clock.advance_to_secs(t);
                self.on_event(t, ev);
            }
        }
        Ok(())
    }

    fn finish(self) -> ScenarioReport {
        // spares never added and shards removed mid-run are outside the
        // ring: report them Down rather than panicking on the lookup
        let shard_states = (0..self.shards.len())
            .map(|s| self.gw.topology.state(ShardId(s as u16)).unwrap_or(ShardState::Down))
            .collect();
        let drained = (0..self.shards.len())
            .map(|s| self.gw.topology.drained(ShardId(s as u16)))
            .collect();
        ScenarioReport {
            log: self.log.render(),
            clients: self
                .clients
                .into_iter()
                .map(|mut c| {
                    if let Some((_, rate)) = &c.delta {
                        c.out.final_qmax = rate.qmax();
                        c.out.quant_coarser = rate.coarser_steps;
                        c.out.quant_finer = rate.finer_steps;
                    }
                    c.out
                })
                .collect(),
            shards: self
                .shards
                .into_iter()
                .map(|mut s| {
                    if let Some(l) = &s.learn {
                        s.out.updates = l.updates;
                        s.out.published = l.published;
                        s.out.adopted_versions = l.adopted_versions.clone();
                        s.out.dropped_incomplete = l.buf.dropped_incomplete;
                        s.out.final_version = l.acting_version;
                    }
                    s.out
                })
                .collect(),
            gateway: self.gw.out,
            autoscale: self.auto.map(|a| a.out).unwrap_or_default(),
            shard_states,
            drained,
            elapsed: self.clock.now_secs(),
            events: self.n_events,
            stage_totals: self.stage_totals,
        }
    }

    fn all_done(&self) -> bool {
        self.clients.iter().all(|c| c.finished)
    }

    fn reply_lane(&self, s: usize, client: u32) -> LaneId {
        if self.cfg.gateway {
            self.shards[s].down
        } else {
            self.clients[client as usize].down
        }
    }

    /// Whether client `c` runs traced: honest inference clients only.
    /// Learning clients keep their experience stream untraced and
    /// attackers forge frames without trailers by definition.
    fn traced(&self, c: usize) -> bool {
        self.cfg.trace && self.clients[c].attack.is_none() && self.clients[c].learn.is_none()
    }

    /// Opportunistic trailer peel at a frame boundary: in a traced run,
    /// a trace-eligible frame is *expected* to carry a trailer, but
    /// attackers forge eligible-typed bodies without one and untraced
    /// cohorts coexist with traced ones — so a failed peel falls back to
    /// the plain body instead of erroring. Deterministic either way: the
    /// split is a pure function of the bytes.
    fn peel_trace<'a>(&self, body: &'a [u8]) -> (&'a [u8], Option<TraceCtx>) {
        if self.cfg.trace && !body.is_empty() && trace::trace_eligible(body[0]) {
            if let Ok((inner, ctx)) = trace::split_trailer(body) {
                return (inner, Some(ctx));
            }
        }
        (body, None)
    }

    /// The idle gap before a client's next decision at virtual time `t`:
    /// the configured `think`, optionally stretched by the diurnal curve.
    /// The curve is a triangle wave — `think * idle_factor` in the trough
    /// (phase 0), shrinking linearly to `think` at the peak (phase 0.5) and
    /// back — so demand ramps into a mid-period rush hour and drains out of
    /// it, with no transcendental functions anywhere near the timeline.
    fn think_gap(&self, t: f64) -> f64 {
        let think = self.cfg.think;
        let Some((period, idle_factor)) = self.cfg.diurnal else {
            return think;
        };
        let phase = (t / period).fract();
        let tri = 1.0 - (2.0 * phase - 1.0).abs();
        think * (idle_factor + (1.0 - idle_factor) * tri)
    }

    // -- event handlers -----------------------------------------------------

    fn on_event(&mut self, t: f64, ev: Ev) {
        match ev {
            Ev::Connect(c) => self.client_connect(t, c),
            Ev::Kick(c) => self.client_kick(t, c),
            Ev::Send(c) => self.client_send(t, c),
            Ev::HelloTimeout { c, epoch } => self.client_hello_timeout(t, c, epoch),
            Ev::ReqTimeout { c, id, epoch } => self.client_req_timeout(t, c, id, epoch),
            Ev::ShardWake(s) => self.shard_pump(t, s),
            Ev::ExecDone { s, incarnation, replies, published } => {
                self.shard_exec_done(t, s, incarnation, replies, published)
            }
            Ev::Probe => self.probe_round(t),
            Ev::AutoscaleTick => self.autoscale_tick(t),
            Ev::Fault(k) => self.apply_fault(t, k),
            Ev::Attack(c) => self.client_attack(t, c),
        }
    }

    fn client_connect(&mut self, t: f64, c: usize) {
        let cl = &mut self.clients[c];
        if cl.finished {
            return;
        }
        let (epoch, up, split) = (cl.epoch, cl.up, cl.mode == Route::Split);
        // a corrupt-codec attacker negotiates delta like an honest split
        // client — its abuse must reach the decoder, not die at the hello
        let codec = if cl.delta.is_some() || cl.attack == Some(AttackKind::CorruptCodec) {
            CODEC_DELTA
        } else {
            0
        };
        let caps = (if cl.learn.is_some() { CAP_EXPERIENCE } else { 0 })
            | (if self.cfg.trace && cl.attack.is_none() && cl.learn.is_none() {
                CAP_TRACE
            } else {
                0
            });
        let body = msg_body(&Msg::Hello(Hello {
            client: c as u32,
            split,
            codec,
            caps,
            shard: None,
            epoch: None,
        }));
        self.log.record(t, "hello", &format!("client={c} epoch={epoch}"));
        self.net.send(up, t, &body, &mut self.log);
        self.events
            .push(t + self.cfg.req_timeout, Ev::HelloTimeout { c, epoch });
    }

    /// Bump the connection epoch (a reconnect) and send a fresh hello.
    /// The old socket is torn down first: anything still in flight on
    /// either lane (a delayed ack, a stale response) is flushed, exactly
    /// as a closed TCP socket would never deliver it — so per-epoch
    /// hello-ack accounting stays honest even when delays exceed the
    /// timeout.
    fn client_reconnect(&mut self, t: f64, c: usize, why: &str) {
        let cl = &mut self.clients[c];
        cl.epoch += 1;
        cl.out.hello_acks.push(0);
        cl.out.reconnects += 1;
        // a new connection epoch is a new session incarnation: the codec
        // chain restarts with a keyframe and the controller notes the loss
        if let Some((encoder, rate)) = &mut cl.delta {
            encoder.force_keyframe();
            rate.on_loss();
        }
        let (epoch, up, down) = (cl.epoch, cl.up, cl.down);
        self.net.flush(up);
        self.net.flush(down);
        self.log
            .record(t, "reconnect", &format!("client={c} epoch={epoch} why={why}"));
        self.events.push(t, Ev::Connect(c));
    }

    /// Spend one unit of the retry budget; returns false (and finishes the
    /// client) when the budget is exhausted.
    fn client_spend_retry(&mut self, t: f64, c: usize) -> bool {
        let cl = &mut self.clients[c];
        cl.out.retries += 1;
        if cl.out.retries > self.cfg.max_retries {
            cl.out.gave_up += 1;
            cl.finished = true;
            self.log.record(t, "give_up", &format!("client={c}"));
            self.gateway_unpin(t, c as u32);
            return false;
        }
        true
    }

    fn client_kick(&mut self, t: f64, c: usize) {
        let cl = &mut self.clients[c];
        if cl.finished {
            return;
        }
        // learning clients finish on episode count (checked in the response
        // path), not on the decision budget
        if cl.learn.is_none() && cl.done >= self.cfg.decisions {
            cl.finished = true;
            self.log.record(t, "client_done", &format!("client={c}"));
            self.gateway_unpin(t, c as u32);
            return;
        }
        if cl.pending.is_some() {
            return;
        }
        let id = cl.next_id;
        cl.next_id += 1;
        cl.pending = Some(Pending { id, t0: t, wire_bytes: 0, expect: None });
        let delay = if cl.mode == Route::Split { self.cfg.encode_j } else { 0.0 };
        if delay > 0.0 {
            self.log
                .record(t, "encode", &format!("client={c} id={id} j={delay:.6}"));
        }
        self.events.push(t + delay, Ev::Send(c));
    }

    fn client_send(&mut self, t: f64, c: usize) {
        if self.clients[c].learn.is_some() {
            return self.learn_client_send(t, c);
        }
        let (id, up, epoch, t0, payload) = {
            let cl = &mut self.clients[c];
            if cl.finished {
                return;
            }
            let Some(p) = &cl.pending else { return };
            let id = p.id;
            let t0 = p.t0;
            let fill = ((c as u64 * 131 + id * 17) % 251) as u8;
            let (fc, fh, fw) = self.cfg.feat;
            let mut expect = None;
            let payload = match cl.mode {
                Route::Full => {
                    let x = self.cfg.obs_x;
                    Payload::RawRgba { x: x as u16, data: vec![fill; 4 * x * x] }
                }
                Route::Split => {
                    let n = fc * fh * fw;
                    match &mut cl.delta {
                        Some((encoder, rate)) => {
                            // negotiated delta codec: quantise at the
                            // controller's ceiling, encode against the
                            // previous frame. A retransmit after a
                            // reconnect re-encodes — the reconnect already
                            // forced a keyframe, so the fresh incarnation
                            // never receives a delta it cannot ground.
                            if rate.keyframe_due() {
                                encoder.force_keyframe();
                            }
                            let qmax = rate.qmax();
                            let synth;
                            let floats: &[f32] = match cl.stream.get(id as usize) {
                                Some(fr) => fr.as_slice(),
                                None => {
                                    synth = vec![fill as f32 / 255.0; n];
                                    &synth
                                }
                            };
                            let scale = codec::quantize_into(floats, qmax, &mut cl.qbuf);
                            let mut data = Vec::new();
                            let (flags, seq) = encoder.encode_into(&cl.qbuf, &mut data);
                            let key = flags & codec::FLAG_KEYFRAME != 0;
                            rate.frame_sent(key);
                            if key {
                                cl.out.keyframes += 1;
                            } else {
                                cl.out.deltas += 1;
                            }
                            // the decoded-content oracle: the shard echoes
                            // this checksum of the quantised bytes
                            expect = Some(checksum_action(&cl.qbuf));
                            Payload::FeaturesV2(FeatureFrame {
                                c: fc as u16,
                                h: fh as u16,
                                w: fw as u16,
                                codec: CODEC_DELTA,
                                flags,
                                qmax,
                                seq,
                                scale,
                                data,
                            })
                        }
                        None => match cl.stream.get(id as usize) {
                            Some(fr) => {
                                // flat codec over the same pendulum stream:
                                // the apples-to-apples baseline the 1 Mb/s
                                // acceptance scenario compares against
                                let (scale, data) = crate::net::quantize_features(fr);
                                Payload::Features {
                                    c: fc as u16,
                                    h: fh as u16,
                                    w: fw as u16,
                                    scale,
                                    data,
                                }
                            }
                            None => Payload::Features {
                                c: fc as u16,
                                h: fh as u16,
                                w: fw as u16,
                                scale: 1.0,
                                data: vec![fill; n],
                            },
                        },
                    }
                }
            };
            let wire_b = payload.wire_bytes();
            cl.out.bytes_sent += wire_b as u64;
            cl.out.frames_sent += 1;
            if let Some(p) = &mut cl.pending {
                p.wire_bytes = wire_b;
                p.expect = expect;
            }
            (id, cl.up, cl.epoch, t0, payload)
        };
        let mut body = msg_body(&Msg::Request(Request { client: c as u32, id, payload }));
        if self.traced(c) {
            // span id mirrors the threaded convention — client in the high
            // word, per-client decision counter in the low. Mint is the
            // kick instant (observation ready); a retransmit re-stamps
            // encode/send but the span still opens at the original t0.
            let mut ctx = TraceCtx::mint(((c as u64) << 32) | id, trace::virtual_ns(t0));
            ctx.stamp(trace::STAGE_ENCODE, trace::virtual_ns(t));
            ctx.stamp(trace::STAGE_SEND, trace::virtual_ns(t));
            trace::append_trailer(&mut body, &ctx);
        }
        self.log
            .record(t, "request", &format!("client={c} id={id} bytes={}", body.len()));
        self.net.send(up, t, &body, &mut self.log);
        self.events
            .push(t + self.cfg.req_timeout, Ev::ReqTimeout { c, id, epoch });
    }

    /// Send the pending experience frame: the current normalised pendulum
    /// observation, delta-encoded at full precision, stamped with the
    /// episode/step cursor and the reward completing the previous
    /// transition. A retransmit re-encodes; the reconnect path already
    /// forced a keyframe so a fresh shard incarnation can always ground it.
    fn learn_client_send(&mut self, t: f64, c: usize) {
        let (id, up, epoch, ep, step, payload) = {
            let cl = &mut self.clients[c];
            if cl.finished {
                return;
            }
            let Some(p) = &cl.pending else { return };
            let id = p.id;
            let lrn = cl.learn.as_ref().unwrap();
            let (ep, step, eflags, reward) = (lrn.ep, lrn.step, lrn.flags, lrn.reward);
            let (encoder, rate) = cl.delta.as_mut().unwrap();
            if rate.keyframe_due() {
                encoder.force_keyframe();
            }
            // qmax pinned at 255: the learning path never acks the rate
            // controller, so the ladder never coarsens — full precision
            // keeps the shard's dequantised observation bit-identical to
            // the offline trainer's quantise round-trip
            let scale = codec::quantize_into(&cl.learn.as_ref().unwrap().obs, 255, &mut cl.qbuf);
            let mut data = Vec::new();
            let (fflags, seq) = encoder.encode_into(&cl.qbuf, &mut data);
            let key = fflags & codec::FLAG_KEYFRAME != 0;
            rate.frame_sent(key);
            if key {
                cl.out.keyframes += 1;
            } else {
                cl.out.deltas += 1;
            }
            let payload = Payload::Experience(ExperienceFrame {
                feat: FeatureFrame {
                    c: 3,
                    h: 1,
                    w: 1,
                    codec: CODEC_DELTA,
                    flags: fflags,
                    qmax: 255,
                    seq,
                    scale,
                    data,
                },
                ep,
                step,
                flags: eflags,
                reward,
            });
            let wire_b = payload.wire_bytes();
            cl.out.bytes_sent += wire_b as u64;
            cl.out.frames_sent += 1;
            if let Some(p) = &mut cl.pending {
                p.wire_bytes = wire_b;
            }
            (id, cl.up, cl.epoch, ep, step, payload)
        };
        let body = msg_body(&Msg::Request(Request { client: c as u32, id, payload }));
        self.log.record(
            t,
            "experience",
            &format!("client={c} id={id} ep={ep} step={step} bytes={}", body.len()),
        );
        self.net.send(up, t, &body, &mut self.log);
        self.events
            .push(t + self.cfg.req_timeout, Ev::ReqTimeout { c, id, epoch });
    }

    fn client_hello_timeout(&mut self, t: f64, c: usize, epoch: u64) {
        let cl = &self.clients[c];
        if cl.finished || cl.epoch != epoch || cl.out.hello_acks[epoch as usize] > 0 {
            return;
        }
        if self.client_spend_retry(t, c) {
            self.client_reconnect(t, c, "hello_timeout");
        }
    }

    fn client_req_timeout(&mut self, t: f64, c: usize, id: u64, epoch: u64) {
        let cl = &self.clients[c];
        if cl.finished || cl.epoch != epoch {
            return;
        }
        let Some(p) = &cl.pending else { return };
        if p.id != id {
            return;
        }
        if self.client_spend_retry(t, c) {
            self.client_reconnect(t, c, "req_timeout");
        }
    }

    /// An explicit `ERR_OVERLOADED` shed: bump the epoch (the old hello
    /// will never be acked), walk the jittered exponential backoff ladder,
    /// and re-hello after the delay. A pending request survives — the next
    /// accepted hello retransmits it.
    fn client_overloaded(&mut self, t: f64, c: usize) {
        {
            let cl = &mut self.clients[c];
            if cl.finished {
                return;
            }
            cl.out.overload_rejections += 1;
            cl.overload_attempts += 1;
        }
        self.log.record(t, "overloaded", &format!("client={c}"));
        if !self.client_spend_retry(t, c) {
            return;
        }
        let cl = &mut self.clients[c];
        cl.epoch += 1;
        cl.out.hello_acks.push(0);
        cl.out.reconnects += 1;
        if let Some((encoder, rate)) = &mut cl.delta {
            encoder.force_keyframe();
            rate.on_loss();
        }
        let (epoch, up, down, attempt) = (cl.epoch, cl.up, cl.down, cl.overload_attempts);
        self.net.flush(up);
        self.net.flush(down);
        // base well under req_timeout, capped at half of it: the retry
        // always lands before the hello-timeout machinery would fire
        let d = backoff_delay(0.005, attempt, 0.5 * self.cfg.req_timeout, &mut self.rng);
        self.log.record(
            t,
            "backoff",
            &format!("client={c} epoch={epoch} attempt={attempt} delay={d:.6}"),
        );
        self.events.push(t + d, Ev::Connect(c));
    }

    /// One hostile frame goes on the wire. Junk attackers ship bytes that
    /// fail `Msg::decode` at the gateway; corrupt-codec attackers ship
    /// structurally valid delta frames whose payload the shard's decoder
    /// must refuse (baseless deltas — they pass every framing and
    /// geometry check and die inside the codec, where the consecutive-
    /// reject budget counts them).
    fn client_attack(&mut self, t: f64, c: usize) {
        let interval = self.cfg.attack_interval;
        let cl = &mut self.clients[c];
        if cl.finished {
            return;
        }
        if cl.attacks_sent >= self.cfg.attack_frames {
            cl.finished = true;
            self.log.record(t, "attacker_done", &format!("client={c}"));
            self.gateway_unpin(t, c as u32);
            return;
        }
        cl.attacks_sent += 1;
        let seq = cl.attacks_sent as u32;
        let id = cl.next_id;
        cl.next_id += 1;
        let up = cl.up;
        let body = match cl.attack {
            Some(AttackKind::JunkFrames) => vec![0xEE; 48],
            Some(AttackKind::CorruptCodec) => {
                let (fc, fh, fw) = self.cfg.feat;
                let n = fc * fh * fw;
                msg_body(&Msg::Request(Request {
                    client: c as u32,
                    id,
                    payload: Payload::FeaturesV2(FeatureFrame {
                        c: fc as u16,
                        h: fh as u16,
                        w: fw as u16,
                        codec: CODEC_DELTA,
                        flags: 0, // delta, but no base was ever established
                        qmax: 255,
                        seq,
                        scale: 1.0,
                        data: vec![0xFF; n],
                    }),
                }))
            }
            None => return,
        };
        self.log
            .record(t, "attack", &format!("client={c} n={seq} bytes={}", body.len()));
        self.net.send(up, t, &body, &mut self.log);
        self.events.push(t + interval, Ev::Attack(c));
    }

    fn client_on_frame(&mut self, t: f64, c: usize, body: &[u8]) {
        let (view, tctx) = self.peel_trace(body);
        let msg = match Msg::decode(view) {
            Ok(m) => m,
            Err(_) => {
                self.log.record(t, "client_frame_error", &format!("client={c}"));
                return;
            }
        };
        // attackers never parse the return path; only the hello ack
        // matters to them (it starts the attack)
        if self.clients[c].attack.is_some() && !matches!(msg, Msg::Hello(_)) {
            return;
        }
        match msg {
            Msg::Hello(h) => {
                let cl = &mut self.clients[c];
                if cl.finished {
                    return;
                }
                let e = cl.epoch as usize;
                cl.out.hello_acks[e] += 1;
                // the gateway stamps its topology epoch into every ack;
                // clients track the high-water mark so scenarios can prove
                // scale events actually propagated to the edge
                if let Some(te) = h.epoch {
                    if te > cl.out.topology_epoch {
                        cl.out.topology_epoch = te;
                    }
                }
                if cl.out.hello_acks[e] == 1 {
                    // an accepted hello resets the overload backoff ladder
                    cl.overload_attempts = 0;
                    let malicious = cl.attack.is_some();
                    let shard = h.shard.map(|s| s as i32).unwrap_or(-1);
                    let resend = cl.pending.is_some();
                    self.log
                        .record(t, "ack", &format!("client={c} epoch={e} shard={shard}"));
                    if malicious {
                        self.events.push(t, Ev::Attack(c));
                    } else if resend {
                        self.events.push(t, Ev::Send(c));
                    } else {
                        self.events.push(t, Ev::Kick(c));
                    }
                } else {
                    self.log.record(t, "dup_ack", &format!("client={c} epoch={e}"));
                }
            }
            Msg::Response(r) => {
                self.client_on_response(t, c, r.id, &r.action, None, tctx);
            }
            Msg::ResponseV2(r) => {
                let feedback = (r.seq, r.need_keyframe(), r.queue_wait_us);
                self.client_on_response(t, c, r.id, &r.action, Some(feedback), tctx);
            }
            Msg::ResponseLearn(r) => self.learn_on_response(t, c, r),
            Msg::Error(e) if e.code == ERR_OVERLOADED => {
                // the fleet shed this session at the admission bound:
                // back off with jitter and re-hello, exactly like the
                // threaded client's retry loop
                self.client_overloaded(t, c);
            }
            Msg::Error(e) => {
                // the server refused the experience capability: a real
                // client would fall back to inference-only; the sim client
                // has nothing to infer, so it retires cleanly
                self.log
                    .record(t, "client_error", &format!("client={c} code={}", e.code));
                let cl = &mut self.clients[c];
                cl.pending = None;
                cl.finished = true;
                self.gateway_unpin(t, c as u32);
            }
            Msg::Request(_) | Msg::Policy(_) => {
                self.log.record(t, "client_unexpected", &format!("client={c}"));
            }
        }
    }

    /// Shared response handling for v1 and v2 responses: id-level
    /// de-duplication, rejection accounting, latency samples, and — for v2
    /// acks — the codec feedback (rate-controller sample, re-key demands,
    /// and the decoded-content checksum oracle).
    fn client_on_response(
        &mut self,
        t: f64,
        c: usize,
        id: u64,
        action: &[f32],
        feedback: Option<(u32, bool, u32)>,
        tctx: Option<TraceCtx>,
    ) {
        let think = self.think_gap(t);
        let cl = &mut self.clients[c];
        if cl.finished {
            return;
        }
        let fresh = cl.pending.as_ref().is_some_and(|p| p.id == id);
        if !fresh {
            cl.out.dup_responses += 1;
            self.log
                .record(t, "stale_response", &format!("client={c} id={id}"));
            return;
        }
        let p = cl.pending.take().unwrap();
        cl.done += 1;
        if let Some((_seq, need_key, queue_wait_us)) = feedback {
            // close the rate-control loop: one link-time sample per ack,
            // and a forced keyframe whenever the shard lost the chain
            if let Some((encoder, rate)) = &mut cl.delta {
                rate.on_ack(p.wire_bytes, t - p.t0, queue_wait_us as f64 * 1e-6);
                if need_key {
                    encoder.force_keyframe();
                    rate.on_loss();
                }
            }
            if need_key {
                cl.out.need_keyframes += 1;
                self.log
                    .record(t, "need_keyframe", &format!("client={c} id={id}"));
            }
        }
        if action.is_empty() {
            cl.out.rejected += 1;
            self.log.record(t, "rejected", &format!("client={c} id={id}"));
        } else {
            if let (Some(exp), Some(_)) = (p.expect, feedback) {
                if (action[0] - exp).abs() > 1e-4 {
                    cl.out.payload_mismatches += 1;
                    self.log.record(
                        t,
                        "payload_mismatch",
                        &format!("client={c} id={id} got={:.6} want={exp:.6}", action[0]),
                    );
                }
            }
            cl.out.decisions += 1;
            cl.out.latencies.push(t - p.t0);
            self.log
                .record(t, "answer", &format!("client={c} id={id} lat={:.6}", t - p.t0));
            if let Some(mut ctx) = tctx {
                // the span closes here; its decomposition feeds the
                // fleet-wide attribution totals and one canonical log line
                ctx.stamp(trace::STAGE_RECV, trace::virtual_ns(t));
                let stages = ctx.stages();
                cl.out.traces.push(ctx);
                self.stage_totals.add(&stages);
                self.log.record(
                    t,
                    "trace",
                    &format!(
                        "client={c} id={id} total_ns={} dominant={}",
                        ctx.total_ns(),
                        stages.dominant().unwrap_or("none")
                    ),
                );
            }
        }
        self.events.push(t + think, Ev::Kick(c));
    }

    /// A learn response closes one experience round-trip: apply the action
    /// to the local pendulum, advance the episode cursor, and kick the next
    /// frame. Re-key, staleness, and back-pressure answers re-send the SAME
    /// cursor without stepping the environment, so the shard's sequence
    /// discipline sees the retry as a duplicate or a fresh frame — never a
    /// hole in the trajectory.
    fn learn_on_response(&mut self, t: f64, c: usize, r: ResponseLearn) {
        let think = self.think_gap(t);
        let spec = self.cfg.learning.as_ref();
        let max_lag = spec.map(|sp| sp.max_lag).unwrap_or(0);
        let episodes = spec.map(|sp| sp.episodes).unwrap_or(0) as u32;
        let cl = &mut self.clients[c];
        if cl.finished || cl.learn.is_none() {
            return;
        }
        let fresh = cl.pending.as_ref().is_some_and(|p| p.id == r.id);
        if !fresh {
            cl.out.dup_responses += 1;
            self.log
                .record(t, "stale_response", &format!("client={c} id={}", r.id));
            return;
        }
        let p = cl.pending.take().unwrap();
        cl.done += 1;
        if r.latest_version > cl.out.latest_version_seen {
            cl.out.latest_version_seen = r.latest_version;
        }
        if r.need_keyframe() {
            // the shard lost the delta chain (restart or back-pressure):
            // re-key and re-send the same cursor — the env does not move
            cl.out.need_keyframes += 1;
            if let Some((encoder, rate)) = &mut cl.delta {
                encoder.force_keyframe();
                rate.on_loss();
            }
            self.log
                .record(t, "need_keyframe", &format!("client={c} id={}", r.id));
            self.events.push(t + think, Ev::Kick(c));
            return;
        }
        if r.stale() {
            // the gateway vetoed the action: the answering shard lagged the
            // fleet policy beyond max_lag; retry once the re-sync lands
            cl.out.stale_rejections += 1;
            self.log
                .record(t, "stale_rejected", &format!("client={c} id={}", r.id));
            self.events.push(t + think, Ev::Kick(c));
            return;
        }
        if r.action.is_empty() {
            cl.out.rejected += 1;
            self.log.record(t, "rejected", &format!("client={c} id={}", r.id));
            self.events.push(t + think, Ev::Kick(c));
            return;
        }
        // staleness oracle: an action the gateway let through must never
        // lag the newest version this client has observed beyond max_lag
        if cl.out.latest_version_seen.saturating_sub(r.acting_version) > max_lag {
            cl.out.applied_stale += 1;
        }
        let lrn = cl.learn.as_mut().unwrap();
        if lrn.ep >= episodes {
            // the flush frame is answered: the final transition has been
            // delivered; the action itself is discarded
            cl.finished = true;
            self.log.record(t, "client_done", &format!("client={c}"));
            self.gateway_unpin(t, c as u32);
            return;
        }
        // apply the action exactly as the offline trainer does: clamp to
        // the torque bound, step, accumulate the return
        let bound = lrn.env.max_action();
        let a = (r.action[0] as f64).clamp(-bound, bound);
        let out = lrn.env.step(&[a]);
        lrn.ep_return += out.reward;
        lrn.reward = out.reward as f32;
        if out.done() {
            cl.out.returns.push(lrn.ep_return);
            cl.out.episodes += 1;
            self.log.record(
                t,
                "episode",
                &format!("client={c} ep={} return={:.3}", lrn.ep, lrn.ep_return),
            );
            lrn.ep += 1;
            lrn.step = 0;
            lrn.ep_return = 0.0;
            lrn.flags = EXP_HAS_REWARD
                | EXP_DONE
                | EXP_EP_START
                | if out.terminated { EXP_TERMINATED } else { 0 };
            let mut rng = episode_rng(lrn.env_seed, lrn.ep as u64);
            lrn.env.reset(&mut rng);
        } else {
            lrn.step += 1;
            lrn.flags = EXP_HAS_REWARD;
        }
        normalize_pendulum_obs(&lrn.env.state(), &mut lrn.obs);
        cl.out.decisions += 1;
        cl.out.latencies.push(t - p.t0);
        self.events.push(t + think, Ev::Kick(c));
    }

    // -- gateway ------------------------------------------------------------

    /// One undecodable frame on a client connection: burn the absolute
    /// per-connection budget (`net::limits` analogue — honest clients
    /// produce zero of these) and quarantine past it: unpin the session
    /// and drop everything it sends from here on.
    fn gateway_frame_error(&mut self, t: f64, c: usize) {
        let n = self.gw.errors.entry(c).or_insert(0);
        *n += 1;
        if *n > self.cfg.gw_error_budget && self.gw.quarantined.insert(c) {
            self.gw.out.quarantined_sessions += 1;
            self.log.record(t, "quarantine", &format!("gw client={c}"));
            self.gateway_unpin(t, c as u32);
        }
    }

    /// Close a session's live pin (client finished or gave up).
    fn gateway_unpin(&mut self, t: f64, session: u32) {
        self.gw.migrations.remove(&session);
        self.gw.inflight.remove(&session);
        if let Some(s) = self.gw.pins.remove(&session) {
            self.gw.topology.conn_closed(ShardId(s as u16));
            self.log
                .record(t, "unpin", &format!("session={session} shard={s}"));
        }
    }

    fn gateway_hello(&mut self, t: f64, h: Hello) {
        let session = h.client;
        if let Some(prev) = self.gw.pins.remove(&session) {
            self.gw.topology.conn_closed(ShardId(prev as u16));
        }
        // a re-hello supersedes any in-flight drain: the old socket (and
        // every reply it owed) is gone, and fresh placement under the
        // current epoch IS the handoff — the shard-side hello invalidates
        // the decoder base exactly as a drained migration would
        self.gw.migrations.remove(&session);
        self.gw.inflight.remove(&session);
        // admission control: past the session bound the hello is shed with
        // an explicit ERR_OVERLOADED frame instead of stalling the fleet —
        // the client backs off and retries (a re-hello from a pinned
        // session re-admits itself: its old pin was just released above)
        if self.cfg.gw_max_sessions > 0 && self.gw.pins.len() >= self.cfg.gw_max_sessions {
            self.gw.out.shed_hellos += 1;
            self.log.record(t, "shed", &format!("session={session}"));
            let body = msg_body(&Msg::Error(ErrorMsg {
                client: session,
                code: ERR_OVERLOADED,
                detail: "gateway at session capacity; retry with backoff".into(),
            }));
            let down = self.clients[session as usize].down;
            self.net.send(down, t, &body, &mut self.log);
            return;
        }
        let pick = self.gw.topology.route(session).map(|sh| sh.id.0 as usize);
        let Some(s) = pick else {
            self.gw.out.no_route += 1;
            self.log.record(t, "no_route", &format!("session={session}"));
            return; // no ack: the client's hello timeout drives the retry
        };
        self.gw.topology.conn_opened(ShardId(s as u16));
        self.gw.pins.insert(session, s);
        match self.gw.last_assign.insert(session, s) {
            Some(prev) if prev != s => {
                self.gw.out.reassigned += 1;
                self.log
                    .record(t, "reassign", &format!("session={session} {prev}->{s}"));
            }
            Some(_) => {}
            None => {
                self.gw.out.assignments += 1;
                self.log.record(t, "pin", &format!("session={session} shard={s}"));
            }
        }
        // the gateway speaks for the fleet: ack with the assigned shard,
        // applying the same codec-negotiation rule the shard reader does
        // (echo known ids, decline unknown ones to flat) — shard-side
        // acks are filtered, so this ack IS the negotiation verdict
        let codec = if CodecId::from_wire(h.codec).is_some() { h.codec } else { 0 };
        // capability negotiation mirrors the server reader: experience is
        // granted only when the fleet actually runs a learning loop, and
        // tracing only when the scenario turned the subsystem on
        let mut caps = if self.cfg.learning.is_some() { h.caps & CAP_EXPERIENCE } else { 0 };
        if self.cfg.trace {
            caps |= h.caps & CAP_TRACE;
        }
        let ack = msg_body(&Msg::Hello(Hello {
            client: session,
            split: h.split,
            codec,
            caps,
            shard: Some(s as u16),
            // the placement's epoch rides the ack (DESIGN.md §10): a
            // client holding this ack can prove which topology assigned it
            epoch: Some(self.gw.topology.epoch()),
        }));
        let down = self.clients[session as usize].down;
        self.net.send(down, t, &ack, &mut self.log);
        // forward the hello upstream; the shard's own ack must be filtered
        let up = self.shards[s].up;
        if self.shards[s].alive && self.net.is_open(up) {
            let fwd = msg_body(&Msg::Hello(Hello {
                client: session,
                split: h.split,
                codec: h.codec,
                caps: h.caps,
                shard: None,
                epoch: None,
            }));
            self.net.send(up, t, &fwd, &mut self.log);
        }
    }

    fn gateway_request(&mut self, t: f64, session: u32, body: &[u8]) {
        // a migrating session keeps draining through its old shard until
        // the last in-flight reply lands; if the old shard died or lost
        // its trunk first, the handoff is forced and the request follows
        // the new pin below
        if let Some(from) = self.gw.migrations.get(&session).map(|m| m.from) {
            if self.shards[from].alive && self.net.is_open(self.shards[from].up) {
                self.gw.out.forwarded_requests += 1;
                *self.gw.inflight.entry(session).or_insert(0) += 1;
                let up = self.shards[from].up;
                self.net.send(up, t, body, &mut self.log);
                return;
            }
            self.finish_migration(t, session, false);
        }
        let pinned = self.gw.pins.get(&session).copied();
        let usable = |w: &World, s: usize| {
            w.shards[s].alive
                && w.net.is_open(w.shards[s].up)
                && w.gw
                    .topology
                    .state(ShardId(s as u16))
                    .is_some_and(|st| st != ShardState::Down)
        };
        let s = match pinned {
            Some(s) if usable(self, s) => s,
            _ => {
                // the pin is gone (crash, cut, Down): re-place the session
                let pick = self.gw.topology.route(session).map(|sh| sh.id.0 as usize);
                let Some(ns) = pick else {
                    self.gw.out.no_route += 1;
                    self.log.record(t, "no_route", &format!("session={session}"));
                    return;
                };
                if let Some(prev) = pinned {
                    self.gw.topology.conn_closed(ShardId(prev as u16));
                }
                self.gw.topology.conn_opened(ShardId(ns as u16));
                self.gw.pins.insert(session, ns);
                if self.gw.last_assign.insert(session, ns) != Some(ns) {
                    self.gw.out.reassigned += 1;
                }
                self.log
                    .record(t, "repin", &format!("session={session} shard={ns}"));
                ns
            }
        };
        self.gw.out.forwarded_requests += 1;
        *self.gw.inflight.entry(session).or_insert(0) += 1;
        let up = self.shards[s].up;
        self.net.send(up, t, body, &mut self.log);
    }

    /// A shard's return trunk closed: treat it like the real gateway's
    /// refused pin — mark Down, drop its pins, let clients re-hello.
    fn gateway_trunk_lost(&mut self, t: f64, s: usize) {
        self.gw.out.crash_detected += 1;
        self.gw.topology.set_state(ShardId(s as u16), ShardState::Down);
        let lost: Vec<u32> = self
            .gw
            .pins
            .iter()
            .filter(|(_, &p)| p == s)
            .map(|(&k, _)| k)
            .collect();
        for session in lost {
            self.gw.pins.remove(&session);
            self.gw.topology.conn_closed(ShardId(s as u16));
        }
        self.log.record(t, "trunk_lost", &format!("shard={s}"));
        // crash mid-drain: replies owed by the old shard will never land,
        // so every handoff draining through it completes now, forced — a
        // migrating session ends up pinned to exactly one live shard, and
        // the sequence discipline re-grounds its stream there
        let stuck: Vec<u32> = self
            .gw
            .migrations
            .iter()
            .filter(|(_, m)| m.from == s)
            .map(|(&k, _)| k)
            .collect();
        for session in stuck {
            self.finish_migration(t, session, false);
        }
    }

    /// A shard published a policy up its trunk: assign the fleet-wide
    /// version and fan the snapshot back out to every reachable shard —
    /// including the publisher, whose adopt records the assigned number.
    fn gateway_publish(&mut self, t: f64, s: usize, p: PolicySync) {
        let v = self.gw.store.publish(&p.params);
        self.gw.out.policy_published += 1;
        self.log.record(t, "publish", &format!("shard={s} version={v}"));
        let body = msg_body(&Msg::Policy(PolicySync { version: v, params: p.params }));
        for i in 0..self.shards.len() {
            let up = self.shards[i].up;
            if self.shards[i].alive && self.net.is_open(up) {
                self.net.send(up, t, &body, &mut self.log);
            }
        }
    }

    /// A learn response passes the staleness gate on its way down: the
    /// gateway stamps the fleet-latest version, vetoes any action from a
    /// shard lagging beyond `max_lag`, and re-syncs the laggard exactly
    /// once per fleet version.
    fn gateway_learn_response(&mut self, t: f64, s: usize, mut r: ResponseLearn) {
        let latest = self.gw.store.version();
        r.latest_version = latest;
        let max_lag = self.cfg.learning.as_ref().map(|sp| sp.max_lag).unwrap_or(0);
        if !r.action.is_empty() && latest.saturating_sub(r.acting_version) > max_lag {
            self.gw.out.policy_stale_rejects += 1;
            r.flags |= RESP_FLAG_STALE;
            r.action.clear();
            self.log.record(
                t,
                "gw_stale_reject",
                &format!(
                    "shard={s} client={} acting={} latest={latest}",
                    r.client, r.acting_version
                ),
            );
            if self.gw.resynced.get(&s) != Some(&latest) {
                self.gw.resynced.insert(s, latest);
                let snap = self.gw.store.snapshot();
                if !snap.params.is_empty() {
                    self.gw.out.policy_resyncs += 1;
                    let body = msg_body(&Msg::Policy(PolicySync {
                        version: snap.version,
                        params: snap.params.clone(),
                    }));
                    let up = self.shards[s].up;
                    if self.shards[s].alive && self.net.is_open(up) {
                        self.net.send(up, t, &body, &mut self.log);
                    }
                    self.log
                        .record(t, "resync", &format!("shard={s} version={}", snap.version));
                }
            }
        }
        self.gw.out.forwarded_responses += 1;
        let session = r.client;
        let down = self.clients[r.client as usize].down;
        let body = msg_body(&Msg::ResponseLearn(r));
        self.net.send(down, t, &body, &mut self.log);
        self.gateway_response_landed(t, session);
    }

    // -- migration (DESIGN.md §10) ------------------------------------------

    /// The epoch-versioned migration sweep: after a topology change,
    /// re-route every pinned session through the new ring. Sessions whose
    /// placement moved enter the per-session drain state machine; already
    /// quiescent sessions hand off immediately. Consistent hashing keeps
    /// the sweep surgical — only the changed shard's keyspace moves.
    fn migrate_sessions(&mut self, t: f64, why: &str) {
        let epoch = self.gw.topology.epoch();
        let sessions: Vec<u32> = self.gw.pins.keys().copied().collect();
        let mut moved = 0usize;
        for session in sessions {
            let cur = self.gw.pins[&session];
            let Some(to) = self.gw.topology.route(session).map(|sh| sh.id.0 as usize) else {
                // nothing routable: drop the pin; the client's timeout
                // path re-hellos once capacity returns
                self.gw.out.no_route += 1;
                self.gateway_unpin(t, session);
                continue;
            };
            if let Some(m) = self.gw.migrations.get_mut(&session) {
                // already draining: retarget under the newer epoch
                m.to = to;
                m.epoch = epoch;
                continue;
            }
            if to == cur {
                continue;
            }
            moved += 1;
            self.gw.migrations.insert(session, MigrationSim { from: cur, to, epoch });
            self.log.record(
                t,
                "migrate_start",
                &format!("session={session} {cur}->{to} epoch={epoch} why={why}"),
            );
            if self.gw.inflight.get(&session).copied().unwrap_or(0) == 0 {
                self.finish_migration(t, session, true);
            }
        }
        self.log
            .record(t, "migration_sweep", &format!("moved={moved} epoch={epoch} why={why}"));
    }

    /// Complete one session handoff: re-pin to the target shard and
    /// re-establish every per-session layer there — the decoder base is
    /// invalidated (the next frame is refused, forcing exactly one
    /// keyframe re-sync), the gateway frame-error budget starts fresh
    /// (the `SessionGate::migrate` rule: budgets never survive the move),
    /// and on a clean drain the learning track (pending transition +
    /// partial rollout) transfers so no experience is lost. The old
    /// shard releases whatever it still holds for the session.
    fn finish_migration(&mut self, t: f64, session: u32, drained: bool) {
        let Some(m) = self.gw.migrations.remove(&session) else { return };
        self.gw.inflight.remove(&session);
        let (from, to) = (m.from, m.to);
        let mut track = false;
        if from != to && self.shards[from].alive {
            if drained {
                let (src, dst) = two_shards(&mut self.shards, from, to);
                if let (Some(a), Some(b)) = (src.learn.as_mut(), dst.learn.as_mut()) {
                    track = a.buf.transfer_client_to(session, &mut b.buf);
                }
            } else if let Some(l) = self.shards[from].learn.as_mut() {
                // forced handoff: the old shard's view of the trajectory
                // is no longer trustworthy — drop it rather than migrate
                // it; the stream re-grounds via the sequence discipline
                l.buf.drop_client(session);
            }
            self.shards[from].codecs.disconnect(session);
            self.shards[from].sessions.disconnect(session);
            self.shards[from].quarantined.remove(&session);
        }
        // the new shard must never ground a delta on a base it never saw:
        // invalidate → next frame refused → need_keyframe → exactly one
        // forced keyframe per handoff (the bounded re-sync storm)
        self.shards[to].codecs.invalidate(session);
        self.gw.errors.remove(&(session as usize));
        if self.gw.pins.get(&session) == Some(&from) {
            self.gw.topology.conn_closed(ShardId(from as u16));
        }
        self.gw.topology.conn_opened(ShardId(to as u16));
        self.gw.pins.insert(session, to);
        if self.gw.last_assign.insert(session, to) != Some(to) {
            self.gw.out.reassigned += 1;
        }
        self.gw.out.migrations += 1;
        if drained {
            self.gw.out.drained_handoffs += 1;
        }
        self.log.record(
            t,
            "migrate",
            &format!(
                "session={session} {from}->{to} epoch={} drained={drained} track={track}",
                m.epoch
            ),
        );
    }

    /// A reply crossed back down to its client: settle the per-session
    /// in-flight ledger. A migrating session whose ledger hits zero is
    /// quiescent — its drain is over and the handoff completes cleanly.
    fn gateway_response_landed(&mut self, t: f64, session: u32) {
        let Some(n) = self.gw.inflight.get_mut(&session) else { return };
        *n = n.saturating_sub(1);
        if *n == 0 {
            self.gw.inflight.remove(&session);
            if self.gw.migrations.contains_key(&session) {
                self.finish_migration(t, session, true);
            }
        }
    }

    // -- shards -------------------------------------------------------------

    fn shard_on_frame(&mut self, t: f64, s: usize, body: &[u8]) {
        if !self.shards[s].alive {
            self.log.record(t, "dead_shard_rx", &format!("shard={s}"));
            return;
        }
        let (view, tctx) = self.peel_trace(body);
        let msg = match Msg::decode(view) {
            Ok(m) => m,
            Err(_) => {
                self.shards[s].out.frame_errors += 1;
                self.log.record(t, "shard_frame_error", &format!("shard={s}"));
                return;
            }
        };
        match msg {
            Msg::Hello(h) => {
                // a (re)connected session is a new incarnation: invalidate
                // its cached codec base before any of its frames arrive;
                // the ack echoes known codec ids and declines unknown ones,
                // like the threaded reader
                self.shards[s].codecs.invalidate(h.client);
                let codec = if CodecId::from_wire(h.codec).is_some() { h.codec } else { 0 };
                let mut caps =
                    if self.shards[s].learn.is_some() { h.caps & CAP_EXPERIENCE } else { 0 };
                if self.cfg.trace {
                    caps |= h.caps & CAP_TRACE;
                }
                let ack = msg_body(&Msg::Hello(Hello {
                    client: h.client,
                    split: h.split,
                    codec,
                    caps,
                    shard: Some(s as u16),
                    epoch: None,
                }));
                let lane = self.reply_lane(s, h.client);
                self.net.send(lane, t, &ack, &mut self.log);
            }
            Msg::Request(r) => self.shard_request(t, s, r, tctx),
            Msg::Policy(p) => self.shard_adopt(t, s, p),
            Msg::Response(_) | Msg::ResponseV2(_) | Msg::ResponseLearn(_) | Msg::Error(_) => {
                self.log.record(t, "shard_unexpected", &format!("shard={s}"));
            }
        }
    }

    /// A policy fan-out from the gateway: adopt iff it is newer than the
    /// version this shard is already acting on (the learner's own
    /// publication comes back numbered — the adopt is then a no-op on the
    /// parameters but records the assigned version).
    fn shard_adopt(&mut self, t: f64, s: usize, p: PolicySync) {
        let Some(l) = &mut self.shards[s].learn else {
            self.log.record(t, "adopt_skip", &format!("shard={s} no_learner"));
            return;
        };
        match l.adopt(p.version, &p.params) {
            Ok(true) => {
                self.log
                    .record(t, "adopt", &format!("shard={s} version={}", p.version));
            }
            Ok(false) => {
                self.log
                    .record(t, "adopt_skip", &format!("shard={s} version={}", p.version));
            }
            Err(_) => {
                self.log
                    .record(t, "adopt_error", &format!("shard={s} version={}", p.version));
            }
        }
    }

    fn shard_request(&mut self, t: f64, s: usize, r: Request, tctx: Option<TraceCtx>) {
        let (client, id) = (r.client, r.id);
        if self.shards[s].quarantined.contains(&client) {
            // the executor shut this session's socket: its frames die
            // before touching the collector or any decoder state
            self.shards[s].out.quarantine_drops += 1;
            return;
        }
        let route = Route::of(&r.payload);
        let reply_lane = self.reply_lane(s, client);
        let now_i = self.clock.instant_at(t);
        let sh = &mut self.shards[s];
        sh.out.requests += 1;
        let work = SimWork {
            client,
            id,
            payload: r.payload,
            trace: tctx.map(|mut ctx| {
                ctx.stamp(trace::STAGE_ENQUEUE, trace::virtual_ns(t));
                ctx
            }),
        };
        if let Some(wk) = sh.collector.push(route, work, now_i) {
            sh.out.rejected += 1;
            // explicit rejection, like the executor's back-pressure path:
            // codec sessions additionally learn the frame never reached
            // the decoder, so the chain re-keys instead of desyncing
            let mut reply = match &wk.payload {
                Payload::FeaturesV2(f) => msg_body(&Msg::ResponseV2(ResponseV2 {
                    client,
                    id,
                    seq: f.seq,
                    flags: RESP_FLAG_NEED_KEYFRAME,
                    queue_wait_us: 0,
                    action: vec![],
                })),
                Payload::Experience(e) => msg_body(&Msg::ResponseLearn(ResponseLearn {
                    client,
                    id,
                    seq: e.feat.seq,
                    flags: RESP_FLAG_NEED_KEYFRAME,
                    acting_version: 0,
                    latest_version: 0,
                    action: vec![],
                })),
                _ => msg_body(&Msg::Response(Response { client, id, action: vec![] })),
            };
            if let Some(mut ctx) = wk.trace {
                // a shed decision still closes its span — every shard
                // stage collapses onto the rejection instant
                for stage in
                    [trace::STAGE_DEQUEUE, trace::STAGE_PACK, trace::STAGE_EXECUTE, trace::STAGE_REPLY]
                {
                    ctx.stamp(stage, trace::virtual_ns(t));
                }
                trace::append_trailer(&mut reply, &ctx);
            }
            self.log
                .record(t, "reject", &format!("shard={s} client={client} id={id}"));
            self.net.send(reply_lane, t, &reply, &mut self.log);
        }
        self.shard_pump(t, s);
    }

    /// Form every ready batch, model its execution window, and schedule
    /// the replies; then arm the next deadline wake.
    fn shard_pump(&mut self, t: f64, s: usize) {
        if !self.shards[s].alive {
            return;
        }
        let thermal_cfg = self
            .cfg
            .thermal
            .as_ref()
            .map(|sp| (sp.idle_watts, sp.active_watts, sp.throttle_factor));
        let update_cost = self.cfg.learning.as_ref().map(|sp| sp.update_cost).unwrap_or(0.0);
        let reject_budget = self.cfg.codec_reject_budget;
        let now_i = self.clock.instant_at(t);
        loop {
            let Some(route) = self.shards[s].collector.ready(now_i) else { break };
            let max_batch = self.shards[s].collector.policy().max_batch;
            let size_fired = self.shards[s].collector.depth(route) >= max_batch;
            let mut batch: Vec<Item<SimWork>> = Vec::new();
            self.shards[s].collector.take_into(route, &mut batch);
            let n = batch.len();
            let start = t.max(self.shards[s].busy_until);
            // the autoscaler's queue signal: enqueue → actual execution
            // start, i.e. fill wait plus executor backlog. The
            // protocol-visible qw_us below deliberately excludes backlog
            // (it feeds the client rate controllers), so the loop samples
            // its own histogram without touching the wire format
            if let Some(auto) = self.auto.as_mut() {
                let backlog = start - t;
                for item in &batch {
                    let waited = now_i.duration_since(item.enqueued).as_secs_f64() + backlog;
                    auto.queue.record_ns(waited * 1e9);
                }
            }
            // thermal: integrate the idle stretch, read the throttle state
            let mut factor = 1.0;
            if let Some((idle_w, _, throttle_factor)) = thermal_cfg {
                let at = self.clock.instant_at(start);
                let sh = &mut self.shards[s];
                if let Some(th) = sh.thermal.as_mut() {
                    th.update(idle_w, at);
                    if th.model().throttled() {
                        factor = throttle_factor;
                        sh.out.throttled_batches += 1;
                    }
                }
            }
            // real ingest machinery, modelled compute; gradient updates
            // triggered inside the batch extend its execution window, so
            // the cost is settled after the items are processed
            let mut replies = Vec::with_capacity(n);
            let mut published: Vec<Vec<f32>> = Vec::new();
            let mut updates_ran = 0usize;
            for item in &batch {
                let w = &item.work;
                let qw_us = now_i
                    .duration_since(item.enqueued)
                    .as_micros()
                    .min(u32::MAX as u128) as u32;
                let default_action = (w.client as f32) * 1e-3 + (w.id as f32) * 1e-6 + 0.125;
                let mut reply = match &w.payload {
                    Payload::RawRgba { x, data } => {
                        let x = *x as usize;
                        let sh = &mut self.shards[s];
                        sh.obs_scratch.clear();
                        sh.obs_scratch.resize(9 * x * x, 0.0);
                        let _ = sh
                            .sessions
                            .ingest_rgba_into(w.client, x, data, &mut sh.obs_scratch);
                        SimReply {
                            client: w.client,
                            id: w.id,
                            action: default_action,
                            v2: None,
                            learn: None,
                            trace: None,
                        }
                    }
                    Payload::Features { scale, data, .. } => {
                        let _ = crate::net::framing::dequantize_features(*scale, data);
                        SimReply {
                            client: w.client,
                            id: w.id,
                            action: default_action,
                            v2: None,
                            learn: None,
                            trace: None,
                        }
                    }
                    Payload::FeaturesV2(f) => {
                        // the real decoder: reconstruct the quantised frame
                        // (or refuse it) exactly as a live executor would
                        let sh = &mut self.shards[s];
                        sh.out.codec_frames += 1;
                        sh.obs_scratch.clear();
                        sh.obs_scratch.resize(f.feat_len(), 0.0);
                        match sh.codecs.decode_into(w.client, f, &mut sh.obs_scratch) {
                            Ok(()) => {
                                let action = sh
                                    .codecs
                                    .frame(w.client)
                                    .map(checksum_action)
                                    .unwrap_or(default_action);
                                SimReply {
                                    client: w.client,
                                    id: w.id,
                                    action,
                                    v2: Some((f.seq, false, qw_us)),
                                    learn: None,
                                    trace: None,
                                }
                            }
                            Err(_) => {
                                sh.out.codec_rejects += 1;
                                let abusive =
                                    sh.codecs.consecutive_rejects(w.client) > reject_budget;
                                self.log.record(
                                    t,
                                    "codec_reject",
                                    &format!("shard={s} client={} id={}", w.client, w.id),
                                );
                                // the executor's quarantine: a session past
                                // its consecutive-reject budget is cut off
                                // without touching any other stream
                                if abusive && self.shards[s].quarantined.insert(w.client) {
                                    self.shards[s].out.quarantined_sessions += 1;
                                    self.log.record(
                                        t,
                                        "quarantine",
                                        &format!("shard={s} client={}", w.client),
                                    );
                                }
                                SimReply {
                                    client: w.client,
                                    id: w.id,
                                    action: 0.0,
                                    v2: Some((f.seq, true, qw_us)),
                                    learn: None,
                                    trace: None,
                                }
                            }
                        }
                    }
                    Payload::Experience(e) => {
                        // the same real decoder feeds the experience buffer:
                        // a refused frame re-keys the chain, a decoded one
                        // advances the learner (and may trigger an update)
                        let sh = &mut self.shards[s];
                        sh.out.codec_frames += 1;
                        sh.out.exp_frames += 1;
                        sh.obs_scratch.clear();
                        sh.obs_scratch.resize(e.feat.feat_len(), 0.0);
                        let empty = |seq, flags, unsupported| LearnReply {
                            seq,
                            flags,
                            acting_version: 0,
                            action: vec![],
                            unsupported,
                        };
                        let learn = match sh
                            .codecs
                            .decode_into(w.client, &e.feat, &mut sh.obs_scratch)
                        {
                            Ok(()) => match &mut sh.learn {
                                Some(learner) => match learner.on_frame(
                                    w.client,
                                    &sh.obs_scratch,
                                    e.ep,
                                    e.step,
                                    e.has_reward(),
                                    e.reward,
                                    e.done(),
                                    e.terminated(),
                                ) {
                                    Ok(step) => {
                                        if step.updated {
                                            updates_ran += 1;
                                        }
                                        if let Some(params) = step.publish {
                                            published.push(params);
                                        }
                                        LearnReply {
                                            seq: e.feat.seq,
                                            flags: 0,
                                            acting_version: step.acting_version,
                                            action: step.action,
                                            unsupported: false,
                                        }
                                    }
                                    Err(_) => {
                                        self.log.record(
                                            t,
                                            "learn_error",
                                            &format!(
                                                "shard={s} client={} id={}",
                                                w.client, w.id
                                            ),
                                        );
                                        empty(e.feat.seq, 0, false)
                                    }
                                },
                                None => empty(e.feat.seq, 0, true),
                            },
                            Err(_) => {
                                sh.out.codec_rejects += 1;
                                let abusive =
                                    sh.codecs.consecutive_rejects(w.client) > reject_budget;
                                self.log.record(
                                    t,
                                    "codec_reject",
                                    &format!("shard={s} client={} id={}", w.client, w.id),
                                );
                                if abusive && self.shards[s].quarantined.insert(w.client) {
                                    self.shards[s].out.quarantined_sessions += 1;
                                    self.log.record(
                                        t,
                                        "quarantine",
                                        &format!("shard={s} client={}", w.client),
                                    );
                                }
                                empty(e.feat.seq, RESP_FLAG_NEED_KEYFRAME, false)
                            }
                        };
                        SimReply {
                            client: w.client,
                            id: w.id,
                            action: 0.0,
                            v2: None,
                            learn: Some(learn),
                            trace: None,
                        }
                    }
                };
                // dequeue and pack land on the batch's actual execution
                // start (fill wait plus backlog behind it), so the span's
                // queue stage equals the autoscaler's queue-wait sample
                // for the same item, exactly
                reply.trace = w.trace.map(|mut ctx| {
                    ctx.stamp(trace::STAGE_DEQUEUE, trace::virtual_ns(start));
                    ctx.stamp(trace::STAGE_PACK, trace::virtual_ns(start));
                    ctx
                });
                replies.push(reply);
            }
            let cost = (self.cfg.exec_fixed + self.cfg.exec_per_item * n as f64) * factor
                + updates_ran as f64 * update_cost;
            let done = start + cost;
            self.shards[s].busy_until = done;
            if let Some((_, active_w, _)) = thermal_cfg {
                let at = self.clock.instant_at(done);
                let sh = &mut self.shards[s];
                if let Some(th) = sh.thermal.as_mut() {
                    th.update(active_w, at);
                    sh.out.max_temp = sh.out.max_temp.max(th.model().temp());
                }
            }
            {
                let sh = &mut self.shards[s];
                sh.out.batches += 1;
                sh.out.max_batch = sh.out.max_batch.max(n);
                if size_fired {
                    sh.out.size_fired += 1;
                } else {
                    sh.out.deadline_fired += 1;
                }
            }
            let fired = if size_fired { "size" } else { "deadline" };
            let throttled = factor > 1.0;
            self.log.record(
                t,
                "batch",
                &format!(
                    "shard={s} route={} n={n} fired={fired} throttled={throttled} done={done:.6}",
                    route.name()
                ),
            );
            let incarnation = self.shards[s].incarnation;
            self.events
                .push(done, Ev::ExecDone { s, incarnation, replies, published });
        }
        if let Some(d) = self.shards[s].collector.next_deadline(now_i) {
            if !self.shards[s].collector.is_empty() {
                self.events
                    .push(t + d.as_secs_f64() + 1e-6, Ev::ShardWake(s));
            }
        }
    }

    fn shard_exec_done(
        &mut self,
        t: f64,
        s: usize,
        incarnation: u64,
        replies: Vec<SimReply>,
        published: Vec<Vec<f32>>,
    ) {
        if !self.shards[s].alive || self.shards[s].incarnation != incarnation {
            // crashed mid-exec (even if already restarted): the batch's
            // work — replies AND policy publications — died with the old
            // incarnation
            self.log
                .record(t, "replies_lost", &format!("shard={s} n={}", replies.len()));
            return;
        }
        // publications first: a policy produced in this batch is visible to
        // the fleet no later than the actions the same batch emitted
        for params in published {
            if self.cfg.gateway {
                // version 0 = unversioned: the gateway's store assigns the
                // fleet-wide number when the publication lands
                let body = msg_body(&Msg::Policy(PolicySync { version: 0, params }));
                self.log.record(t, "publish_tx", &format!("shard={s}"));
                let down = self.shards[s].down;
                self.net.send(down, t, &body, &mut self.log);
            } else {
                // direct mode: the store and the only learner live on this
                // process; publish and self-adopt are immediate
                let v = self.gw.store.publish(&params);
                self.gw.out.policy_published += 1;
                if let Some(l) = &mut self.shards[s].learn {
                    let _ = l.adopt(v, &params);
                }
                self.log.record(t, "publish", &format!("shard={s} version={v}"));
            }
        }
        for r in replies {
            let lane = self.reply_lane(s, r.client);
            let mut body = match (r.learn, r.v2) {
                (Some(lr), _) if lr.unsupported => msg_body(&Msg::Error(ErrorMsg {
                    client: r.client,
                    code: ERR_EXPERIENCE_UNSUPPORTED,
                    detail: "experience frames were not negotiated on this session".into(),
                })),
                (Some(lr), _) => {
                    // direct mode stamps the live store version; gateway
                    // mode stamps 0 and the gateway overwrites it in flight
                    let latest = if self.cfg.gateway { 0 } else { self.gw.store.version() };
                    msg_body(&Msg::ResponseLearn(ResponseLearn {
                        client: r.client,
                        id: r.id,
                        seq: lr.seq,
                        flags: lr.flags,
                        acting_version: lr.acting_version,
                        latest_version: latest,
                        action: lr.action,
                    }))
                }
                (None, Some((seq, need_key, queue_wait_us))) => {
                    msg_body(&Msg::ResponseV2(ResponseV2 {
                        client: r.client,
                        id: r.id,
                        seq,
                        flags: if need_key { RESP_FLAG_NEED_KEYFRAME } else { 0 },
                        queue_wait_us,
                        action: if need_key { vec![] } else { vec![r.action] },
                    }))
                }
                (None, None) => msg_body(&Msg::Response(Response {
                    client: r.client,
                    id: r.id,
                    action: vec![r.action],
                })),
            };
            if let Some(mut ctx) = r.trace {
                // execute and reply land on the modelled completion
                // instant; capability errors are not trace-eligible, so
                // the guard keeps a span off any frame the client-side
                // peel would refuse to split
                ctx.stamp(trace::STAGE_EXECUTE, trace::virtual_ns(t));
                ctx.stamp(trace::STAGE_REPLY, trace::virtual_ns(t));
                if !body.is_empty() && trace::trace_eligible(body[0]) {
                    trace::append_trailer(&mut body, &ctx);
                }
            }
            self.net.send(lane, t, &body, &mut self.log);
        }
    }

    // -- health & faults ----------------------------------------------------

    fn probe_round(&mut self, t: f64) {
        if self.cfg.gateway {
            for s in 0..self.shards.len() {
                let id = ShardId(s as u16);
                // spares not yet joined and shards removed from the ring
                // are outside the fleet: the prober has nothing to drive
                let Some(cur) = self.gw.topology.state(id) else {
                    continue;
                };
                let reachable = self.shards[s].alive
                    && !self.partitioned[s]
                    && self.net.is_open(self.shards[s].up)
                    && self.net.is_open(self.shards[s].down);
                let rtt = reachable
                    .then(|| Duration::from_secs_f64(2.0 * self.cfg.shard_link.latency + 1e-4));
                let st = &mut self.probe_stats[s];
                st.probes += 1;
                match rtt {
                    Some(d) => {
                        st.consecutive_failures = 0;
                        st.last_rtt = Some(d.as_secs_f64());
                    }
                    None => {
                        st.failures += 1;
                        st.consecutive_failures += 1;
                    }
                }
                let consecutive = st.consecutive_failures;
                if let Some(next) = probe_transition(cur, rtt, consecutive, &self.cfg.health) {
                    self.gw.topology.set_state(id, next);
                    self.log.record(
                        t,
                        "probe_state",
                        &format!("shard={s} {}->{}", cur.name(), next.name()),
                    );
                }
            }
        }
        match self.cfg.probe_interval {
            Some(p) if !self.all_done() => self.events.push(t + p, Ev::Probe),
            _ => {}
        }
    }

    fn apply_fault(&mut self, t: f64, k: usize) {
        let (_, cmd) = self.cfg.faults[k];
        match cmd {
            FaultCmd::CrashShard(s) => {
                self.log.record(t, "fault_crash", &format!("shard={s}"));
                self.shards[s].alive = false;
                let (up, down) = (self.shards[s].up, self.shards[s].down);
                self.net.cut(up, false, t, &mut self.log);
                self.net.cut(down, false, t, &mut self.log);
            }
            FaultCmd::RestartShard(s) => {
                self.log.record(t, "fault_restart", &format!("shard={s}"));
                let policy = self.cfg.policy;
                let max_depth = self.cfg.max_depth;
                let learn_spec = self.cfg.learning.as_ref().map(|sp| sp.learner.clone());
                let sh = &mut self.shards[s];
                sh.alive = true;
                sh.incarnation += 1;
                sh.collector = BatchCollector::new(policy, max_depth);
                sh.sessions = SessionManager::new();
                // fresh incarnation, fresh decoder state: any delta built
                // against the dead incarnation's base is refused, never
                // decoded against stale bytes
                sh.codecs = Decoders::new();
                // the learner restarts at policy version 0 with an empty
                // buffer: the gateway's staleness gate catches its first
                // stale action and re-syncs it to the fleet version
                sh.learn = learn_spec.map(Learner::new);
                // quarantine verdicts die with the incarnation, like every
                // other per-session judgement the old process held
                sh.quarantined.clear();
                sh.busy_until = t;
                let (up, down) = (sh.up, sh.down);
                self.net.reopen(up, t, &mut self.log);
                self.net.reopen(down, t, &mut self.log);
                if self.cfg.gateway && self.cfg.probe_interval.is_none() {
                    // no prober to revive it: treat the restart as the
                    // operator bringing it back
                    self.gw.topology.set_state(ShardId(s as u16), ShardState::Up);
                }
            }
            FaultCmd::PartitionShard(s) => {
                self.partitioned[s] = true;
                let (up, down) = (self.shards[s].up, self.shards[s].down);
                self.net.set_partitioned(up, true, t, &mut self.log);
                self.net.set_partitioned(down, true, t, &mut self.log);
            }
            FaultCmd::HealShard(s) => {
                self.partitioned[s] = false;
                let (up, down) = (self.shards[s].up, self.shards[s].down);
                self.net.set_partitioned(up, false, t, &mut self.log);
                self.net.set_partitioned(down, false, t, &mut self.log);
            }
            FaultCmd::DrainShard(s) => {
                self.gw.topology.drain(ShardId(s as u16));
                self.log.record(t, "fault_drain", &format!("shard={s}"));
            }
            FaultCmd::CutShardUplinkMidFrame(s) => {
                let up = self.shards[s].up;
                self.net.cut(up, true, t, &mut self.log);
            }
            FaultCmd::AddShard(s) => {
                if !self.join_shard(t, s, "fault_add_shard", "scale_up") {
                    // already in the ring: joining is not re-entrant
                    self.log.record(t, "add_shard_noop", &format!("shard={s}"));
                }
            }
            FaultCmd::RemoveShard(s) => {
                if !self.leave_shard(t, s, "fault_remove_shard", "scale_down") {
                    self.log.record(t, "remove_shard_noop", &format!("shard={s}"));
                }
            }
            FaultCmd::SampleThermal(s) => {
                let idle_w = self.cfg.thermal.as_ref().map(|sp| sp.idle_watts).unwrap_or(0.0);
                let at = self.clock.instant_at(t);
                let sh = &mut self.shards[s];
                if let Some(th) = sh.thermal.as_mut() {
                    th.update(idle_w, at);
                    sh.out.max_temp = sh.out.max_temp.max(th.model().temp());
                    sh.out.final_throttled = th.model().throttled();
                    let temp = th.model().temp();
                    let throttled = th.model().throttled();
                    self.log.record(
                        t,
                        "thermal",
                        &format!("shard={s} temp={temp:.3} throttled={throttled}"),
                    );
                }
            }
        }
    }

    /// A pre-provisioned shard joins the ring — by timed fault or by
    /// autoscaler verdict; `tag` names the log line and `why` labels the
    /// migration sweep. Returns false (and does nothing) when the shard is
    /// already in the ring.
    fn join_shard(&mut self, t: f64, s: usize, tag: &str, why: &str) -> bool {
        if self.gw.topology.state(ShardId(s as u16)).is_some() {
            return false;
        }
        let policy = self.cfg.policy;
        let max_depth = self.cfg.max_depth;
        let learn_spec = self.cfg.learning.as_ref().map(|sp| sp.learner.clone());
        // the pre-provisioned spare boots with fresh state, exactly
        // like a restart: nothing from any earlier incarnation
        // (decoder bases, sessions, quarantine verdicts) survives
        let sh = &mut self.shards[s];
        sh.alive = true;
        sh.incarnation += 1;
        sh.collector = BatchCollector::new(policy, max_depth);
        sh.sessions = SessionManager::new();
        sh.codecs = Decoders::new();
        sh.learn = learn_spec.map(Learner::new);
        sh.quarantined.clear();
        sh.busy_until = t;
        let (up, down) = (sh.up, sh.down);
        self.net.reopen(up, t, &mut self.log);
        self.net.reopen(down, t, &mut self.log);
        self.gw.topology.add_shard(
            ShardId(s as u16),
            format!("127.0.0.1:{}", 9000 + s).parse().unwrap(),
        );
        self.log.record(t, tag, &format!("shard={s} epoch={}", self.gw.topology.epoch()));
        if self.cfg.gateway {
            // a joining shard acts at policy version 0: push the
            // fleet-latest snapshot down its trunk immediately so
            // it never serves archaic actions to migrated sessions
            let snap = self.gw.store.snapshot();
            if !snap.params.is_empty() {
                self.gw.out.policy_resyncs += 1;
                let body = msg_body(&Msg::Policy(PolicySync {
                    version: snap.version,
                    params: snap.params.clone(),
                }));
                let up = self.shards[s].up;
                self.net.send(up, t, &body, &mut self.log);
                self.log.record(t, "resync", &format!("shard={s} version={}", snap.version));
            }
            self.migrate_sessions(t, why);
        }
        true
    }

    /// A shard leaves the ring — by timed fault or by autoscaler verdict.
    /// Planned scale-down: the topology epoch bumps, its sessions enter the
    /// drain state machine, and the process itself stays up to answer
    /// everything still in flight — nothing new routes to it once its pins
    /// move. Returns false when the shard is not in the ring.
    fn leave_shard(&mut self, t: f64, s: usize, tag: &str, why: &str) -> bool {
        if self.gw.topology.state(ShardId(s as u16)).is_none() {
            return false;
        }
        self.gw.topology.remove_shard(ShardId(s as u16));
        self.log.record(t, tag, &format!("shard={s} epoch={}", self.gw.topology.epoch()));
        if self.cfg.gateway {
            self.migrate_sessions(t, why);
        }
        true
    }

    /// One closed-loop autoscaling observation (DESIGN.md §11): subtract
    /// the previous tick's cumulative state from the queue-wait histogram
    /// and the gateway admission counters, feed the windowed sample to the
    /// autoscaler on the virtual clock, and apply its verdict through the
    /// same join/leave machinery the timed faults use. Spares join lowest
    /// index first and the highest-index ring member leaves first, so the
    /// shard chosen is a pure function of ring state.
    fn autoscale_tick(&mut self, t: f64) {
        let Some(interval) = self.cfg.autoscale.as_ref().map(|sp| sp.interval) else {
            return;
        };
        let routable = self.gw.topology.n_routable();
        let gateway = GatewayCounters {
            shed_sessions: self.gw.out.shed_hellos,
            rate_limited: 0,
            quarantined_sessions: self.gw.out.quarantined_sessions,
            quarantine_drops: self.gw.out.quarantine_drops,
        };
        let requests = self.gw.out.forwarded_requests;
        let auto = self.auto.as_mut().expect("autoscale spec without AutoSim state");
        let sample = auto.window.sample_parts(&auto.queue, gateway, requests, routable);
        let action = auto.scaler.observe(t, sample);
        auto.out.samples += 1;
        // traced fleets attribute the verdict: the window's per-stage
        // delta names the stage that dominated this interval (untraced
        // runs keep the log line byte-identical to before)
        let dominant = if self.cfg.trace {
            let w = auto.window.stage_window(&self.stage_totals);
            format!(" dominant={}", w.dominant().unwrap_or("none"))
        } else {
            String::new()
        };
        self.log.record(
            t,
            "autoscale_sample",
            &format!(
                "p95_us={} shed={:.4} shards={} verdict={:?}{dominant}",
                sample.queue_p95_ns / 1000,
                sample.shed_rate,
                sample.shards,
                action
            ),
        );
        match action {
            ScaleAction::ScaleUp => {
                // lowest-index provisioned spare outside the ring
                let target = (0..self.shards.len())
                    .find(|&s| self.gw.topology.state(ShardId(s as u16)).is_none());
                if let Some(s) = target {
                    if self.join_shard(t, s, "autoscale_add_shard", "autoscale_up") {
                        if let Some(a) = self.auto.as_mut() {
                            a.out.scale_ups += 1;
                        }
                    }
                }
            }
            ScaleAction::ScaleDown => {
                // highest-index ring member leaves first
                let target = (0..self.shards.len())
                    .rev()
                    .find(|&s| self.gw.topology.state(ShardId(s as u16)).is_some());
                if let Some(s) = target {
                    if self.leave_shard(t, s, "autoscale_remove_shard", "autoscale_down") {
                        if let Some(a) = self.auto.as_mut() {
                            a.out.scale_downs += 1;
                        }
                    }
                }
            }
            ScaleAction::Hold => {}
        }
        if !self.all_done() {
            self.events.push(t + interval, Ev::AutoscaleTick);
        }
    }

    // -- delivery dispatch ---------------------------------------------------

    fn on_delivery(&mut self, t: f64, lane: LaneId, d: Delivery) {
        match self.owners[lane] {
            Owner::Client(c) => match d {
                Delivery::Frame(body) => self.client_on_frame(t, c, &body),
                Delivery::Truncated(_) => {
                    self.log.record(t, "client_torn_frame", &format!("client={c}"));
                }
                Delivery::Closed => {
                    self.log.record(t, "client_conn_closed", &format!("client={c}"));
                }
            },
            Owner::GatewayFromClient(c) => match d {
                Delivery::Frame(mut body) => {
                    if self.gw.quarantined.contains(&c) {
                        // the threaded gateway shut this socket: frames
                        // die unread, shard state untouched
                        self.gw.out.quarantine_drops += 1;
                        return;
                    }
                    let (view, tctx) = self.peel_trace(&body);
                    match Msg::decode(view) {
                        Ok(Msg::Hello(h)) => self.gateway_hello(t, h),
                        Ok(Msg::Request(r)) => {
                            if tctx.is_some() {
                                // stamp the forward hop into the same bytes
                                // the shard will receive: the trailer rides
                                // the wire, not gateway state
                                trace::stamp_body_tail(
                                    &mut body,
                                    trace::STAGE_GW_FORWARD,
                                    trace::virtual_ns(t),
                                );
                            }
                            self.gateway_request(t, r.client, &body)
                        }
                        Ok(
                            Msg::Response(_)
                            | Msg::ResponseV2(_)
                            | Msg::ResponseLearn(_)
                            | Msg::Error(_)
                            | Msg::Policy(_),
                        ) => {
                            self.log.record(t, "gw_unexpected", &format!("client={c}"));
                        }
                        Err(_) => {
                            self.log.record(t, "gw_frame_error", &format!("client={c}"));
                            self.gateway_frame_error(t, c);
                        }
                    }
                }
                Delivery::Truncated(_) => {
                    self.log.record(t, "gw_torn_frame", &format!("client={c}"));
                }
                Delivery::Closed => {
                    self.gateway_unpin(t, c as u32);
                }
            },
            Owner::GatewayFromShard(s) => match d {
                // classification peels the trailer; the body (trailer and
                // all) still forwards verbatim — the gateway never rewrites
                // reply bytes on the way down
                Delivery::Frame(body) => match Msg::decode(self.peel_trace(&body).0) {
                    Ok(Msg::Hello(_)) => {
                        // shard-side hello acks stay internal to the fleet
                        self.gw.out.filtered_shard_acks += 1;
                        self.log.record(t, "filter_ack", &format!("shard={s}"));
                    }
                    Ok(Msg::Response(r)) => {
                        self.gw.out.forwarded_responses += 1;
                        let down = self.clients[r.client as usize].down;
                        self.net.send(down, t, &body, &mut self.log);
                        self.gateway_response_landed(t, r.client);
                    }
                    Ok(Msg::ResponseV2(r)) => {
                        // codec acks forward verbatim, exactly like v1
                        // responses — the gateway never decodes payloads
                        self.gw.out.forwarded_responses += 1;
                        let down = self.clients[r.client as usize].down;
                        self.net.send(down, t, &body, &mut self.log);
                        self.gateway_response_landed(t, r.client);
                    }
                    Ok(Msg::ResponseLearn(r)) => self.gateway_learn_response(t, s, r),
                    Ok(Msg::Policy(p)) => self.gateway_publish(t, s, p),
                    Ok(Msg::Error(e)) => {
                        // capability errors forward verbatim to the client
                        self.gw.out.forwarded_responses += 1;
                        let down = self.clients[e.client as usize].down;
                        self.net.send(down, t, &body, &mut self.log);
                        self.gateway_response_landed(t, e.client);
                    }
                    Ok(Msg::Request(_)) => {
                        self.log.record(t, "gw_unexpected", &format!("shard={s}"));
                    }
                    Err(_) => {
                        self.log.record(t, "gw_frame_error", &format!("shard={s}"));
                    }
                },
                Delivery::Truncated(_) => {
                    self.log.record(t, "gw_torn_frame", &format!("shard={s}"));
                    self.gateway_trunk_lost(t, s);
                }
                Delivery::Closed => self.gateway_trunk_lost(t, s),
            },
            Owner::Shard(s) => match d {
                Delivery::Frame(body) => self.shard_on_frame(t, s, &body),
                Delivery::Truncated(_) => {
                    self.shards[s].out.frame_errors += 1;
                    self.log.record(t, "shard_torn_frame", &format!("shard={s}"));
                }
                Delivery::Closed => {
                    self.log.record(t, "shard_uplink_closed", &format!("shard={s}"));
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::native::NativeConfig;

    fn base(seed: u64) -> ScenarioConfig {
        ScenarioConfig { seed, ..ScenarioConfig::default() }
    }

    #[test]
    fn baseline_scenario_completes_every_decision() {
        let r = run_scenario(&base(1)).expect("scenario");
        assert_eq!(r.total_give_ups(), 0);
        assert_eq!(r.completed_decisions(), 4 * 8);
        assert!(r.hello_acks_exactly_once(), "{:?}", r.clients[0].hello_acks);
        assert_eq!(r.gateway.no_route, 0);
        assert_eq!(r.gateway.reassigned, 0);
        let shard_reqs: u64 = r.shards.iter().map(|s| s.requests).sum();
        assert_eq!(shard_reqs, 32);
        assert!(r.elapsed > 0.0 && r.elapsed < 10.0, "{}", r.elapsed);
    }

    #[test]
    fn same_seed_is_byte_identical_different_seed_is_not() {
        let a = run_scenario(&base(7)).unwrap();
        let b = run_scenario(&base(7)).unwrap();
        assert_eq!(a.log, b.log, "same-seed logs diverged");
        let c = run_scenario(&base(8)).unwrap();
        assert_ne!(a.log, c.log, "different seeds produced the same log");
    }

    #[test]
    fn direct_mode_skips_the_gateway() {
        let cfg = ScenarioConfig {
            gateway: false,
            shards: 1,
            raw_clients: 1,
            split_clients: 1,
            decisions: 5,
            ..base(3)
        };
        let r = run_scenario(&cfg).unwrap();
        assert_eq!(r.total_give_ups(), 0);
        assert_eq!(r.completed_decisions(), 10);
        assert_eq!(r.gateway.forwarded_requests, 0, "gateway must be inert");
        assert_eq!(r.shards[0].requests, 10);
    }

    #[test]
    fn split_clients_pay_the_encode_time() {
        let cfg = ScenarioConfig {
            gateway: false,
            shards: 1,
            raw_clients: 0,
            split_clients: 1,
            decisions: 4,
            encode_j: 0.05,
            ..base(4)
        };
        let mut r = run_scenario(&cfg).unwrap();
        assert_eq!(r.completed_decisions(), 4);
        assert!(
            r.clients[0].latencies.median() >= 0.05,
            "latency must include j: {}",
            r.clients[0].latencies.median()
        );
    }

    #[test]
    fn learning_direct_mode_trains_and_completes() {
        let learner = LearnerConfig {
            core: NativeConfig { hidden: 8, minibatch: 8, ..NativeConfig::default() },
            rollout_steps: 32,
            ppo_epochs: 2,
            gae_lambda: 0.95,
            publish_every: 1,
        };
        let cfg = ScenarioConfig {
            gateway: false,
            shards: 1,
            raw_clients: 0,
            split_clients: 0,
            learning: Some(LearnSpec { clients: 1, episodes: 2, learner, ..LearnSpec::default() }),
            ..base(5)
        };
        let r = run_scenario(&cfg).unwrap();
        assert_eq!(r.total_give_ups(), 0);
        assert_eq!(r.total_episodes(), 2);
        assert_eq!(r.clients[0].returns.len(), 2);
        assert!(r.clients[0].returns.iter().all(|&g| g < 0.0 && g > -4000.0));
        assert!(r.shards[0].exp_frames > 0);
        // 2 episodes x 200 steps across 32-step segments: updates must run
        // and every one publishes + self-adopts in direct mode
        assert!(r.shards[0].updates >= 10, "updates={}", r.shards[0].updates);
        assert_eq!(r.gateway.policy_published, r.shards[0].published);
        assert!(r.shards[0].final_version > 0);
        let vs = &r.shards[0].adopted_versions;
        assert!(vs.windows(2).all(|w| w[0] < w[1]), "{vs:?}");
        assert_eq!(r.total_applied_stale(), 0);
        assert_eq!(r.clients[0].final_qmax, 255, "learning path must stay full-precision");
    }

    #[test]
    fn rejects_misaligned_learning_configs() {
        let learner = LearnerConfig {
            core: NativeConfig { minibatch: 48, ..NativeConfig::default() },
            rollout_steps: 100,
            ..LearnerConfig::default()
        };
        let cfg = ScenarioConfig {
            raw_clients: 0,
            split_clients: 0,
            learning: Some(LearnSpec { learner, ..LearnSpec::default() }),
            ..base(1)
        };
        assert!(run_scenario(&cfg).is_err());
    }

    #[test]
    fn diurnal_think_gap_is_a_bounded_periodic_triangle() {
        let cfg = ScenarioConfig { think: 0.01, diurnal: Some((10.0, 5.0)), ..base(1) };
        let w = World::new(cfg).unwrap();
        // trough at phase 0 stretches think by idle_factor; peak at
        // phase 0.5 is the configured think; one full period later the
        // curve repeats exactly
        assert!((w.think_gap(0.0) - 0.05).abs() < 1e-12);
        assert!((w.think_gap(5.0) - 0.01).abs() < 1e-12);
        assert!((w.think_gap(15.0) - 0.01).abs() < 1e-12);
        for i in 0..200 {
            let g = w.think_gap(i as f64 * 0.37);
            assert!((0.01 - 1e-12..=0.05 + 1e-12).contains(&g), "gap {g} escaped the band");
        }
        // no curve configured: the gap is flat
        let flat = World::new(ScenarioConfig { think: 0.02, ..base(1) }).unwrap();
        assert_eq!(flat.think_gap(123.4), 0.02);
    }

    #[test]
    fn idle_autoscaled_scenario_samples_but_never_acts() {
        // a light run far below every watermark: the loop must observe on
        // its cadence and hold — scaling on noise would churn migrations
        let cfg = ScenarioConfig {
            think: 0.001,
            decisions: 32,
            autoscale: Some(AutoscaleSpec {
                cfg: AutoscaleConfig { min_shards: 1, max_shards: 4, ..AutoscaleConfig::default() },
                interval: 0.005,
            }),
            ..base(6)
        };
        let r = run_scenario(&cfg).unwrap();
        assert_eq!(r.total_give_ups(), 0);
        assert!(r.autoscale.samples >= 2, "samples={}", r.autoscale.samples);
        assert_eq!(r.autoscale.scale_ups, 0);
        assert_eq!(r.autoscale.scale_downs, 0);
        assert!(r.log.contains(" autoscale_sample "), "sample lines must be in the log");
        assert_eq!(r.gateway.migrations, 0);
    }

    #[test]
    fn traced_run_closes_every_span_and_untraced_stays_silent() {
        let cfg = ScenarioConfig { trace: true, ..base(7) };
        let r = run_scenario(&cfg).expect("traced scenario");
        assert_eq!(r.total_give_ups(), 0);
        assert_eq!(r.completed_decisions(), 4 * 8);
        for (c, cl) in r.clients.iter().enumerate() {
            assert_eq!(cl.traces.len(), cl.decisions, "client {c}: one span per decision");
            for tr in &cl.traces {
                assert_eq!((tr.id >> 32) as usize, c, "span id carries the client");
                assert!(tr.total_ns() > 0, "client {c}: open span {:#x}", tr.id);
            }
        }
        assert!(r.stage_totals.total() > 0);
        assert!(r.log.contains(" trace "), "traced runs must log span closures");

        // same seed, trace off: no spans, no trace log lines, no totals —
        // the observability layer must be invisible when not negotiated
        let u = run_scenario(&base(7)).expect("untraced scenario");
        assert!(!u.log.contains(" trace "));
        assert!(u.clients.iter().all(|c| c.traces.is_empty()));
        assert_eq!(u.stage_totals.total(), 0);
    }

    #[test]
    fn rejects_autoscale_without_gateway_and_bad_diurnal_curves() {
        assert!(run_scenario(&ScenarioConfig {
            gateway: false,
            shards: 1,
            autoscale: Some(AutoscaleSpec { cfg: AutoscaleConfig::default(), interval: 1.0 }),
            ..base(1)
        })
        .is_err());
        assert!(run_scenario(&ScenarioConfig {
            autoscale: Some(AutoscaleSpec { cfg: AutoscaleConfig::default(), interval: 0.0 }),
            ..base(1)
        })
        .is_err());
        assert!(run_scenario(&ScenarioConfig { diurnal: Some((0.0, 2.0)), ..base(1) }).is_err());
        assert!(run_scenario(&ScenarioConfig { diurnal: Some((10.0, 0.5)), ..base(1) }).is_err());
    }

    #[test]
    fn rejects_configs_without_actors() {
        assert!(run_scenario(&ScenarioConfig { shards: 0, ..base(1) }).is_err());
        assert!(run_scenario(&ScenarioConfig {
            raw_clients: 0,
            split_clients: 0,
            ..base(1)
        })
        .is_err());
    }
}

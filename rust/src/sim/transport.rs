//! The simulated network: deterministic, seeded, fault-injected links that
//! speak the exact `net::framing` byte protocol.
//!
//! Two layers share one fault engine ([`LinkFaults`] + [`frame_fate`]):
//!
//! * [`SimNet`] — the scenario runner's lane fabric: unidirectional lanes
//!   between named actors, an [`EventQueue`] of in-flight frames, and the
//!   full injector set (serialisation/token-bucket bandwidth, latency,
//!   jitter, drop, duplicate, reorder, partition, mid-frame cut). Purely
//!   event-driven: `send` schedules arrivals, `pop` yields them in virtual
//!   time order.
//! * [`SimDuplex`] / [`SimEndpoint`] — an in-process socket pair exposing
//!   the same `Read`/`Write` surface as `net::tcp`'s streams, so
//!   `read_msg`/`write_msg` (and the [`Transport`] trait) run unmodified
//!   over simulated links; a mid-frame cut surfaces exactly like a torn
//!   TCP connection (an `UnexpectedEof` inside the frame body).
//!
//! All randomness comes from one seeded [`Rng`]; identical seeds give
//! identical delivery schedules, byte for byte.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::rc::Rc;

use anyhow::Result;

use crate::util::rng::Rng;
use crate::util::simclock::EventQueue;

use super::log::EventLog;

/// Frame-oriented transport surface: the framing contract of `net::tcp`
/// (`write_raw_frame`/`read_raw_frame`) behind one trait, implemented for
/// every `Read + Write` stream — real `TcpStream`s and [`SimEndpoint`]s
/// alike. Bodies exclude the 4-byte length prefix; `recv_frame` returns
/// `Ok(false)` on clean EOF at a frame boundary.
pub trait Transport {
    fn send_frame(&mut self, body: &[u8]) -> Result<()>;
    fn recv_frame(&mut self, buf: &mut Vec<u8>) -> Result<bool>;
}

impl<T: Read + Write> Transport for T {
    fn send_frame(&mut self, body: &[u8]) -> Result<()> {
        crate::net::tcp::write_raw_frame(self, body)
    }

    fn recv_frame(&mut self, buf: &mut Vec<u8>) -> Result<bool> {
        crate::net::tcp::read_raw_frame(self, buf)
    }
}

/// Per-lane fault model. All times in seconds, rates in bits/s.
#[derive(Debug, Clone, Copy)]
pub struct LinkFaults {
    /// one-way propagation delay
    pub latency: f64,
    /// uniform extra delay in `[0, jitter)` per frame
    pub jitter: f64,
    /// serialisation bandwidth (token-bucket drain rate); None = infinite
    pub rate_bps: Option<f64>,
    /// probability a frame is silently lost
    pub drop_p: f64,
    /// probability a frame is delivered twice
    pub dup_p: f64,
    /// probability a frame is held back by `reorder_delay` (landing after
    /// frames sent later)
    pub reorder_p: f64,
    pub reorder_delay: f64,
}

impl Default for LinkFaults {
    fn default() -> Self {
        LinkFaults {
            latency: 0.0005,
            jitter: 0.0,
            rate_bps: None,
            drop_p: 0.0,
            dup_p: 0.0,
            reorder_p: 0.0,
            reorder_delay: 0.005,
        }
    }
}

impl LinkFaults {
    /// A clean, fast lane (sub-millisecond, unshaped, lossless).
    pub fn ideal() -> LinkFaults {
        LinkFaults::default()
    }

    /// A bandwidth-shaped lossless lane: the sim counterpart of wrapping a
    /// socket in `net::shaped::ShapedWriter` (same `bytes·8/rate`
    /// serialisation arithmetic as `net::shaped::LinkModel`).
    pub fn shaped(rate_bps: f64, latency: f64) -> LinkFaults {
        LinkFaults { latency, rate_bps: Some(rate_bps), ..LinkFaults::default() }
    }
}

/// What a receiver observes on a lane.
#[derive(Debug, Clone, PartialEq)]
pub enum Delivery {
    /// A whole frame body (length prefix stripped, as `read_raw_frame`
    /// would hand it up).
    Frame(Vec<u8>),
    /// A torn frame: the bytes that made it before a mid-frame cut.
    Truncated(Vec<u8>),
    /// The lane closed (peer crash or cut); no more deliveries follow.
    Closed,
}

/// One frame's fate on a faulty link.
struct FrameFate {
    /// delivery times (empty = dropped; two entries = duplicated)
    arrivals: Vec<f64>,
    reordered: bool,
}

/// Decide delivery times for a frame of `wire_bytes` sent at `now`.
/// Serialisation queues behind `busy_until` (the token-bucket drain), so
/// back-to-back frames on a shaped lane pace out exactly like
/// `ShapedWriter` pacing on a real socket.
fn frame_fate(
    f: &LinkFaults,
    busy_until: &mut f64,
    rng: &mut Rng,
    now: f64,
    wire_bytes: usize,
) -> FrameFate {
    if f.drop_p > 0.0 && rng.uniform() < f.drop_p {
        return FrameFate { arrivals: Vec::new(), reordered: false };
    }
    let depart = now.max(*busy_until);
    let ser = match f.rate_bps {
        Some(r) => wire_bytes as f64 * 8.0 / r,
        None => 0.0,
    };
    let done = depart + ser;
    *busy_until = done;
    let mut arrival = done + f.latency;
    if f.jitter > 0.0 {
        arrival += rng.uniform() * f.jitter;
    }
    let mut reordered = false;
    if f.reorder_p > 0.0 && rng.uniform() < f.reorder_p {
        arrival += f.reorder_delay;
        reordered = true;
    }
    let mut arrivals = vec![arrival];
    if f.dup_p > 0.0 && rng.uniform() < f.dup_p {
        arrivals.push(arrival + f.latency.max(1e-4));
    }
    FrameFate { arrivals, reordered }
}

pub type LaneId = usize;

struct Lane {
    from: String,
    to: String,
    faults: LinkFaults,
    open: bool,
    partitioned: bool,
    cut_next_mid_frame: bool,
    busy_until: f64,
    seq: u64,
    /// latest scheduled arrival on this lane — a close must never overtake
    /// bytes already in flight (TCP delivers in order, then EOF)
    last_arrival: f64,
    /// per-lane delivery sequence (assigned at scheduling time)
    next_delivery: u64,
    /// deliveries with sequence below this were flushed (connection torn
    /// down by the endpoint) and are dropped at pop time
    flush_before: u64,
}

/// The scenario fabric: lanes + in-flight frame queue over virtual time.
pub struct SimNet {
    lanes: Vec<Lane>,
    queue: EventQueue<(LaneId, u64, Delivery)>,
    rng: Rng,
}

impl SimNet {
    pub fn new(seed: u64) -> SimNet {
        SimNet {
            lanes: Vec::new(),
            queue: EventQueue::new(),
            rng: Rng::new(seed ^ 0x51D_0E7),
        }
    }

    /// Create a unidirectional lane `from -> to`.
    pub fn lane(&mut self, from: &str, to: &str, faults: LinkFaults) -> LaneId {
        self.lanes.push(Lane {
            from: from.to_string(),
            to: to.to_string(),
            faults,
            open: true,
            partitioned: false,
            cut_next_mid_frame: false,
            busy_until: 0.0,
            seq: 0,
            last_arrival: 0.0,
            next_delivery: 0,
            flush_before: 0,
        });
        self.lanes.len() - 1
    }

    /// Discard everything still in flight on a lane — the endpoint tore
    /// its connection down (a reconnecting client's old socket), so bytes
    /// from the previous incarnation must never be delivered.
    pub fn flush(&mut self, lane: LaneId) {
        let l = &mut self.lanes[lane];
        l.flush_before = l.next_delivery;
    }

    pub fn is_open(&self, lane: LaneId) -> bool {
        self.lanes[lane].open
    }

    /// Blackhole (or heal) a lane: while partitioned, frames vanish
    /// silently — the link is up, the path is not.
    pub fn set_partitioned(&mut self, lane: LaneId, on: bool, now: f64, log: &mut EventLog) {
        let l = &mut self.lanes[lane];
        if l.partitioned != on {
            l.partitioned = on;
            let kind = if on { "partition" } else { "heal" };
            log.record(now, kind, &format!("lane={} {}->{}", lane, l.from, l.to));
        }
    }

    /// Tear the lane down. `mid_frame = false` closes cleanly (the
    /// receiver sees [`Delivery::Closed`] after one propagation delay);
    /// `mid_frame = true` arms the cut to fire inside the *next* frame
    /// sent, delivering a truncated prefix and then the close.
    pub fn cut(&mut self, lane: LaneId, mid_frame: bool, now: f64, log: &mut EventLog) {
        let l = &mut self.lanes[lane];
        if !l.open {
            return;
        }
        if mid_frame {
            l.cut_next_mid_frame = true;
            log.record(now, "cut_armed", &format!("lane={} {}->{}", lane, l.from, l.to));
        } else {
            l.open = false;
            // a close never overtakes bytes already in flight: TCP
            // delivers in order, then EOF
            let at = (now + l.faults.latency).max(l.last_arrival);
            l.last_arrival = at;
            let dseq = l.next_delivery;
            l.next_delivery += 1;
            self.queue.push(at, (lane, dseq, Delivery::Closed));
            log.record(now, "cut", &format!("lane={} {}->{}", lane, l.from, l.to));
        }
    }

    /// Re-establish a previously cut lane (a restarted shard's listener
    /// coming back). Anything still in flight from the old incarnation is
    /// flushed.
    pub fn reopen(&mut self, lane: LaneId, now: f64, log: &mut EventLog) {
        let l = &mut self.lanes[lane];
        if !l.open {
            l.open = true;
            l.cut_next_mid_frame = false;
            l.busy_until = now;
            l.last_arrival = now;
            l.flush_before = l.next_delivery;
            log.record(now, "reopen", &format!("lane={} {}->{}", lane, l.from, l.to));
        }
    }

    /// Put one frame body on a lane at virtual time `now`. Wire accounting
    /// includes the 4-byte length prefix, matching the real transport.
    pub fn send(&mut self, lane: LaneId, now: f64, body: &[u8], log: &mut EventLog) {
        let l = &mut self.lanes[lane];
        if !l.open {
            log.record(now, "send_closed", &format!("lane={lane} bytes={}", body.len()));
            return;
        }
        l.seq += 1;
        let seq = l.seq;
        if l.cut_next_mid_frame {
            l.cut_next_mid_frame = false;
            l.open = false;
            let cut = if body.len() >= 2 { 1 + self.rng.below(body.len() - 1) } else { 0 };
            let at = (now + l.faults.latency).max(l.last_arrival);
            l.last_arrival = at;
            let dseq = l.next_delivery;
            l.next_delivery += 2;
            self.queue.push(at, (lane, dseq, Delivery::Truncated(body[..cut].to_vec())));
            self.queue.push(at, (lane, dseq + 1, Delivery::Closed));
            log.record(
                now,
                "cut_mid_frame",
                &format!("lane={lane} seq={seq} bytes={cut}/{}", body.len()),
            );
            return;
        }
        if l.partitioned {
            log.record(now, "blackhole", &format!("lane={lane} seq={seq} bytes={}", body.len()));
            return;
        }
        let fate = frame_fate(&l.faults, &mut l.busy_until, &mut self.rng, now, body.len() + 4);
        if fate.arrivals.is_empty() {
            log.record(now, "drop", &format!("lane={lane} seq={seq} bytes={}", body.len()));
            return;
        }
        if fate.reordered {
            log.record(now, "reorder", &format!("lane={lane} seq={seq}"));
        }
        for (i, &at) in fate.arrivals.iter().enumerate() {
            let kind = if i == 0 { "send" } else { "dup" };
            log.record(
                now,
                kind,
                &format!("lane={lane} seq={seq} bytes={} arrive={at:.6}", body.len()),
            );
            let l = &mut self.lanes[lane];
            l.last_arrival = l.last_arrival.max(at);
            let dseq = l.next_delivery;
            l.next_delivery += 1;
            self.queue.push(at, (lane, dseq, Delivery::Frame(body.to_vec())));
        }
    }

    /// Virtual time of the next *live* delivery, if any (flushed entries
    /// are purged here so the caller's event interleaving stays in time
    /// order).
    pub fn peek_time(&mut self) -> Option<f64> {
        loop {
            let flushed = match self.queue.peek() {
                Some((_, (lane, dseq, _))) => *dseq < self.lanes[*lane].flush_before,
                None => return None,
            };
            if flushed {
                self.queue.pop();
            } else {
                return self.queue.peek_time();
            }
        }
    }

    /// Pop the next live delivery in time order (FIFO on ties).
    pub fn pop(&mut self) -> Option<(f64, LaneId, Delivery)> {
        while let Some((t, (lane, dseq, d))) = self.queue.pop() {
            if dseq < self.lanes[lane].flush_before {
                continue; // the endpoint tore this connection down
            }
            return Some((t, lane, d));
        }
        None
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty()
    }

    /// `from -> to` label of a lane (for logs and dispatch diagnostics).
    pub fn lane_label(&self, lane: LaneId) -> String {
        let l = &self.lanes[lane];
        format!("{}->{}", l.from, l.to)
    }
}

// ---------------------------------------------------------------------------
// The Read/Write surface: an in-process duplex pipe over the same faults.
// ---------------------------------------------------------------------------

enum Chunk {
    Bytes(Vec<u8>),
    Close,
}

struct PipeDir {
    faults: LinkFaults,
    busy_until: f64,
    open: bool,
    cut_next_mid_frame: bool,
    in_flight: EventQueue<Chunk>,
    rbuf: VecDeque<u8>,
    closed_for_reader: bool,
    /// latest scheduled arrival: a close queues behind in-flight bytes
    last_arrival: f64,
}

impl PipeDir {
    fn new(faults: LinkFaults) -> PipeDir {
        PipeDir {
            faults,
            busy_until: 0.0,
            open: true,
            cut_next_mid_frame: false,
            in_flight: EventQueue::new(),
            rbuf: VecDeque::new(),
            closed_for_reader: false,
            last_arrival: 0.0,
        }
    }
}

struct PipeCore {
    now: f64,
    rng: Rng,
    // dirs[0]: a -> b, dirs[1]: b -> a
    dirs: [PipeDir; 2],
}

impl PipeCore {
    fn send(&mut self, d: usize, frame: Vec<u8>) {
        let dir = &mut self.dirs[d];
        if !dir.open {
            return;
        }
        if dir.cut_next_mid_frame {
            dir.cut_next_mid_frame = false;
            dir.open = false;
            let cut = if frame.len() >= 2 { 1 + self.rng.below(frame.len() - 1) } else { 0 };
            let at = (self.now + dir.faults.latency).max(dir.last_arrival);
            dir.last_arrival = at;
            dir.in_flight.push(at, Chunk::Bytes(frame[..cut].to_vec()));
            dir.in_flight.push(at, Chunk::Close);
            return;
        }
        let fate =
            frame_fate(&dir.faults, &mut dir.busy_until, &mut self.rng, self.now, frame.len());
        for &at in &fate.arrivals {
            dir.last_arrival = dir.last_arrival.max(at);
            dir.in_flight.push(at, Chunk::Bytes(frame.clone()));
        }
    }

    fn advance(&mut self, dt: f64) {
        self.now += dt;
        for dir in self.dirs.iter_mut() {
            while dir.in_flight.peek_time().is_some_and(|t| t <= self.now) {
                match dir.in_flight.pop().unwrap().1 {
                    Chunk::Bytes(b) => dir.rbuf.extend(b),
                    Chunk::Close => dir.closed_for_reader = true,
                }
            }
        }
    }
}

/// Handle on a simulated duplex link; hand the two [`SimEndpoint`]s to the
/// peers, then drive delivery with [`SimDuplex::advance`].
pub struct SimDuplex {
    core: Rc<RefCell<PipeCore>>,
}

/// One end of a [`SimDuplex`]: a `Read + Write` stream. Writes are
/// buffered until `flush` (one flush = one wire frame, exactly how
/// `write_msg`/`write_raw_frame` flush per frame); reads drain bytes that
/// have *arrived* in virtual time — an empty, open pipe reads as
/// `WouldBlock`, a closed one as EOF.
pub struct SimEndpoint {
    core: Rc<RefCell<PipeCore>>,
    /// direction this endpoint writes into (reads come from the other)
    write_dir: usize,
    wbuf: Vec<u8>,
}

impl SimDuplex {
    pub fn new(faults: LinkFaults, seed: u64) -> (SimDuplex, SimEndpoint, SimEndpoint) {
        let core = Rc::new(RefCell::new(PipeCore {
            now: 0.0,
            rng: Rng::new(seed ^ 0xD0_97E1),
            dirs: [PipeDir::new(faults), PipeDir::new(faults)],
        }));
        let a = SimEndpoint { core: core.clone(), write_dir: 0, wbuf: Vec::new() };
        let b = SimEndpoint { core: core.clone(), write_dir: 1, wbuf: Vec::new() };
        (SimDuplex { core }, a, b)
    }

    /// Advance virtual time, landing any frames whose arrival has come.
    pub fn advance(&self, dt: f64) {
        self.core.borrow_mut().advance(dt);
    }

    /// Arm a mid-frame cut on the a→b direction (`dir = 0`) or b→a
    /// (`dir = 1`): the next frame written tears inside its body.
    pub fn cut_mid_frame(&self, dir: usize) {
        self.core.borrow_mut().dirs[dir].cut_next_mid_frame = true;
    }

    /// Close a direction cleanly at a frame boundary (queued behind any
    /// bytes still in flight, like a real FIN).
    pub fn close(&self, dir: usize) {
        let mut core = self.core.borrow_mut();
        let at = core.now.max(core.dirs[dir].last_arrival);
        let d = &mut core.dirs[dir];
        d.open = false;
        d.last_arrival = at;
        d.in_flight.push(at, Chunk::Close);
    }
}

impl Write for SimEndpoint {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.wbuf.extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        if !self.wbuf.is_empty() {
            let frame = std::mem::take(&mut self.wbuf);
            let dir = self.write_dir;
            self.core.borrow_mut().send(dir, frame);
        }
        Ok(())
    }
}

impl Read for SimEndpoint {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let read_dir = 1 - self.write_dir;
        let mut core = self.core.borrow_mut();
        let dir = &mut core.dirs[read_dir];
        if dir.rbuf.is_empty() {
            if dir.closed_for_reader {
                return Ok(0);
            }
            return Err(std::io::Error::new(
                std::io::ErrorKind::WouldBlock,
                "no simulated bytes have arrived yet",
            ));
        }
        let n = buf.len().min(dir.rbuf.len());
        for slot in buf.iter_mut().take(n) {
            *slot = dir.rbuf.pop_front().unwrap();
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::framing::{Hello, Msg, Payload, Request};
    use crate::net::tcp::{read_msg, write_msg};

    fn hello(client: u32) -> Msg {
        Msg::Hello(Hello { client, split: false, codec: 0, caps: 0, shard: None, epoch: None })
    }

    fn request(client: u32, id: u64, n: usize) -> Msg {
        Msg::Request(Request {
            client,
            id,
            payload: Payload::Features {
                c: 1,
                h: 1,
                w: n as u16,
                scale: 1.0,
                data: vec![7; n],
            },
        })
    }

    #[test]
    fn transport_trait_roundtrips_over_any_read_write() {
        let mut wire = std::io::Cursor::new(Vec::new());
        wire.send_frame(&[1, 2, 3]).unwrap();
        wire.send_frame(&[9]).unwrap();
        wire.set_position(0);
        let mut buf = Vec::new();
        assert!(wire.recv_frame(&mut buf).unwrap());
        assert_eq!(buf, vec![1, 2, 3]);
        assert!(wire.recv_frame(&mut buf).unwrap());
        assert_eq!(buf, vec![9]);
        assert!(!wire.recv_frame(&mut buf).unwrap()); // clean EOF
    }

    #[test]
    fn simnet_shaped_lane_paces_like_the_link_model() {
        let mut net = SimNet::new(1);
        let mut log = EventLog::new();
        // 1 Mb/s, 1 ms latency: a 1246-byte body (1250 on the wire) takes
        // 10 ms serialisation + 1 ms propagation
        let lane = net.lane("a", "b", LinkFaults::shaped(1e6, 0.001));
        let body = [0u8; 1246];
        net.send(lane, 0.0, &body, &mut log);
        let (t, l, d) = net.pop().unwrap();
        assert_eq!(l, lane);
        assert!(matches!(d, Delivery::Frame(ref b) if b.len() == 1246));
        assert!((t - 0.011).abs() < 1e-9, "{t}");
        // a second frame queues behind the first (token-bucket drain)
        net.send(lane, 0.0, &body, &mut log);
        net.send(lane, 0.0, &body, &mut log);
        let (t2, ..) = net.pop().unwrap();
        let (t3, ..) = net.pop().unwrap();
        assert!((t2 - 0.021).abs() < 1e-9, "{t2}");
        assert!((t3 - 0.031).abs() < 1e-9, "{t3}");
    }

    #[test]
    fn simnet_drop_dup_and_partition() {
        let mut log = EventLog::new();
        let mut net = SimNet::new(2);
        let always_drop = net.lane("a", "b", LinkFaults { drop_p: 1.0, ..LinkFaults::ideal() });
        let always_dup = net.lane("a", "b", LinkFaults { dup_p: 1.0, ..LinkFaults::ideal() });
        net.send(always_drop, 0.0, &[1], &mut log);
        assert!(net.idle(), "dropped frame must not be scheduled");
        net.send(always_dup, 0.0, &[2], &mut log);
        let a = net.pop().unwrap();
        let b = net.pop().unwrap();
        assert!(matches!(a.2, Delivery::Frame(ref f) if f == &[2]));
        assert!(matches!(b.2, Delivery::Frame(ref f) if f == &[2]));
        assert!(b.0 > a.0, "duplicate lands strictly later");
        // partition blackholes silently
        net.set_partitioned(always_dup, true, 1.0, &mut log);
        net.send(always_dup, 1.0, &[3], &mut log);
        assert!(net.idle());
        net.set_partitioned(always_dup, false, 2.0, &mut log);
        net.send(always_dup, 2.0, &[4], &mut log);
        assert!(!net.idle());
        assert_eq!(log.count("drop"), 1);
        assert_eq!(log.count("blackhole"), 1);
    }

    #[test]
    fn simnet_reorder_inverts_arrival_order() {
        let mut log = EventLog::new();
        let mut net = SimNet::new(3);
        let lane = net.lane(
            "a",
            "b",
            LinkFaults { reorder_p: 1.0, reorder_delay: 0.05, ..LinkFaults::ideal() },
        );
        let plain = net.lane("a", "b", LinkFaults::ideal());
        net.send(lane, 0.0, &[1], &mut log); // held back 50 ms
        net.send(plain, 0.001, &[2], &mut log);
        let first = net.pop().unwrap();
        let second = net.pop().unwrap();
        assert!(matches!(first.2, Delivery::Frame(ref f) if f == &[2]));
        assert!(matches!(second.2, Delivery::Frame(ref f) if f == &[1]));
    }

    #[test]
    fn simnet_cut_closes_and_reopen_revives() {
        let mut log = EventLog::new();
        let mut net = SimNet::new(4);
        let lane = net.lane("gw", "shard", LinkFaults::ideal());
        net.cut(lane, false, 0.5, &mut log);
        let (_, _, d) = net.pop().unwrap();
        assert_eq!(d, Delivery::Closed);
        net.send(lane, 0.6, &[1], &mut log);
        assert!(net.idle(), "closed lane must drop sends");
        net.reopen(lane, 1.0, &mut log);
        net.send(lane, 1.0, &[2], &mut log);
        assert!(matches!(net.pop().unwrap().2, Delivery::Frame(_)));
    }

    #[test]
    fn simnet_mid_frame_cut_truncates_then_closes() {
        let mut log = EventLog::new();
        let mut net = SimNet::new(5);
        let lane = net.lane("a", "b", LinkFaults::ideal());
        net.cut(lane, true, 0.0, &mut log);
        let body = [9u8; 100];
        net.send(lane, 0.0, &body, &mut log);
        let (_, _, first) = net.pop().unwrap();
        let (_, _, second) = net.pop().unwrap();
        match first {
            Delivery::Truncated(b) => {
                assert!(!b.is_empty() && b.len() < 100, "cut {} bytes", b.len())
            }
            other => panic!("expected truncation, got {other:?}"),
        }
        assert_eq!(second, Delivery::Closed);
        assert_eq!(log.count("cut_mid_frame"), 1);
    }

    #[test]
    fn duplex_roundtrips_real_messages() {
        let (link, mut a, mut b) = SimDuplex::new(LinkFaults::ideal(), 7);
        write_msg(&mut a, &hello(3)).unwrap();
        write_msg(&mut a, &request(3, 1, 16)).unwrap();
        // nothing has arrived yet: an open, empty pipe would block
        let err = read_msg(&mut b).unwrap_err();
        assert!(format!("{err:#}").contains("arrived"), "{err:#}");
        link.advance(0.01);
        assert_eq!(read_msg(&mut b).unwrap().unwrap(), hello(3));
        assert_eq!(read_msg(&mut b).unwrap().unwrap(), request(3, 1, 16));
        // reply direction works too
        write_msg(&mut b, &hello(3)).unwrap();
        link.advance(0.01);
        assert_eq!(read_msg(&mut a).unwrap().unwrap(), hello(3));
    }

    #[test]
    fn duplex_clean_close_reads_as_eof() {
        let (link, mut a, mut b) = SimDuplex::new(LinkFaults::ideal(), 8);
        write_msg(&mut a, &hello(1)).unwrap();
        link.close(0);
        link.advance(0.01);
        assert_eq!(read_msg(&mut b).unwrap().unwrap(), hello(1));
        assert!(read_msg(&mut b).unwrap().is_none(), "close at boundary = clean EOF");
    }

    #[test]
    fn duplex_mid_frame_cut_is_a_transport_error_not_a_frame() {
        let (link, mut a, mut b) = SimDuplex::new(LinkFaults::ideal(), 9);
        link.cut_mid_frame(0);
        write_msg(&mut a, &request(1, 1, 64)).unwrap();
        link.advance(0.01);
        // exactly how a torn TCP stream surfaces: an error inside the
        // frame, never a short "valid" message
        assert!(read_msg(&mut b).is_err());
    }

    #[test]
    fn same_seed_same_delivery_schedule() {
        let run = |seed: u64| {
            let mut log = EventLog::new();
            let mut net = SimNet::new(seed);
            let lane = net.lane(
                "a",
                "b",
                LinkFaults {
                    jitter: 0.01,
                    drop_p: 0.2,
                    dup_p: 0.2,
                    reorder_p: 0.2,
                    ..LinkFaults::ideal()
                },
            );
            for i in 0..50u8 {
                net.send(lane, i as f64 * 0.001, &[i], &mut log);
            }
            let mut out = Vec::new();
            while let Some((t, _, d)) = net.pop() {
                out.push(format!("{t:.9}-{d:?}"));
            }
            (log.render(), out)
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42).0, run(43).0);
    }
}

//! Deterministic simulation substrate (the "simnet", DESIGN.md §6).
//!
//! Everything the fleet/serve stack needs to run under **virtual time**,
//! fully in-process, with seeded fault injection:
//!
//! * [`clock`] — the [`Clock`] seam ([`WallClock`] / [`SimClock`] /
//!   [`ClockHandle`]) threaded through `net::shaped`, the coordinator's
//!   client and server, and `device::thermal`. Sim clocks mint ordinary
//!   `Instant`s, so `Duration` arithmetic downstream is untouched.
//! * [`transport`] — the [`Transport`] framing surface, [`SimNet`] lane
//!   fabric (latency/jitter, token-bucket bandwidth, drop, duplicate,
//!   reorder, partition, mid-frame cuts), and the [`SimDuplex`]
//!   `Read`/`Write` socket pair that `net::tcp::read_msg`/`write_msg`
//!   drive unmodified.
//! * [`log`] — the canonical [`EventLog`]: byte-identical across
//!   same-seed runs (CI diffs it to enforce determinism).
//! * [`scenario`] — the chaos runner: gateway + N shards + M clients as a
//!   discrete-event simulation reusing the real `Topology`,
//!   `BatchCollector`, `SessionManager`, `net::framing`, and
//!   `probe_transition` state machine. `rust/tests/sim_scenarios.rs` is
//!   the scenario suite; DESIGN.md §6 documents how to write a new one.
//!
//! Zero `std::thread::sleep` exists anywhere under this module: waiting
//! is advancing the clock.

pub mod clock;
pub mod log;
pub mod scenario;
pub mod transport;

pub use clock::{Clock, ClockHandle, SimClock, WallClock};
pub use log::EventLog;
pub use scenario::{
    run_scenario, AutoscaleOutcome, AutoscaleSpec, ClientOutcome, FaultCmd, GatewayOutcome,
    LearnSpec, ScenarioConfig, ScenarioReport, ShardOutcome, ThermalSpec,
};
pub use transport::{Delivery, LaneId, LinkFaults, SimDuplex, SimEndpoint, SimNet, Transport};

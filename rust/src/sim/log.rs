//! Canonical scenario event log: the seed/replay contract's witness.
//!
//! Every observable simulation step (a frame put on a lane, a fault
//! injected, a batch formed, a probe verdict) appends one line. The
//! rendering is fully determined by the event sequence — fixed-width
//! `t=SSSSSS.UUUUUU` timestamps, no pointers, no wall-clock reads, no
//! hash-map iteration anywhere upstream — so two runs with the same seed
//! produce **byte-identical** logs. CI runs the suite twice and diffs the
//! rendered bytes; a nondeterminism regression shows up as a diff, not a
//! flake.

/// Append-only event log over virtual time.
#[derive(Debug, Default)]
pub struct EventLog {
    lines: Vec<String>,
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog::default()
    }

    /// Record one event at virtual time `t` (seconds). `kind` is a short
    /// stable tag; `detail` is free-form but must itself be deterministic.
    pub fn record(&mut self, t: f64, kind: &str, detail: &str) {
        debug_assert!(t.is_finite(), "event at non-finite time");
        self.lines.push(format!("t={t:013.6} {kind} {detail}"));
    }

    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    pub fn lines(&self) -> &[String] {
        &self.lines
    }

    /// Count events whose tag matches `kind` exactly.
    pub fn count(&self, kind: &str) -> usize {
        let needle = format!(" {kind} ");
        self.lines.iter().filter(|l| l.contains(&needle)).count()
    }

    /// Render the canonical byte form: one line per event, `\n`-terminated.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for l in &self.lines {
            out.push_str(l);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_fixed_width_timestamps() {
        let mut log = EventLog::new();
        log.record(0.0, "start", "x=1");
        log.record(12.345678, "send", "lane=0 bytes=10");
        let s = log.render();
        assert_eq!(s, "t=000000.000000 start x=1\nt=000012.345678 send lane=0 bytes=10\n");
        assert_eq!(log.len(), 2);
        assert!(!log.is_empty());
    }

    #[test]
    fn identical_sequences_render_identically() {
        let build = || {
            let mut log = EventLog::new();
            for i in 0..50 {
                log.record(i as f64 * 0.1, "ev", &format!("i={i}"));
            }
            log.render()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn count_matches_exact_tags() {
        let mut log = EventLog::new();
        log.record(0.0, "send", "a");
        log.record(0.1, "send", "b");
        log.record(0.2, "sendx", "c");
        assert_eq!(log.count("send"), 2);
        assert_eq!(log.count("sendx"), 1);
        assert_eq!(log.count("recv"), 0);
    }
}
